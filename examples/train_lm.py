"""End-to-end LM training driver: train a small llama-family model for a few
hundred steps on the synthetic corpus, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --size 10m --steps 200
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300 \
        --mesh 2x4   # with XLA_FLAGS=--xla_force_host_platform_device_count=8

The ~100M configuration is the harness's end-to-end target; on this
single-CPU-core container the 10m size demonstrates the identical code path
at tractable wall-clock (the step function, sharding rules, checkpointing
and data pipeline do not depend on size).
"""

import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.configs.base import _REGISTRY, register
from repro.launch import train as train_mod

SIZES = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)  ~params
    "2m": (2, 128, 4, 2, 512, 2048),  # CI smoke
    "10m": (6, 320, 5, 5, 1280, 8192),  # ~13M
    "100m": (12, 640, 10, 5, 2560, 50304),  # ~123M
}


def lm_config(size: str):
    l, d, h, kv, ff, v = SIZES[size]
    base = get_config("yi-9b")
    return dataclasses.replace(
        base, name=f"lm-{size}", n_layers=l, d_model=d, n_heads=h,
        n_kv_heads=kv, head_dim=d // h, d_ff=ff, vocab=v,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="10m", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = lm_config(args.size)
    print(f"[train_lm] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{args.steps} steps @ seq {args.seq} batch {args.batch}")
    # register so the generic trainer can look it up
    _REGISTRY[cfg.name] = lambda c=cfg: c

    argv = [
        "--arch", cfg.name, "--steps", str(args.steps),
        "--seq", str(args.seq), "--global-batch", str(args.batch),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10",
    ]
    if args.mesh:
        argv += ["--mesh", args.mesh]
    if args.resume:
        argv += ["--resume"]
    losses = train_mod.main(argv)
    assert losses[-1] < losses[0] - 0.3, "loss did not decrease"
    print("[train_lm] loss decreased — OK")


if __name__ == "__main__":
    main()
