"""Quickstart: exact vs approximate inference on a Bayes net (the paper's
core workload) in ~30 lines, through the `repro.compile` chain.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.compile import cache_stats, canonicalize, compile_graph
from repro.core.exact import ve_marginal
from repro.core.graphs import bn_repository_replica


def main():
    # the paper's "alarm" benchmark (structure-matched replica)
    bn = bn_repository_replica("alarm")
    evidence = {0: 1, 5: 0}
    query = 20

    # exact inference (variable elimination) — the Table IV baseline
    exact = ve_marginal(bn, query, evidence)

    # AIA compile chain (Fig. 8): BN -> SamplingGraph IR -> moralize ->
    # DSATUR -> greedy mesh placement -> round schedule -> CompiledProgram
    prog = compile_graph(bn, evidence=evidence)
    cost = prog.schedule.cost()
    print(f"alarm replica: {prog.ir.n_nodes} nodes -> "
          f"{prog.diagnostics['n_colors']} colors, "
          f"{cost['n_rounds']} rounds/sweep, "
          f"~{cost['total_cycles']} model cycles "
          f"(compiled in {prog.compile_s*1e3:.0f} ms, "
          f"program {prog.program_key[:12]}...)")
    # a repeated request hits the program cache instead of re-compiling
    prog2 = compile_graph(bn, evidence=evidence)
    assert prog2 is prog
    print(f"program cache: {cache_stats()['hits']} hit(s)")

    # execute: chromatic parallel Gibbs with LUT-exp (C2) + rejection-KY (C1),
    # running the compiled Schedule's rounds directly (backend="schedule";
    # bit-exact with backend="eager" — cross-checked at first lowering)
    marginals, _ = prog.run(
        jax.random.key(0), n_chains=64, n_iters=500, burn_in=125,
        sampler="lut_ky", backend="schedule",
    )
    approx = np.asarray(marginals)[query][: len(exact)]

    print(f"P(X{query} | e)  exact : {np.round(exact, 4)}")
    print(f"P(X{query} | e)  gibbs : {np.round(approx, 4)}")
    tvd = 0.5 * np.abs(exact - approx).sum()
    print(f"total variation distance: {tvd:.4f}")
    assert tvd < 0.05, "Gibbs failed to converge"

    # the serving path (repro.runtime) compiles structure-only instead:
    # evidence becomes a *runtime* clamp, so every query on this model —
    # whatever it observed — reuses one cached program, bit-exact with
    # baking that evidence at compile time
    served = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    marg_rt, _ = served.run(
        jax.random.key(0), n_chains=64, n_iters=500, burn_in=125,
        evidence=evidence, backend="schedule",
    )
    np.testing.assert_array_equal(np.asarray(marg_rt), np.asarray(marginals))
    print(f"runtime-clamped program {served.program_key[:12]}... serves any "
          "evidence dict, bit-exact with the baked compile")
    print("OK")


if __name__ == "__main__":
    main()
