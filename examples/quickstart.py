"""Quickstart: exact vs approximate inference on a Bayes net (the paper's
core workload) in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import bayesnet as bnet
from repro.core.exact import ve_marginal
from repro.core.graphs import bn_repository_replica


def main():
    # the paper's "alarm" benchmark (structure-matched replica)
    bn = bn_repository_replica("alarm")
    evidence = {0: 1, 5: 0}
    query = 20

    # exact inference (variable elimination) — the Table IV baseline
    exact = ve_marginal(bn, query, evidence)

    # AIA pipeline: DSATUR coloring -> chromatic parallel Gibbs with
    # LUT-exp (C2) + rejection-KY sampling (C1)
    compiled = bnet.compile_bayesnet(bn, evidence=evidence)
    print(f"alarm replica: {bn.n_nodes} nodes, "
          f"{max(compiled.colors) + 1} colors "
          f"(parallel Gibbs sweeps per iteration)")
    marginals, _ = bnet.run_gibbs(
        compiled, jax.random.key(0), n_chains=64, n_iters=500, burn_in=125,
        sampler="lut_ky",
    )
    approx = np.asarray(marginals)[query][: len(exact)]

    print(f"P(X{query} | e)  exact : {np.round(exact, 4)}")
    print(f"P(X{query} | e)  gibbs : {np.round(approx, 4)}")
    tvd = 0.5 * np.abs(exact - approx).sum()
    print(f"total variation distance: {tvd:.4f}")
    assert tvd < 0.05, "Gibbs failed to converge"
    print("OK")


if __name__ == "__main__":
    main()
