"""Batched LM serving with the paper's normalization-free KY token sampler.

Prefills a batch of prompts, then decodes tokens with, per step:
logits -> LUT-exp integer weights (C2) -> hierarchical rejection-KY (C1) —
no softmax anywhere in the sampling path.  Compares against gumbel-max and
greedy on the same checkpoint.

    PYTHONPATH=src python examples/serve_lm.py --arch musicgen-medium
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-medium")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jax.numpy.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), dtype="int32"
    )
    features = None
    if cfg.frontend:
        features = jax.numpy.asarray(rng.normal(
            0, 1, (args.batch, cfg.frontend_len, tfm.FRONTEND_DIM)
        ), dtype="float32")

    for sampler in ("ky", "gumbel", "greedy"):
        toks, times = generate(
            cfg, params, prompts, args.gen, sampler=sampler,
            features=features, key=jax.random.key(7),
        )
        tput = args.batch / np.mean(times[1:]) if len(times) > 1 else 0
        uniq = len(np.unique(np.asarray(toks[:, args.prompt_len:])))
        print(f"[serve_lm] {sampler:7s}: {tput:8.1f} tok/s, "
              f"{uniq:4d} distinct generated tokens "
              f"(batch {args.batch} x {args.gen})")
    print("OK")


if __name__ == "__main__":
    main()
