"""Image denoising with a grid MRF (the paper's Penguin/Art workload),
single-device and distributed (shard_map + ppermute halo exchange).

    PYTHONPATH=src python examples/mrf_denoise.py            # single device
    PYTHONPATH=src python examples/mrf_denoise.py --devices 8  # 2x4 mesh
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--labels", type=int, default=4)
    ap.add_argument("--noise", type=float, default=0.25)
    ap.add_argument("--iters", type=int, default=40)
    args = ap.parse_args()

    if args.devices > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import mrf as mrf_mod
    from repro.core.graphs import GridMRF

    clean, noisy = mrf_mod.make_denoising_problem(
        args.size, args.size, args.labels, args.noise, seed=0
    )
    m = GridMRF(args.size, args.size, args.labels, theta=1.2, h=2.0)

    if args.devices > 1:
        from repro.core.distributed import mrf_gibbs_sharded
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, args.devices // 2), ("data", "model"))
        labels = mrf_gibbs_sharded(
            m, jnp.asarray(noisy), jax.random.key(0), mesh,
            n_chains=2, n_iters=args.iters,
        )
        mode = f"distributed {dict(mesh.shape)} (ppermute halo exchange)"
    else:
        labels = mrf_mod.run_mrf_gibbs(
            m, jnp.asarray(noisy), jax.random.key(0), n_chains=2,
            n_iters=args.iters,
        )
        mode = "single device"

    res = np.asarray(labels[0])
    err_in = (noisy != clean).mean()
    err_out = (res != clean).mean()
    print(f"[{mode}] {args.size}x{args.size} Potts-{args.labels}")
    print(f"noisy error {err_in:.3f} -> denoised error {err_out:.3f}")

    def ascii_img(img, rows=12, cols=48):
        chars = " .:-=+*#%@"
        rr = np.linspace(0, img.shape[0] - 1, rows).astype(int)
        cc = np.linspace(0, img.shape[1] - 1, cols).astype(int)
        for r in rr:
            print("".join(
                chars[int(img[r, c] * (len(chars) - 1) / max(args.labels - 1, 1))]
                for c in cc))

    print("-- noisy --")
    ascii_img(noisy)
    print("-- denoised --")
    ascii_img(res)
    assert err_out < err_in / 2
    print("OK")


if __name__ == "__main__":
    main()
