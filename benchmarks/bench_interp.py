"""Paper Table III — interpolation unit: 1 fused op vs 9-instruction software
LUT.  We count HLO instructions of (a) the fused interp kernel path and
(b) the naive gather-based software sequence, plus accuracy vs exact exp and
wall-clock at batch 64k."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.core.interp import build_lut, interp_ref
from repro.kernels import ops


def _count_hlo_ops(fn, *args) -> int:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return sum(
        1 for line in txt.splitlines()
        if "=" in line and line.strip().startswith("%")
        and "parameter(" not in line and "constant(" not in line
    )


def software_lut(x, table, spec):
    """The 9-instruction memory-based sequence of Table III: shift/add/and/
    mult/loads, spelled out."""
    u = (x - spec.x0) / spec.dx
    idx = jnp.clip(u.astype(jnp.int32), 0, spec.size - 2)  # shift+and
    frac = u - idx.astype(x.dtype)  # sub
    y0 = jnp.take(table, idx)  # load
    y1 = jnp.take(table, idx + 1)  # add + load
    return y0 + frac * (y1 - y0)  # sub + mult + add


def run(quick: bool = False):
    rows = []
    tab, spec = build_lut(np.exp, -8.0, 0.0, 16)
    x = jnp.asarray(np.random.default_rng(0).uniform(-8, 0, 65536),
                    jnp.float32)

    n_hw = _count_hlo_ops(lambda v: ops.interp(v, tab, spec), x)
    n_sw = _count_hlo_ops(lambda v: software_lut(v, tab, spec), x)
    rows.append(csv_row(
        "table3_opcount", 0.0,
        f"fused_unit_hlo_ops={n_hw};software_lut_hlo_ops={n_sw}",
    ))

    t_hw = timeit(lambda: ops.interp(x, tab, spec))
    t_sw = timeit(lambda: jax.jit(software_lut, static_argnums=2)(x, tab,
                                                                  spec))
    err = float(jnp.abs(interp_ref(x, tab, spec) - jnp.exp(x)).max())
    rows.append(csv_row(
        "table3_walltime", t_hw / len(x) * 1e6,
        f"sw_us_per_elem={t_sw/len(x)*1e6:.4f};max_abs_err_vs_exp={err:.4f}",
    ))
    return rows


if __name__ == "__main__":
    run()
