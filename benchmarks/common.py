"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (fn must return jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    row = f"{name},{us_per_call:.2f},{derived}"
    print(row, flush=True)
    return row
