"""Paper Fig. 11 — peak performance scales with distribution entropy.

KY consumes O(H) random bits per sample (H = entropy); we sweep synthetic
distributions from ~0 to 5 bits of entropy over 32 bins and report measured
bits/sample (the paper's samples/cycle analogue: AIA's sampler retires one
DDG level per cycle) and CPU samples/s."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.core import ky as ky_core

B, N = 8192, 32


def _make_dist(h_target: float, rng) -> np.ndarray:
    """Peaked distribution with approximately h_target bits of entropy."""
    if h_target <= 0.05:
        w = np.zeros(N)
        w[0] = 255
        return w
    # temperature-scaled geometric profile, tuned by bisection
    lo, hi = 0.01, 50.0
    for _ in range(40):
        tau = 0.5 * (lo + hi)
        p = np.exp(-np.arange(N) / tau)
        p /= p.sum()
        h = -(p * np.log2(p + 1e-30)).sum()
        if h < h_target:
            lo = tau
        else:
            hi = tau
    w = np.maximum(np.round(p / p.max() * 255), 0)
    w[0] = max(w[0], 1)
    return w


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    targets = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0]
    if quick:
        targets = [0.0, 2.0, 5.0]
    for h_t in targets:
        w_row = _make_dist(h_t, rng)
        h_true = ky_core.entropy(w_row + 1e-12)
        w = jnp.tile(jnp.asarray(w_row, jnp.int32), (B, 1))
        words = ky_core.random_words(jax.random.key(3), (B,), 4)

        def call():
            return ky_core.ky_sample_ref(w, words, n_bins=N)[0]

        t = timeit(call, warmup=1, iters=3)
        _, stats = ky_core.ky_sample_ref(w, words, n_bins=N)
        bits = float(stats["bits_used"].mean())
        rejs = float(stats["rejections"].mean())
        rows.append(csv_row(
            f"fig11_H{h_t:.0f}", t / B * 1e6,
            f"entropy_bits={h_true:.2f};bits_per_sample={bits:.2f};"
            f"rej_per_sample={rejs:.3f};samples/s={B/t:.3e}",
        ))
    return rows


if __name__ == "__main__":
    run()
