"""Paper Fig. 9 — graph-coloring stats + core-count scaling per BN workload,
plus the Sec. IV-B mapping heuristic's communication-cost win (vs random
placement on a 4x4 mesh)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core import coloring, mapping
from repro.core.graphs import bn_repository_names, bn_repository_replica


def run(quick: bool = False):
    rows = []
    names = bn_repository_names()
    if quick:
        names = names[:5]
    for name in names:
        bn = bn_repository_replica(name)
        adj = bn.moral_adjacency()
        colors = coloring.dsatur(adj)
        stats = coloring.color_stats(colors)
        speedups = {
            k: coloring.parallel_speedup(colors, k) for k in (4, 16, 64)
        }
        pl = mapping.greedy_map(adj, colors, (4, 4))
        c_greedy = mapping.comm_cost(adj, pl)
        c_rand = np.mean([
            mapping.comm_cost(adj, mapping.random_map(bn.n_nodes, (4, 4), s))
            for s in range(3)
        ])
        rows.append(csv_row(
            f"fig9_{name}", 0.0,
            f"nodes={bn.n_nodes};colors={stats['n_colors']};"
            f"balance={stats['balance']:.2f};"
            f"speedup@4={speedups[4]:.1f};speedup@16={speedups[16]:.1f};"
            f"speedup@64={speedups[64]:.1f};"
            f"map_hops={c_greedy:.0f};random_hops={c_rand:.0f}",
        ))
    return rows


if __name__ == "__main__":
    run()
