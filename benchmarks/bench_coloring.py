"""Paper Fig. 9 — graph-coloring stats + core-count scaling per BN workload,
plus the Sec. IV-B mapping heuristic's communication-cost win (vs random
placement on a 4x4 mesh).

Runs through `repro.compile`: one `run_pipeline` call per workload yields
coloring, placement, and schedule diagnostics in one pass context; the
random baseline swaps in `RandomMapPass` instead of re-wiring heuristics.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.compile import ir as compile_ir
from repro.compile import run_pipeline
from repro.compile.passes import random_baseline_pipeline
from repro.core import coloring
from repro.core.graphs import bn_repository_names, bn_repository_replica


def run(quick: bool = False):
    rows = []
    names = bn_repository_names()
    if quick:
        names = names[:5]
    for name in names:
        bn = bn_repository_replica(name)
        graph = compile_ir.from_bayesnet(bn)
        ctx = run_pipeline(graph, mesh_shape=(4, 4))
        d = ctx.diagnostics
        speedups = {
            k: coloring.parallel_speedup(ctx.colors, k) for k in (4, 16, 64)
        }
        c_rand = np.mean([
            run_pipeline(
                graph, mesh_shape=(4, 4),
                # comm_hops only: stop before the schedule lowering
                passes=random_baseline_pipeline(s)[:-1],
            ).diagnostics["comm_hops"]
            for s in range(3)
        ])
        rows.append(csv_row(
            f"fig9_{name}", 0.0,
            f"nodes={d['n_nodes']};colors={d['n_colors']};"
            f"balance={d['color_balance']:.2f};"
            f"speedup@4={speedups[4]:.1f};speedup@16={speedups[16]:.1f};"
            f"speedup@64={speedups[64]:.1f};"
            f"map_hops={d['comm_hops']:.0f};random_hops={c_rand:.0f};"
            f"sweep_cycles={d['schedule_cost']['total_cycles']}",
        ))
    return rows


if __name__ == "__main__":
    run()
