"""Paper Fig. 2a — runtime breakdown of a Gibbs update: distribution
computation (gathers + ALU), nonlinear exp stage, and sampling.  Measured by
timing pipeline prefixes of the BN engine (jit'd, CPU), mirroring the
profiling methodology the paper applied to aGrUM on an i7."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.core import bayesnet as bnet
from repro.core.draws import draw_from_logits
from repro.core.graphs import bn_repository_replica


def run(quick: bool = False):
    rows = []
    for name in (["alarm"] if quick else ["alarm", "hailfinder"]):
        bn = bn_repository_replica(name)
        cbn = bnet.compile_bayesnet(bn)
        n_chains = 64
        key = jax.random.key(0)
        vals, _ = bnet.init_chain_values(cbn, key, n_chains)
        g = max(cbn.groups, key=lambda gr: gr.nodes.shape[0])

        @jax.jit
        def stage_conditionals(vals):
            return bnet.group_log_conditionals(cbn, g, vals)

        logp = stage_conditionals(vals)

        @jax.jit
        def stage_weights(logp):
            z = logp - logp.max(-1, keepdims=True)
            from repro.core.interp import interp_ref

            return jnp.round(
                interp_ref(z, cbn.exp_table, cbn.exp_spec)
            ).astype(jnp.int32)

        @jax.jit
        def stage_sample(logp):
            return draw_from_logits(logp, jax.random.key(1), "lut_ky",
                                    cbn.exp_table, cbn.exp_spec)

        t_cond = timeit(stage_conditionals, vals)
        t_wt = timeit(stage_weights, logp)
        t_smp = timeit(stage_sample, logp) - t_wt  # sampling-only share
        total = t_cond + t_wt + max(t_smp, 0.0)
        rows.append(csv_row(
            f"fig2a_{name}", total * 1e6,
            f"distribution_pct={t_cond/total*100:.0f};"
            f"exp_lut_pct={t_wt/total*100:.0f};"
            f"sampling_pct={max(t_smp,0)/total*100:.0f}",
        ))
    return rows


if __name__ == "__main__":
    run()
