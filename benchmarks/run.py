"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and, when every suite ran,
writes the pass to ``benchmarks/results/BENCH_BASELINE.json`` — the
machine-readable perf+quality baseline: each PR's full run snapshots
every suite's rows, a sampling-quality sweep (``repro.diag`` at the CI
budget — split R-hat / ESS / TV-vs-exact per model and backend variant),
the git SHA it was measured at, and the backend and budget flags, so
later PRs can diff themselves against a recorded baseline
(``benchmarks/check_regression.py``) instead of folklore.  Partial
``--smoke``/``--only`` passes leave the baseline untouched.  Every
baseline write also appends a timestamped copy to
``benchmarks/results/trajectory/`` — the per-PR history the snapshots
overwrite.  ``--quick`` trims budgets; ``--fused`` routes the
bayesnet/compile suites through the fused Pallas kernels as well;
``--skip-quality`` omits the quality sweep; ``--roofline`` additionally
summarizes the dry-run roofline table (requires
benchmarks/results/dryrun/*.json from repro.launch.dryrun)."""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_bayesnet, bench_breakdown, bench_coloring,
                        bench_compile, bench_entropy, bench_interp,
                        bench_mrf, bench_runtime, bench_sampler,
                        bench_token_sampler)

SUITES = {
    "sampler": bench_sampler.run,          # Table II
    "interp": bench_interp.run,            # Table III
    "bayesnet": bench_bayesnet.run,        # Table IV
    "mrf": bench_mrf.run,                  # Fig. 12/13
    "entropy": bench_entropy.run,          # Fig. 11
    "coloring": bench_coloring.run,        # Fig. 9
    "breakdown": bench_breakdown.run,      # Fig. 2a
    "token_sampler": bench_token_sampler.run,  # beyond-paper (Table V ana.)
    "compile": bench_compile.run,          # compile chain (Sec. IV / Fig. 8)
    "runtime": bench_runtime.run,          # batched serving vs serial
}

# CI sanity set: fast, CPU-friendly, exercises the compile chain end to end
SMOKE_SUITES = ("coloring", "compile")

# suites that understand the --fused knob (the Pallas round kernels)
FUSED_SUITES = ("bayesnet", "compile")

BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results",
    "BENCH_BASELINE.json",
)
TRAJECTORY_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results", "trajectory",
)


def git_sha() -> str:
    """HEAD SHA of the repo the benchmarks live in, or "unknown" outside
    a checkout — stamped into every baseline so a trajectory entry names
    the exact code it measured."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


def quality_rows(quick: bool) -> list[dict]:
    """The sampling-quality side of the baseline: the `repro.diag` sweep
    at the CI (--quick) budget — one row per (model, variant) with
    rhat_max / ess_min / tv_max — so the regression gate can diff quality
    alongside us_per_call.  Full (non-quick) benchmark passes still use
    the quick *quality* budget: the gate needs stable, cheap reference
    numbers, not the deepest possible audit."""
    from repro.diag.__main__ import (QUICK_BURN_IN, QUICK_N_ITERS,
                                     quality_sweep)

    report = quality_sweep(
        ("survey",) if quick else ("survey", "alarm"),
        n_iters=QUICK_N_ITERS,
        burn_in=QUICK_BURN_IN,
    )
    for f in report.findings:
        print(f"# quality finding: {f.render()}")
    return report.meta["rows"]


def profile_rows(quick: bool) -> list[dict]:
    """The static-cost side of the baseline: `repro.obs.profile`'s fixed
    model-zoo sweep — per-executable-signature flops / hbm_bytes /
    collective_bytes + roofline bottleneck, derived from the compiled
    artifacts at a tiny fixed budget.  Pure compile-time data (no wall
    clock), so the drift gate can re-derive and diff it bit-for-bit on
    the same jax version."""
    from repro.obs import profile as profile_mod

    return profile_mod.static_profile_sweep(quick=quick)


def parse_row(row: str) -> dict:
    """One ``name,us_per_call,derived`` CSV row -> a JSON-friendly record
    (``derived`` stays a raw string: its key=value grammar is per-suite)."""
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def write_baseline(suite_rows: dict, args) -> None:
    """Snapshot this pass as the machine-readable perf baseline.

    Refuses to overwrite a baseline measured under *different* budgets
    (quick vs full, fused on/off): diffing us_per_call across budget
    regimes is exactly the folklore this artifact exists to kill.  A
    mismatched pass lands in BENCH_BASELINE.new.json instead — promote it
    by hand when the budget change is intentional."""
    path = BASELINE_PATH
    if os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH) as f:
            prev = json.load(f)
        if (prev.get("quick"), prev.get("fused")) != (
            bool(args.quick), bool(args.fused)
        ):
            path = BASELINE_PATH.replace(".json", ".new.json")
            print(f"# budget mismatch with recorded baseline "
                  f"(quick={prev.get('quick')}, fused={prev.get('fused')}): "
                  f"writing {os.path.relpath(path)} instead")
    record = {
        "schema": 2,
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": git_sha(),
        "backend": __import__("jax").default_backend(),
        "jax": __import__("jax").__version__,
        "quick": bool(args.quick),
        "smoke": bool(args.smoke),
        "fused": bool(args.fused),
        "suites": {
            name: [parse_row(r) for r in rows]
            for name, rows in suite_rows.items()
        },
        "quality": (
            [] if args.skip_quality else quality_rows(bool(args.quick))
        ),
        "profile": (
            [] if getattr(args, "skip_profile", False)
            else profile_rows(bool(args.quick))
        ),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"# wrote {os.path.relpath(path)} "
          f"({sum(len(v) for v in record['suites'].values())} rows, "
          f"{len(record['quality'])} quality rows, "
          f"{len(record['profile'])} profile rows)")
    # every baseline write also appends to the trajectory history: the
    # baseline file is a snapshot (each PR overwrites it), the trajectory
    # is the record of how the numbers moved PR over PR
    os.makedirs(TRAJECTORY_DIR, exist_ok=True)
    stamp = record["created_utc"].replace(":", "").replace("-", "")
    traj = os.path.join(
        TRAJECTORY_DIR, f"{stamp}__{record['git_sha'][:12]}.json"
    )
    with open(traj, "w") as f:
        json.dump(record, f, indent=1)
    print(f"# appended {os.path.relpath(traj)}")


def roofline_summary():
    d = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results",
                     "dryrun")
    if not os.path.isdir(d):
        print("# no dryrun results yet")
        return
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, f)))
        if r.get("status") != "ok":
            print(f"roofline_{r['arch']}_{r['cell']}_{r['mesh']},0.00,"
                  f"status=skipped")
            continue
        rf = r["roofline"]
        dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                  key=lambda k: rf[k])
        print(f"roofline_{r['arch']}_{r['cell']}_{r['mesh']},"
              f"{rf[dom]*1e6:.0f},"
              f"bottleneck={rf['bottleneck']};"
              f"tc={rf['t_compute_s']:.3f};tm={rf['t_memory_s']:.3f};"
              f"tcoll={rf['t_collective_s']:.3f};"
              f"useful={rf['useful_flops_ratio']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity pass: quick budgets, smoke suites only")
    ap.add_argument("--only", default="")
    ap.add_argument("--fused", action="store_true",
                    help="route the bayesnet/compile suites through the "
                         "fused Pallas round kernels as well")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--skip-quality", action="store_true",
                    help="omit the sampling-quality sweep from the "
                         "baseline snapshot")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="runtime suite: also write a traced bursty-pass "
                         "snapshot (Perfetto JSON + .jsonl + .attrib.json) "
                         "alongside the baseline")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="runtime suite (with --trace-out): also write the "
                         "compiled-artifact profile of the snapshot pass "
                         "(profile.json + .series.jsonl)")
    ap.add_argument("--skip-profile", action="store_true",
                    help="omit the static-cost profile sweep from the "
                         "baseline snapshot")
    args = ap.parse_args()
    if args.smoke:
        args.quick = True
    print("name,us_per_call,derived")
    if args.only:
        suites = {args.only: SUITES[args.only]}
    elif args.smoke:
        suites = {k: SUITES[k] for k in SMOKE_SUITES}
    else:
        suites = SUITES
    suite_rows = {}
    for name, fn in suites.items():
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        kwargs = {"quick": args.quick}
        if args.fused and name in FUSED_SUITES:
            kwargs["fused"] = True
        if args.trace_out and name == "runtime":
            kwargs["trace_out"] = args.trace_out
            if args.profile_out:
                kwargs["profile_out"] = args.profile_out
        suite_rows[name] = fn(**kwargs) or []
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if set(suite_rows) == set(SUITES):
        write_baseline(suite_rows, args)
    else:
        # partial passes (--smoke / --only) must never clobber the
        # committed full-suite perf baseline
        print(f"# partial pass ({', '.join(suite_rows)}): "
              f"{os.path.relpath(BASELINE_PATH)} left untouched")
    if args.roofline:
        print("# --- roofline (from dry-run) ---")
        roofline_summary()


if __name__ == "__main__":
    main()
