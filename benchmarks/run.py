"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` trims budgets;
``--roofline`` additionally summarizes the dry-run roofline table (requires
benchmarks/results/dryrun/*.json from repro.launch.dryrun)."""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_bayesnet, bench_breakdown, bench_coloring,
                        bench_compile, bench_entropy, bench_interp,
                        bench_mrf, bench_runtime, bench_sampler,
                        bench_token_sampler)

SUITES = {
    "sampler": bench_sampler.run,          # Table II
    "interp": bench_interp.run,            # Table III
    "bayesnet": bench_bayesnet.run,        # Table IV
    "mrf": bench_mrf.run,                  # Fig. 12/13
    "entropy": bench_entropy.run,          # Fig. 11
    "coloring": bench_coloring.run,        # Fig. 9
    "breakdown": bench_breakdown.run,      # Fig. 2a
    "token_sampler": bench_token_sampler.run,  # beyond-paper (Table V ana.)
    "compile": bench_compile.run,          # compile chain (Sec. IV / Fig. 8)
    "runtime": bench_runtime.run,          # batched serving vs serial
}

# CI sanity set: fast, CPU-friendly, exercises the compile chain end to end
SMOKE_SUITES = ("coloring", "compile")


def roofline_summary():
    d = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results",
                     "dryrun")
    if not os.path.isdir(d):
        print("# no dryrun results yet")
        return
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, f)))
        if r.get("status") != "ok":
            print(f"roofline_{r['arch']}_{r['cell']}_{r['mesh']},0.00,"
                  f"status=skipped")
            continue
        rf = r["roofline"]
        dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                  key=lambda k: rf[k])
        print(f"roofline_{r['arch']}_{r['cell']}_{r['mesh']},"
              f"{rf[dom]*1e6:.0f},"
              f"bottleneck={rf['bottleneck']};"
              f"tc={rf['t_compute_s']:.3f};tm={rf['t_memory_s']:.3f};"
              f"tcoll={rf['t_collective_s']:.3f};"
              f"useful={rf['useful_flops_ratio']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity pass: quick budgets, smoke suites only")
    ap.add_argument("--only", default="")
    ap.add_argument("--roofline", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        args.quick = True
    print("name,us_per_call,derived")
    if args.only:
        suites = {args.only: SUITES[args.only]}
    elif args.smoke:
        suites = {k: SUITES[k] for k in SMOKE_SUITES}
    else:
        suites = SUITES
    for name, fn in suites.items():
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        fn(quick=args.quick)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if args.roofline:
        print("# --- roofline (from dry-run) ---")
        roofline_summary()


if __name__ == "__main__":
    main()
