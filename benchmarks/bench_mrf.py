"""Paper Fig. 12/13 — throughput-gain breakdown on MRF + BN workloads.

Feature ablations, all running the identical chromatic-Gibbs schedule:

  cdf       : software CDF sampler, exact exp        (PULP-style baseline)
  exact_ky  : + hardware KY sampler (C1), exact exp  (ablates only C2)
  lut_ky    : + interpolation unit  (C2)             (full AIA pipeline)
  gumbel    : beyond-paper TPU-native alternative

Reported as site-updates/s and speedup over the cdf baseline — the paper's
Fig. 12 bars (sampling-dominated workloads gain most from C1, the rest from
the memory-locality features, which on TPU are the fused-engine layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.core import bayesnet as bnet
from repro.core import mrf as mrf_mod
from repro.core.graphs import GridMRF, bn_repository_replica

SAMPLERS = ("cdf", "exact_ky", "lut_ky", "gumbel")


def run(quick: bool = False):
    rows = []
    # --- MRF (Penguin/Art-style denoising grids) ---------------------------
    for name, (h, w, v) in {
        "penguin": (64, 64, 4), "art": (48, 48, 8),
    }.items():
        if quick and name == "art":
            continue
        clean, noisy = mrf_mod.make_denoising_problem(h, w, v, 0.25, seed=1)
        m = GridMRF(h, w, v, theta=1.2, h=2.0)
        ev = jnp.asarray(noisy)
        iters = 10 if quick else 20
        site_updates = h * w * iters * 2
        times = {}
        for s in SAMPLERS:
            def call(s=s):
                return mrf_mod.run_mrf_gibbs(
                    m, ev, jax.random.key(0), n_chains=1, n_iters=iters,
                    sampler=s,
                )

            times[s] = timeit(call, warmup=1, iters=3)
        base = times["cdf"]
        der = ";".join(
            f"{s}={site_updates/times[s]:.3e}ups|x{base/times[s]:.2f}"
            for s in SAMPLERS
        )
        rows.append(csv_row(f"fig12_mrf_{name}", times["lut_ky"] * 1e6, der))

    # --- BN (irregular) -----------------------------------------------------
    for name in (["alarm"] if quick else ["alarm", "hepar2"]):
        bn = bn_repository_replica(name)
        cbn = bnet.compile_bayesnet(bn)
        iters = 100 if quick else 200
        updates = bn.n_nodes * iters * 32
        times = {}
        for s in SAMPLERS:
            def call(s=s):
                return bnet.run_gibbs(
                    cbn, jax.random.key(0), n_chains=32, n_iters=iters,
                    burn_in=0, sampler=s,
                )[1]

            times[s] = timeit(call, warmup=1, iters=3)
        base = times["cdf"]
        der = ";".join(
            f"{s}={updates/times[s]:.3e}ups|x{base/times[s]:.2f}"
            for s in SAMPLERS
        )
        rows.append(csv_row(f"fig12_bn_{name}", times["lut_ky"] * 1e6, der))
    return rows


if __name__ == "__main__":
    run()
