"""Perf + sampling-quality regression gate against the recorded baseline.

    python benchmarks/check_regression.py            # full gate
    python benchmarks/check_regression.py --quick    # CI budget
    python benchmarks/check_regression.py --format json --out gate.json
    python benchmarks/check_regression.py --skip-perf   # quality only

Re-measures the current tree and diffs it against
``benchmarks/results/BENCH_BASELINE.json`` (written by ``run.py`` full
passes) with per-metric tolerances:

  * **perf** — reruns the smoke benchmark suites (one discarded warmup
    pass first, so first-time XLA compiles aren't charged to the suite
    the way they never are in a full-pass baseline) and compares each
    row's ``us_per_call`` to the baseline row of the same name; a row
    fails when
    ``current > baseline * --perf-tol + --perf-slack-us`` (default 2x +
    500us: wall noise on shared CI boxes is real, order-of-magnitude
    regressions are what the gate exists to catch).  Rows below the slack
    floor in the baseline are timer noise and are skipped.
  * **quality** — reruns the ``repro.diag`` sweep at the same CI budget
    the baseline's quality rows were measured under and gates each
    (model, variant) row: split R-hat may rise at most ``--rhat-tol``
    above baseline, TV-vs-exact at most ``--tv-tol`` above, and ESS may
    fall at most ``--ess-frac`` below.  Same seed + same budget means
    same-machine reruns reproduce the baseline bit-for-bit, so the
    tolerances only absorb cross-machine RNG-free numeric drift.
  * **static cost** — re-derives the ``repro.obs.profile`` model-zoo
    sweep (per-executable-signature flops / hbm_bytes /
    collective_bytes from the *compiled* HLO — zero wall-clock noise)
    and gates each metric at ``--cost-tol`` relative drift.  Skipped
    with a note when the baseline was recorded under a different jax
    version (XLA optimizes differently across releases) or carries no
    profile rows.

Failures are error-severity findings (``diag-perf-regression`` /
``diag-quality-regression`` / ``obs-cost-drift`` from the
`repro.analysis` catalog); exit
status is nonzero iff any — the CI contract.  Baseline rows the current
run didn't measure (and vice versa) are listed in the report meta, never
silently dropped.  A schema-1 baseline (pre-quality) skips the quality
side with a warning note; regenerate via a full ``run.py`` pass.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.analysis import Finding, Report

BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results",
    "BENCH_BASELINE.json",
)

# perf gate reruns only the fast CPU-friendly suites (run.py SMOKE_SUITES):
# the gate must be cheap enough to run on every PR
PERF_SUITES = ("coloring", "compile")

DEFAULT_PERF_TOL = 2.0
DEFAULT_PERF_SLACK_US = 500.0
DEFAULT_RHAT_TOL = 0.05
DEFAULT_TV_TOL = 0.01
DEFAULT_ESS_FRAC = 0.3
DEFAULT_COST_TOL = 0.10


def check_perf(baseline: dict, report: Report, *, suites=PERF_SUITES,
               tol=DEFAULT_PERF_TOL, slack_us=DEFAULT_PERF_SLACK_US,
               warmup=True) -> None:
    from benchmarks import run as run_mod

    quick = bool(baseline.get("quick"))
    base_rows = {
        r["name"]: r
        for s in suites
        for r in baseline.get("suites", {}).get(s, [])
    }
    cur_rows = {}
    for s in suites:
        if warmup:
            # the baseline comes from a *full* run.py pass, where earlier
            # suites have already paid every first-time XLA compile; a
            # fresh gate process measuring cold would charge those
            # compiles to the suite (observed ~80x on compile_cold_ms).
            # One discarded warmup pass makes the second comparable.
            run_mod.SUITES[s](quick=quick)
        for row in run_mod.SUITES[s](quick=quick) or []:
            rec = run_mod.parse_row(row)
            cur_rows[rec["name"]] = rec
    compared = 0
    for name, cur in cur_rows.items():
        base = base_rows.get(name)
        if base is None or base["us_per_call"] < slack_us:
            continue
        compared += 1
        limit = base["us_per_call"] * tol + slack_us
        row = {
            "name": name,
            "baseline_us": base["us_per_call"],
            "current_us": cur["us_per_call"],
            "limit_us": round(limit, 1),
            "ok": cur["us_per_call"] <= limit,
        }
        report.meta["perf_rows"].append(row)
        if not row["ok"]:
            report.extend([Finding(
                "diag-perf-regression", f"bench:{name}",
                f"{cur['us_per_call']:.1f}us vs baseline "
                f"{base['us_per_call']:.1f}us (limit {limit:.1f}us = "
                f"{tol}x + {slack_us:.0f}us slack)",
                fixit="profile the suite; if the slowdown is intended, "
                      "regenerate the baseline with benchmarks/run.py",
            )])
    report.meta["perf_missing"] = sorted(
        set(base_rows) - set(cur_rows)
    )
    report.meta["perf_new"] = sorted(set(cur_rows) - set(base_rows))
    report.meta["perf_compared"] = compared


def check_quality(baseline: dict, report: Report, *, quick=False,
                  rhat_tol=DEFAULT_RHAT_TOL, tv_tol=DEFAULT_TV_TOL,
                  ess_frac=DEFAULT_ESS_FRAC) -> None:
    from repro.diag.__main__ import (QUICK_BURN_IN, QUICK_N_ITERS,
                                     quality_sweep)

    base_rows = {
        (r["model"], r["variant"]): r for r in baseline.get("quality", [])
    }
    if not base_rows:
        report.meta["quality_note"] = (
            "baseline has no quality rows (schema<2 or --skip-quality); "
            "regenerate it with a full benchmarks/run.py pass"
        )
        return
    models = sorted({m for m, _ in base_rows})
    if quick:
        models = models[:1]
    sweep = quality_sweep(
        tuple(models), n_iters=QUICK_N_ITERS, burn_in=QUICK_BURN_IN,
    )
    compared = 0
    for cur in sweep.meta["rows"]:
        base = base_rows.get((cur["model"], cur["variant"]))
        if base is None:
            continue
        compared += 1
        loc = f"{cur['model']}/{cur['variant']}"
        checks = []
        if base.get("rhat_max") is not None and cur["rhat_max"] is not None:
            limit = base["rhat_max"] + rhat_tol
            checks.append(("rhat_max", cur["rhat_max"], limit,
                           cur["rhat_max"] <= limit))
        if base.get("tv_max") is not None and cur["tv_max"] is not None:
            limit = base["tv_max"] + tv_tol
            checks.append(("tv_max", cur["tv_max"], limit,
                           cur["tv_max"] <= limit))
        if base.get("ess_min") is not None and cur["ess_min"] is not None:
            limit = base["ess_min"] * (1.0 - ess_frac)
            checks.append(("ess_min", cur["ess_min"], limit,
                           cur["ess_min"] >= limit))
        report.meta["quality_rows"].append({
            "model": cur["model"], "variant": cur["variant"],
            "checks": [
                {"metric": m, "current": c, "limit": round(lim, 4), "ok": ok}
                for m, c, lim, ok in checks
            ],
        })
        for metric, curval, limit, ok in checks:
            if not ok:
                report.extend([Finding(
                    "diag-quality-regression", loc,
                    f"{metric} {curval:.4f} breaches baseline-relative "
                    f"limit {limit:.4f}",
                    fixit="bisect the sampling/schedule change; if the "
                          "shift is intended, regenerate the baseline",
                )])
    report.meta["quality_missing"] = sorted(
        f"{m}/{v}" for (m, v) in base_rows
        if (m, v) not in {(r["model"], r["variant"])
                          for r in sweep.meta["rows"]}
        and (not quick or m in models)
    )
    report.meta["quality_compared"] = compared


def check_static_cost(baseline: dict, report: Report, *,
                      tol=DEFAULT_COST_TOL, sweep_rows=None) -> None:
    """Gate the *static* HLO costs of the profile model-zoo sweep.

    Unlike the perf gate these numbers carry zero wall-clock noise: the
    sweep lowers the same programs at the same fixed budget and reads
    flops / hbm_bytes / collective_bytes off the optimized HLO, so on
    the same jax version a clean rerun reproduces the baseline exactly
    and any drift beyond ``tol`` is a real compiler-visible change (a
    silent recompute, a lost fusion, a new collective).  Baselines
    recorded under a *different* jax version are skipped with a note —
    XLA is free to optimize differently across releases.  ``sweep_rows``
    lets tests inject rows without paying for compiles."""
    base_rows = {r["sig"]: r for r in baseline.get("profile", [])}
    if not base_rows:
        report.meta["cost_note"] = (
            "baseline has no profile rows (pre-profile schema or "
            "--skip-profile); regenerate it with a full benchmarks/run.py "
            "pass"
        )
        return
    import jax
    if baseline.get("jax") != jax.__version__:
        report.meta["cost_note"] = (
            f"baseline jax {baseline.get('jax')} != current "
            f"{jax.__version__}: static HLO costs are not comparable "
            "across jax releases; regenerate the baseline"
        )
        return
    if sweep_rows is None:
        from repro.obs import profile as profile_mod

        sweep_rows = profile_mod.static_profile_sweep(
            quick=bool(baseline.get("quick"))
        )
    cur_rows = {r["sig"]: r for r in sweep_rows}
    compared = 0
    for sig, cur in cur_rows.items():
        base = base_rows.get(sig)
        if base is None:
            continue
        compared += 1
        checks = []
        for metric in ("flops", "hbm_bytes", "collective_bytes"):
            b = float(base.get(metric) or 0.0)
            c = float(cur.get(metric) or 0.0)
            drift = abs(c - b) / max(abs(b), 1.0)
            checks.append((metric, b, c, drift, drift <= tol))
        report.meta["cost_rows"].append({
            "sig": sig,
            "checks": [
                {"metric": m, "baseline": b, "current": c,
                 "drift": round(d, 4), "ok": ok}
                for m, b, c, d, ok in checks
            ],
        })
        for metric, b, c, drift, ok in checks:
            if not ok:
                report.extend([Finding(
                    "obs-cost-drift", f"profile:{sig}",
                    f"{metric} {c:.4g} vs baseline {b:.4g} "
                    f"(drift {drift:.1%} > {tol:.0%} tolerance)",
                    fixit="inspect the lowered HLO (repro.obs.profile) to "
                          "find the recompute/fusion change; if intended, "
                          "regenerate the baseline with benchmarks/run.py",
                )])
    report.meta["cost_missing"] = sorted(set(base_rows) - set(cur_rows))
    report.meta["cost_new"] = sorted(set(cur_rows) - set(base_rows))
    report.meta["cost_compared"] = compared


def check_sharded_fused(baseline: dict, report: Report) -> None:
    """Gate the recorded sharded fused-vs-unfused serving wall.

    Reads the baseline runtime suite's ``runtime_sharded_fused`` row (no
    rerun: the runtime suite is far too slow for the PR gate) and checks
    the fused shard_map datapath actually beat the legacy per-device
    engines.  On interpret hosts the recorder marks the row
    ``gated=advisory`` (CPU interpret-mode Pallas is not the compiled
    kernel's cost) and a sub-1x speedup downgrades to a warning; a
    ``gated=yes`` (TPU-recorded) baseline with sub-1x speedup is an
    error."""
    row = next(
        (r for r in baseline.get("suites", {}).get("runtime", [])
         if r.get("name") == "runtime_sharded_fused"), None,
    )
    if row is None:
        report.meta["sharded_fused_note"] = (
            "baseline has no runtime_sharded_fused row; regenerate it "
            "with a full benchmarks/run.py pass"
        )
        return
    derived = dict(
        kv.split("=", 1) for kv in row.get("derived", "").split(";") if "=" in kv
    )
    speedup = float(derived.get("fused_speedup", "nan"))
    gated = derived.get("gated", "advisory")
    report.meta["sharded_fused"] = {"speedup": speedup, "gated": gated}
    if not speedup >= 1.0:
        report.extend([Finding(
            "diag-perf-regression", "bench:runtime_sharded_fused",
            f"sharded fused serving {speedup:.2f}x vs the legacy engines "
            f"(recorded gated={gated})",
            severity="error" if gated == "yes" else "warning",
            fixit="profile the fused shard_map dispatch (repro.obs.profile "
                  "roofline); on interpret hosts this is advisory noise",
        )])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/check_regression.py",
        description="perf + sampling-quality regression gate vs "
                    "BENCH_BASELINE.json",
    )
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", help="also write the JSON report to this path")
    ap.add_argument("--quick", action="store_true",
                    help="CI budget: first baseline quality model only")
    ap.add_argument("--skip-perf", action="store_true")
    ap.add_argument("--skip-quality", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="skip the static-HLO-cost drift gate")
    ap.add_argument("--perf-tol", type=float, default=DEFAULT_PERF_TOL)
    ap.add_argument("--perf-slack-us", type=float,
                    default=DEFAULT_PERF_SLACK_US)
    ap.add_argument("--rhat-tol", type=float, default=DEFAULT_RHAT_TOL)
    ap.add_argument("--tv-tol", type=float, default=DEFAULT_TV_TOL)
    ap.add_argument("--ess-frac", type=float, default=DEFAULT_ESS_FRAC)
    ap.add_argument("--cost-tol", type=float, default=DEFAULT_COST_TOL,
                    help="relative drift tolerance for static HLO costs")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run benchmarks/run.py first",
              file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)

    report = Report(meta={
        "baseline": os.path.relpath(args.baseline),
        "baseline_sha": baseline.get("git_sha", "unknown"),
        "baseline_created": baseline.get("created_utc"),
        "perf_rows": [],
        "quality_rows": [],
        "cost_rows": [],
    })
    if not args.skip_perf:
        check_perf(baseline, report, tol=args.perf_tol,
                   slack_us=args.perf_slack_us)
    if not args.skip_quality:
        check_quality(baseline, report, quick=args.quick,
                      rhat_tol=args.rhat_tol, tv_tol=args.tv_tol,
                      ess_frac=args.ess_frac)
    if not args.skip_cost:
        check_static_cost(baseline, report, tol=args.cost_tol)
    check_sharded_fused(baseline, report)

    if args.out:
        pathlib.Path(args.out).write_text(report.to_json())
    if args.format == "json":
        print(report.to_json())
    else:
        for r in report.meta["perf_rows"]:
            mark = "ok" if r["ok"] else "FAIL"
            print(f"perf  {mark:4} {r['name']}: {r['current_us']:.1f}us "
                  f"(baseline {r['baseline_us']:.1f}us, "
                  f"limit {r['limit_us']:.1f}us)")
        for r in report.meta["quality_rows"]:
            for c in r["checks"]:
                mark = "ok" if c["ok"] else "FAIL"
                print(f"qual  {mark:4} {r['model']}/{r['variant']} "
                      f"{c['metric']}: {c['current']:.4f} "
                      f"(limit {c['limit']:.4f})")
        if report.meta.get("quality_note"):
            print(f"note: {report.meta['quality_note']}")
        for r in report.meta["cost_rows"]:
            for c in r["checks"]:
                mark = "ok" if c["ok"] else "FAIL"
                print(f"cost  {mark:4} {r['sig']} "
                      f"{c['metric']}: {c['current']:.4g} "
                      f"(baseline {c['baseline']:.4g}, "
                      f"drift {c['drift']:.1%})")
        if report.meta.get("cost_note"):
            print(f"note: {report.meta['cost_note']}")
        print(report.render_text())
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
