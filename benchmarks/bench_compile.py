"""Compile-chain benchmark: compile time, program-cache hit rate, the
schedule's comm cost under the greedy placement vs a random baseline, and —
since the schedule-direct backend landed — eager-vs-schedule execution
wall-clock plus the cost model's predicted-cycle vs measured-time
correlation for greedy and random placements.

This is the serving-facing view of `repro.compile`: a repeated workload
should pay the pass pipeline once (cache hit ~ dict lookup), the schedule
the pipeline picks should move fewer bytes x hops than a random placement
of the same colored graph, and executing the schedule directly should cost
no more than delegating to the eager engines.

Writes one JSON record per workload to ``benchmarks/results/compile/`` so
``launch/report.py`` can render the compile table without re-running.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/bench_compile.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import csv_row
from repro.compile import (
    cache_stats,
    clear_program_cache,
    compile_graph,
    run_pipeline,
)
from repro.compile import ir as compile_ir
from repro.compile.passes import random_baseline_pipeline
from repro.core.graphs import GridMRF, bn_repository_replica

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results", "compile"
)
BN_WORKLOADS = ["survey", "alarm", "insurance", "water", "hepar2", "pigs"]
N_REPEAT_REQUESTS = 16  # serving-style: same model re-submitted


def _graphs(quick: bool):
    names = BN_WORKLOADS[:3] if quick else BN_WORKLOADS
    graphs = [compile_ir.from_bayesnet(bn_repository_replica(n))
              for n in names]
    graphs.append(compile_ir.from_mrf(
        GridMRF(16 if quick else 32, 16 if quick else 32, 4, name="grid")))
    return graphs


def _time_run(prog, backend: str, *, n_chains: int, n_iters: int,
              fused: bool = False):
    """Steady-state seconds per Gibbs sweep for one backend (first call —
    jit compile + the schedule backend's one-time cross-check — untimed).
    `fused=True` routes through the fused Pallas round kernels (schedule
    backend only; bit-exact, so the delta is pure execution cost)."""
    key = jax.random.key(0)
    if prog.kind == "bn":
        run = lambda: prog.run(
            key, n_chains=n_chains, n_iters=n_iters, burn_in=0,
            backend=backend, fused=fused,
        )[1]
    else:
        ev = jnp.zeros((prog.mrf.height, prog.mrf.width), jnp.int32)
        run = lambda: prog.run(
            key, n_chains=n_chains, n_iters=n_iters, evidence=ev,
            backend=backend, fused=fused,
        )
    jax.block_until_ready(run())  # warmup
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    return (time.perf_counter() - t0) / n_iters


def _time_run_sharded(prog, mesh, *, n_chains: int, n_iters: int,
                      fused: bool):
    """Steady-state seconds per sweep for the shard_map route (warmup pays
    the compile plus, for fused, the one-time sharded cross-check)."""
    key = jax.random.key(0)
    if prog.kind == "bn":
        run = lambda: prog.run_sharded(
            key, mesh, n_chains=n_chains, n_iters=n_iters, burn_in=0,
            fused=fused,
        )[1]
    else:
        ev = jnp.zeros((prog.mrf.height, prog.mrf.width), jnp.int32)
        run = lambda: prog.run_sharded(
            key, mesh, n_chains=n_chains, n_iters=n_iters, evidence=ev,
            fused=fused,
        )
    jax.block_until_ready(run())  # warmup
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    return (time.perf_counter() - t0) / n_iters


def _pearson(xs, ys) -> float:
    if len(xs) < 2 or np.std(xs) == 0 or np.std(ys) == 0:
        return float("nan")
    return float(np.corrcoef(xs, ys)[0, 1])


def run(quick: bool = False, backend: str = "schedule",
        fused: bool = False):
    rows = []
    os.makedirs(RESULTS_DIR, exist_ok=True)
    n_chains, n_iters = (8, 10) if quick else (16, 25)
    fused_iters = 5 if quick else 10  # interpret hosts: small fused budget
    # (predicted total_cycles, measured s/sweep) pairs per placement family
    corr_pairs = {"greedy": [], "random": []}
    for graph in _graphs(quick):
        clear_program_cache()
        t0 = time.perf_counter()
        prog = compile_graph(graph)
        cold_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        for _ in range(N_REPEAT_REQUESTS - 1):
            compile_graph(graph)
        warm_s = (time.perf_counter() - t0) / (N_REPEAT_REQUESTS - 1)
        stats = cache_stats()

        cost = prog.schedule.cost()
        rand_progs = [
            compile_graph(
                graph, passes=random_baseline_pipeline(s), cache=False
            )
            for s in range(3)
        ]
        rand_costs = [p.schedule.cost() for p in rand_progs]
        rand_hop_bytes = float(np.mean(
            [c["total_hop_bytes"] for c in rand_costs]))
        rand_cycles = float(np.mean([c["total_cycles"] for c in rand_costs]))

        # backend execution: eager vs schedule wall-clock on the greedy
        # program, plus the cost model's prediction vs the measured time of
        # the benchmarked backend under both placements
        eager_s = _time_run(prog, "eager", n_chains=n_chains, n_iters=n_iters)
        sched_s = _time_run(
            prog, "schedule", n_chains=n_chains, n_iters=n_iters)
        fused_s = float("nan")
        sharded_s = sharded_fused_s = float("nan")
        if fused:
            fused_s = _time_run(
                prog, "schedule", n_chains=n_chains, n_iters=fused_iters,
                fused=True,
            )
            # sharded fused-vs-unfused wall: the fused pass runs the one
            # shard_map body (Pallas rounds + ppermute/psum collectives),
            # the unfused pass the legacy per-device engines.  Needs a
            # real mesh, and the grid's rows must split evenly; single-
            # device hosts record nothing rather than a fake mesh number.
            shard_w = 4
            mrf_ok = (graph.kind != "mrf"
                      or prog.mrf.height % shard_w == 0)
            if len(jax.devices()) >= shard_w and mrf_ok:
                from repro.core import compat

                mesh = compat.make_mesh((1, shard_w), ("data", "model"))
                sharded_s = _time_run_sharded(
                    prog, mesh, n_chains=n_chains, n_iters=fused_iters,
                    fused=False,
                )
                sharded_fused_s = _time_run_sharded(
                    prog, mesh, n_chains=n_chains, n_iters=fused_iters,
                    fused=True,
                )
        measured_s = sched_s if backend == "schedule" else eager_s
        rand_measured_s = _time_run(
            rand_progs[0], backend, n_chains=n_chains, n_iters=n_iters)
        corr_pairs["greedy"].append((cost["total_cycles"], measured_s))
        corr_pairs["random"].append(
            (rand_costs[0]["total_cycles"], rand_measured_s))

        rec = {
            "workload": graph.name,
            "kind": graph.kind,
            "n_nodes": graph.n_nodes,
            "ir_key": graph.ir_key[:16],
            "compile_cold_ms": cold_s * 1e3,
            "compile_warm_us": warm_s * 1e6,
            "cache_hit_rate": stats["hit_rate"],
            "cache_evictions": stats["evictions"],
            "cache_size": stats["size"],
            "cache_capacity": stats["capacity"],
            "n_colors": prog.diagnostics["n_colors"],
            "n_rounds": cost["n_rounds"],
            "sweep_cycles": cost["total_cycles"],
            "comm_hop_bytes": cost["total_hop_bytes"],
            "random_hop_bytes": rand_hop_bytes,
            "random_sweep_cycles": rand_cycles,
            "exec_backend": backend,
            "eager_sweep_s": eager_s,
            "schedule_sweep_s": sched_s,
            "fused_sweep_s": fused_s if fused else None,
            "sharded_sweep_s": sharded_s if sharded_s == sharded_s else None,
            "sharded_fused_sweep_s": (
                sharded_fused_s if sharded_fused_s == sharded_fused_s
                else None),
            "random_measured_sweep_s": rand_measured_s,
            "pass_times_s": prog.diagnostics["pass_times_s"],
        }
        with open(os.path.join(RESULTS_DIR, f"{graph.name}.json"), "w") as f:
            json.dump(rec, f, indent=1)

        assert cost["total_hop_bytes"] <= rand_hop_bytes, (
            graph.name, cost["total_hop_bytes"], rand_hop_bytes)
        # placement-aware compute cost: the greedy placement's critical path
        # must not exceed the random baseline's (it balances per-core load)
        assert cost["compute_cycles"] <= max(
            c["compute_cycles"] for c in rand_costs
        ), graph.name
        rows.append(csv_row(
            f"compile_{graph.name}", cold_s * 1e6,
            f"kind={graph.kind};nodes={graph.n_nodes};"
            f"cold_ms={cold_s*1e3:.1f};warm_us={warm_s*1e6:.1f};"
            f"hit_rate={stats['hit_rate']:.3f};"
            f"hop_bytes={cost['total_hop_bytes']};"
            f"random_hop_bytes={rand_hop_bytes:.0f};"
            f"sweep_cycles={cost['total_cycles']};"
            f"random_sweep_cycles={rand_cycles:.0f};"
            f"eager_sweep_us={eager_s*1e6:.0f};"
            f"schedule_sweep_us={sched_s*1e6:.0f}"
            + (f";fused_sweep_us={fused_s*1e6:.0f}" if fused else "")
            + (f";sharded_sweep_us={sharded_s*1e6:.0f};"
               f"sharded_fused_sweep_us={sharded_fused_s*1e6:.0f};"
               f"sharded_fused_speedup={sharded_s/sharded_fused_s:.2f}"
               if sharded_fused_s == sharded_fused_s else ""),
        ))

    for fam, pairs in corr_pairs.items():
        pred, meas = zip(*pairs)
        r = _pearson(np.log(pred), np.log(meas))
        rows.append(csv_row(
            f"compile_cycle_corr_{fam}", 0.0,
            f"backend={backend};pearson_r_log={r:.3f};n={len(pairs)};"
            f"pred_cycles={','.join(str(p) for p in pred)}",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="schedule",
                    choices=["eager", "schedule"],
                    help="execution backend measured for the predicted-vs-"
                         "measured cycle correlation")
    ap.add_argument("--fused", action="store_true",
                    help="additionally time the fused Pallas round kernels "
                         "(BN + MRF) on the schedule backend")
    args = ap.parse_args()
    run(quick=args.quick, backend=args.backend, fused=args.fused)
