"""Compile-chain benchmark: compile time, program-cache hit rate, and the
schedule's comm cost under the greedy placement vs a random baseline.

This is the serving-facing view of `repro.compile`: a repeated workload
should pay the pass pipeline once (cache hit ~ dict lookup), and the
schedule the pipeline picks should move fewer bytes x hops than a random
placement of the same colored graph.

Writes one JSON record per workload to ``benchmarks/results/compile/`` so
``launch/report.py`` can render the compile table without re-running.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import csv_row
from repro.compile import (
    cache_stats,
    clear_program_cache,
    compile_graph,
    run_pipeline,
)
from repro.compile import ir as compile_ir
from repro.compile.passes import random_baseline_pipeline
from repro.core.graphs import GridMRF, bn_repository_replica

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results", "compile"
)
BN_WORKLOADS = ["survey", "alarm", "insurance", "water", "hepar2", "pigs"]
N_REPEAT_REQUESTS = 16  # serving-style: same model re-submitted


def _graphs(quick: bool):
    names = BN_WORKLOADS[:3] if quick else BN_WORKLOADS
    graphs = [compile_ir.from_bayesnet(bn_repository_replica(n))
              for n in names]
    graphs.append(compile_ir.from_mrf(
        GridMRF(16 if quick else 32, 16 if quick else 32, 4, name="grid")))
    return graphs


def run(quick: bool = False):
    rows = []
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for graph in _graphs(quick):
        clear_program_cache()
        t0 = time.perf_counter()
        prog = compile_graph(graph)
        cold_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        for _ in range(N_REPEAT_REQUESTS - 1):
            compile_graph(graph)
        warm_s = (time.perf_counter() - t0) / (N_REPEAT_REQUESTS - 1)
        stats = cache_stats()

        cost = prog.schedule.cost()
        rand_costs = [
            run_pipeline(
                graph, mesh_shape=(4, 4), passes=random_baseline_pipeline(s),
            ).schedule.cost()
            for s in range(3)
        ]
        rand_hop_bytes = float(np.mean(
            [c["total_hop_bytes"] for c in rand_costs]))
        rand_cycles = float(np.mean([c["total_cycles"] for c in rand_costs]))

        rec = {
            "workload": graph.name,
            "kind": graph.kind,
            "n_nodes": graph.n_nodes,
            "ir_key": graph.ir_key[:16],
            "compile_cold_ms": cold_s * 1e3,
            "compile_warm_us": warm_s * 1e6,
            "cache_hit_rate": stats["hit_rate"],
            "n_colors": prog.diagnostics["n_colors"],
            "n_rounds": cost["n_rounds"],
            "sweep_cycles": cost["total_cycles"],
            "comm_hop_bytes": cost["total_hop_bytes"],
            "random_hop_bytes": rand_hop_bytes,
            "random_sweep_cycles": rand_cycles,
            "pass_times_s": prog.diagnostics["pass_times_s"],
        }
        with open(os.path.join(RESULTS_DIR, f"{graph.name}.json"), "w") as f:
            json.dump(rec, f, indent=1)

        assert cost["total_hop_bytes"] <= rand_hop_bytes, (
            graph.name, cost["total_hop_bytes"], rand_hop_bytes)
        rows.append(csv_row(
            f"compile_{graph.name}", cold_s * 1e6,
            f"kind={graph.kind};nodes={graph.n_nodes};"
            f"cold_ms={cold_s*1e3:.1f};warm_us={warm_s*1e6:.1f};"
            f"hit_rate={stats['hit_rate']:.3f};"
            f"hop_bytes={cost['total_hop_bytes']};"
            f"random_hop_bytes={rand_hop_bytes:.0f};"
            f"sweep_cycles={cost['total_cycles']};"
            f"random_sweep_cycles={rand_cycles:.0f}",
        ))
    return rows


if __name__ == "__main__":
    run()
