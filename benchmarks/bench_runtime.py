"""Serving-runtime benchmark: batched engine vs one-query-at-a-time, plus
the multi-worker executor gates.

Replays the synthetic Zipf-over-models trace twice through two serving
disciplines over the same compiled-program cache:

  * **batched** — `repro.runtime.Engine`: structure-only programs, clamp-set
    bucketing, vmapped microbatches (the tentpole path).
  * **serial baseline** — every query individually through
    `CompiledProgram.run(evidence=...)`, i.e. the best you could do before
    the runtime existed (still cached, still schedule backend — the delta
    is batching alone, not caching).

Both are measured over a *second* pass (first pass pays jit compiles for
both disciplines; serving steady-state is the regime that matters), and the
acceptance gates are asserted here:

  * program-cache hit rate >= 0.9 on the Zipf trace, batched qps above the
    serial baseline;
  * **workers** — 4-worker simulated qps strictly above 1-worker on the
    same trace (the executor overlap gate; simulated time, so the
    comparison is exact and machine-independent);
  * **slicing** — sliced long-query serving bit-exact with uninterrupted
    serving, asserted over every query (states and marginals);
  * **calibration** — after measured-time warmup, service predictions
    within 25% median relative error of the real dispatch walls;
  * **bursty backpressure** — under the on/off saturating trace, bounded
    queues never exceed the configured limit, the shed rate is reported,
    and two same-seed runs produce identical simulated metrics.

Writes one JSON record to ``benchmarks/results/runtime/`` for
``launch/report.py``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

if __package__ in (None, ""):  # `python benchmarks/bench_runtime.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import csv_row
from repro import obs
from repro.compile import cache_stats, clear_program_cache, compile_graph
from repro.runtime import (
    AdmissionConfig,
    Engine,
    EngineConfig,
    Query,
    bursty_trace,
    zipf_trace,
)

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results", "runtime"
)


def _run_engine(models, queries, backend: str, quick: bool):
    # the full pad ladder matters here: on a CPU host the samplers are
    # compute-bound, so padding every microbatch to the max size would bill
    # the batched discipline for discarded lanes (pass 1 absorbs the extra
    # jit compiles; pass 2 is the steady state being measured)
    engine = Engine(models, EngineConfig(
        backend=backend,
        pad_sizes=(1, 2, 4, 8),
    ))
    engine.submit(list(queries))
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0
    assert len(results) == len(queries)
    return engine, wall


def _run_serial(models, queries, backend: str):
    """One program.run() dispatch per query — the pre-runtime discipline."""
    from repro.compile import ir as compile_ir

    graphs = {
        name: compile_ir.canonicalize(m, evidence_mode="runtime")
        for name, m in models.items()
    }
    t0 = time.perf_counter()
    outs = []
    for q in queries:
        prog = compile_graph(graphs[q.model], pipeline="runtime")
        key = jax.random.key(q.seed)
        if prog.kind == "bn":
            out = prog.run(
                key, n_chains=q.n_chains, n_iters=q.n_iters,
                burn_in=q.burn_in, thin=q.thin, sampler=q.sampler,
                evidence=q.evidence, backend=backend,
            )
        else:
            out = prog.run(
                key, n_chains=q.n_chains, n_iters=q.n_iters,
                sampler=q.sampler, evidence=jnp.asarray(q.image),
                pins=q.evidence, backend=backend,
            )
        outs.append(out)
    jax.block_until_ready(outs[-1])
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# executor gates (multi-worker, slicing, calibration, bursty backpressure)
# ---------------------------------------------------------------------------

# determinism comparisons must skip the two wall-derived keys
_WALL_KEYS = ("wall_s", "calib_median_err")


def _gate_trace(quick: bool, seed: int = 5):
    """A small, fast zoo for the executor gates (they run several full
    engine passes; the zipf discipline comparison above covers scale)."""
    models, queries = zipf_trace(
        60 if quick else 80, quick=True, seed=seed, mean_interarrival_s=5e-5,
    )
    return models, queries


def _engine_pass(models, queries, **cfg):
    # single-pad ladder: the gates compare sim-time/bit properties, and
    # every extra (signature, pad) pair is a fresh XLA compile on the
    # gate's critical path
    eng = Engine(models, EngineConfig(
        pad_sizes=(8,), max_batch=8, **cfg,
    ))
    eng.submit(list(queries))
    results = eng.run()
    return eng, results


def gate_workers(quick: bool) -> dict:
    """4-worker simulated qps strictly above 1-worker on the same trace."""
    models, queries = _gate_trace(quick)
    e1, r1 = _engine_pass(models, queries, n_workers=1)
    e4, r4 = _engine_pass(models, queries, n_workers=4)
    qps1 = e1.metrics.summary()["throughput_qps"]
    qps4 = e4.metrics.summary()["throughput_qps"]
    assert qps4 > qps1, (
        "4-worker executor no faster than 1 worker (simulated)", qps4, qps1,
    )
    # the pool changes the clock, never the posterior
    for qid in r1:
        assert (r1[qid].final_state == r4[qid].final_state).all()
    return {"workers_qps_1": qps1, "workers_qps_4": qps4,
            "workers_speedup": qps4 / qps1}


def gate_slicing(quick: bool) -> dict:
    """Sliced long-query serving == uninterrupted serving, bit for bit,
    asserted for every query (not sampled)."""
    models, queries = _gate_trace(quick, seed=6)
    e_whole, r_whole = _engine_pass(models, queries)
    e_slice, r_slice = _engine_pass(models, queries, slice_iters=5)
    assert sorted(r_whole) == sorted(r_slice)
    for qid in r_whole:
        assert (r_whole[qid].final_state == r_slice[qid].final_state).all()
        if r_whole[qid].marginals is not None:
            assert (r_whole[qid].marginals == r_slice[qid].marginals).all()
    n_whole = e_whole.metrics.summary()["n_batches"]
    n_slice = e_slice.metrics.summary()["n_batches"]
    assert n_slice > n_whole  # slices really interleaved
    return {"slicing_batches_whole": n_whole, "slicing_batches": n_slice}


def gate_calibration(quick: bool) -> dict:
    """Measured-time calibration: predictions within 25% median relative
    error of the real dispatch walls, after warmup (single-pad ladder so
    every dispatch reuses the warmed executable; chain/iter budgets sized
    so one dispatch takes tens of milliseconds — short dispatches drown
    the measurement in host noise and the gate would test the OS
    scheduler, not the calibrator)."""
    from repro.core.graphs import bn_repository_replica

    rng_models = {n: bn_repository_replica(n) for n in ("survey", "cancer")}
    queries = [
        Query(
            qid=i, model=("survey", "cancer")[i % 2], evidence={0: i % 2},
            n_chains=8, n_iters=48, burn_in=8, seed=100 + i,
            arrival_s=1e-4 * i,
        )
        for i in range(32 if quick else 48)
    ]
    eng = Engine(rng_models, EngineConfig(pad_sizes=(8,), max_batch=8))
    eng.submit(queries)
    eng.calibrate(repeats=5)
    eng.run()
    s = eng.metrics.summary()
    assert s["calibrated_batches"] == s["n_batches"], (
        "some dispatches fell back to the line model after warmup", s,
    )
    assert s["calib_median_err"] is not None
    assert s["calib_median_err"] <= 0.25, (
        "calibrated service predictions off by more than 25% median",
        s["calib_median_err"],
    )
    return {"calib_median_err": s["calib_median_err"],
            "calibrated_batches": s["calibrated_batches"]}


def gate_bursty(quick: bool) -> dict:
    """Bursty saturation: bounded queues hold their limit, sheds are
    reported, and the event loop replays deterministically."""
    queue_limit = 8
    cfg = dict(
        admission=AdmissionConfig(
            rate_qps=3000.0, burst=8, queue_limit=queue_limit,
            max_defer_s=0.01,
        ),
    )
    n = 60 if quick else 100

    def one_pass():
        clear_program_cache()  # replay equality includes the cache counters
        models, queries = bursty_trace(n, quick=True, seed=8)
        eng, results = _engine_pass(models, queries, **cfg)
        return eng.metrics.summary(), results, len(queries)

    s1, r1, n_submitted = one_pass()
    assert s1["max_queue_depth"] <= queue_limit, (
        "bounded queue exceeded its limit", s1["max_queue_depth"],
    )
    assert s1["sheds"] + s1["defers"] > 0, (
        "the bursty trace never saturated admission; gate is vacuous", s1,
    )
    assert s1["n_queries"] + s1["sheds"] == n_submitted
    s2, r2, _ = one_pass()
    for k in s1:
        if k not in _WALL_KEYS:
            assert s1[k] == s2[k], ("bursty replay diverged", k, s1[k], s2[k])
    for qid in r1:
        assert (r1[qid].final_state == r2[qid].final_state).all()
    return {"bursty_max_queue_depth": s1["max_queue_depth"],
            "bursty_shed_rate": s1["shed_rate"],
            "bursty_sheds": s1["sheds"], "bursty_defers": s1["defers"]}


def gate_sharded_fused(quick: bool) -> dict:
    """Sharded serving, fused vs unfused, on the same pin-free grid trace:
    both passes route every bucket through the mesh slice; the fused pass
    executes the one-shard_map-body Pallas datapath, the unfused pass the
    legacy per-device engines.  The speedup assertion is hard only on TPU
    — interpret-mode Pallas on CPU hosts (with or without simulated
    devices) bears no relation to the compiled kernel's cost, so there
    the numbers are recorded as advisory."""
    from repro.core import mrf as mrf_mod
    from repro.core.graphs import GridMRF

    n = 8 if quick else 16
    mrf = GridMRF(8, 8, 3, theta=1.1, h=1.5)
    imgs = [mrf_mod.make_denoising_problem(8, 8, 3, 0.25, seed=s)[1]
            for s in range(4)]

    def queries():
        return [
            Query(qid=i, model="grid", image=imgs[i % 4], n_chains=2,
                  n_iters=8, seed=i, arrival_s=1e-5 * i)
            for i in range(n)
        ]

    def wall_of(fused: bool) -> float:
        cfg = dict(n_workers=4, shard_width=4, shard_min_sites=64,
                   fused=fused)
        clear_program_cache()
        _engine_pass({"grid": mrf}, queries(), **cfg)  # compile pass
        t0 = time.perf_counter()
        eng, res = _engine_pass({"grid": mrf}, queries(), **cfg)
        wall = time.perf_counter() - t0
        assert len(res) == n
        recs = eng.metrics.batch_records
        assert recs and all(b.route == "sharded" for b in recs), (
            "sharded-fused gate did not take the sharded route",
            [b.route for b in recs],
        )
        return wall

    unfused_wall = wall_of(False)
    fused_wall = wall_of(True)
    speedup = unfused_wall / fused_wall
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        assert speedup > 1.0, (
            "fused sharded serving slower than the legacy engines on "
            "compiled hardware", fused_wall, unfused_wall,
        )
    else:
        print(f"[bench_runtime] sharded fused speedup {speedup:.2f}x "
              f"(advisory on {jax.default_backend()})", flush=True)
    return {
        "sharded_fused_wall_s": fused_wall,
        "sharded_unfused_wall_s": unfused_wall,
        "sharded_fused_speedup": speedup,
        "sharded_fused_n_queries": n,
        "sharded_fused_gated": "yes" if on_tpu else "advisory",
    }


def trace_snapshot(trace_out: str, quick: bool,
                   profile_out: str | None = None) -> dict:
    """One traced bursty engine pass: Perfetto timeline + deterministic
    JSONL + attribution sidecar written alongside the BENCH_BASELINE
    artifacts, asserted gap-free (every dispatched program has round
    costs).  With ``profile_out``, the same pass runs the compiled-
    artifact profiler: static costs + roofline joined against the
    dispatch spans (asserted fully attributed) land in profile.json and
    the sim-clock metrics series next to it.  Runs with a cold cache and
    its own tracer so the snapshot is self-contained; tracing is disabled
    again before the timed passes' numbers could be affected (the
    snapshot runs after them)."""
    from repro.obs import profile as profile_mod

    clear_program_cache()
    obs.enable()
    if profile_out:
        profile_mod.enable()
    try:
        models, queries = bursty_trace(60 if quick else 100, quick=True,
                                       seed=8)
        eng, _ = _engine_pass(models, queries, n_workers=4)
        tr = obs.get()
        events = list(tr.events)
        base = os.path.splitext(trace_out)[0]
        obs.export.write_perfetto(trace_out, events)
        obs.export.write_jsonl(base + ".jsonl", events)
        dicts = obs.export.events_as_dicts(events)
        rows, gaps = obs.attrib.attribution(dicts)
        with open(base + ".attrib.json", "w") as f:
            json.dump({"rows": rows, "gaps": gaps,
                       "n_events": len(events), "dropped": tr.dropped},
                      f, indent=1, sort_keys=True)
        assert not gaps, ("attribution gaps in the trace snapshot", gaps)
        n_batches = eng.metrics.summary()["n_batches"]
        n_spans = sum(1 for r in rows if r["kind"] == "round")
        print(f"[bench_runtime] trace snapshot: {len(events)} events, "
              f"{n_batches} dispatches, {n_spans} attributed rounds "
              f"-> {trace_out}", flush=True)
        if profile_out:
            prec = profile_mod.write_profile(
                profile_out, profile_mod.get(), dicts
            )
            eng.metrics.series.write_jsonl(
                os.path.splitext(profile_out)[0] + ".series.jsonl"
            )
            joined = prec["joined"]
            assert not joined["unattributed"], (
                "unattributed dispatches in the profile snapshot",
                joined["unattributed"],
            )
            print(f"[bench_runtime] profile snapshot: "
                  f"{len(prec['buckets'])} executables over "
                  f"{joined['n_dispatches']} dispatches -> {profile_out}",
                  flush=True)
        return {"trace_dropped": tr.dropped}
    finally:
        if profile_out:
            profile_mod.disable()
        obs.disable()


def run(quick: bool = False, backend: str = "schedule",
        trace_out: str | None = None, profile_out: str | None = None):
    rows = []
    os.makedirs(RESULTS_DIR, exist_ok=True)
    n_queries = 60 if quick else 150
    models, queries = zipf_trace(n_queries, quick=quick, seed=0)

    # pass 1: cold — pays every program compile and jit trace in both
    # disciplines and yields the meaningful Zipf hit rate (misses ==
    # distinct models).  Steady state is then measured as best-of-N with
    # the disciplines interleaved: wall timings on a shared host are noisy
    # enough to flip a single-pass comparison either way, and the minimum
    # is the standard noise-robust estimator for "what the code costs".
    clear_program_cache()
    cold_engine, _ = _run_engine(models, queries, backend, quick)
    serial_cold_s = _run_serial(models, queries, backend)
    print("[bench_runtime] cold pass done", flush=True)
    batched_wall, serial_wall = float("inf"), float("inf")
    engine = None
    for i in range(3):
        eng, w = _run_engine(models, queries, backend, quick)
        if w < batched_wall:
            batched_wall, engine = w, eng
        serial_wall = min(serial_wall, _run_serial(models, queries, backend))
        print(f"[bench_runtime] steady-state pass {i + 1}/3 done", flush=True)

    s = engine.metrics.summary()
    cold_hit_rate = cold_engine.metrics.summary()["cache_hit_rate"]
    batched_qps = len(queries) / batched_wall
    serial_qps = len(queries) / serial_wall
    stats = cache_stats()

    rec = {
        "trace": "zipf",
        "backend": backend,
        "n_models": len(models),
        "n_queries": len(queries),
        "n_batches": s["n_batches"],
        "mean_batch": s["mean_batch"],
        "pad_efficiency": s["pad_efficiency"],
        "sim_latency_p50_ms": s["latency_p50_s"] * 1e3,
        "sim_latency_p95_ms": s["latency_p95_s"] * 1e3,
        "sim_latency_p99_ms": (
            s["latency_p99_s"] * 1e3
            if s["latency_p99_s"] is not None else None
        ),
        "sim_throughput_qps": s["throughput_qps"],
        "batched_wall_s": batched_wall,
        "batched_qps": batched_qps,
        "serial_wall_s": serial_wall,
        "serial_qps": serial_qps,
        "speedup": batched_qps / serial_qps,
        "serial_cold_s": serial_cold_s,
        "cache_hit_rate": cold_hit_rate,
        "warm_hit_rate": s["cache_hit_rate"],
        "cache_evictions": stats["evictions"],
        "cache_size": stats["size"],
        "cache_capacity": stats["capacity"],
        "recompiles": s["recompiles"],
    }
    with open(os.path.join(RESULTS_DIR, "zipf.json"), "w") as f:
        json.dump(rec, f, indent=1)

    # acceptance gates: the Zipf trace must be a caching+batching win
    assert cold_hit_rate >= 0.9, (
        "program-cache hit rate below 0.9 on the Zipf trace", cold_hit_rate,
    )
    assert batched_qps > serial_qps, (
        "batched serving no faster than one-query-at-a-time",
        batched_qps, serial_qps,
    )
    rows.append(csv_row(
        "runtime_zipf", batched_wall * 1e6 / len(queries),
        f"backend={backend};queries={len(queries)};"
        f"batched_qps={batched_qps:.1f};serial_qps={serial_qps:.1f};"
        f"speedup={batched_qps / serial_qps:.2f};"
        f"hit_rate={cold_hit_rate:.3f};"
        f"mean_batch={s['mean_batch']:.2f};"
        f"p95_sim_ms={s['latency_p95_s'] * 1e3:.2f};"
        f"recompiles={s['recompiles']}",
    ))

    # executor gates (each asserts its acceptance criterion internally)
    gates = {}
    for gate in (gate_workers, gate_slicing, gate_calibration, gate_bursty,
                 gate_sharded_fused):
        clear_program_cache()
        t0 = time.perf_counter()
        gates.update(gate(quick))
        print(f"[bench_runtime] {gate.__name__} ok "
              f"({time.perf_counter() - t0:.1f}s)", flush=True)
    rec.update(gates)
    with open(os.path.join(RESULTS_DIR, "zipf.json"), "w") as f:
        json.dump(rec, f, indent=1)
    rows.append(csv_row(
        "runtime_executor", gates["workers_speedup"],
        f"workers_speedup={gates['workers_speedup']:.2f};"
        f"slicing_batches={gates['slicing_batches']};"
        f"calib_median_err={gates['calib_median_err']:.3f};"
        f"bursty_maxq={gates['bursty_max_queue_depth']};"
        f"bursty_shed_rate={gates['bursty_shed_rate']:.3f};"
        f"bursty_defers={gates['bursty_defers']}",
    ))
    rows.append(csv_row(
        "runtime_sharded_fused",
        gates["sharded_fused_wall_s"] * 1e6 / gates["sharded_fused_n_queries"],
        f"backend={jax.default_backend()};"
        f"fused_wall_s={gates['sharded_fused_wall_s']:.3f};"
        f"unfused_wall_s={gates['sharded_unfused_wall_s']:.3f};"
        f"fused_speedup={gates['sharded_fused_speedup']:.2f};"
        f"gated={gates['sharded_fused_gated']}",
    ))
    if trace_out:
        rec.update(trace_snapshot(trace_out, quick,
                                  profile_out=profile_out))
        with open(os.path.join(RESULTS_DIR, "zipf.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="schedule",
                    choices=["schedule", "eager"])
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also write a traced bursty-pass snapshot: "
                         "Perfetto JSON at PATH plus .jsonl/.attrib.json")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="with --trace-out: also profile the snapshot "
                         "pass (static HLO costs + roofline joined "
                         "against dispatch spans) into PATH plus the "
                         "metrics series (.series.jsonl)")
    args = ap.parse_args()
    run(quick=args.quick, backend=args.backend, trace_out=args.trace_out,
        profile_out=args.profile_out)
