"""Serving-runtime benchmark: batched engine vs one-query-at-a-time.

Replays the synthetic Zipf-over-models trace twice through two serving
disciplines over the same compiled-program cache:

  * **batched** — `repro.runtime.Engine`: structure-only programs, clamp-set
    bucketing, vmapped microbatches (the tentpole path).
  * **serial baseline** — every query individually through
    `CompiledProgram.run(evidence=...)`, i.e. the best you could do before
    the runtime existed (still cached, still schedule backend — the delta
    is batching alone, not caching).

Both are measured over a *second* pass (first pass pays jit compiles for
both disciplines; serving steady-state is the regime that matters), and the
acceptance gates are asserted here: program-cache hit rate >= 0.9 on the
Zipf trace and batched queries/sec above the serial baseline.

Writes one JSON record to ``benchmarks/results/runtime/`` for
``launch/report.py``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

if __package__ in (None, ""):  # `python benchmarks/bench_runtime.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import csv_row
from repro.compile import cache_stats, clear_program_cache, compile_graph
from repro.runtime import Engine, EngineConfig, zipf_trace

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "results", "runtime"
)


def _run_engine(models, queries, backend: str, quick: bool):
    # the full pad ladder matters here: on a CPU host the samplers are
    # compute-bound, so padding every microbatch to the max size would bill
    # the batched discipline for discarded lanes (pass 1 absorbs the extra
    # jit compiles; pass 2 is the steady state being measured)
    engine = Engine(models, EngineConfig(
        backend=backend,
        pad_sizes=(1, 2, 4, 8),
    ))
    engine.submit(list(queries))
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0
    assert len(results) == len(queries)
    return engine, wall


def _run_serial(models, queries, backend: str):
    """One program.run() dispatch per query — the pre-runtime discipline."""
    from repro.compile import ir as compile_ir

    graphs = {
        name: compile_ir.canonicalize(m, evidence_mode="runtime")
        for name, m in models.items()
    }
    t0 = time.perf_counter()
    outs = []
    for q in queries:
        prog = compile_graph(graphs[q.model], pipeline="runtime")
        key = jax.random.key(q.seed)
        if prog.kind == "bn":
            out = prog.run(
                key, n_chains=q.n_chains, n_iters=q.n_iters,
                burn_in=q.burn_in, thin=q.thin, sampler=q.sampler,
                evidence=q.evidence, backend=backend,
            )
        else:
            out = prog.run(
                key, n_chains=q.n_chains, n_iters=q.n_iters,
                sampler=q.sampler, evidence=jnp.asarray(q.image),
                pins=q.evidence, backend=backend,
            )
        outs.append(out)
    jax.block_until_ready(outs[-1])
    return time.perf_counter() - t0


def run(quick: bool = False, backend: str = "schedule"):
    rows = []
    os.makedirs(RESULTS_DIR, exist_ok=True)
    n_queries = 60 if quick else 150
    models, queries = zipf_trace(n_queries, quick=quick, seed=0)

    # pass 1: cold — pays every program compile and jit trace in both
    # disciplines and yields the meaningful Zipf hit rate (misses ==
    # distinct models).  Steady state is then measured as best-of-N with
    # the disciplines interleaved: wall timings on a shared host are noisy
    # enough to flip a single-pass comparison either way, and the minimum
    # is the standard noise-robust estimator for "what the code costs".
    clear_program_cache()
    cold_engine, _ = _run_engine(models, queries, backend, quick)
    serial_cold_s = _run_serial(models, queries, backend)
    batched_wall, serial_wall = float("inf"), float("inf")
    engine = None
    for _ in range(3):
        eng, w = _run_engine(models, queries, backend, quick)
        if w < batched_wall:
            batched_wall, engine = w, eng
        serial_wall = min(serial_wall, _run_serial(models, queries, backend))

    s = engine.metrics.summary()
    cold_hit_rate = cold_engine.metrics.summary()["cache_hit_rate"]
    batched_qps = len(queries) / batched_wall
    serial_qps = len(queries) / serial_wall
    stats = cache_stats()

    rec = {
        "trace": "zipf",
        "backend": backend,
        "n_models": len(models),
        "n_queries": len(queries),
        "n_batches": s["n_batches"],
        "mean_batch": s["mean_batch"],
        "pad_efficiency": s["pad_efficiency"],
        "sim_latency_p50_ms": s["latency_p50_ms"],
        "sim_latency_p95_ms": s["latency_p95_ms"],
        "sim_throughput_qps": s["throughput_qps"],
        "batched_wall_s": batched_wall,
        "batched_qps": batched_qps,
        "serial_wall_s": serial_wall,
        "serial_qps": serial_qps,
        "speedup": batched_qps / serial_qps,
        "serial_cold_s": serial_cold_s,
        "cache_hit_rate": cold_hit_rate,
        "warm_hit_rate": s["cache_hit_rate"],
        "cache_evictions": stats["evictions"],
        "cache_size": stats["size"],
        "cache_capacity": stats["capacity"],
        "recompiles": s["recompiles"],
    }
    with open(os.path.join(RESULTS_DIR, "zipf.json"), "w") as f:
        json.dump(rec, f, indent=1)

    # acceptance gates: the Zipf trace must be a caching+batching win
    assert cold_hit_rate >= 0.9, (
        "program-cache hit rate below 0.9 on the Zipf trace", cold_hit_rate,
    )
    assert batched_qps > serial_qps, (
        "batched serving no faster than one-query-at-a-time",
        batched_qps, serial_qps,
    )
    rows.append(csv_row(
        "runtime_zipf", batched_wall * 1e6 / len(queries),
        f"backend={backend};queries={len(queries)};"
        f"batched_qps={batched_qps:.1f};serial_qps={serial_qps:.1f};"
        f"speedup={batched_qps / serial_qps:.2f};"
        f"hit_rate={cold_hit_rate:.3f};"
        f"mean_batch={s['mean_batch']:.2f};"
        f"p95_sim_ms={s['latency_p95_ms']:.2f};"
        f"recompiles={s['recompiles']}",
    ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="schedule",
                    choices=["schedule", "eager"])
    args = ap.parse_args()
    run(quick=args.quick, backend=args.backend)
