"""Paper Table IV — single-marginal runtime on the BN-repository workloads.

Columns reproduced (structure-matched synthetic replicas — the original
BN-repo CPTs are not downloadable offline, see DESIGN.md Sec. 7):

  exact VE      — the "Dice"-style exact-inference baseline;
  gibbs_cdf     — software CDF sampling (the CPU/PULP-style baseline);
  gibbs_lut_ky  — AIA pipeline (LUT-exp + rejection-KY), ours.

Accuracy is reported as max TVD vs the exact marginals where VE is
tractable within the budget.

``--fused`` additionally measures the fused Pallas BN round kernel
(`kernels/bn_gibbs.py`, the paper's C1+C2 datapath in one VMEM-resident
pass) against the unfused schedule backend and reports the speedup.  On a
real TPU backend the sized (largest) workload must come out >1x — that is
the perf claim this PR makes — and the bench asserts it; interpret-mode
hosts (CPU CI) print the ratio as advisory only, since interpret mode
serializes the kernel and says nothing about hardware behavior.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/bench_bayesnet.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import csv_row, timeit
from repro.compile import compile_graph
from repro.core.exact import ve_marginal
from repro.core.graphs import bn_repository_replica

WORKLOADS = ["survey", "cancer", "alarm", "insurance", "water",
             "hailfinder", "hepar2", "pigs"]
VE_BUDGET_S = 30.0


def _fused_timings(prog, n_iters: int):
    """(unfused schedule s/sweep, fused s/sweep) for lut_ky — small budget
    (the per-sweep ratio is what the column reports)."""
    key = jax.random.key(0)

    def call(fused):
        def run():
            return prog.run(
                key, n_chains=32, n_iters=n_iters, burn_in=0, fused=fused,
            )[1]
        return run

    t_unfused = timeit(call(False), warmup=1, iters=3) / n_iters
    t_fused = timeit(call(True), warmup=1, iters=3) / n_iters
    return t_unfused, t_fused


def run(quick: bool = False, fused: bool = False):
    rows = []
    workloads = WORKLOADS[:4] if quick else WORKLOADS
    iters = 150 if quick else 300
    fused_iters = 10 if quick else 25
    on_tpu = jax.default_backend() == "tpu"
    fused_ratio = {}
    for name in workloads:
        bn = bn_repository_replica(name)
        prog = compile_graph(bn)  # cached compile chain (IR -> passes -> program)
        q = bn.n_nodes // 2

        # exact VE (Dice-analogue).  The dense/large replicas (hepar2, pigs)
        # blow up VE memory — precisely the regime where the paper argues
        # sampling wins; guard by moralized max clique size.
        t0 = time.perf_counter()
        exact = None
        t_ve = float("nan")
        max_mb = max(len(bn.markov_blanket(i)) for i in range(bn.n_nodes))
        if bn.n_nodes <= 60 and max_mb <= 16:
            try:
                exact = ve_marginal(bn, q)
                t_ve = time.perf_counter() - t0
            except Exception:
                pass
            if time.perf_counter() - t0 > VE_BUDGET_S:
                t_ve = float("nan")

        marg = {}
        times = {}
        for sampler in ("lut_ky", "cdf"):
            def call(s=sampler):
                return prog.run(
                    jax.random.key(0), n_chains=32, n_iters=iters,
                    burn_in=iters // 4, sampler=s,
                )[0]

            times[sampler] = timeit(call, warmup=1, iters=3)
            marg[sampler] = np.asarray(call())

        fused_col = ""
        if fused:
            t_unf, t_fus = _fused_timings(prog, fused_iters)
            fused_ratio[name] = t_unf / t_fus
            fused_col = (
                f";unfused_sweep_us={t_unf*1e6:.0f}"
                f";fused_sweep_us={t_fus*1e6:.0f}"
                f";fused_speedup={t_unf/t_fus:.2f}"
            )

        tvd = float("nan")
        if exact is not None:
            tvd = 0.5 * np.abs(
                marg["lut_ky"][q][: len(exact)] - exact
            ).sum()
        rows.append(csv_row(
            f"table4_{name}", times["lut_ky"] * 1e6,
            f"ve_ms={t_ve*1e3:.1f};gibbs_lutky_ms={times['lut_ky']*1e3:.1f};"
            f"gibbs_cdf_ms={times['cdf']*1e3:.1f};"
            f"nodes={bn.n_nodes};tvd_vs_exact={tvd:.4f}{fused_col}",
        ))

    if fused:
        sized = workloads[-1]  # the sized model: largest workload benched
        ratio = fused_ratio[sized]
        rows.append(csv_row(
            f"table4_fused_gate_{sized}", 0.0,
            f"fused_speedup={ratio:.2f};backend={jax.default_backend()};"
            f"gated={'yes' if on_tpu else 'advisory'}",
        ))
        if on_tpu:
            # the perf claim, gated where it is meaningful: the fused
            # VMEM-resident round path must beat the unfused ~6-kernel
            # round on real hardware
            assert ratio > 1.0, (
                f"fused BN rounds slower than unfused on {sized}: "
                f"{ratio:.2f}x"
            )
        else:
            print(f"# fused speedup gate advisory on "
                  f"{jax.default_backend()} (interpret mode): "
                  f"{sized} {ratio:.2f}x")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fused", action="store_true",
                    help="measure the fused Pallas BN round kernel vs the "
                         "unfused schedule backend (gated >1x on TPU)")
    args = ap.parse_args()
    run(quick=args.quick, fused=args.fused)
