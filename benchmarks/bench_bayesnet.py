"""Paper Table IV — single-marginal runtime on the BN-repository workloads.

Columns reproduced (structure-matched synthetic replicas — the original
BN-repo CPTs are not downloadable offline, see DESIGN.md Sec. 7):

  exact VE      — the "Dice"-style exact-inference baseline;
  gibbs_cdf     — software CDF sampling (the CPU/PULP-style baseline);
  gibbs_lut_ky  — AIA pipeline (LUT-exp + rejection-KY), ours.

Accuracy is reported as max TVD vs the exact marginals where VE is
tractable within the budget."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.compile import compile_graph
from repro.core.exact import ve_marginal
from repro.core.graphs import bn_repository_replica

WORKLOADS = ["survey", "cancer", "alarm", "insurance", "water",
             "hailfinder", "hepar2", "pigs"]
VE_BUDGET_S = 30.0


def run(quick: bool = False):
    rows = []
    workloads = WORKLOADS[:4] if quick else WORKLOADS
    iters = 150 if quick else 300
    for name in workloads:
        bn = bn_repository_replica(name)
        prog = compile_graph(bn)  # cached compile chain (IR -> passes -> program)
        q = bn.n_nodes // 2

        # exact VE (Dice-analogue).  The dense/large replicas (hepar2, pigs)
        # blow up VE memory — precisely the regime where the paper argues
        # sampling wins; guard by moralized max clique size.
        t0 = time.perf_counter()
        exact = None
        t_ve = float("nan")
        max_mb = max(len(bn.markov_blanket(i)) for i in range(bn.n_nodes))
        if bn.n_nodes <= 60 and max_mb <= 16:
            try:
                exact = ve_marginal(bn, q)
                t_ve = time.perf_counter() - t0
            except Exception:
                pass
            if time.perf_counter() - t0 > VE_BUDGET_S:
                t_ve = float("nan")

        marg = {}
        times = {}
        for sampler in ("lut_ky", "cdf"):
            def call(s=sampler):
                return prog.run(
                    jax.random.key(0), n_chains=32, n_iters=iters,
                    burn_in=iters // 4, sampler=s,
                )[0]

            times[sampler] = timeit(call, warmup=1, iters=3)
            marg[sampler] = np.asarray(call())

        tvd = float("nan")
        if exact is not None:
            tvd = 0.5 * np.abs(
                marg["lut_ky"][q][: len(exact)] - exact
            ).sum()
        rows.append(csv_row(
            f"table4_{name}", times["lut_ky"] * 1e6,
            f"ve_ms={t_ve*1e3:.1f};gibbs_lutky_ms={times['lut_ky']*1e3:.1f};"
            f"gibbs_cdf_ms={times['cdf']*1e3:.1f};"
            f"nodes={bn.n_nodes};tvd_vs_exact={tvd:.4f}",
        ))
    return rows


if __name__ == "__main__":
    run()
