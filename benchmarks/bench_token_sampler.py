"""Beyond-paper: the AIA pipeline as an LM token sampler (Table V analogue).

Hierarchical 128-ary rejection-KY vs gumbel-max vs full softmax+CDF over
LM-scale vocabularies (2k EnCodec ... 202k llama4), batch 64.  Reports
tokens/s and the 8-bit quantization TVD of the KY path."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.core.draws import draw_from_logits
from repro.models.sampling import gumbel_token_sample, ky_token_sample

B = 64


def run(quick: bool = False):
    rows = []
    vocabs = [2048, 50304] if quick else [2048, 50304, 202048]
    for v in vocabs:
        logits = jax.random.normal(jax.random.key(v % 97), (B, v),
                                   jnp.float32) * 2.0

        t_ky = timeit(lambda: ky_token_sample(logits, jax.random.key(1)),
                      warmup=1, iters=3)
        t_gb = timeit(lambda: gumbel_token_sample(logits, jax.random.key(2)),
                      warmup=1, iters=3)
        t_cdf = timeit(
            lambda: draw_from_logits(logits, jax.random.key(3), "cdf"),
            warmup=1, iters=3,
        )
        # quantization bias of the 8-bit LUT path on one row
        p = np.asarray(jax.nn.softmax(logits[0]))
        toks = np.asarray(ky_token_sample(
            jnp.tile(logits[:1], (4096, 1)), jax.random.key(4)))
        emp = np.bincount(toks, minlength=v) / len(toks)
        tvd = 0.5 * np.abs(emp - p).sum()
        noise = 0.5 * np.sqrt(2 / np.pi) * np.sqrt(
            p * (1 - p) / len(toks)).sum()
        rows.append(csv_row(
            f"token_sampler_v{v}", t_ky / B * 1e6,
            f"ky_tok/s={B/t_ky:.3e};gumbel_tok/s={B/t_gb:.3e};"
            f"cdf_tok/s={B/t_cdf:.3e};ky_tvd={tvd:.4f};"
            f"sampling_noise={noise:.4f}",
        ))
    return rows


if __name__ == "__main__":
    run()
