"""Paper Table II — sampler-unit comparison: rejection-KY vs CDF.

The ASIC numbers (area um^2, pJ/sample) are circuit properties; the
architecture-independent claims we reproduce are:

  * throughput modes: lower precision => more samples per random-bit budget
    (32b/16b/8b -> 1/2/4 samples per cycle in the paper; here: bits consumed
    per sample halves as weight precision drops);
  * KY beats CDF per-sample cost: O(H) bit-steps vs O(N) cumsum + search;
  * measured CPU wall-clock for both pipelines (jit, batch=4096).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit
from repro.core import ky as ky_core
from repro.core.draws import draw_from_logits
from repro.core.interp import build_exp_weight_lut

B, N = 4096, 32


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    weights = jnp.asarray(rng.integers(1, 200, (B, N)), jnp.int32)
    logp = jnp.log(weights.astype(jnp.float32))
    tab, spec = build_exp_weight_lut()

    # --- precision modes (Table II "operating mode" columns) ---------------
    # at precision p the distribution must be quantized so sum(m) <= 2^p:
    # per-weight bits = p - ceil(log2 N), exactly the paper's packing trade
    for prec, label in ((30, "32b"), (16, "16b"), (8, "8b")):
        wq = ky_core.quantize_probs(
            weights.astype(jnp.float32), bits=prec - 5 - 1
        )
        n_words = -(-prec * 8 // 32)
        words = ky_core.random_words(jax.random.key(0), (B,), n_words)

        def call(w=wq, wd=words, p=prec):
            return ky_core.ky_sample_fast(w, wd, n_bins=N, precision=p)[0]

        t = timeit(call)
        _, stats = ky_core.ky_sample_fast(wq, words, n_bins=N,
                                          precision=prec)
        bits = float(stats["bits_used"].mean())
        fb = float(stats["fallback"].mean())
        rows.append(csv_row(
            f"table2_ky_{label}", t / B * 1e6,
            f"samples/s={B/t:.3e};bits/sample={bits:.2f};fallback={fb:.4f}",
        ))

    # --- CDF baseline (normalize + cumsum + inverse search) ----------------
    def cdf_call():
        return draw_from_logits(logp, jax.random.key(1), "cdf")

    t_cdf = timeit(cdf_call)
    rows.append(csv_row(
        "table2_cdf_32b", t_cdf / B * 1e6, f"samples/s={B/t_cdf:.3e}"
    ))

    # --- full AIA pipeline (LUT-exp + KY) vs CDF --------------------------
    def aia_call():
        return draw_from_logits(logp, jax.random.key(2), "lut_ky",
                                tab, spec)

    t_aia = timeit(aia_call)
    rows.append(csv_row(
        "table2_lutky_pipeline", t_aia / B * 1e6,
        f"samples/s={B/t_aia:.3e};speedup_vs_cdf={t_cdf/t_aia:.2f}x",
    ))
    return rows


if __name__ == "__main__":
    run()
