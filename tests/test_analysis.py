"""repro.analysis: schedule-verifier fault injection (every mutation must be
caught with its structured rule id), kernel VMEM linter + batcher demotion,
repo-convention source lint, and the CLI / pipeline wiring."""

import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.analysis import Finding, RULES, Report
from repro.analysis import kernel_lint, source_lint
from repro.analysis import verify as verify_mod
from repro.analysis.__main__ import main as analysis_main
from repro.compile import ir as compile_ir
from repro.compile.passes import (
    named_pipeline,
    random_baseline_pipeline,
    run_pipeline,
)
from repro.compile.schedule import CommOp
from repro.core.graphs import (
    GridMRF,
    bn_repository_names,
    bn_repository_replica,
)

SRC_ROOT = pathlib.Path(source_lint.__file__).parents[1]  # .../src/repro


def _bn_ir(name="alarm", evidence=None):
    bn = bn_repository_replica(name)
    if evidence is not None:
        return compile_ir.from_bayesnet(bn, evidence)
    return compile_ir.from_bayesnet(bn, evidence_mode="runtime")


def _compiled(name="alarm", pipeline="default", evidence=None):
    g = _bn_ir(name, evidence)
    ctx = run_pipeline(g, (4, 4), named_pipeline(pipeline))
    return g, ctx


def _rules(findings):
    return {f.rule for f in findings}


def _verify(g, ctx, schedule=None, placement=..., diagnostics=None):
    return verify_mod.verify_schedule_static(
        g,
        schedule if schedule is not None else ctx.schedule,
        placement=ctx.placement if placement is ... else placement,
        diagnostics=diagnostics,
        adj=ctx.adj,
    )


# ---------------------------------------------------------------------------
# clean artifacts verify clean: the whole model zoo x both named pipelines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline", ["default", "runtime"])
def test_clean_sweep_zoo(pipeline):
    """Every zoo BN and a pair of MRFs lower cleanly through both named
    pipelines: VerifyPass runs by default, raises nothing, and no
    error-severity finding survives a full re-verify."""
    graphs = [_bn_ir(name) for name in bn_repository_names()]
    graphs += [
        compile_ir.from_mrf(GridMRF(8, 8, 3)),
        compile_ir.from_mrf(GridMRF(16, 16, 2)),
    ]
    for g in graphs:
        ctx = run_pipeline(g, (4, 4), named_pipeline(pipeline))
        assert ctx.diagnostics["verify"]["n_rules"] == len(
            verify_mod.VERIFY_RULES
        )
        findings = _verify(g, ctx, diagnostics=ctx.diagnostics)
        assert [f for f in findings if f.severity == "error"] == []


def test_verify_pass_is_default_in_every_named_pipeline():
    for pipeline in ("default", "runtime"):
        names = [p.name for p in named_pipeline(pipeline)]
        assert names[-1] == "verify"
    assert [p.name for p in random_baseline_pipeline()][-1] == "verify"


def test_verify_program_reports_clean():
    from repro.compile import clear_program_cache, compile_graph

    clear_program_cache()
    try:
        program = compile_graph(bn_repository_replica("survey"))
        report = verify_mod.verify_program(program)
        assert report.exit_code == 0
        assert report.meta["model"] == "survey"
        assert report.meta["n_rules"] == len(verify_mod.VERIFY_RULES)
        assert report.meta["verify_s"] >= 0
    finally:
        clear_program_cache()


# ---------------------------------------------------------------------------
# fault injection: every mutation is caught with its structured rule id
# ---------------------------------------------------------------------------


def test_injected_merged_rounds_race():
    """Merging two DSATUR rounds creates a same-round conflict edge — the
    parallel-Gibbs race the verifier exists to catch."""
    g, ctx = _compiled()
    r0, r1 = ctx.schedule.rounds[0], ctx.schedule.rounds[1]
    merged = dataclasses.replace(r0, nodes=r0.nodes + r1.nodes)
    bad = dataclasses.replace(
        ctx.schedule, rounds=(merged,) + ctx.schedule.rounds[2:]
    )
    assert "race-in-round" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_dropped_comm_op():
    g, ctx = _compiled()
    rounds = list(ctx.schedule.rounds)
    for i, r in enumerate(rounds):
        if r.comm:
            rounds[i] = dataclasses.replace(r, comm=r.comm[1:])
            break
    else:
        pytest.skip("no comm ops on this mesh")
    bad = dataclasses.replace(ctx.schedule, rounds=tuple(rounds))
    assert "comm-missing" in _rules(_verify(g, ctx, schedule=bad))


def _tamper_first_comm(schedule, **changes):
    rounds = list(schedule.rounds)
    for i, r in enumerate(rounds):
        if r.comm:
            op = dataclasses.replace(r.comm[0], **changes)
            rounds[i] = dataclasses.replace(r, comm=(op,) + r.comm[1:])
            return dataclasses.replace(schedule, rounds=tuple(rounds))
    pytest.skip("no comm ops on this mesh")


def test_injected_wrong_mechanism():
    g, ctx = _compiled()
    bad = _tamper_first_comm(ctx.schedule, mechanism="ppermute_halo")
    assert "comm-mechanism" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_wrong_bytes():
    g, ctx = _compiled()
    op0 = next(r.comm[0] for r in ctx.schedule.rounds if r.comm)
    bad = _tamper_first_comm(ctx.schedule, n_bytes=op0.n_bytes + 4)
    assert "comm-bytes" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_wrong_hops():
    g, ctx = _compiled()
    op0 = next(r.comm[0] for r in ctx.schedule.rounds if r.comm)
    bad = _tamper_first_comm(ctx.schedule, hops=op0.hops + 1)
    assert "comm-hops" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_spurious_comm_is_warning():
    """A core-0 -> core-0 op matches no cross-core edge: flagged, but as a
    warning (the cost model overcharges; the samples stay correct)."""
    g, ctx = _compiled()
    r0 = ctx.schedule.rounds[0]
    ghost = CommOp("psum_broadcast", 0, 0, 4, 0)
    bad = dataclasses.replace(
        ctx.schedule,
        rounds=(dataclasses.replace(r0, comm=r0.comm + (ghost,)),)
        + ctx.schedule.rounds[1:],
    )
    findings = _verify(g, ctx, schedule=bad)
    spurious = [f for f in findings if f.rule == "comm-spurious"]
    assert spurious and all(f.severity == "warning" for f in spurious)


def test_injected_clamped_node_in_round():
    g, ctx = _compiled(evidence={0: 0})
    assert g.evidence  # node 0 is clamped
    r0 = ctx.schedule.rounds[0]
    bad = dataclasses.replace(
        ctx.schedule,
        rounds=(dataclasses.replace(r0, nodes=r0.nodes + (0,)),)
        + ctx.schedule.rounds[1:],
    )
    assert "clamp-resampled" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_duplicate_node():
    g, ctx = _compiled()
    r0 = ctx.schedule.rounds[0]
    bad = dataclasses.replace(
        ctx.schedule,
        rounds=(dataclasses.replace(r0, nodes=r0.nodes + (r0.nodes[0],)),)
        + ctx.schedule.rounds[1:],
    )
    assert "node-dup" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_orphaned_and_unknown_nodes():
    g, ctx = _compiled()
    r0 = ctx.schedule.rounds[0]
    orphaned = dataclasses.replace(
        ctx.schedule,
        rounds=(dataclasses.replace(r0, nodes=r0.nodes[1:]),)
        + ctx.schedule.rounds[1:],
    )
    assert "coverage" in _rules(_verify(g, ctx, schedule=orphaned))
    unknown = dataclasses.replace(
        ctx.schedule,
        rounds=(dataclasses.replace(r0, nodes=r0.nodes + (g.n_nodes + 5,)),)
        + ctx.schedule.rounds[1:],
    )
    assert "coverage" in _rules(_verify(g, ctx, schedule=unknown))


def test_injected_off_mesh_placement():
    g, ctx = _compiled()
    arr = np.asarray(ctx.placement.placement).copy()
    arr[0] = ctx.schedule.n_cores  # one past the last core
    bad = dataclasses.replace(ctx.placement, placement=arr)
    assert "placement-range" in _rules(_verify(g, ctx, placement=bad))


def test_injected_core_load_tamper():
    g, ctx = _compiled()
    r0 = ctx.schedule.rounds[0]
    load = list(r0.core_load)
    load[0] += 1
    bad = dataclasses.replace(
        ctx.schedule,
        rounds=(dataclasses.replace(r0, core_load=tuple(load)),)
        + ctx.schedule.rounds[1:],
    )
    assert "placement-load" in _rules(_verify(g, ctx, schedule=bad))


def test_injected_cost_diagnostics_tamper():
    g, ctx = _compiled()
    diag = dict(ctx.diagnostics)
    diag["schedule_cost"] = dict(
        diag["schedule_cost"], total_cycles=diag["schedule_cost"]["total_cycles"] + 1
    )
    assert "cost-model" in _rules(_verify(g, ctx, diagnostics=diag))
    diag2 = dict(ctx.diagnostics, critical_core_load=10**6)
    assert "cost-model" in _rules(_verify(g, ctx, diagnostics=diag2))


def test_injected_full_parity_pins():
    """`from_mrf` rejects full-parity pins at construction; the verifier is
    the second line of defense for IRs that arrive by other routes."""
    mrf = GridMRF(4, 4, 3)
    g = compile_ir.from_mrf(mrf)
    ctx = run_pipeline(g, (2, 2), named_pipeline("default"))
    parity0 = tuple(
        (r * 4 + c, 0) for r in range(4) for c in range(4) if (r + c) % 2 == 0
    )
    pinned = dataclasses.replace(g, evidence=parity0)
    findings = verify_mod.verify_schedule_static(
        pinned, ctx.schedule, adj=ctx.adj
    )
    assert "pin-full-parity" in _rules(findings)


# ---------------------------------------------------------------------------
# the error type: explicit raise, -O survival, AssertionError back-compat
# ---------------------------------------------------------------------------


def test_raise_on_errors_is_structured_assertion_error():
    f = Finding(rule="race-in-round", loc="t", message="injected")
    with pytest.raises(verify_mod.ScheduleVerificationError) as ei:
        verify_mod.raise_on_errors([f])
    assert isinstance(ei.value, AssertionError)  # legacy pytest.raises sites
    assert ei.value.findings == (f,)
    assert "race-in-round" in str(ei.value)
    verify_mod.raise_on_errors([])  # no errors -> no raise


def test_coloring_violation_raises_under_python_O():
    """The checks that used to be `assert verify_coloring(...)` must still
    fire when assertions are stripped."""
    code = textwrap.dedent(
        """
        import numpy as np
        from repro.analysis import verify
        assert True is not None or True  # stripped under -O, proving the mode
        try:
            verify.require_proper_coloring(
                [{1}, {0}], np.zeros(2, np.int64), loc="sabotage"
            )
        except verify.ScheduleVerificationError as e:
            print("CAUGHT", e.findings[0].rule)
        """
    )
    env = dict(os.environ, PYTHONPATH=str(SRC_ROOT.parent))
    out = subprocess.run(
        [sys.executable, "-O", "-c", code],
        capture_output=True, text=True, env=env,
    )
    assert out.returncode == 0, out.stderr
    assert "CAUGHT race-in-round" in out.stdout


# ---------------------------------------------------------------------------
# kernel resource linter + batcher demotion
# ---------------------------------------------------------------------------


def test_ky_lanes_constant_pinned_to_kernel():
    from repro.kernels import ky_sampler

    assert kernel_lint.KY_LANES == ky_sampler.LANES


def test_bn_footprint_scales_with_chains_mrf_does_not():
    pigs = _bn_ir("pigs")
    wide = kernel_lint.bn_fused_footprint(pigs, 32)
    narrow = kernel_lint.bn_fused_footprint(pigs, 8)
    assert wide.total_bytes > narrow.total_bytes
    assert wide.total_bytes > kernel_lint.vmem_budget()  # the demotion story
    assert narrow.total_bytes <= kernel_lint.vmem_budget()
    mrf = compile_ir.from_mrf(GridMRF(64, 64, 4))
    a = kernel_lint.mrf_fused_footprint(mrf, 32)
    b = kernel_lint.mrf_fused_footprint(mrf, 1)
    # chains vmap the grid: per-step residency is one tile either way
    assert a.total_bytes == b.total_bytes
    assert a.total_bytes <= kernel_lint.vmem_budget()


def test_footprint_findings_severity():
    pigs = _bn_ir("pigs")
    fp = kernel_lint.bn_fused_footprint(pigs, 32)
    demoted = fp.findings()
    assert [f.rule for f in demoted] == ["vmem-budget"]
    assert demoted[0].severity == "warning"  # batcher guard makes it advisory
    forced = fp.findings(demotable=False)
    assert forced[0].severity == "error"
    # just over the pressure threshold, under the budget -> warning only
    pressured = fp.findings(budget=int(fp.total_bytes / 0.8))
    assert [f.rule for f in pressured] == ["vmem-pressure"]
    assert fp.findings(budget=fp.total_bytes * 10) == []


def test_batcher_demotes_oversized_fused_bucket():
    """The acceptance story: a deliberately oversized fused bucket is
    demoted by the static estimate inside `bucket_key`, not OOMed."""
    from repro.runtime import batcher

    g = _bn_ir("pigs")
    wide = batcher.Query(qid=0, model="pigs", n_chains=32)
    key = batcher.bucket_key(wide, g, "schedule", fused=True)
    assert key.fused is False  # ~18.6 MiB estimate vs the 16 MiB budget
    narrow = batcher.Query(qid=1, model="pigs", n_chains=8)
    assert batcher.bucket_key(narrow, g, "schedule", fused=True).fused is True
    # shrink the budget and the same narrow bucket demotes too
    prev = kernel_lint.set_vmem_budget(1 << 16)
    try:
        key = batcher.bucket_key(narrow, g, "schedule", fused=True)
        assert key.fused is False
    finally:
        kernel_lint.set_vmem_budget(prev)
    assert batcher.bucket_key(narrow, g, "schedule", fused=True).fused is True


def test_sharded_footprints_budget_the_per_shard_envelope():
    """Satellite: `shard_width > 1` budgets what each device of the
    shard_map body actually allocates — the local row slab plus halo rows
    on the grid, the owned node slice on the BN — not the whole model."""
    mrf = compile_ir.from_mrf(GridMRF(64, 64, 4))
    whole = kernel_lint.mrf_fused_footprint(mrf, 8)
    sh = kernel_lint.mrf_fused_footprint(mrf, 8, shard_width=4)
    assert sh.shard_width == 4
    assert "halo_rows" in sh.breakdown and "halo_rows" not in whole.breakdown
    # the 16-row local slab (64 rows / 4 shards) undercuts the 32-row
    # block_h tile even after paying the two halo rows
    assert sh.total_bytes < whole.total_bytes
    over = sh.findings(budget=1)
    assert over and "@sh4" in over[0].loc  # findings name the slice width
    pigs = _bn_ir("pigs")
    bn_whole = kernel_lint.bn_fused_footprint(pigs, 32)
    bn_sh = kernel_lint.bn_fused_footprint(pigs, 32, shard_width=4)
    assert bn_sh.total_bytes < bn_whole.total_bytes
    # the pigs-class demotion story inverts on a slice: the whole envelope
    # busts the budget, the per-device owned node slice fits
    assert bn_whole.total_bytes > kernel_lint.vmem_budget()
    assert bn_sh.total_bytes <= kernel_lint.vmem_budget()


def test_fused_fits_judges_sharded_buckets_per_shard():
    """The demotion oracle keys on the slice width: a bucket too wide for
    one core's VMEM stays fused when it will run the shard_map body."""
    from repro.runtime import batcher

    g = _bn_ir("pigs")
    assert not kernel_lint.fused_fits(g, 32)
    assert kernel_lint.fused_fits(g, 32, shard_width=4)
    wide = batcher.Query(qid=0, model="pigs", n_chains=32)
    assert batcher.bucket_key(wide, g, "schedule", fused=True).fused is False
    assert batcher.bucket_key(
        wide, g, "schedule", fused=True, shard_width=4
    ).fused is True


# ---------------------------------------------------------------------------
# repo-convention source lint
# ---------------------------------------------------------------------------


def _lint_snippet(tmp_path, rel, code):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return source_lint.lint_file(p, root=tmp_path)


def test_lint_wallclock_in_sim_and_pragma(tmp_path):
    code = """
        import time

        def tick():
            return time.perf_counter()
        """
    found = _lint_snippet(tmp_path, "repro/runtime/engine.py", code)
    assert [f.rule for f in found] == ["wallclock-in-sim"]
    allowed = code.replace(
        "time.perf_counter()",
        "time.perf_counter()  # lint: allow[wallclock-in-sim]",
    )
    assert _lint_snippet(tmp_path, "repro/runtime/engine.py", allowed) == []
    # same call outside the sim scope is fine
    assert _lint_snippet(tmp_path, "repro/launch/bench.py", code) == []


def test_lint_compat_import(tmp_path):
    code = """
        from jax.experimental import pallas as pl
        """
    found = _lint_snippet(tmp_path, "repro/kernels/new_kernel.py", code)
    assert [f.rule for f in found] == ["compat-import"]
    # compat.py itself is the one allowed importer
    assert _lint_snippet(tmp_path, "repro/core/compat.py", code) == []


def test_lint_pyrandom_in_jit(tmp_path):
    code = """
        import functools
        import jax
        import numpy as np

        @jax.jit
        def bad(x):
            return x + np.random.rand()

        @functools.partial(jax.jit, static_argnames=("n",))
        def also_bad(x, n):
            return x + np.random.rand()

        def fine(x):
            return x + np.random.rand()
        """
    found = _lint_snippet(tmp_path, "repro/core/newmod.py", code)
    assert [f.rule for f in found] == ["pyrandom-in-jit"] * 2


def test_lint_bare_assert_scope(tmp_path):
    code = """
        def check(x):
            assert x > 0
        """
    found = _lint_snippet(tmp_path, "repro/compile/newpass.py", code)
    assert [f.rule for f in found] == ["bare-assert"]
    # tests/benchmark-style modules outside the pipeline scope are exempt
    assert _lint_snippet(tmp_path, "repro/runtime/helpers.py", code) == []


def test_lint_syntax_error_is_a_finding(tmp_path):
    found = _lint_snippet(tmp_path, "repro/compile/broken.py", "def f(:\n")
    assert len(found) == 1 and found[0].severity == "error"


def test_repo_lints_clean():
    """The shipped tree obeys its own conventions (pragmas included)."""
    findings = source_lint.lint_repo(SRC_ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# finding model + report spine
# ---------------------------------------------------------------------------


def test_finding_model():
    with pytest.raises(ValueError):
        Finding(rule="no-such-rule", loc="x", message="m")
    f = Finding(rule="race-in-round", loc="m:round 0", message="boom")
    assert f.severity == RULES["race-in-round"][0] == "error"
    assert "error[race-in-round]" in f.render()
    r = Report(findings=[f])
    assert r.exit_code == 1 and len(r.errors) == 1
    d = json.loads(r.to_json())
    assert d["schema"] == 1 and d["n_errors"] == 1
    assert d["findings"][0]["rule"] == "race-in-round"
    assert Report().exit_code == 0


# ---------------------------------------------------------------------------
# CLI: exit codes, JSON artifact, verification table
# ---------------------------------------------------------------------------


def test_cli_clean_repo_exits_zero(capsys, tmp_path):
    out = tmp_path / "findings.json"
    rc = analysis_main([
        "--skip-verify", "--skip-kernels", "--format", "json",
        "--out", str(out),
    ])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["n_errors"] == 0
    assert data["meta"]["analyzers"] == ["source_lint"]


def test_cli_injected_violation_exits_nonzero(tmp_path):
    bad = tmp_path / "repro" / "compile" / "sabotage.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(x):\n    assert x\n")
    out = tmp_path / "findings.json"
    rc = analysis_main([
        "--skip-verify", "--skip-kernels", "--root", str(tmp_path),
        "--format", "json", "--out", str(out),
    ])
    assert rc == 1
    data = json.loads(out.read_text())
    assert data["n_errors"] == 1
    assert data["findings"][0]["rule"] == "bare-assert"


def test_cli_verify_sweep_and_table(capsys):
    rc = analysis_main(["--skip-lint", "--skip-kernels", "--models", "survey"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "| survey | bn | default |" in text
    assert "clean" in text


def test_verification_table_renders():
    from repro.launch.report import verification_table

    rows = [{
        "model": "alarm", "kind": "bn", "pipeline": "runtime",
        "n_nodes": 37, "n_rounds": 5, "n_rules": 14, "n_findings": 0,
        "verify_s": 0.0004,
    }]
    table = verification_table(rows)
    assert "| alarm | bn | runtime | 37 | 5 | 14 | clean |" in table
