"""Schedule-direct execution backend: bit-exactness with the eager engines
on BN and MRF workloads for every sampler, legality re-verification at
lowering, the fused Pallas round path, and backend argument plumbing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import (
    BNScheduleExec,
    MRFScheduleExec,
    ScheduleLoweringError,
    clear_program_cache,
    compile_graph,
    cross_check,
    lower_schedule,
)
from repro.compile.backend import BackendMismatch
from repro.compile.schedule import Round, Schedule, verify_schedule
from repro.core import mrf as mrf_mod
from repro.core.draws import SAMPLERS
from repro.core.graphs import GridMRF, bn_repository_replica


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_program_cache()
    yield
    clear_program_cache()


# ---------------------------------------------------------------------------
# Bit-exactness: schedule backend == eager backend, every sampler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workload", ["survey", "alarm"])
@pytest.mark.parametrize("sampler", SAMPLERS)
def test_bn_schedule_bit_exact(workload, sampler):
    prog = compile_graph(bn_repository_replica(workload), evidence={0: 0})
    kwargs = dict(n_chains=4, n_iters=12, burn_in=3, sampler=sampler)
    marg_e, vals_e = prog.run(jax.random.key(9), backend="eager", **kwargs)
    marg_s, vals_s = prog.run(jax.random.key(9), backend="schedule", **kwargs)
    np.testing.assert_array_equal(np.asarray(vals_e), np.asarray(vals_s))
    np.testing.assert_array_equal(np.asarray(marg_e), np.asarray(marg_s))


@pytest.mark.parametrize("sampler", SAMPLERS)
def test_mrf_schedule_bit_exact(sampler):
    mrf = GridMRF(8, 8, 3, theta=1.2, h=2.0)
    _, noisy = mrf_mod.make_denoising_problem(8, 8, 3, 0.25, seed=1)
    ev = jnp.asarray(noisy)
    prog = compile_graph(mrf)
    kwargs = dict(n_chains=2, n_iters=8, sampler=sampler, evidence=ev)
    lab_e = prog.run(jax.random.key(5), backend="eager", **kwargs)
    lab_s = prog.run(jax.random.key(5), backend="schedule", **kwargs)
    np.testing.assert_array_equal(np.asarray(lab_e), np.asarray(lab_s))


def test_mrf_fused_rounds_bit_exact():
    """The Pallas round path derives its random words exactly as
    draw_from_logits does, so fused lut_ky == eager lut_ky, bit for bit."""
    mrf = GridMRF(8, 8, 4, theta=1.0, h=1.5)
    _, noisy = mrf_mod.make_denoising_problem(8, 8, 4, 0.3, seed=2)
    ev = jnp.asarray(noisy)
    prog = compile_graph(mrf)
    lab_e = prog.run(jax.random.key(3), n_chains=2, n_iters=5, evidence=ev,
                     backend="eager")
    lab_f = prog.run(
        jax.random.key(3), n_chains=2, n_iters=5, evidence=ev,
        backend="schedule", fused=True,
    )
    np.testing.assert_array_equal(np.asarray(lab_e), np.asarray(lab_f))


def test_fused_requires_schedule_backend_and_fused_samplers():
    mrf_prog = compile_graph(GridMRF(4, 4, 2))
    ev = jnp.zeros((4, 4), jnp.int32)
    with pytest.raises(ValueError):  # fused needs the schedule backend
        mrf_prog.run(jax.random.key(0), evidence=ev, fused=True,
                     backend="eager")
    with pytest.raises(ValueError):
        mrf_prog.run(
            jax.random.key(0), evidence=ev, backend="schedule", fused=True,
            sampler="cdf",
        )
    # BN fused rounds exist since the bn_gibbs kernel landed: lut_ky runs,
    # samplers outside the kernel's datapath still fail loudly
    bn_prog = compile_graph(bn_repository_replica("survey"))
    bn_prog.run(jax.random.key(0), n_chains=2, n_iters=2,
                backend="schedule", fused=True)
    with pytest.raises(ValueError):
        bn_prog.run(jax.random.key(0), backend="schedule", fused=True,
                    sampler="gumbel")


def test_unknown_backend_rejected():
    prog = compile_graph(bn_repository_replica("survey"))
    with pytest.raises(ValueError):
        prog.run(jax.random.key(0), backend="pallas")


# ---------------------------------------------------------------------------
# Lowering: legality re-verification + structure checks
# ---------------------------------------------------------------------------


def test_lowering_reverifies_legality():
    """A corrupted schedule (node scheduled twice) must fail at lowering,
    before any round-ordered execution happens."""
    prog = compile_graph(bn_repository_replica("survey"))
    r0 = prog.schedule.rounds[0]
    dup = dataclasses.replace(
        prog.schedule.rounds[1],
        nodes=prog.schedule.rounds[1].nodes + (r0.nodes[0],),
    )
    bad_sched = Schedule(
        rounds=(r0, dup) + prog.schedule.rounds[2:],
        mesh_shape=prog.schedule.mesh_shape,
    )
    bad_prog = dataclasses.replace(prog, schedule=bad_sched)
    with pytest.raises(AssertionError):
        lower_schedule(bad_prog)


def test_legality_holds_after_round_ordered_execution():
    """Executing via the schedule does not mutate it: the rounds the backend
    ran from still verify as a legal partition afterwards."""
    for model, ev in ((bn_repository_replica("alarm"), {0: 1}),
                      (GridMRF(8, 8, 2), None)):
        prog = compile_graph(model, evidence=ev)
        if prog.kind == "bn":
            prog.run(jax.random.key(0), n_chains=2, n_iters=4,
                     backend="schedule")
        else:
            prog.run(jax.random.key(0), n_chains=2, n_iters=4,
                     evidence=jnp.zeros((8, 8), jnp.int32),
                     backend="schedule")
        verify_schedule(prog.ir, prog.schedule)


def test_bn_lowering_builds_round_ordered_groups():
    prog = compile_graph(bn_repository_replica("alarm"), evidence={3: 0})
    ex = lower_schedule(prog)
    assert isinstance(ex, BNScheduleExec)
    assert len(ex.round_groups) == len(prog.schedule.rounds)
    for g, r in zip(ex.round_groups, prog.schedule.rounds):
        assert tuple(int(v) for v in np.asarray(g.nodes)) == r.nodes


def test_mrf_lowering_extracts_checkerboard_parities():
    prog = compile_graph(GridMRF(6, 6, 3))
    ex = lower_schedule(prog)
    assert isinstance(ex, MRFScheduleExec)
    assert sorted(ex.parities) == [0, 1]
    for parity, r in zip(ex.parities, prog.schedule.rounds):
        for v in r.nodes:
            assert (v // 6 + v % 6) % 2 == parity


def test_mrf_partial_parity_round_rejected():
    """A legal schedule that splits one parity class into two rounds has no
    lowering in the whole-parity grid path: it must fail loudly at lowering,
    not execute a different plan than was compiled."""
    prog = compile_graph(GridMRF(4, 4, 2))
    r0, r1 = prog.schedule.rounds
    half = len(r0.nodes) // 2
    split = (
        dataclasses.replace(r0, nodes=r0.nodes[:half]),
        dataclasses.replace(r0, color=2, nodes=r0.nodes[half:]),
        r1,
    )
    bad_prog = dataclasses.replace(
        prog, schedule=Schedule(rounds=split, mesh_shape=(4, 4))
    )
    with pytest.raises(ScheduleLoweringError):
        lower_schedule(bad_prog)


def test_mrf_mixed_parity_round_rejected():
    prog = compile_graph(GridMRF(4, 4, 2))
    r0, r1 = prog.schedule.rounds
    merged = Round(
        color=0, nodes=tuple(sorted(r0.nodes + r1.nodes)), comm=(),
        core_load=r0.core_load,
    )
    bad_prog = dataclasses.replace(
        prog,
        schedule=Schedule(rounds=(merged,), mesh_shape=(4, 4)),
    )
    with pytest.raises((ScheduleLoweringError, AssertionError)):
        lower_schedule(bad_prog)


# ---------------------------------------------------------------------------
# Cross-check: the compile-time bit-exactness guarantee
# ---------------------------------------------------------------------------


def test_cross_check_passes_and_is_cached():
    prog = compile_graph(
        bn_repository_replica("survey"), evidence={1: 0}, cross_check=True,
    )
    ex = prog.schedule_executable()
    assert prog.schedule_executable() is ex  # lowered + checked once


def test_cross_check_catches_divergent_lowering():
    """An executable whose rounds differ from the schedule's (here: reversed
    round order) must be flagged as a backend mismatch."""
    prog = compile_graph(bn_repository_replica("alarm"), evidence={0: 0})
    ex = lower_schedule(prog)
    ex.round_groups = list(reversed(ex.round_groups))
    with pytest.raises(BackendMismatch):
        cross_check(prog, ex)
