"""DSATUR coloring + mesh mapping: correctness and paper-claim properties."""

import numpy as np
import pytest

try:  # hypothesis is optional (offline containers): property tests skip
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import coloring, mapping
from repro.core.graphs import (
    GridMRF,
    bn_repository_names,
    bn_repository_replica,
    random_bayesnet,
)


def _random_adj(n, p, seed):
    rng = np.random.default_rng(seed)
    adj = [set() for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p:
                adj[i].add(j)
                adj[j].add(i)
    return adj


def _check_proper_coloring(n, p, seed):
    adj = _random_adj(n, p, seed)
    colors = coloring.dsatur(adj)
    assert coloring.verify_coloring(adj, colors)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 40), st.floats(0.0, 0.9), st.integers(0, 10**6))
    def test_property_proper_coloring(n, p, seed):
        """Hypothesis: DSATUR always yields a proper coloring."""
        _check_proper_coloring(n, p, seed)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_proper_coloring():
        pass


def test_grid_needs_two_colors():
    """Paper Sec. II-B.2: 2-D grids = 2-color checkerboard."""
    mrf = GridMRF(8, 8, 2)
    colors = coloring.dsatur(mrf.adjacency())
    assert colors.max() + 1 == 2
    assert coloring.verify_coloring(mrf.adjacency(), colors)
    np.testing.assert_array_equal(
        colors.reshape(8, 8), mrf.checkerboard_colors()
    )


@pytest.mark.parametrize("name", bn_repository_names())
def test_bn_replicas_color_like_paper(name):
    """Fig. 9: the benchmark BNs color with a small number of colors (the
    paper reports <= 6 on the moral graphs of its replicas)."""
    bn = bn_repository_replica(name)
    adj = bn.moral_adjacency()
    colors = coloring.dsatur(adj)
    assert coloring.verify_coloring(adj, colors)
    assert colors.max() + 1 <= 12  # small vs n_nodes
    stats = coloring.color_stats(colors)
    assert stats["n_colors"] < bn.n_nodes or bn.n_nodes <= 6


def test_speedup_scales_for_large_graphs():
    """Fig. 9 line graphs: big sparse graphs scale with cores, tiny ones
    saturate."""
    big = bn_repository_replica("pigs")
    small = bn_repository_replica("cancer")
    cb = coloring.dsatur(big.moral_adjacency())
    cs = coloring.dsatur(small.moral_adjacency())
    assert coloring.parallel_speedup(cb, 16) > 8.0
    assert coloring.parallel_speedup(cs, 16) < 4.0
    # more cores never hurt
    for c in (cb, cs):
        seq = [coloring.parallel_speedup(c, k) for k in (1, 2, 4, 8, 16)]
        assert all(b >= a - 1e-9 for a, b in zip(seq, seq[1:]))


def test_markov_blanket_and_moral_graph():
    bn = random_bayesnet(15, max_parents=3, seed=2)
    adj = bn.moral_adjacency()
    for i in range(bn.n_nodes):
        assert adj[i] == bn.markov_blanket(i)
        assert i not in adj[i]
        for j in adj[i]:
            assert i in adj[j]


def test_greedy_map_beats_random():
    """Sec. IV-B: the placement heuristic reduces communication distance."""
    bn = bn_repository_replica("alarm")
    adj = bn.moral_adjacency()
    colors = coloring.dsatur(adj)
    pl = mapping.greedy_map(adj, colors, (4, 4))
    costs_rand = [
        mapping.comm_cost(adj, mapping.random_map(bn.n_nodes, (4, 4), s))
        for s in range(5)
    ]
    assert mapping.comm_cost(adj, pl) < min(costs_rand)


def test_greedy_map_balances_load():
    bn = bn_repository_replica("hepar2")
    adj = bn.moral_adjacency()
    colors = coloring.dsatur(adj)
    pl = mapping.greedy_map(adj, colors, (4, 4))
    for c in range(colors.max() + 1):
        per_core = np.bincount(pl.placement[colors == c], minlength=16)
        cap = -(-int((colors == c).sum()) // 16)
        assert per_core.max() <= cap
