"""Optimizer, checkpointing, data pipeline, and the fault-tolerant trainer."""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.optim.compression import (
    dequantize_int8,
    quantize_int8,
)

ROOT = Path(__file__).resolve().parent.parent


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    target = {"w": jnp.asarray([3.0, -2.0, 0.5])}
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    for _ in range(200):
        grads = jax.tree.map(lambda p, t: p - t, params, target)
        params, state, _ = adamw.update(params, grads, state, cfg)
    np.testing.assert_allclose(params["w"], target["w"], atol=1e-2)


def test_adamw_clips_global_norm():
    cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_bf16_moments_mode():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((8, 8))}
    state = adamw.init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((8, 8), 0.1)}
    p2, s2, _ = adamw.update(params, g, state, cfg)
    assert s2["v"]["w"].dtype == jnp.bfloat16
    assert jnp.isfinite(p2["w"]).all()


def test_int8_quantization_roundtrip():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x).max()
    assert float(err) <= float(scale) * 0.51


def test_checkpoint_roundtrip_and_rotation(tmp_path):
    tree = {
        "a": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "b": [np.ones(5, np.int32), np.zeros((2, 2), np.float64)],
    }
    d = str(tmp_path)
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, tree, extra={"note": f"s{step}"})
    ckpt.rotate(d, keep_last=2)
    assert ckpt.latest_step(d) == 40
    manifest, restored = ckpt.restore(d, 40, like=tree)
    assert manifest["extra"]["note"] == "s40"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)
    # rotated away
    assert not os.path.isdir(os.path.join(d, "ckpt_0000000010"))


def test_data_pipeline_deterministic_and_shaped():
    ds = SyntheticLM(vocab=1000, seq_len=32, global_batch=4, seed=7)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
    # next-token alignment
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert not np.array_equal(ds.batch(6)["tokens"], b1["tokens"])


@pytest.mark.slow
def test_trainer_checkpoint_restart_end_to_end(tmp_path):
    """Kill-and-resume: the trainer restarts from its checkpoint and the
    loss keeps improving (fault-tolerance deliverable)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "yi-9b",
            "--reduced", "--seq", "64", "--global-batch", "4",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
            "--log-every", "5"]
    r1 = subprocess.run(base + ["--steps", "20"], env=env,
                        capture_output=True, text=True, timeout=900)
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert ckpt.latest_step(str(tmp_path)) == 20
    r2 = subprocess.run(base + ["--steps", "40", "--resume"], env=env,
                        capture_output=True, text=True, timeout=900)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 20" in r2.stdout
    assert ckpt.latest_step(str(tmp_path)) == 40
    first = float(r1.stdout.split("loss ")[1].split()[0])
    last = float(r2.stdout.strip().rsplit("-> ", 1)[1])
    assert last < first
