"""repro.obs: the tracing/attribution acceptance gates.

  * off-path cost model: disabled tracing allocates nothing and returns the
    shared null span;
  * ring-buffer bounds and the `dropped` counter;
  * **JSONL byte-determinism** — two cold-cache same-seed engine passes
    (fresh tracer each, program cache cleared) produce byte-identical
    event logs after wall stripping;
  * event counts reconcile with `RuntimeMetrics` (one dispatch span per
    BatchRecord, real-query counts agree);
  * Perfetto structure: one sim lane per worker, counter tracks, >= 1 span
    per BatchRecord;
  * attribution coverage: every dispatched program has round costs (no
    gaps), comm rows name the schedule's mechanism;
  * the `worker_stall_frac` satellite: WorkerPool stall accounting and its
    surfacing in `metrics.table()`;
  * the CLI round trip: `python -m repro.runtime --trace-out` writes all
    three artifacts and `python -m repro.obs` validates them.
"""

import json
import os

import numpy as np
import pytest

from repro import obs
from repro.compile import clear_program_cache
from repro.launch.report import attribution_table
from repro.obs import attrib, export, tracer
from repro.obs.tracer import NULL_SPAN, Tracer
from repro.runtime import Engine, EngineConfig, zipf_trace
from repro.runtime.executor import WorkerPool


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test leaves the process with tracing disabled and the program
    cache cold (traced compile spans must not leak across tests)."""
    obs.disable()
    clear_program_cache()
    yield
    obs.disable()
    clear_program_cache()


def _engine_pass(n=24, seed=3, **cfg):
    models, queries = zipf_trace(n, quick=True, seed=seed,
                                 mean_interarrival_s=5e-5)
    eng = Engine(models, EngineConfig(pad_sizes=(8,), max_batch=8, **cfg))
    eng.submit(queries)
    results = eng.run()
    return eng, results


def _traced_pass(**cfg):
    clear_program_cache()
    tr = obs.enable()
    eng, results = _engine_pass(**cfg)
    events = list(tr.events)
    obs.disable()
    return eng, results, events


# ---------------------------------------------------------------------------
# off-path + ring buffer
# ---------------------------------------------------------------------------


def test_disabled_tracing_is_a_null_span():
    assert not obs.enabled()
    s = tracer.span("x", foo=1)
    assert s is NULL_SPAN  # the shared instance: no allocation when off
    with s as live:
        live.set(a=1)
        live.set_wall(b=2)
    tracer.instant("x")  # all silently dropped
    tracer.counter("x", 1)
    tracer.sim_span("x", 0.0, 1.0)
    assert obs.get() is None


def test_enable_disable_roundtrip():
    tr = obs.enable()
    assert obs.enabled() and obs.get() is tr
    with tracer.span("s", cat="test", k=1) as s:
        s.set(extra=2)
        s.set_wall(w=0.5)
    assert len(tr.events) == 1
    ev = tr.events[0]
    assert ev.kind == "span" and ev.name == "s"
    assert ev.args == {"k": 1, "extra": 2} and ev.wargs == {"w": 0.5}
    assert ev.wall_t1 >= ev.wall_t0
    obs.disable()
    assert not obs.enabled()


def test_ring_buffer_evicts_oldest_and_counts_dropped():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.emit("instant", f"e{i}", "test")
    assert len(tr.events) == 4
    assert tr.dropped == 6
    assert [e.name for e in tr.events] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert len(tr.events) == 0 and tr.dropped == 0


def test_tracer_rejects_degenerate_capacity():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


# ---------------------------------------------------------------------------
# the determinism gate: byte-identical JSONL across same-seed runs
# ---------------------------------------------------------------------------


def test_jsonl_byte_identical_across_same_seed_runs():
    _, r1, ev1 = _traced_pass(n_workers=2)
    _, r2, ev2 = _traced_pass(n_workers=2)
    j1, j2 = export.to_jsonl(ev1), export.to_jsonl(ev2)
    assert j1 == j2  # byte-for-byte: wall fields are gone, sim fields agree
    assert len(j1.splitlines()) == len(ev1) > 0
    for qid in r1:
        assert (r1[qid].final_state == r2[qid].final_state).all()


def test_jsonl_strips_wall_and_roundtrips(tmp_path):
    _, _, events = _traced_pass()
    path = os.path.join(tmp_path, "t.jsonl")
    export.write_jsonl(path, events)
    loaded = export.load_jsonl(path)
    assert len(loaded) == len(events)
    for rec in loaded:
        assert "wall_t0" not in rec and "wall_t1" not in rec
        assert "wargs" not in rec
    # the round trip is exact: re-serializing the loaded dicts matches
    relines = [json.dumps(r, sort_keys=True) for r in loaded]
    assert "\n".join(relines) + "\n" == export.to_jsonl(events)


# ---------------------------------------------------------------------------
# reconciliation with RuntimeMetrics
# ---------------------------------------------------------------------------


def test_event_counts_reconcile_with_metrics():
    eng, results, events = _traced_pass(n_workers=2)
    m = eng.metrics
    dicts = export.events_as_dicts(events)
    disp = [e for e in dicts
            if e["name"] == "dispatch" and e["kind"] == "span"]
    # exactly one dispatch span per BatchRecord (lane spans are separate)
    assert len(disp) == len(m.batch_records) > 0
    assert (sum(e["args"]["n_real"] for e in disp)
            == sum(b.n_real for b in m.batch_records))
    flushes = [e for e in dicts if e["name"] == "flush"]
    assert len(flushes) == len(m.batch_records)
    # dispatch spans carry the prediction the pool was booked with
    by_start = sorted(disp, key=lambda e: (e["sim_t0"], e["seq"]))
    recs = sorted(m.batch_records, key=lambda b: (b.start_s, b.finish_s))
    assert [round(e["args"]["service_s"], 12) for e in by_start] == \
        [round(b.service_s, 12) for b in recs]
    # kernel entry spans (bn_rounds/mrf_rounds host entries — here reached
    # via the first-lowering cross-checks; bucket dispatches enter through
    # execute_bucket instead)
    kernels = [e for e in dicts if e["cat"] == "kernel"]
    assert kernels
    assert {e["name"] for e in kernels} <= {"bn_rounds", "mrf_rounds"}
    # batcher pad decisions on every vmap dispatch
    buckets = [e for e in dicts if e["name"] == "execute_bucket"]
    vmap_recs = [b for b in m.batch_records if b.route == "vmap"]
    assert len(buckets) == len(vmap_recs)
    for e in buckets:
        assert 0.0 < e["args"]["pad_efficiency"] <= 1.0
        assert e["args"]["n_real"] <= e["args"]["n_padded"]


def test_run_start_declares_worker_lanes():
    _, _, events = _traced_pass(n_workers=4)
    starts = [e for e in events if e.name == "run_start"]
    assert len(starts) == 1 and starts[0].args["n_workers"] == 4


# ---------------------------------------------------------------------------
# Perfetto structure
# ---------------------------------------------------------------------------


def test_perfetto_worker_lanes_and_span_coverage():
    eng, _, events = _traced_pass(n_workers=4)
    doc = export.to_perfetto(events)
    te = doc["traceEvents"]
    lanes = {e["args"]["name"]: e["tid"] for e in te
             if e.get("ph") == "M" and e["name"] == "thread_name"
             and e["pid"] == export.SIM_PID}
    # one lane per engine worker, even the ones that stayed idle
    for w in range(4):
        assert lanes.get(f"worker{w}") == 10 + w
    disp = [e for e in te if e.get("ph") == "X" and e["name"] == "dispatch"]
    assert len(disp) == len(eng.metrics.batch_records) > 0
    for e in disp:
        assert e["pid"] == export.SIM_PID
        assert e["tid"] in lanes.values()
        assert e["dur"] >= 0.0
        # wall-derived annotation rides along in the viewable export
        assert "measured_s" in e["args"]
    counters = {e["name"] for e in te if e.get("ph") == "C"}
    assert "queue_depth" in counters
    # host process: compile spans land under the wall clock
    host = [e for e in te if e.get("pid") == export.HOST_PID
            and e.get("ph") == "X"]
    assert any(e["name"].startswith("pass:") for e in host)
    assert any(e["name"] == "lower_schedule" for e in host)
    assert any(e["name"] == "cross_check" for e in host)
    assert json.dumps(doc)  # serializable as-is


def test_perfetto_loads_from_cli_artifact(tmp_path):
    path = os.path.join(tmp_path, "trace.json")
    from repro.runtime.__main__ import main as runtime_main

    # enough queries that the zipf trace clears the CLI's own >= 0.9
    # cache-hit acceptance gate (4 models -> 4 cold misses)
    rc = runtime_main([
        "--quick", "--trace", "zipf", "--queries", "48",
        "--workers", "2", "--trace-out", path,
    ])
    assert rc == 0
    assert not obs.enabled()  # the CLI turns tracing back off
    doc = json.load(open(path))
    assert any(e.get("name") == "dispatch" for e in doc["traceEvents"])
    base = os.path.splitext(path)[0]
    assert os.path.exists(base + ".jsonl")
    sidecar = json.load(open(base + ".attrib.json"))
    assert sidecar["gaps"] == [] and sidecar["rows"]
    # the CI checker accepts both artifact forms
    from repro.obs.__main__ import main as obs_main

    assert obs_main([base + ".jsonl"]) == 0
    assert obs_main([base + ".attrib.json"]) == 0


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------


def test_attribution_covers_every_dispatch():
    eng, _, events = _traced_pass(n_workers=2)
    dicts = export.events_as_dicts(events)
    rows, gaps = attrib.attribution(dicts)
    assert gaps == []
    rounds = [r for r in rows if r["kind"] == "round"]
    comms = [r for r in rows if r["kind"] == "comm"]
    assert rounds and comms
    # per program: round shares sum to 1, dispatch counts match the run
    by_prog = {}
    for r in rounds:
        by_prog.setdefault(r["program"], []).append(r)
    n_disp = 0
    for prog, rr in by_prog.items():
        assert sum(r["share"] for r in rr) == pytest.approx(1.0)
        counts = {r["n_dispatches"] for r in rr}
        assert len(counts) == 1  # every round of a program sees them all
        n_disp += counts.pop()
    # each dispatch belongs to one program: the per-program counts add up
    # to the run's batch records — attribution covers every dispatched round
    assert n_disp == len(eng.metrics.batch_records)
    for r in rounds:
        assert r["pred_s"] > 0.0
        assert r["meas_s"] > 0.0 and r["n_measured"] > 0  # walls recorded
        assert r["rel_err"] is not None
    for c in comms:
        assert c["mechanism"] in ("ppermute_halo", "psum_broadcast")
        assert c["comm_cycles"] > 0 and c["n_comm_ops"] > 0
    cov = attrib.coverage(dicts)
    assert cov["n_gaps"] == 0
    assert cov["n_dispatch_spans"] == len(eng.metrics.batch_records)


def test_attribution_from_stripped_jsonl_has_no_measured(tmp_path):
    _, _, events = _traced_pass()
    path = os.path.join(tmp_path, "t.jsonl")
    export.write_jsonl(path, events)
    rows, gaps = attrib.attribution(export.load_jsonl(path))
    assert gaps == []
    for r in rows:
        if r["kind"] == "round":
            assert r["n_measured"] == 0 and r["rel_err"] is None
    table = attribution_table(rows)
    assert "n/a" in table and "| round |" in table


def test_attribution_flags_gaps():
    rows, gaps = attrib.attribution([
        {"seq": 0, "kind": "span", "name": "dispatch", "cat": "runtime",
         "args": {"program": "p1", "model": "m", "service_s": 0.5}},
    ])
    assert rows == []
    assert len(gaps) == 1 and gaps[0]["program"] == "p1"
    assert gaps[0]["n_dispatches"] == 1
    from repro.obs.__main__ import check_rows

    assert check_rows(rows, gaps) == 2  # the CI step fails on holes


# ---------------------------------------------------------------------------
# the worker_stall_frac satellite
# ---------------------------------------------------------------------------


def test_worker_pool_stall_accounting():
    pool = WorkerPool(2)
    # work arrived at t=3, worker 0 free since t=0, dispatch starts at t=5:
    # 2s of idle-while-work-waited
    pool.commit((0,), 5.0, 7.0, ready_t=3.0)
    assert pool.stall_s[0] == pytest.approx(2.0)
    # back-compat default: no ready time, no stall charged
    pool.commit((1,), 4.0, 6.0)
    assert pool.stall_s[1] == 0.0
    # busy until 7; work ready at 6; next start at 7 -> no gap, no stall
    pool.commit((0,), 7.0, 8.0, ready_t=6.0)
    assert pool.stall_s[0] == pytest.approx(2.0)
    # idle 8->10 but work only arrived at 9.5: half a second of stall
    pool.commit((0,), 10.0, 11.0, ready_t=9.5)
    assert pool.stall_s[0] == pytest.approx(2.5)
    assert pool.busy_s[0] == pytest.approx(2.0 + 1.0 + 1.0)


def test_engine_surfaces_worker_stall_frac():
    eng, _ = _engine_pass(n_workers=2)
    s = eng.metrics.summary()
    assert len(s["worker_stall_frac"]) == 2
    for stall, util in zip(s["worker_stall_frac"], s["worker_util"]):
        assert 0.0 <= stall <= 1.0
        assert stall + util <= 1.0 + 1e-9  # stall is a slice of idle time
    # the dashboard renders it (column between util and shed)
    assert "| stall |" in eng.metrics.table().splitlines()[0]


def test_stall_frac_deterministic_across_replays():
    eng1, _ = _engine_pass(seed=9, n_workers=2)
    clear_program_cache()
    eng2, _ = _engine_pass(seed=9, n_workers=2)
    assert eng1.metrics.summary()["worker_stall_frac"] == \
        eng2.metrics.summary()["worker_stall_frac"]


# ---------------------------------------------------------------------------
# tracing must not change what the engine computes
# ---------------------------------------------------------------------------


def test_tracing_does_not_change_results_or_sim_metrics():
    eng_off, r_off = _engine_pass(seed=4, n_workers=2)
    clear_program_cache()
    obs.enable()
    eng_on, r_on = _engine_pass(seed=4, n_workers=2)
    obs.disable()
    s_off, s_on = eng_off.metrics.summary(), eng_on.metrics.summary()
    for k in s_off:
        if k not in ("wall_s", "calib_median_err"):
            assert s_off[k] == s_on[k], k
    for qid in r_off:
        assert (r_off[qid].final_state == r_on[qid].final_state).all()
        m = r_off[qid].marginals
        if m is not None:
            assert (np.asarray(m) == np.asarray(r_on[qid].marginals)).all()
