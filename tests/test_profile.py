"""repro.obs.profile + repro.obs.timeseries: the roofline-profiler gates.

  * typed series semantics: counter monotonicity, gauge last-value,
    histogram bucketing + conservative quantiles (including the
    < 2-sample refusal shared with ``runtime.metrics.percentile``);
  * **series byte-determinism** — two cold-cache same-seed engine passes
    produce byte-identical ``to_jsonl()`` output;
  * bucket/program signature stability and distinctness across the
    static fields that shape a jit specialization;
  * capture + join: a profiled engine pass leaves **zero unattributed
    dispatches** and every row carries measured walls and a roofline
    bottleneck;
  * the static-cost drift gate: injected baseline rows pass clean,
    perturbed rows trip ``obs-cost-drift``, jax-version mismatch and
    row-free baselines skip with a note;
  * ``validate_profile`` flags structural holes;
  * the tracer ``dropped`` counter surfaces in the metrics summary.
"""

import json

import pytest

from benchmarks import check_regression
from repro import obs
from repro.analysis import Report
from repro.compile import clear_program_cache
from repro.obs import export
from repro.obs import profile as profile_mod
from repro.obs import timeseries
from repro.runtime import Engine, EngineConfig, zipf_trace
from repro.runtime.batcher import BucketKey


@pytest.fixture(autouse=True)
def _profiling_off():
    """Tests must not leak tracer/profiler state or warm program caches."""
    obs.disable()
    profile_mod.disable()
    clear_program_cache()
    yield
    obs.disable()
    profile_mod.disable()
    clear_program_cache()


def _engine_pass(n=24, seed=3, **cfg):
    models, queries = zipf_trace(n, quick=True, seed=seed,
                                 mean_interarrival_s=5e-5)
    eng = Engine(models, EngineConfig(pad_sizes=(8,), max_batch=8, **cfg))
    eng.submit(queries)
    results = eng.run()
    return eng, results


# ---------------------------------------------------------------------------
# timeseries
# ---------------------------------------------------------------------------


def test_exp_boundaries():
    b = timeseries.exp_boundaries(1e-4, 2.0, 5)
    assert b == (1e-4, 2e-4, 4e-4, 8e-4, 16e-4)
    with pytest.raises(ValueError):
        timeseries.exp_boundaries(0.0, 2.0, 5)
    with pytest.raises(ValueError):
        timeseries.exp_boundaries(1.0, 1.0, 5)


def test_counter_is_cumulative_and_gauge_is_instant():
    reg = timeseries.SeriesRegistry()
    c = reg.counter("q")
    c.inc(0.1)
    c.inc(0.2, 4)
    assert c.total == 5
    assert [v for _, _, v in c.samples] == [1, 5]
    g = reg.gauge("depth")
    g.sample(0.3, 7)
    g.sample(0.4, 2)
    assert g.last == 2
    # same name, different type: refused, not silently rebound
    with pytest.raises(TypeError):
        reg.gauge("q")


def test_histogram_quantiles_are_conservative():
    reg = timeseries.SeriesRegistry()
    h = reg.histogram("lat", boundaries=(1.0, 2.0, 4.0))
    assert h.quantile(50) is None  # zero samples: no distribution
    h.observe(0.0, 0.5)
    assert h.quantile(50) is None  # one sample: still refused
    h.observe(0.1, 1.5)
    h.observe(0.2, 3.0)
    h.observe(0.3, 100.0)  # overflow bucket
    assert h.count == 4 and h.bucket_counts == [1, 1, 1, 1]
    assert h.quantile(0) == 1.0     # bucket upper bound, not the value
    assert h.quantile(40) == 2.0    # rank 2 of 4
    assert h.quantile(50) == 4.0    # nearest-rank, same as metrics.percentile
    assert h.quantile(100) == 100.0  # overflow reports the observed max
    assert h.vmin == 0.5 and h.vmax == 100.0
    with pytest.raises(ValueError):
        reg.histogram("bad", boundaries=(2.0, 1.0))


def test_registry_jsonl_interleaves_by_emission_order():
    reg = timeseries.SeriesRegistry()
    reg.counter("b").inc(0.1)
    reg.gauge("a").sample(0.2, 9)
    reg.counter("b").inc(0.3)
    lines = [json.loads(x) for x in reg.to_jsonl().splitlines()]
    assert [r["series"] for r in lines] == ["b", "a", "b"]
    assert [r["seq"] for r in lines] == [1, 2, 3]
    assert lines[1] == {"kind": "gauge", "seq": 2, "series": "a",
                        "t": 0.2, "value": 9}
    snap = reg.snapshot()
    assert snap["b"]["total"] == 2 and snap["a"]["last"] == 9


def test_series_jsonl_byte_deterministic_across_runs():
    eng1, _ = _engine_pass()
    blob1 = eng1.metrics.series.to_jsonl()
    clear_program_cache()
    eng2, _ = _engine_pass()
    blob2 = eng2.metrics.series.to_jsonl()
    assert blob1 and blob1 == blob2
    names = {json.loads(x)["series"] for x in blob1.splitlines()}
    assert {"queue_depth", "pad_efficiency", "bucket_service_s",
            "query_latency_s", "worker_stall_s"} <= names


def test_metrics_summary_surfaces_histogram_p99():
    eng, results = _engine_pass()
    s = eng.metrics.summary()
    assert s["latency_p99_s"] is not None
    assert s["latency_p99_s"] >= s["latency_p50_s"]
    assert "p99" in eng.metrics.table() and "dropped" in eng.metrics.table()


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------


def _key(**over):
    base = dict(
        program_key="a" * 64, kind="bn", clamp_nodes=(1, 3), has_pins=False,
        n_chains=8, n_iters=40, burn_in=10, thin=1, sampler="lut_ky",
        backend="schedule",
    )
    base.update(over)
    return BucketKey(**base)


def test_bucket_signature_stable_and_distinct():
    sig = profile_mod.bucket_signature(_key(), 8)
    assert sig == profile_mod.bucket_signature(_key(), 8)  # pure function
    seen = {sig}
    for variant in (
        dict(program_key="b" * 64), dict(clamp_nodes=()), dict(n_chains=16),
        dict(n_iters=41), dict(burn_in=11), dict(thin=2),
        dict(sampler="gumbel"), dict(fused=True), dict(resumed=True),
        dict(diagnostics=True),
    ):
        s = profile_mod.bucket_signature(_key(**variant), 8)
        assert s not in seen, variant
        seen.add(s)
    assert profile_mod.bucket_signature(_key(), 16) not in seen  # pad width


def test_bucket_signature_sharded_route_qualified():
    """A sharded dispatch runs a different jit specialization (the
    shard_map body over a mesh slice), so the route and slice width extend
    the signature; the vmap format is untouched by the new arguments."""
    base = profile_mod.bucket_signature(_key(), 8)
    assert profile_mod.bucket_signature(
        _key(), 8, route="vmap", shard_width=1
    ) == base
    sh4 = profile_mod.bucket_signature(
        _key(), 8, route="sharded", shard_width=4
    )
    assert sh4 != base and sh4.endswith("|sharded|sh4")
    assert profile_mod.bucket_signature(
        _key(), 8, route="sharded", shard_width=2
    ) != sh4


# ---------------------------------------------------------------------------
# capture + join
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_profiled_pass_joins_every_dispatch():
    tr = obs.enable()
    reg = profile_mod.enable()
    eng, results = _engine_pass()
    events = export.events_as_dicts(list(tr.events))
    assert results and reg.profiles
    for prof in reg.profiles.values():
        assert prof.hbm_bytes > 0
        assert prof.bottleneck in profile_mod.BOTTLENECKS
        assert prof.roofline_s > 0
        det = prof.as_dict(deterministic=True)
        assert "capture_s" not in det  # wall term excluded from exports
        assert "capture_s" in prof.as_dict(deterministic=False)
    joined = profile_mod.join_dispatches(reg.profiles, events)
    assert joined["unattributed"] == []
    assert joined["n_dispatches"] == len(eng.metrics.batch_records)
    assert joined["rows"]
    for row in joined["rows"]:
        assert row["n_dispatches"] > 0
        assert row["measured_mean_s"] > 0
        assert 0 < row["peak_frac"] <= 1.0
    rec = {"schema": 1, "buckets": reg.rows(deterministic=False),
           "joined": joined, "peaks": {}}
    assert profile_mod.validate_profile(rec) == []


@pytest.mark.slow
def test_capture_cache_hits_by_signature():
    obs.enable()
    reg = profile_mod.enable()
    _engine_pass()
    n_first = len(reg.profiles)
    assert n_first > 0
    # same workload again in the same process: every bucket is a cache hit
    _engine_pass()
    assert len(reg.profiles) == n_first
    assert reg.hits > 0


def test_validate_profile_flags_holes():
    bad = {
        "schema": 1,
        "buckets": [{"sig": "s", "flops": -1.0, "hbm_bytes": 0.0,
                     "collective_bytes": 0.0, "bottleneck": "nonsense"}],
        "joined": {"unattributed": [{"sig": "x", "n_dispatches": 3}]},
    }
    problems = profile_mod.validate_profile(bad)
    assert any("unattributed" in p for p in problems)
    assert any("bottleneck" in p or "nonsense" in p for p in problems)
    assert profile_mod.validate_profile({"schema": 1, "buckets": [],
                                         "joined": {}}) != []


def test_join_attributes_sharded_dispatches():
    """Sharded-route dispatches join like any other bucket: a profile
    captured under the route-qualified signature attributes them (comm
    rows included), and a missing profile is an unattributed finding —
    never a silent skip (the old ``n_sharded_skipped`` behavior)."""
    sig = profile_mod.bucket_signature(
        _key(fused=True), 8, route="sharded", shard_width=4
    )
    prof = {
        "sig": sig, "flops": 2.0e6, "hbm_bytes": 1.0e6,
        "collective_bytes": 4096.0, "roofline_s": 1e-4,
        "collective_by_op": {"collective-permute": 4096.0},
        "bottleneck": "collective",
    }
    mk = lambda s: {
        "name": "dispatch",
        "args": {"route": "sharded", "profile_sig": s, "model": "g",
                 "service_s": 2e-3},
        "wargs": {"measured_s": 1e-3},
    }
    joined = profile_mod.join_dispatches(
        {sig: prof}, [mk(sig), mk(sig), mk("bucket|nope|sharded|sh4")]
    )
    assert joined["n_dispatches"] == 3 and joined["n_sharded"] == 3
    (row,) = joined["rows"]
    assert row["sig"] == sig and row["n_dispatches"] == 2
    assert row["peak_frac"] == pytest.approx(0.1)
    (comm,) = joined["comm"]
    assert comm["mechanism"] == "ppermute_halo"
    assert comm["total_bytes"] == pytest.approx(2 * 4096.0)
    (un,) = joined["unattributed"]
    assert un["n_dispatches"] == 1


def test_trace_dropped_surfaces_in_summary():
    obs.enable(capacity=16)  # force ring-buffer overflow
    eng, _ = _engine_pass()
    s = eng.metrics.summary()
    assert s["trace_dropped"] > 0
    assert f"{s['trace_dropped']}" in eng.metrics.table()


# ---------------------------------------------------------------------------
# static-cost drift gate
# ---------------------------------------------------------------------------


def _cost_rows():
    return [
        {"sig": "run|aaaa|bn|lut_ky|ch8|it32|bi8|th1|fused0",
         "flops": 0.0, "hbm_bytes": 2.5e6, "collective_bytes": 0.0},
        {"sig": "run|bbbb|mrf|lut_ky|ch8|it32|bi8|th1|fused1",
         "flops": 1.0e9, "hbm_bytes": 5.4e8, "collective_bytes": 1024.0},
    ]


def _cost_baseline():
    import jax

    return {"schema": 2, "quick": True, "jax": jax.__version__,
            "profile": _cost_rows()}


def test_check_static_cost_clean_rerun_passes():
    rep = Report(meta={"cost_rows": []})
    check_regression.check_static_cost(
        _cost_baseline(), rep, sweep_rows=_cost_rows())
    assert rep.exit_code == 0
    assert rep.meta["cost_compared"] == 2
    assert rep.meta["cost_missing"] == [] and rep.meta["cost_new"] == []


def test_check_static_cost_trips_on_injected_drift():
    for metric, bad in (("flops", 2.0e9), ("hbm_bytes", 1.0),
                        ("collective_bytes", 4096.0)):
        rows = _cost_rows()
        rows[1][metric] = bad
        rep = Report(meta={"cost_rows": []})
        check_regression.check_static_cost(
            _cost_baseline(), rep, sweep_rows=rows)
        assert rep.exit_code == 1, metric
        assert rep.findings[0].rule == "obs-cost-drift"
        assert metric in rep.findings[0].message


def test_check_static_cost_within_tolerance_passes():
    rows = _cost_rows()
    rows[1]["hbm_bytes"] *= 1.05  # inside the 10% default band
    rep = Report(meta={"cost_rows": []})
    check_regression.check_static_cost(
        _cost_baseline(), rep, sweep_rows=rows)
    assert rep.exit_code == 0


def test_check_static_cost_skips_across_jax_versions():
    base = _cost_baseline()
    base["jax"] = "0.0.1"
    rep = Report(meta={"cost_rows": []})
    check_regression.check_static_cost(base, rep, sweep_rows=_cost_rows())
    assert rep.exit_code == 0
    assert "not comparable" in rep.meta["cost_note"]


def test_check_static_cost_skips_rowless_baseline():
    rep = Report(meta={"cost_rows": []})
    check_regression.check_static_cost({"schema": 2}, rep, sweep_rows=[])
    assert rep.exit_code == 0
    assert "no profile rows" in rep.meta["cost_note"]


def test_check_static_cost_reports_missing_and_new_sigs():
    rows = _cost_rows()
    renamed = [dict(rows[0], sig="run|cccc|new"), rows[1]]
    rep = Report(meta={"cost_rows": []})
    check_regression.check_static_cost(
        _cost_baseline(), rep, sweep_rows=renamed)
    assert rep.exit_code == 0  # renames are meta, never silent failures
    assert rep.meta["cost_compared"] == 1
    assert rep.meta["cost_missing"] == [_cost_rows()[0]["sig"]]
    assert rep.meta["cost_new"] == ["run|cccc|new"]
