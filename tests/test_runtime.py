"""repro.runtime: runtime evidence clamping bit-exact with baked-evidence
compilation (every sampler, both backends), MRF pinned pixels, microbatch
bucketing/vmap equivalence, the merge_small_colors pass, and the
deterministic serving engine."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compile import (
    canonicalize,
    clear_program_cache,
    compile_graph,
    lower_schedule,
    run_pipeline,
)
from repro.compile import ir as compile_ir
from repro.compile.backend import ScheduleLoweringError
from repro.compile.passes import (
    MergeSmallColorsPass,
    named_pipeline,
    runtime_pipeline,
)
from repro.compile.schedule import verify_schedule
from repro.core import mrf as mrf_mod
from repro.core.draws import SAMPLERS
from repro.core.graphs import GridMRF, bn_repository_replica, random_bayesnet
from repro.runtime import (
    Engine,
    EngineConfig,
    Query,
    bucket_key,
    execute_bucket,
    pad_size,
    zipf_trace,
)


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_program_cache()
    yield
    clear_program_cache()


# ---------------------------------------------------------------------------
# Tentpole guarantee: runtime clamping == baked-evidence compilation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampler", SAMPLERS)
def test_bn_runtime_clamp_bit_exact_with_baked(sampler):
    """The acceptance gate: for every sampler, clamping evidence at run()
    on a structure-only program gives the same bits as baking the same
    evidence at compile time — on both backends."""
    bn = random_bayesnet(12, max_parents=3, cards=(2, 3), seed=7)
    ev = {1: 0, 5: 1, 9: 0}
    baked = compile_graph(bn, evidence=ev)
    rt = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    kwargs = dict(n_chains=4, n_iters=10, burn_in=2, sampler=sampler)
    for backend in ("eager", "schedule"):
        mb, vb = baked.run(jax.random.key(3), backend=backend, **kwargs)
        mr, vr = rt.run(
            jax.random.key(3), evidence=ev, backend=backend, **kwargs
        )
        np.testing.assert_array_equal(np.asarray(vb), np.asarray(vr))
        np.testing.assert_array_equal(np.asarray(mb), np.asarray(mr))


def test_bn_runtime_clamp_bit_exact_on_runtime_pipeline():
    """Same guarantee under the serving pipeline (merged colors)."""
    bn = bn_repository_replica("insurance")
    ev = {3: 1, 10: 0}
    baked = compile_graph(bn, evidence=ev, pipeline="runtime")
    rt = compile_graph(
        canonicalize(bn, evidence_mode="runtime"), pipeline="runtime"
    )
    kwargs = dict(n_chains=2, n_iters=8, burn_in=2)
    for backend in ("eager", "schedule"):
        mb, vb = baked.run(jax.random.key(1), backend=backend, **kwargs)
        mr, vr = rt.run(
            jax.random.key(1), evidence=ev, backend=backend, **kwargs
        )
        np.testing.assert_array_equal(np.asarray(vb), np.asarray(vr))
        np.testing.assert_array_equal(np.asarray(mb), np.asarray(mr))


@pytest.mark.parametrize("sampler", SAMPLERS)
def test_mrf_runtime_pins_bit_exact_with_baked(sampler):
    """MRF pinned pixels at run() == the same pins baked into the IR."""
    mrf = GridMRF(8, 8, 3, theta=1.1, h=1.5)
    pins = {0: 2, 9: 1, 20: 0}
    baked = compile_graph(compile_ir.from_mrf(mrf, pinned=pins))
    rt = compile_graph(compile_ir.from_mrf(mrf))
    _, noisy = mrf_mod.make_denoising_problem(8, 8, 3, 0.25, seed=0)
    img = jnp.asarray(noisy)
    kwargs = dict(n_chains=2, n_iters=6, sampler=sampler, evidence=img)
    for backend in ("eager", "schedule"):
        lb = baked.run(jax.random.key(2), backend=backend, **kwargs)
        lr = rt.run(jax.random.key(2), pins=pins, backend=backend, **kwargs)
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(lr))
    # pinned pixels hold their labels in every chain
    lab = np.asarray(lr)
    for site, val in pins.items():
        assert (lab[:, site // 8, site % 8] == val).all()


def test_mrf_fused_rounds_respect_pins():
    mrf = GridMRF(8, 8, 4, theta=1.0, h=1.5)
    pins = {5: 3, 17: 0}
    rt = compile_graph(compile_ir.from_mrf(mrf))
    _, noisy = mrf_mod.make_denoising_problem(8, 8, 4, 0.3, seed=2)
    img = jnp.asarray(noisy)
    lab_u = rt.run(
        jax.random.key(3), n_chains=2, n_iters=4, evidence=img, pins=pins,
        backend="schedule",
    )
    lab_f = rt.run(
        jax.random.key(3), n_chains=2, n_iters=4, evidence=img, pins=pins,
        backend="schedule", fused=True,
    )
    np.testing.assert_array_equal(np.asarray(lab_u), np.asarray(lab_f))


def test_empty_pins_match_plain_run():
    mrf = GridMRF(6, 6, 2)
    prog = compile_graph(compile_ir.from_mrf(mrf))
    img = jnp.zeros((6, 6), jnp.int32)
    plain = prog.run(jax.random.key(0), n_chains=2, n_iters=4, evidence=img)
    pinned = prog.run(
        jax.random.key(0), n_chains=2, n_iters=4, evidence=img, pins={},
    )
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(pinned))


def test_full_parity_pin_rejected_at_canonicalization():
    mrf = GridMRF(2, 2, 2)
    even = {0: 0, 3: 1}  # sites (0,0) and (1,1): the whole even class
    with pytest.raises(ValueError):
        compile_ir.from_mrf(mrf, pinned=even)


def test_runtime_evidence_validation():
    bn = random_bayesnet(6, seed=0)
    rt = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    with pytest.raises(ValueError):  # out of range
        rt.run(jax.random.key(0), evidence={0: 99})
    with pytest.raises(ValueError):  # clamping everything leaves no free RV
        rt.run(
            jax.random.key(0),
            evidence={i: 0 for i in range(bn.n_nodes)},
        )
    with pytest.raises(ValueError):  # pins are MRF-speak
        rt.run(jax.random.key(0), pins={0: 1})
    baked = compile_graph(bn)
    with pytest.raises(ValueError):  # baked-mode programs reject clamps
        baked.run(jax.random.key(0), evidence={0: 1})
    mrf_baked = compile_graph(compile_ir.from_mrf(GridMRF(4, 4, 2),
                                                  pinned={0: 1}))
    with pytest.raises(ValueError):  # and baked pins reject runtime pins
        mrf_baked.run(
            jax.random.key(0), evidence=jnp.zeros((4, 4), jnp.int32),
            pins={1: 0},
        )
    with pytest.raises(ValueError):  # sharded path: clamps not supported
        rt.run_sharded(jax.random.key(0), None, evidence={0: 1})


def test_clamped_executable_cached_per_node_set():
    bn = random_bayesnet(10, seed=4)
    rt = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    kwargs = dict(n_chains=2, n_iters=4, burn_in=0, backend="schedule")
    rt.run(jax.random.key(0), evidence={1: 0}, **kwargs)
    n = rt.clamp_lowerings
    rt.run(jax.random.key(1), evidence={1: 1}, **kwargs)  # same node set
    assert rt.clamp_lowerings == n  # values changed, no new lowering
    rt.run(jax.random.key(2), evidence={2: 0}, **kwargs)  # new node set
    assert rt.clamp_lowerings == n + 1


# ---------------------------------------------------------------------------
# merge_small_colors pass
# ---------------------------------------------------------------------------


class _SplitLastClass:
    """Test-only coloring splinterer: explode the last color class into
    singletons (still a proper coloring — they were independent)."""

    name = "split_last"

    def __call__(self, ctx):
        colors = np.asarray(ctx.colors).copy()
        last = int(colors.max())
        for i, v in enumerate(np.where(colors == last)[0]):
            colors[v] = last + i
        ctx.colors = colors


def _split_pipeline(merge: bool):
    from repro.compile.passes import (
        DsaturPass, GreedyMapPass, MoralizePass, SchedulePass,
    )

    mid = [_SplitLastClass()] + ([MergeSmallColorsPass()] if merge else [])
    return [MoralizePass(), DsaturPass(), *mid, GreedyMapPass(),
            SchedulePass()]


def test_merge_small_colors_fuses_splintered_rounds():
    """The pass fuses tiny independent classes back into one round: a
    splintered tail (here: the last DSATUR class exploded to singletons)
    collapses back to the unsplintered round count, and the result is a
    legal, loweable, bit-exact schedule."""
    graph = compile_ir.from_bayesnet(bn_repository_replica("alarm"))
    base = run_pipeline(graph)
    inflated = run_pipeline(graph, passes=_split_pipeline(merge=False))
    merged = run_pipeline(graph, passes=_split_pipeline(merge=True))
    assert len(inflated.schedule.rounds) > len(base.schedule.rounds)
    assert len(merged.schedule.rounds) == len(base.schedule.rounds)
    assert merged.diagnostics["rounds_merged"] > 0
    verify_schedule(graph, merged.schedule)  # raises on violation
    # merged rounds execute through the backend, cross-checked bit-exact
    prog = compile_graph(
        graph, passes=_split_pipeline(merge=True), cross_check=True,
    )
    assert len(prog.schedule.rounds) == len(base.schedule.rounds)


def test_merge_small_colors_is_identity_on_greedy_colorings():
    """DSATUR is saturation-tight (every class conflicts with every earlier
    one), so the pass must change nothing — on BNs or checkerboards."""
    for graph in (
        compile_ir.from_bayesnet(bn_repository_replica("hepar2")),
        compile_ir.from_mrf(GridMRF(6, 6, 2)),
    ):
        base = run_pipeline(graph)
        merged = run_pipeline(graph, passes=runtime_pipeline())
        assert len(merged.schedule.rounds) == len(base.schedule.rounds)
        assert merged.diagnostics["rounds_merged"] == 0
        np.testing.assert_array_equal(base.colors, merged.colors)


def test_merge_pass_determinism():
    graph = compile_ir.from_bayesnet(bn_repository_replica("water"))
    c1 = run_pipeline(graph, passes=_split_pipeline(merge=True))
    c2 = run_pipeline(graph, passes=_split_pipeline(merge=True))
    np.testing.assert_array_equal(c1.colors, c2.colors)
    assert c1.schedule == c2.schedule


def test_named_pipeline_registry():
    assert [p.name for p in named_pipeline("runtime")] == [
        "moralize", "dsatur", "merge_small_colors", "greedy_map", "schedule",
        "verify",
    ]
    with pytest.raises(ValueError):
        named_pipeline("bogus")


def test_illegal_merge_fails_at_lowering():
    """A hypothetically buggy merge (adjacent classes fused into one round)
    must be caught by the legality re-checks, not silently executed."""
    from repro.compile.schedule import build_schedule
    from repro.core.mapping import greedy_map

    graph = compile_ir.from_bayesnet(random_bayesnet(8, seed=2))
    assert graph.n_edges > 0
    ctx = run_pipeline(graph)
    bad = np.zeros_like(ctx.colors)  # all nodes one color: adjacent pairs
    placement = greedy_map(ctx.adj, bad, (4, 4))
    sched = build_schedule(graph, bad, placement)
    with pytest.raises(AssertionError):
        verify_schedule(graph, sched)
    # and the pass itself never produces such a coloring
    ctx2 = run_pipeline(graph, passes=runtime_pipeline())
    verify_schedule(graph, ctx2.schedule)


# ---------------------------------------------------------------------------
# batching: bucket grouping, padding, vmap == single-query bits
# ---------------------------------------------------------------------------


def test_bucket_key_grouping():
    bn = random_bayesnet(8, seed=1)
    graph = canonicalize(bn, evidence_mode="runtime")
    q1 = Query(qid=0, model="m", evidence={1: 0, 3: 1})
    q2 = Query(qid=1, model="m", evidence={3: 0, 1: 1})  # same node set
    q3 = Query(qid=2, model="m", evidence={2: 0})  # different set
    q4 = Query(qid=3, model="m", evidence={1: 0, 3: 1}, thin=2)
    k1, k2 = bucket_key(q1, graph, "schedule"), bucket_key(q2, graph,
                                                           "schedule")
    assert k1 == k2
    assert bucket_key(q3, graph, "schedule") != k1
    assert bucket_key(q4, graph, "schedule") != k1  # thin is static
    assert bucket_key(q1, graph, "eager") != k1  # backend is static


def test_pad_size_ladder():
    assert [pad_size(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert pad_size(33) == 33  # beyond the ladder: exact occupancy
    with pytest.raises(ValueError):  # which the engine refuses to configure
        Engine({}, EngineConfig(pad_sizes=(4,), max_batch=64))


@pytest.mark.parametrize("backend", ["schedule", "eager"])
def test_bn_microbatch_bit_exact_with_single_queries(backend):
    """vmap lanes == standalone runs: batching never changes an answer."""
    bn = random_bayesnet(9, max_parents=2, cards=(2, 3), seed=5)
    graph = canonicalize(bn, evidence_mode="runtime")
    prog = compile_graph(graph, pipeline="runtime")
    queries = [
        Query(qid=i, model="m", evidence={1: i % 2, 4: 0},
              n_chains=3, n_iters=6, burn_in=1, seed=100 + i)
        for i in range(3)
    ]
    key = bucket_key(queries[0], graph, backend)
    results = execute_bucket(prog, key, queries)
    assert len(results) == 3
    for q, r in zip(queries, results):
        marg, vals = prog.run(
            jax.random.key(q.seed), n_chains=3, n_iters=6, burn_in=1,
            evidence=q.evidence, backend=backend,
        )
        np.testing.assert_array_equal(r.final_state, np.asarray(vals))
        np.testing.assert_array_equal(r.marginals, np.asarray(marg))


def test_mrf_microbatch_bit_exact_with_single_queries():
    mrf = GridMRF(6, 6, 3, theta=1.0, h=1.5)
    graph = compile_ir.from_mrf(mrf)
    prog = compile_graph(graph, pipeline="runtime")
    rng = np.random.default_rng(0)
    queries = [
        Query(qid=i, model="m", evidence={int(i): 1},
              image=rng.integers(0, 3, (6, 6)).astype(np.int32),
              n_chains=2, n_iters=5, burn_in=0, seed=7 + i)
        for i in range(2)
    ]
    key = bucket_key(queries[0], graph, "schedule")
    results = execute_bucket(prog, key, queries)
    for q, r in zip(queries, results):
        lab = prog.run(
            jax.random.key(q.seed), n_chains=2, n_iters=5,
            evidence=jnp.asarray(q.image), pins=q.evidence,
            backend="schedule",
        )
        np.testing.assert_array_equal(r.final_state, np.asarray(lab))


# ---------------------------------------------------------------------------
# engine: deterministic event loop
# ---------------------------------------------------------------------------


def _tiny_trace():
    models, queries = zipf_trace(
        14, quick=True, seed=11, mean_interarrival_s=2e-4
    )
    # trim the zoo to keep jit compiles cheap in unit tests
    keep = {"survey", "cancer", "grid"}
    models = {k: v for k, v in models.items() if k in keep}
    queries = [q for q in queries if q.model in keep]
    return models, queries


def _engine_cfg(**kw):
    return EngineConfig(pad_sizes=(4,), max_batch=4, **kw)


def test_engine_answers_every_query_and_is_deterministic():
    models, queries = _tiny_trace()
    eng1 = Engine(models, _engine_cfg())
    eng1.submit(queries)
    res1 = eng1.run()
    assert sorted(res1) == [q.qid for q in sorted(queries,
                                                  key=lambda q: q.qid)]
    s1 = eng1.metrics.summary()
    assert s1["n_queries"] == len(queries)
    assert s1["latency_p95_s"] >= s1["latency_p50_s"] > 0

    # replay from a cold program cache: every simulated metric (and every
    # posterior bit) must reproduce exactly
    clear_program_cache()
    models2, queries2 = _tiny_trace()
    eng2 = Engine(models2, _engine_cfg())
    eng2.submit(queries2)
    res2 = eng2.run()
    s2 = eng2.metrics.summary()
    for k in s1:
        if k != "wall_s":  # sim metrics replay exactly; wall time never
            assert s1[k] == s2[k], k
    for qid in res1:
        np.testing.assert_array_equal(
            res1[qid].final_state, res2[qid].final_state
        )
        assert res1[qid].finish_s == res2[qid].finish_s


def test_engine_eager_escape_hatch_same_bits():
    """backend='eager' serves the same posteriors the schedule path does
    (the PR-2 bit-exactness carried into the runtime)."""
    res_s = None
    for backend in ("schedule", "eager"):
        m, qs = _tiny_trace()
        eng = Engine(m, _engine_cfg(backend=backend))
        eng.submit(qs)
        res = eng.run()
        if res_s is None:
            res_s = res
        else:
            for qid in res_s:
                np.testing.assert_array_equal(
                    res_s[qid].final_state, res[qid].final_state
                )


def test_engine_rejects_bad_queries():
    models, _ = _tiny_trace()
    eng = Engine(models, _engine_cfg())
    with pytest.raises(KeyError):
        eng.submit([Query(qid=0, model="nope")])
    with pytest.raises(ValueError):  # MRF query without an image
        eng.submit([Query(qid=1, model="grid")])
    with pytest.raises(ValueError):
        Engine(models, _engine_cfg(backend="pallas"))


def test_engine_batches_and_hits_cache():
    """A bursty single-model stream batches up and compiles once."""
    bn = bn_repository_replica("survey")
    eng = Engine({"survey": bn}, _engine_cfg(window_s=1.0))
    queries = [
        Query(qid=i, model="survey", evidence={0: i % 2},
              n_chains=2, n_iters=4, burn_in=0, seed=i,
              arrival_s=1e-6 * i)
        for i in range(8)
    ]
    eng.submit(queries)
    res = eng.run()
    assert len(res) == 8
    s = eng.metrics.summary()
    assert s["n_batches"] == 2  # 8 queries / max_batch 4
    assert s["mean_batch"] == 4.0
    assert s["cache_misses"] == 1 and s["cache_hits"] >= 1
    # one clamp-set lowering serves all batches of the same pattern
    assert s["clamp_lowerings"] == 1
