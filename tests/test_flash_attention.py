"""Flash attention (pure-JAX online softmax + custom VJP) vs naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention_reference, flash_attention


@pytest.mark.parametrize(
    "b,sq,skv,h,kvh,d,off,win",
    [
        (2, 64, 64, 8, 2, 16, 0, 0),
        (1, 128, 128, 4, 4, 32, 0, 32),  # chunked-local (llama4 iRoPE)
        (2, 1, 96, 8, 2, 16, 95, 0),  # decode-shaped
        (1, 48, 48, 6, 3, 8, 0, 0),  # non-power-of-two
        (1, 256, 256, 2, 1, 8, 0, 64),
    ],
)
def test_forward_matches_reference(b, sq, skv, h, kvh, d, off, win):
    ks = jax.random.split(jax.random.PRNGKey(sq + win), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, kvh, d), jnp.float32)
    o1 = flash_attention(q, k, v, off, win, 32, 32)
    o2 = attention_reference(q, k, v, q_offset=off, window=win)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


@pytest.mark.parametrize("win", [0, 32])
def test_custom_vjp_matches_reference_grads(win):
    ks = jax.random.split(jax.random.PRNGKey(win), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.float32)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, 0, win, 32, 32) ** 2).sum()

    def loss_ref(q, k, v):
        return (
            attention_reference(q, k, v, window=win).astype(jnp.float32) ** 2
        ).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, atol=3e-4)


def test_causality():
    """Changing future keys/values must not change past outputs."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 8), jnp.float32)
    k = jax.random.normal(ks[1], (1, 32, 2, 8), jnp.float32)
    v = jax.random.normal(ks[2], (1, 32, 2, 8), jnp.float32)
    o1 = flash_attention(q, k, v, 0, 0, 16, 16)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    o2 = flash_attention(q, k2, v2, 0, 0, 16, 16)
    np.testing.assert_allclose(o1[:, :20], o2[:, :20], atol=1e-6)
    assert not np.allclose(o1[:, 21:], o2[:, 21:])


def test_chunk_window_blocks_cross_chunk():
    """window=W: queries must ignore keys from earlier chunks entirely."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 8), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 8), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 8), jnp.float32)
    o1 = flash_attention(q, k, v, 0, 32, 16, 16)
    # mutate chunk 0 only: outputs for chunk 1 must be identical
    k2 = k.at[:, :32].set(7.0)
    v2 = v.at[:, :32].set(-7.0)
    o2 = flash_attention(q, k2, v2, 0, 32, 16, 16)
    np.testing.assert_allclose(o1[:, 32:], o2[:, 32:], atol=1e-6)
