"""KY rejection sampler: kernel-vs-oracle exactness, statistics, properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional (offline containers): property tests skip
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import ky
from repro.kernels import ops
from repro.kernels.ky_sampler import ky_sample_kernel


def _words(key, b, precision, max_retries):
    n_words = -(-precision * max_retries // 32)
    return ky.random_words(key, (b,), n_words)


@pytest.mark.parametrize("b", [1, 7, 300])
@pytest.mark.parametrize("n", [2, 5, 31, 100])
@pytest.mark.parametrize("precision", [8, 16, 24])
def test_kernel_matches_ref_exactly(b, n, precision):
    """Same random bit-stream => identical labels and bit accounting."""
    rng = np.random.default_rng(b * 1000 + n + precision)
    w = jnp.asarray(rng.integers(0, 50, size=(b, n)), jnp.int32)
    words = _words(jax.random.key(42), b, precision, 8)
    lab_ref, st_ref = ky.ky_sample_ref(w, words, n_bins=n, precision=precision)
    wpad = jnp.pad(w, ((0, 0), (0, 128 - n)))
    lab_k, st_k = ky_sample_kernel(
        wpad, words, n_bins=n, precision=precision, interpret=True
    )
    np.testing.assert_array_equal(lab_k, lab_ref)
    np.testing.assert_array_equal(st_k["bits_used"], st_ref["bits_used"])
    np.testing.assert_array_equal(st_k["rejections"], st_ref["rejections"])


def test_block_padding_edges():
    """Batch not a multiple of the block: wrapper pads and slices correctly."""
    w = jnp.tile(jnp.asarray([[3, 1]], jnp.int32), (301, 1))
    labels = ops.ky_sample(w, jax.random.key(0), block_b=64)
    assert labels.shape == (301,)
    assert set(np.asarray(labels).tolist()) <= {0, 1}


@pytest.mark.parametrize(
    "weights",
    [[1, 1, 1], [1, 2, 3, 4, 10], [255] * 32, [1] + [0] * 10 + [9]],
)
def test_sampling_distribution_tvd(weights):
    """Empirical law matches m_i / sum(m) — the exactness claim of C1."""
    n = len(weights)
    target = np.asarray(weights, np.float64)
    target /= target.sum()
    b = 20000
    w = jnp.tile(jnp.asarray(weights, jnp.int32), (b, 1))
    labels = ops.ky_sample(w, jax.random.key(7))
    emp = np.bincount(np.asarray(labels), minlength=n) / b
    tvd = 0.5 * np.abs(emp - target).sum()
    # expected TVD of a multinomial with b draws, with 2.5x headroom
    expected = 0.5 * np.sqrt(2 / np.pi) * np.sqrt(target * (1 - target) / b).sum()
    assert tvd < 2.5 * max(expected, 1e-3)


def test_zero_weight_bins_never_sampled():
    w = jnp.tile(jnp.asarray([5, 0, 7, 0, 1], jnp.int32), (5000, 1))
    labels = np.asarray(ops.ky_sample(w, jax.random.key(3)))
    assert not np.isin(labels, [1, 3]).any()


def test_deterministic_distribution():
    w = jnp.tile(jnp.asarray([0, 0, 9, 0], jnp.int32), (100, 1))
    labels = ops.ky_sample(w, jax.random.key(1))
    assert (labels == 2).all()


def test_entropy_scaling_bits_used():
    """Fig. 11 at unit level: expected bits/sample tracks entropy H (<= H+2),
    so low-entropy distributions sample faster."""
    b = 4000
    peaked = jnp.tile(jnp.asarray([240, 2, 2, 2], jnp.int32), (b, 1))
    flat = jnp.tile(jnp.asarray([61, 61, 62, 62], jnp.int32), (b, 1))
    _, st_p = ops.ky_sample(peaked, jax.random.key(0), return_stats=True)
    _, st_f = ops.ky_sample(flat, jax.random.key(0), return_stats=True)
    bp = float(st_p["bits_used"].mean())
    bf = float(st_f["bits_used"].mean())
    h_p = ky.entropy(np.array([240, 2, 2, 2]))
    h_f = ky.entropy(np.array([61, 61, 62, 62]))
    assert bp < bf  # entropy-adaptive cost
    assert bp <= h_p + 2.1 and bf <= h_f + 2.1  # Knuth-Yao optimality bound


def test_scale_to_fill_reduces_rejection():
    """The scale-to-fill preprocessing keeps P(reject) << 1/2."""
    w = jnp.tile(jnp.asarray([1, 1, 1], jnp.int32), (8000, 1))
    _, stats = ops.ky_sample(w, jax.random.key(2), return_stats=True)
    assert float(stats["rejections"].mean()) < 0.05
    assert not bool(stats["fallback"].any())


def _check_labels_valid_and_supported(weights, seed):
    n = len(weights)
    w = jnp.tile(jnp.asarray(weights, jnp.int32), (64, 1))
    labels = np.asarray(ops.ky_sample(w, jax.random.key(seed)))
    assert ((labels >= 0) & (labels < n)).all()
    assert all(weights[l] > 0 for l in labels)


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        st.lists(st.integers(0, 1000), min_size=2, max_size=64).filter(
            lambda ws: sum(ws) > 0
        ),
        st.integers(0, 2**31 - 1),
    )
    def test_property_labels_valid_and_supported(weights, seed):
        """Any weight vector: labels in range and only positive-weight bins."""
        _check_labels_valid_and_supported(weights, seed)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_labels_valid_and_supported():
        pass


def test_ddg_matrix_invariant():
    """Extended weights sum to exactly 2^W => DDG tree is complete."""
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.integers(1, 99, size=(50, 7)), jnp.int32)
    ext = ky.prepare(m, precision=16)
    np.testing.assert_array_equal(np.asarray(ext.sum(-1)), 1 << 16)
    mat = ky.ddg_matrix(ext, 16)
    # reconstruct weights from the binary matrix
    recon = (mat * (2 ** (16 - 1 - np.arange(16)))).sum(-1)
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(ext))
