"""Chromatic Gibbs on Bayes nets: convergence to exact marginals, ablations."""

import jax
import numpy as np
import pytest

from repro.core import bayesnet as bnet
from repro.core.draws import SAMPLERS
from repro.core.exact import ve_marginal
from repro.core.graphs import bn_repository_replica, random_bayesnet


def _max_tvd(bn, cbn, marg, evidence):
    errs = []
    for q in range(bn.n_nodes):
        if q in evidence:
            continue
        exact = ve_marginal(bn, q, evidence)
        errs.append(0.5 * np.abs(marg[q][: len(exact)] - exact).sum())
    return max(errs)


@pytest.mark.parametrize("sampler", SAMPLERS)
def test_converges_to_exact_marginals(sampler):
    bn = random_bayesnet(8, max_parents=2, cards=(2, 3), seed=1)
    ev = {0: 1}
    cbn = bnet.compile_bayesnet(bn, evidence=ev)
    marg, _ = bnet.run_gibbs(
        cbn, jax.random.key(0), n_chains=64, n_iters=400, burn_in=100,
        sampler=sampler,
    )
    assert _max_tvd(bn, cbn, np.asarray(marg), ev) < 0.03


def test_chain_init_uniform_over_cards():
    """Regression: chain init used to draw randint(0, 1<<30) % card, a
    modulo-fold whose bias the fix (jax.random.randint with per-node maxval)
    removes.  Chi-square-ish check on a card-3 node: each value should get
    ~1/3 of the mass across many chains."""
    bn = random_bayesnet(5, max_parents=2, cards=3, seed=2)
    cbn = bnet.compile_bayesnet(bn, evidence={4: 1})
    n_chains = 30_000
    vals, _ = bnet.init_chain_values(cbn, jax.random.key(11), n_chains)
    vals = np.asarray(vals)
    assert vals.shape == (n_chains, 5)
    assert (vals[:, 4] == 1).all()  # evidence stays clamped
    for node in range(4):
        counts = np.bincount(vals[:, node], minlength=3)
        expected = n_chains / 3
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        # chi-square, 2 dof: P(chi2 > 13.8) ~ 1e-3
        assert chi2 < 13.8, (node, counts)


def test_no_evidence_marginals():
    bn = random_bayesnet(10, max_parents=2, cards=2, seed=7)
    cbn = bnet.compile_bayesnet(bn)
    marg, _ = bnet.run_gibbs(
        cbn, jax.random.key(1), n_chains=64, n_iters=400, burn_in=100
    )
    assert _max_tvd(bn, cbn, np.asarray(marg), {}) < 0.03


def test_repo_replica_inference():
    """End-to-end on the alarm-sized replica (Table IV row, small budget)."""
    bn = bn_repository_replica("insurance")
    cbn = bnet.compile_bayesnet(bn)
    marg, _ = bnet.run_gibbs(
        cbn, jax.random.key(2), n_chains=32, n_iters=250, burn_in=80
    )
    marg = np.asarray(marg)
    # spot-check a handful of nodes against VE
    errs = []
    for q in range(0, bn.n_nodes, 6):
        exact = ve_marginal(bn, q)
        errs.append(0.5 * np.abs(marg[q][: len(exact)] - exact).sum())
    assert max(errs) < 0.08


def test_evidence_respected():
    bn = random_bayesnet(8, max_parents=2, cards=2, seed=3)
    ev = {2: 1, 5: 0}
    cbn = bnet.compile_bayesnet(bn, evidence=ev)
    _, vals = bnet.run_gibbs(
        cbn, jax.random.key(0), n_chains=16, n_iters=20, burn_in=5
    )
    vals = np.asarray(vals)
    assert (vals[:, 2] == 1).all() and (vals[:, 5] == 0).all()


def test_values_always_in_range():
    bn = random_bayesnet(12, max_parents=3, cards=(2, 3, 4), seed=4)
    cbn = bnet.compile_bayesnet(bn)
    _, vals = bnet.run_gibbs(
        cbn, jax.random.key(0), n_chains=16, n_iters=30, burn_in=0
    )
    vals = np.asarray(vals)
    cards = np.asarray(cbn.cards)
    assert (vals >= 0).all() and (vals < cards[None]).all()


def test_color_groups_partition_nodes():
    bn = random_bayesnet(20, max_parents=3, seed=5)
    cbn = bnet.compile_bayesnet(bn, evidence={3: 0})
    seen = np.concatenate([np.asarray(g.nodes) for g in cbn.groups])
    assert sorted(seen.tolist()) == [i for i in range(20) if i != 3]


def test_deterministic_given_key():
    bn = random_bayesnet(9, max_parents=2, seed=6)
    cbn = bnet.compile_bayesnet(bn)
    m1, v1 = bnet.run_gibbs(cbn, jax.random.key(9), n_chains=8, n_iters=50,
                            burn_in=10)
    m2, v2 = bnet.run_gibbs(cbn, jax.random.key(9), n_chains=8, n_iters=50,
                            burn_in=10)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
