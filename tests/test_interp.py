"""Interpolation-LUT kernel: kernel-vs-oracle sweeps, accuracy, properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional (offline containers): property tests skip
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.interp import (
    LUTSpec,
    build_exp_weight_lut,
    build_log_lut,
    build_lut,
    interp_ref,
)
from repro.kernels import ops


@pytest.mark.parametrize("shape", [(4,), (37, 53), (3, 5, 7), (1, 1)])
@pytest.mark.parametrize("size", [8, 16, 32])
def test_kernel_matches_ref(shape, size):
    tab, spec = build_lut(np.exp, -8.0, 0.0, size)
    rng = np.random.default_rng(size)
    x = jnp.asarray(rng.uniform(-10, 2, size=shape), jnp.float32)
    np.testing.assert_allclose(
        ops.interp(x, tab, spec), interp_ref(x, tab, spec), atol=1e-6
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    tab, spec = build_lut(np.tanh, -4.0, 4.0, 16)
    x = jnp.linspace(-5, 5, 97).astype(dtype)
    y = ops.interp(x.astype(jnp.float32), tab, spec)
    ref = interp_ref(x.astype(jnp.float32), tab, spec)
    np.testing.assert_allclose(y, ref, atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_exact_at_knots():
    tab, spec = build_lut(np.sin, 0.0, 3.0, 16)
    xs = jnp.asarray(spec.x0 + spec.dx * np.arange(16), jnp.float32)
    np.testing.assert_allclose(ops.interp(xs, tab, spec), tab, atol=1e-5)


def test_saturating_ends():
    tab, spec = build_lut(np.exp, -8.0, 0.0, 16)
    y = ops.interp(jnp.asarray([-100.0, 100.0], jnp.float32), tab, spec)
    np.testing.assert_allclose(y, [tab[0], tab[-1]], atol=1e-6)


def test_exp_lut_accuracy_paper_config():
    """16-entry table over [-8, 0]: adequate for 8-bit sampling weights
    (CoopMC / paper Sec. III-D accuracy point)."""
    tab, spec = build_lut(np.exp, -8.0, 0.0, 16)
    x = jnp.linspace(-8.0, 0.0, 2000)
    err = jnp.abs(ops.interp(x, tab, spec) - jnp.exp(x)).max()
    assert float(err) < 0.03  # < 8 LSB of an 8-bit weight


def test_exp_weight_lut_quantization():
    tab, spec = build_exp_weight_lut(bits=8)
    assert int(tab[-1]) == 255 and int(tab[0]) == 0
    w = ops.lut_exp_weights(
        jnp.asarray([[0.0, -1.0, -2.0, -50.0]], jnp.float32), tab, spec
    )
    assert w.dtype == jnp.int32
    assert int(w[0, 0]) == 255 and int(w[0, 3]) == 0
    assert int(w[0, 1]) > int(w[0, 2]) > 0


def test_log_lut():
    tab, spec = build_log_lut(size=32)
    x = jnp.linspace(1.0, 2.0, 500)
    err = jnp.abs(ops.interp(x, tab, spec) - jnp.log(x)).max()
    assert float(err) < 1e-3


def _check_output_within_adjacent_knots(x, size):
    tab, spec = build_lut(np.cos, -3.0, 3.0, size)
    y = float(ops.interp(jnp.asarray([x], jnp.float32), tab, spec)[0])
    t = np.asarray(tab)
    assert t.min() - 1e-5 <= y <= t.max() + 1e-5


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.floats(-20, 20), st.integers(4, 32))
    def test_property_output_within_adjacent_knots(x, size):
        """Linear interpolation never over/undershoots its bracketing entries."""
        _check_output_within_adjacent_knots(x, size)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_output_within_adjacent_knots():
        pass
