"""Hierarchical KY token sampling over LM-scale vocabularies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.sampling import (
    greedy_token,
    gumbel_token_sample,
    ky_token_sample,
    sample_tokens,
)


@pytest.mark.parametrize("v", [50, 2048, 50304])
def test_ky_matches_target_distribution(v):
    """Hierarchical (128-ary) KY draw is exact for the quantized weights."""
    rng = np.random.default_rng(v)
    logits_row = np.full(v, -40.0, np.float32)
    support = rng.choice(v, size=8, replace=False)
    logits_row[support] = rng.uniform(0, 3, 8)
    b = 8000
    logits = jnp.tile(jnp.asarray(logits_row), (b, 1))
    toks = np.asarray(ky_token_sample(logits, jax.random.key(0)))
    assert np.isin(toks, support).all()
    p = np.exp(logits_row[support] - logits_row[support].max())
    p /= p.sum()
    emp = np.array([(toks == s).mean() for s in support])
    assert 0.5 * np.abs(emp - p).sum() < 0.03


def test_ky_vs_gumbel_statistical_agreement():
    """KY (paper, 8-bit quantized weights) and gumbel-max (beyond-paper,
    exact float) agree up to multinomial noise + the documented 8-bit
    quantization bias (~2% TVD on a 1000-bin Gaussian logit profile)."""
    v, b = 1000, 20000
    logits_row = np.random.default_rng(0).normal(0, 2, v).astype(np.float32)
    logits = jnp.tile(jnp.asarray(logits_row), (b, 1))
    t_ky = np.asarray(ky_token_sample(logits, jax.random.key(1)))
    t_gb = np.asarray(gumbel_token_sample(logits, jax.random.key(2)))
    h_ky = np.bincount(t_ky, minlength=v) / b
    h_gb = np.bincount(t_gb, minlength=v) / b
    p = np.exp(logits_row - logits_row.max())
    p /= p.sum()
    noise = 0.5 * np.sqrt(2 / np.pi) * np.sqrt(p * (1 - p) / b).sum()
    # each empirical law is within noise (+ quantization slack for KY)...
    assert 0.5 * np.abs(h_gb - p).sum() < 2.0 * noise
    assert 0.5 * np.abs(h_ky - p).sum() < 2.0 * noise + 0.03
    # ...and against each other
    assert 0.5 * np.abs(h_ky - h_gb).sum() < 3.0 * noise + 0.03


def test_peaked_distribution_deterministic():
    v = 4096
    logits_row = np.full(v, -100.0, np.float32)
    logits_row[1234] = 10.0
    logits = jnp.tile(jnp.asarray(logits_row), (64, 1))
    toks = np.asarray(ky_token_sample(logits, jax.random.key(3)))
    assert (toks == 1234).all()
    assert (np.asarray(greedy_token(logits)) == 1234).all()


def test_per_row_distributions_differ():
    """Each batch row samples from its own logits (no cross-row leakage)."""
    v = 300
    l0 = np.full(v, -50.0, np.float32)
    l1 = l0.copy()
    l0[7] = 5.0
    l1[200] = 5.0
    logits = jnp.asarray(np.stack([l0, l1] * 32))
    toks = np.asarray(sample_tokens(logits, jax.random.key(4), "ky"))
    assert (toks[0::2] == 7).all() and (toks[1::2] == 200).all()


def test_token_ids_in_range():
    for v in (129, 16384, 202048):
        logits = jax.random.normal(jax.random.key(v % 7), (16, v))
        toks = np.asarray(ky_token_sample(logits, jax.random.key(5)))
        assert ((toks >= 0) & (toks < v)).all()
