"""End-to-end behaviour tests for the paper's system.

The full AIA pipeline on its two workload classes (irregular Bayes net,
regular grid MRF), plus the LM-serving integration of the sampling technique.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import bayesnet as bnet
from repro.core import mrf as mrf_mod
from repro.core.exact import ve_marginal
from repro.core.graphs import GridMRF, bn_repository_replica
from repro.models import transformer as tfm
from repro.models.sampling import sample_tokens


def test_bayesnet_inference_end_to_end():
    """Compiler chain (coloring -> tensorization) + chromatic Gibbs with the
    full AIA pipeline (LUT-exp + rejection-KY) reproduces exact marginals on
    an alarm-sized irregular network with evidence."""
    bn = bn_repository_replica("alarm")
    evidence = {0: 1, 5: 0}
    cbn = bnet.compile_bayesnet(bn, evidence=evidence)
    assert max(cbn.colors) + 1 <= 8  # paper: small chromatic number
    marg, _ = bnet.run_gibbs(
        cbn, jax.random.key(0), n_chains=64, n_iters=400, burn_in=100
    )
    marg = np.asarray(marg)
    errs = []
    for q in (3, 12, 20, 30):
        exact = ve_marginal(bn, q, evidence)
        errs.append(0.5 * np.abs(marg[q][: len(exact)] - exact).sum())
    assert max(errs) < 0.05, errs


def test_mrf_denoising_end_to_end():
    """Regular-PM workload: checkerboard chromatic Gibbs halves the error of
    a noisy Potts image (the paper's Penguin/Art task, synthetic)."""
    clean, noisy = mrf_mod.make_denoising_problem(48, 48, 4, 0.25, seed=3)
    m = GridMRF(48, 48, 4, theta=1.2, h=2.0)
    lab = mrf_mod.run_mrf_gibbs(
        m, jnp.asarray(noisy), jax.random.key(1), n_chains=1, n_iters=35
    )
    assert (np.asarray(lab[0]) != clean).mean() < (noisy != clean).mean() / 2


def test_lm_serving_with_ky_sampler():
    """The paper technique as a first-class serving feature: prefill then
    decode with normalization-free KY token sampling inside the step."""
    cfg = get_config("musicgen-medium").reduced()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 12)), jnp.int32),
        "features": jnp.asarray(
            rng.normal(0, 1, (4, cfg.frontend_len, tfm.FRONTEND_DIM)),
            jnp.float32,
        ),
    }
    logits, caches = tfm.prefill(params, cfg, batch)
    caches = tfm.grow_attn_caches(caches, cfg, 8)
    key = jax.random.key(5)
    tok = sample_tokens(logits, key, "ky")[:, None]
    toks = [tok]
    pos0 = 12 + cfg.frontend_len
    for t in range(4):
        key, sub = jax.random.split(key)
        lg, caches = tfm.decode_step(
            params, cfg, tok, caches, jnp.asarray(pos0 + t, jnp.int32)
        )
        tok = sample_tokens(lg, sub, "ky")[:, None]
        toks.append(tok)
    out = np.concatenate([np.asarray(t) for t in toks], axis=1)
    assert out.shape == (4, 5)
    assert (out >= 0).all() and (out < cfg.vocab).all()
    # deterministic given the key chain
    logits2, _ = tfm.prefill(params, cfg, batch)
    tok2 = sample_tokens(logits2, jax.random.key(5), "ky")[:, None]
    np.testing.assert_array_equal(np.asarray(toks[0]), np.asarray(tok2))


def test_sampler_statistical_equivalence_in_system():
    """lut_ky and cdf Gibbs agree on marginals within Monte-Carlo noise on a
    medium irregular network (system-level version of the Fig. 12 claim that
    the ablations change throughput, not statistics)."""
    bn = bn_repository_replica("insurance")
    cbn = bnet.compile_bayesnet(bn)
    m1, _ = bnet.run_gibbs(cbn, jax.random.key(2), n_chains=48, n_iters=300,
                           burn_in=75, sampler="lut_ky")
    m2, _ = bnet.run_gibbs(cbn, jax.random.key(3), n_chains=48, n_iters=300,
                           burn_in=75, sampler="cdf")
    tvd = 0.5 * np.abs(np.asarray(m1) - np.asarray(m2)).sum(-1).max()
    assert tvd < 0.08, tvd
