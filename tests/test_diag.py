"""repro.diag: streaming quality accumulators (Welford/R-hat/ESS math,
carry-over bit-identity, zero perturbation of the draw streams), oracle
audits (VE tractability declaration, KY-quantization attribution,
chi-square GOF of fused KY draws against the quantized target pmf), the
quality CLI's threshold/exit-code contract, and the perf+quality
regression gate."""

import dataclasses
import json
import os
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import RULES, Finding, Report
from repro.compile import clear_program_cache, compile_graph
from repro.core.graphs import DiscreteBayesNet, bn_repository_replica
from repro.diag import accum as diag_accum
from repro.diag import oracle as diag_oracle
from repro.diag.__main__ import main as diag_main
from repro.diag.__main__ import quality_sweep
from repro.runtime import Engine, EngineConfig, Query

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import check_regression


# ---------------------------------------------------------------------------
# accumulator math
# ---------------------------------------------------------------------------


def _onehot(vals, n_values):
    return (np.asarray(vals)[..., None]
            == np.arange(n_values)).astype(np.int32)


def test_welford_matches_numpy_moments():
    rng = np.random.default_rng(0)
    n_chains, n_sites, n_values, total = 4, 3, 5, 40
    draws = rng.integers(0, n_values, size=(total, n_chains, n_sites))
    q = diag_accum.make_accum(n_chains, n_sites, n_values, total)
    for t in range(total):
        q = diag_accum.update(
            q, jnp.asarray(_onehot(draws[t], n_values)), jnp.asarray(True)
        )
    oh = _onehot(draws, n_values)  # (total, chains, sites, values)
    # the two split halves each hold their own exact moments
    half = total // 2
    for s, (lo, hi) in enumerate(((0, half), (half, total))):
        np.testing.assert_allclose(
            np.asarray(q.mean)[s], oh[lo:hi].mean(0), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(q.m2)[s], oh[lo:hi].var(0) * (hi - lo),
            rtol=1e-5, atol=1e-4,
        )
    snap = diag_accum.summarize(q)
    # merged marginal = the plain empirical marginal over all kept draws
    np.testing.assert_allclose(
        snap.p_hat, oh.mean(axis=(0, 1)), rtol=1e-6
    )


def test_rhat_converged_near_one_and_split_chains_diverge():
    rng = np.random.default_rng(1)
    n_chains, n_sites, n_values, total = 8, 2, 3, 200
    # converged: every chain draws iid from the same distribution
    draws = rng.integers(0, n_values, size=(total, n_chains, n_sites))
    q = diag_accum.make_accum(n_chains, n_sites, n_values, total)
    for t in range(total):
        q = diag_accum.update(
            q, jnp.asarray(_onehot(draws[t], n_values)), jnp.asarray(True)
        )
    b = diag_accum.summarize(q).brief()
    assert b["rhat_max"] is not None and b["rhat_max"] < 1.05
    assert b["ess_min"] > 0

    # stuck-apart: half the chains pinned at value 0, half at value 1 —
    # zero within-chain variance, huge between-chain variance
    vals = np.zeros((n_chains, n_sites), np.int64)
    vals[n_chains // 2:] = 1
    q2 = diag_accum.make_accum(n_chains, n_sites, n_values, total)
    oh2 = jnp.asarray(_onehot(vals, n_values))
    for _ in range(total):
        q2 = diag_accum.update(q2, oh2, jnp.asarray(True))
    b2 = diag_accum.summarize(q2).brief()
    assert b2["rhat_max"] > 1.1  # the gate must catch this (inf counts)
    # every chain constant -> batch-means variance is 0/0: ESS undefined,
    # reported None (never a fabricated number)
    assert b2["ess_min"] is None

    # half the chains stuck, half mixing: the stuck half contributes 0
    # ESS, so the total sits well below the all-mixing value
    q3 = diag_accum.make_accum(n_chains, n_sites, n_values, total)
    for t in range(total):
        mixed = draws[t].copy()
        mixed[n_chains // 2:] = 0  # stuck half
        q3 = diag_accum.update(
            q3, jnp.asarray(_onehot(mixed, n_values)), jnp.asarray(True)
        )
    b3 = diag_accum.summarize(q3).brief()
    assert b3["ess_min"] is not None
    assert b3["ess_min"] < 0.75 * b["ess_min"]


def test_accum_overflow_flag():
    q = diag_accum.make_accum(2, 2, 2, 100)
    assert not diag_accum.summarize(q).brief()["overflow_risk"]
    q = dataclasses.replace(
        q, counts=jnp.full_like(q.counts, 2**30 + 1)
    )
    assert diag_accum.summarize(q).brief()["overflow_risk"]


# ---------------------------------------------------------------------------
# in-loop wiring: bit-identity guarantees
# ---------------------------------------------------------------------------


def test_diagnostics_leave_draws_bit_identical():
    prog = compile_graph(bn_repository_replica("survey"))
    kw = dict(n_chains=8, n_iters=40, burn_in=10)
    m0, v0 = prog.run(key=jax.random.key(3), **kw)
    m1, v1, snap = prog.run(key=jax.random.key(3), diagnostics=True, **kw)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    # and the snapshot's merged marginal is itself coherent: a proper
    # distribution over each node's support
    np.testing.assert_allclose(snap.p_hat.sum(-1), 1.0, rtol=1e-5)


def test_fused_and_unfused_snapshots_bit_identical():
    prog = compile_graph(bn_repository_replica("survey"))
    kw = dict(n_chains=8, n_iters=30, burn_in=6, diagnostics=True)
    _, _, s_unfused = prog.run(key=jax.random.key(5), **kw)
    _, _, s_fused = prog.run(key=jax.random.key(5), fused=True, **kw)
    assert s_unfused.to_dict() == s_fused.to_dict()


def test_sliced_equals_unsliced_snapshot():
    """Quality accumulators must be carry-over safe: the same budget cut
    into slices yields the bit-identical snapshot (split point fixed from
    the total budget at accumulator creation)."""
    from repro.core import bayesnet as bnet

    cbn = bnet.compile_bayesnet(bn_repository_replica("survey"))
    kw = dict(n_chains=8, burn_in=10, thin=1, diag_total=40)
    _, _, whole = bnet.run_gibbs(cbn, jax.random.key(7), n_iters=40,
                                 return_state=True, **kw)
    # same budget in two slices: the accumulator declares the *total*
    # kept budget up front, so the carry resumes mid-stream exactly
    _, _, st = bnet.run_gibbs(cbn, jax.random.key(7), n_iters=15,
                              return_state=True, **kw)
    _, _, sliced = bnet.run_gibbs(cbn, None, n_iters=25, carry=st,
                                  return_state=True, **kw)
    for f in ("counts", "mean", "m2", "bm_mean", "bm_m2", "cur_sum",
              "cur_n", "bm_count"):
        np.testing.assert_array_equal(
            np.asarray(getattr(whole.quality, f)),
            np.asarray(getattr(sliced.quality, f)), err_msg=f,
        )
    assert (diag_accum.summarize(whole.quality).to_dict()
            == diag_accum.summarize(sliced.quality).to_dict())

    # engine-level: sliced serving produces the same quality brief
    clear_program_cache()
    bn = bn_repository_replica("survey")
    queries = [Query(qid=i, model="survey", n_chains=8, n_iters=40,
                     burn_in=10, seed=i) for i in range(3)]
    e1 = Engine({"survey": bn}, EngineConfig(
        pad_sizes=(4,), max_batch=4, diagnostics=True))
    e1.submit([dataclasses.replace(q) for q in queries])
    r1 = e1.run()
    clear_program_cache()
    e2 = Engine({"survey": bn}, EngineConfig(
        pad_sizes=(4,), max_batch=4, diagnostics=True, slice_iters=15))
    e2.submit([dataclasses.replace(q) for q in queries])
    r2 = e2.run()
    for qid in r1:
        assert r1[qid].quality is not None
        assert r1[qid].quality == r2[qid].quality


def test_engine_quality_briefs_and_metrics_rollup():
    clear_program_cache()
    bn = bn_repository_replica("survey")
    eng = Engine({"survey": bn}, EngineConfig(
        pad_sizes=(4,), max_batch=4, diagnostics=True))
    eng.submit([Query(qid=i, model="survey", n_chains=8, n_iters=30,
                      burn_in=5, seed=i) for i in range(3)])
    res = eng.run()
    for r in res.values():
        assert set(r.quality) >= {"rhat_max", "ess_min", "kept"}
        assert r.quality["kept"] == 25
    s = eng.metrics.summary()
    assert s["quality_queries"] == 3
    assert s["rhat_max"] is not None and s["ess_min"] is not None
    assert "rhat max" in eng.metrics.table()


def test_engine_emits_quality_trace_instants():
    from repro.obs import tracer

    clear_program_cache()
    tracer.enable()
    try:
        eng = Engine({"survey": bn_repository_replica("survey")},
                     EngineConfig(pad_sizes=(4,), max_batch=4,
                                  diagnostics=True))
        eng.submit([Query(qid=i, model="survey", n_chains=8, n_iters=20,
                          burn_in=5) for i in range(2)])
        eng.run()
        evs = [e for e in tracer.get().events if e.name == "quality"]
    finally:
        tracer.disable()
    assert len(evs) == 2
    for e in evs:
        assert e.cat == "quality"
        assert {"qid", "model", "rhat_max", "ess_min"} <= set(e.args)


def test_resume_without_quality_carry_raises():
    prog = compile_graph(bn_repository_replica("survey"))
    _, _, st = prog.run(key=jax.random.key(1), n_chains=4, n_iters=10,
                        burn_in=2, return_state=True)
    with pytest.raises(ValueError, match="diagnostics"):
        prog.run(key=None, n_chains=4, n_iters=10, burn_in=2,
                 carry_state=st, diagnostics=True)


# ---------------------------------------------------------------------------
# oracle audits
# ---------------------------------------------------------------------------


def test_oracle_audit_ok_and_declared_na():
    from repro.core import exact

    bn = bn_repository_replica("survey")
    truth = exact.all_marginals(bn, {})
    p_hat = np.zeros((bn.n_nodes, int(max(bn.cards))))
    for i, row in enumerate(truth):
        p_hat[i, : len(row)] = row
    audit = diag_oracle.oracle_audit(bn, p_hat)
    assert audit["status"] == "ok"
    assert audit["tv_max"] < 1e-12

    # the same model under a starvation limit is *declared* n/a
    na = diag_oracle.oracle_audit(bn, p_hat, limit=1)
    assert na["status"] == "n/a"
    assert na["ve_cost"] > 1 and "limit" in na["reason"]


def test_ky_quantization_floor_ordering():
    bn = bn_repository_replica("alarm")
    lut = diag_oracle.ky_quantization_tv(bn, "lut_ky")["tv_max"]
    exact15 = diag_oracle.ky_quantization_tv(bn, "exact_ky")["tv_max"]
    # int8 LUT weights quantize far coarser than the 15-bit exact grid
    assert 0 <= exact15 < 1e-3 < lut < 0.05
    with pytest.raises(ValueError, match="KY concept"):
        diag_oracle.quantized_pmf(np.zeros(3), "cdf")


def test_chi_square_fused_ky_draws_match_quantized_pmf():
    """GOF capstone: draws from the fused KY datapath are distributed per
    the *quantized* pmf `diag.oracle.quantized_pmf` predicts.  A 1-node
    BN makes the Gibbs conditional the prior itself, so after one sweep
    each chain holds one iid KY draw; chi-square against the quantized
    target must accept at alpha=0.001 (df=3, crit 16.27) for both KY
    samplers, fused and unfused."""
    pmf = np.array([0.05, 0.15, 0.3, 0.5])
    bn = DiscreteBayesNet(
        cards=np.array([4]), parents=[[]], cpts=[pmf], name="one_node",
    )
    prog = compile_graph(bn)
    n = 4096
    for sampler in ("lut_ky", "exact_ky"):
        expected = n * diag_oracle.quantized_pmf(np.log(pmf), sampler)
        for fused in (False, True):
            marg, _ = prog.run(
                key=jax.random.key(11), n_chains=n, n_iters=1, burn_in=0,
                sampler=sampler, fused=fused,
            )
            counts = np.asarray(marg)[0] * n
            chi2 = float(((counts - expected) ** 2 / expected).sum())
            assert chi2 < 16.27, (sampler, fused, chi2)


# ---------------------------------------------------------------------------
# CLI: thresholds are the contract, exit codes are the API
# ---------------------------------------------------------------------------

_TINY = ["--models", "survey", "--variants", "unfused",
         "--n-chains", "16", "--n-iters", "80", "--burn-in", "20"]


def test_diag_cli_passes_with_sane_thresholds(tmp_path, capsys):
    out = tmp_path / "snap.json"
    rc = diag_main(_TINY + ["--rhat-threshold", "5", "--tv-threshold", "1",
                            "--ess-floor", "0", "--out", str(out)])
    assert rc == 0
    snap = json.loads(out.read_text())
    assert snap["n_errors"] == 0
    (row,) = snap["meta"]["rows"]
    assert (row["model"], row["variant"]) == ("survey", "unfused")
    assert row["oracle"] == "ok" and row["kept"] == 60
    assert "survey/unfused" in snap["meta"]["snapshots"]
    assert "| survey | unfused |" in capsys.readouterr().out


def test_diag_cli_exits_nonzero_on_injected_breach():
    # an impossible R-hat threshold forces a diag-threshold-breach
    rc = diag_main(_TINY + ["--rhat-threshold", "0.5", "--tv-threshold", "1",
                            "--ess-floor", "0"])
    assert rc == 1
    # an impossible ESS floor trips the other arm of the same rule
    rc = diag_main(_TINY + ["--rhat-threshold", "5", "--tv-threshold", "1",
                            "--ess-floor", "1e9"])
    assert rc == 1


def test_diag_cli_declares_oracle_na_as_warning():
    rep = quality_sweep(("survey",), ("unfused",), n_chains=16, n_iters=80,
                        burn_in=20, rhat_threshold=5.0, tv_threshold=1.0,
                        ess_floor=0.0, ve_limit=1)
    assert [f.rule for f in rep.warnings] == ["diag-oracle-unavailable"]
    assert rep.exit_code == 0  # n/a is declared, not failed
    assert rep.meta["rows"][0]["oracle"] == "n/a"


def test_diag_rules_registered():
    for rule, sev in (("diag-threshold-breach", "error"),
                      ("diag-oracle-unavailable", "warning"),
                      ("diag-accum-overflow", "error"),
                      ("diag-perf-regression", "error"),
                      ("diag-quality-regression", "error")):
        assert RULES[rule][0] == sev
        Finding(rule, "x", "y")  # constructible


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------


def _fake_sweep_report(rows):
    return Report(meta={"rows": rows})


def test_check_regression_quality_tolerances(monkeypatch):
    baseline = {"quality": [
        {"model": "survey", "variant": "unfused",
         "rhat_max": 1.01, "ess_min": 1000.0, "tv_max": 0.010},
    ]}
    cur = {"model": "survey", "variant": "unfused",
           "rhat_max": 1.02, "ess_min": 900.0, "tv_max": 0.012}
    monkeypatch.setattr(
        "repro.diag.__main__.quality_sweep",
        lambda *a, **k: _fake_sweep_report([dict(cur)]),
    )
    rep = Report(meta={"quality_rows": []})
    check_regression.check_quality(baseline, rep)
    assert rep.exit_code == 0 and rep.meta["quality_compared"] == 1

    # each metric's tolerance trips independently
    for key, bad in (("rhat_max", 1.30), ("tv_max", 0.05),
                     ("ess_min", 100.0)):
        monkeypatch.setattr(
            "repro.diag.__main__.quality_sweep",
            lambda *a, **k: _fake_sweep_report([{**cur, key: bad}]),
        )
        rep = Report(meta={"quality_rows": []})
        check_regression.check_quality(baseline, rep)
        assert rep.exit_code == 1, key
        assert rep.findings[0].rule == "diag-quality-regression"
        assert key in rep.findings[0].message


def test_check_regression_schema1_baseline_skips_quality():
    rep = Report(meta={"quality_rows": []})
    check_regression.check_quality({"schema": 1}, rep)
    assert rep.exit_code == 0
    assert "no quality rows" in rep.meta["quality_note"]


def test_check_regression_perf_rows(monkeypatch):
    base = {"quick": True, "suites": {
        "coloring": [{"name": "a", "us_per_call": 10_000.0, "derived": ""}],
        "compile": [{"name": "b", "us_per_call": 100.0, "derived": ""}],
    }}
    monkeypatch.setattr(
        check_regression, "PERF_SUITES", ("coloring", "compile"))
    import benchmarks.run as run_mod
    monkeypatch.setitem(
        run_mod.SUITES, "coloring", lambda **k: ["a,50000.0,"])
    monkeypatch.setitem(
        run_mod.SUITES, "compile", lambda **k: ["b,90000.0,", "new,1.0,"])
    rep = Report(meta={"perf_rows": []})
    check_regression.check_perf(base, rep)
    # "a" regressed past 2x+slack; "b" sat below the noise floor and is
    # skipped; "new" has no baseline row and lands in perf_new
    assert [f.rule for f in rep.findings] == ["diag-perf-regression"]
    assert rep.meta["perf_compared"] == 1
    assert rep.meta["perf_new"] == ["new"]


def test_check_regression_missing_baseline_exit_2(tmp_path):
    rc = check_regression.main(
        ["--baseline", str(tmp_path / "nope.json")])
    assert rc == 2


def test_quality_table_renders_rows():
    from repro.launch.report import quality_table

    txt = quality_table([{
        "model": "survey", "variant": "fused", "n_nodes": 6,
        "n_chains": 64, "kept": 300, "rhat_max": 1.0144, "ess_min": 5819.0,
        "oracle": "ok", "tv_max": 0.0135, "maxabs_max": 0.0135,
        "ky_tv": 8.0e-3, "wall_s": 17.7,
    }, {
        "model": "water", "variant": "unfused", "n_nodes": 32,
        "n_chains": 64, "kept": 300, "rhat_max": 1.06, "ess_min": 7000.0,
        "oracle": "n/a", "tv_max": None, "maxabs_max": None,
        "ky_tv": 1.0e-2, "wall_s": 35.0,
    }])
    assert "| survey | fused |" in txt and "| n/a |" in txt


# ---------------------------------------------------------------------------
# sharded-route quality bit-identity (advisory multi-device CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_quality_snapshot_bit_identical_8dev():
    """Satellite gate: the fused sharded engines thread the *same* quality
    accumulator through the shard_map body, so a sharded run's
    QualitySnapshot equals the single-device run's field for field — no
    demotion, no "diagnostics ran unsharded" asterisk (subprocess with 8
    simulated host devices, mirroring test_distributed_pm)."""
    import subprocess
    import textwrap
    from pathlib import Path

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.compile import compile_graph
        from repro.compile import ir as compile_ir
        from repro.core import compat
        from repro.core.graphs import GridMRF, random_bayesnet

        mesh = compat.make_mesh((2, 4), ("data", "model"))

        def assert_snap_equal(a, b):
            da, db = a.to_dict(), b.to_dict()
            assert da.keys() == db.keys()
            for k in da:
                x, y = da[k], db[k]
                if isinstance(x, str) or isinstance(y, str):
                    assert x == y, k
                elif x is None or y is None:
                    assert x is y, k
                else:
                    xa, ya = np.asarray(x), np.asarray(y)
                    if np.issubdtype(xa.dtype, np.floating):
                        assert np.array_equal(xa, ya, equal_nan=True), k
                    else:
                        assert x == y, k

        mrf = GridMRF(8, 16, 4, theta=1.1)
        prog = compile_graph(compile_ir.from_mrf(mrf))
        ev = jnp.zeros((8, 16), jnp.int32)
        lab1, snap1 = prog.run(jax.random.key(7), evidence=ev, n_chains=4,
                               n_iters=5, fused=True, diagnostics=True)
        lab2, snap2 = prog.run_sharded(jax.random.key(7), mesh, evidence=ev,
                                       n_chains=4, n_iters=5, fused=True,
                                       diagnostics=True)
        assert (np.asarray(lab1) == np.asarray(lab2)).all()
        assert_snap_equal(snap1, snap2)

        bn = random_bayesnet(12, seed=3)
        pbn = compile_graph(compile_ir.from_bayesnet(bn))
        kw = dict(n_chains=4, n_iters=6, burn_in=2, thin=2, fused=True,
                  diagnostics=True)
        m1, v1, sn1 = pbn.run(jax.random.key(11), **kw)
        m2, v2, sn2 = pbn.run_sharded(jax.random.key(11), mesh, **kw)
        assert (np.asarray(v1) == np.asarray(v2)).all()
        assert (np.asarray(m1) == np.asarray(m2)).all()
        assert_snap_equal(sn1, sn2)
        print("SHARDED_QUALITY_OK")
        """
    )
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARDED_QUALITY_OK" in res.stdout
