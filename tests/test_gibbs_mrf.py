"""Checkerboard Gibbs on grid MRFs + the fused Pallas kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ky as ky_core
from repro.core import mrf as mrf_mod
from repro.core.graphs import GridMRF
from repro.core.interp import build_exp_weight_lut
from repro.kernels import ref
from repro.kernels.mrf_gibbs import mrf_half_step_kernel


def test_denoising_improves():
    clean, noisy = mrf_mod.make_denoising_problem(48, 48, 4, 0.25, seed=0)
    m = GridMRF(48, 48, 4, theta=1.2, h=2.0)
    lab = mrf_mod.run_mrf_gibbs(
        m, jnp.asarray(noisy), jax.random.key(0), n_chains=2, n_iters=40
    )
    err_before = (noisy != clean).mean()
    err_after = (np.asarray(lab[0]) != clean).mean()
    assert err_after < err_before / 2


def test_energy_increases():
    """Gibbs drifts toward high-probability (high log-potential) states."""
    clean, noisy = mrf_mod.make_denoising_problem(32, 32, 2, 0.3, seed=1)
    m = GridMRF(32, 32, 2, theta=1.0, h=1.5)
    ev = jnp.asarray(noisy)
    key = jax.random.key(0)
    lab0 = jax.random.randint(key, (1, 32, 32), 0, 2, jnp.int32)
    e0 = float(mrf_mod.total_energy(m, lab0, ev)[0])
    lab = mrf_mod.run_mrf_gibbs(m, ev, key, n_chains=1, n_iters=25)
    e1 = float(mrf_mod.total_energy(m, lab, ev)[0])
    assert e1 > e0


@pytest.mark.parametrize("sampler", ["lut_ky", "cdf", "gumbel"])
def test_samplers_agree_statistically(sampler):
    """All sampler pipelines reach comparable denoising quality (Fig. 12's
    throughput differs, statistics must not)."""
    clean, noisy = mrf_mod.make_denoising_problem(32, 32, 3, 0.25, seed=2)
    m = GridMRF(32, 32, 3, theta=1.2, h=2.0)
    lab = mrf_mod.run_mrf_gibbs(
        m, jnp.asarray(noisy), jax.random.key(3), n_chains=1, n_iters=30,
        sampler=sampler,
    )
    assert (np.asarray(lab[0]) != clean).mean() < 0.1


@pytest.mark.parametrize("shape,v,block_h", [
    ((32, 32), 2, 8), ((64, 48), 4, 16), ((16, 128), 7, 16), ((8, 8), 3, 8),
])
def test_fused_kernel_matches_ref_exactly(shape, v, block_h):
    """Kernel sweep: bit-identical to the oracle given the same random words."""
    h, w = shape
    rng = np.random.default_rng(v)
    labels = jnp.asarray(rng.integers(0, v, (h, w)), jnp.int32)
    evid = jnp.asarray(rng.integers(0, v, (h, w)), jnp.int32)
    tab, spec = build_exp_weight_lut()
    words = ky_core.random_words(jax.random.key(1), (h, w), 4)
    for parity in (0, 1):
        out_ref = ref.mrf_gibbs_half_step(
            labels, evid, words, parity=parity, theta=1.2, h=2.0,
            n_labels=v, exp_table=tab, exp_spec=spec,
        )
        out_k = mrf_half_step_kernel(
            labels, evid, words.reshape(h, -1),
            tab.reshape(1, -1).astype(jnp.float32),
            parity=parity, theta=1.2, h=2.0, n_labels=v, spec=spec,
            block_h=block_h, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_ref))


def test_half_step_only_touches_own_color():
    m = GridMRF(16, 16, 3, theta=1.0, h=1.0)
    rng = np.random.default_rng(0)
    lab = jnp.asarray(rng.integers(0, 3, (1, 16, 16)), jnp.int32)
    ev = jnp.asarray(rng.integers(0, 3, (16, 16)), jnp.int32)
    out = mrf_mod.half_step(m, lab, ev, jax.random.key(0), parity=0)
    mask = np.asarray(mrf_mod.checkerboard_mask(16, 16, 0))
    np.testing.assert_array_equal(
        np.asarray(out)[0][~mask], np.asarray(lab)[0][~mask]
    )
