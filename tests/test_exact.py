"""Variable-elimination oracle vs brute-force enumeration."""

import numpy as np
import pytest

from repro.core.exact import brute_force_marginal, ve_marginal
from repro.core.graphs import random_bayesnet


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n", [4, 7, 9])
def test_ve_matches_brute_force(n, seed):
    bn = random_bayesnet(n, max_parents=3, cards=(2, 3), seed=seed)
    for q in range(0, n, max(1, n // 3)):
        np.testing.assert_allclose(
            ve_marginal(bn, q), brute_force_marginal(bn, q), atol=1e-10
        )


@pytest.mark.parametrize("seed", [3, 4])
def test_ve_with_evidence(seed):
    bn = random_bayesnet(7, max_parents=2, cards=(2, 3), seed=seed)
    ev = {0: 1, 3: 0}
    for q in (1, 2, 5, 6):
        np.testing.assert_allclose(
            ve_marginal(bn, q, ev), brute_force_marginal(bn, q, ev), atol=1e-10
        )


def test_ve_handles_larger_nets():
    bn = random_bayesnet(40, max_parents=3, cards=2, seed=5)
    m = ve_marginal(bn, 20)
    assert m.shape == (2,) and abs(m.sum() - 1.0) < 1e-9 and (m >= 0).all()
