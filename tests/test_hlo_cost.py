"""Validate the trip-count-aware HLO cost walker against known programs.

Runs in a subprocess with 8 simulated devices so the main process keeps one
device (the dry-run methodology depends on this parser being right)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch import hlo_cost

    M, K, N = 256, 512, 128
    f = jax.jit(lambda a, b: a @ b)
    comp = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                   jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    c = hlo_cost.analyze(comp.as_text())
    want = 2 * M * K * N
    assert abs(c.flops - want) / want < 0.01, (c.flops, want)

    # scan of 10 matmuls: parser must multiply by the trip count
    def scanned(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y
    comp2 = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((10, K, K), jnp.float32)).compile()
    c2 = hlo_cost.analyze(comp2.as_text())
    want2 = 10 * 2 * M * K * K
    assert abs(c2.flops - want2) / want2 < 0.01, (c2.flops, want2)
    # ... and XLA's own analysis indeed undercounts (sanity of premise)
    xla = comp2.cost_analysis()
    if isinstance(xla, list):  # older jax: one record per device
        xla = xla[0]
    assert float(xla["flops"]) < 0.2 * want2

    # nested scan: multipliers compose
    def nested(a, ws):
        def outer(x, w):
            def inner(y, _):
                return jnp.tanh(y @ w), None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, a, ws)
        return y
    comp3 = jax.jit(nested).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((4, K, K), jnp.float32)).compile()
    c3 = hlo_cost.analyze(comp3.as_text())
    want3 = 4 * 5 * 2 * M * K * K
    assert abs(c3.flops - want3) / want3 < 0.02, (c3.flops, want3)

    # collective bytes: all-reduce of a (1024,) f32 row
    from repro.core import compat
    mesh = compat.make_mesh((8,), ("x",))
    f4 = jax.jit(lambda a: a.sum(0),
                 in_shardings=(NamedSharding(mesh, P("x", None)),),
                 out_shardings=NamedSharding(mesh, P(None)))
    comp4 = f4.lower(jax.ShapeDtypeStruct((64, 1024), jnp.float32)).compile()
    c4 = hlo_cost.analyze(comp4.as_text())
    assert c4.collective_by_op["all-reduce"] == 4096.0, c4.collective_by_op

    # hbm traffic: matmul reads A + B and writes C at minimum
    lo = 4 * (M * K + K * N + M * N)
    assert c.hbm_bytes >= lo, (c.hbm_bytes, lo)
    assert c.hbm_bytes < 10 * lo
    print("HLO_COST_OK")
    """
)


@pytest.mark.slow
def test_hlo_cost_known_programs():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "HLO_COST_OK" in res.stdout
