"""Validate the trip-count-aware HLO cost walker against known programs.

Runs in a subprocess with 8 simulated devices so the main process keeps one
device (the dry-run methodology depends on this parser being right)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch import hlo_cost

    M, K, N = 256, 512, 128
    f = jax.jit(lambda a, b: a @ b)
    comp = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                   jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    c = hlo_cost.analyze(comp.as_text())
    want = 2 * M * K * N
    assert abs(c.flops - want) / want < 0.01, (c.flops, want)

    # scan of 10 matmuls: parser must multiply by the trip count
    def scanned(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, a, ws)
        return y
    comp2 = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((10, K, K), jnp.float32)).compile()
    c2 = hlo_cost.analyze(comp2.as_text())
    want2 = 10 * 2 * M * K * K
    assert abs(c2.flops - want2) / want2 < 0.01, (c2.flops, want2)
    # ... and XLA's own analysis indeed undercounts (sanity of premise)
    xla = comp2.cost_analysis()
    if isinstance(xla, list):  # older jax: one record per device
        xla = xla[0]
    assert float(xla["flops"]) < 0.2 * want2

    # nested scan: multipliers compose
    def nested(a, ws):
        def outer(x, w):
            def inner(y, _):
                return jnp.tanh(y @ w), None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, a, ws)
        return y
    comp3 = jax.jit(nested).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((4, K, K), jnp.float32)).compile()
    c3 = hlo_cost.analyze(comp3.as_text())
    want3 = 4 * 5 * 2 * M * K * K
    assert abs(c3.flops - want3) / want3 < 0.02, (c3.flops, want3)

    # collective bytes: all-reduce of a (1024,) f32 row
    from repro.core import compat
    mesh = compat.make_mesh((8,), ("x",))
    f4 = jax.jit(lambda a: a.sum(0),
                 in_shardings=(NamedSharding(mesh, P("x", None)),),
                 out_shardings=NamedSharding(mesh, P(None)))
    comp4 = f4.lower(jax.ShapeDtypeStruct((64, 1024), jnp.float32)).compile()
    c4 = hlo_cost.analyze(comp4.as_text())
    assert c4.collective_by_op["all-reduce"] == 4096.0, c4.collective_by_op

    # hbm traffic: matmul reads A + B and writes C at minimum
    lo = 4 * (M * K + K * N + M * N)
    assert c.hbm_bytes >= lo, (c.hbm_bytes, lo)
    assert c.hbm_bytes < 10 * lo
    print("HLO_COST_OK")
    """
)


@pytest.mark.slow
def test_hlo_cost_known_programs():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "HLO_COST_OK" in res.stdout


_COMPILED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.compile import backend as backend_mod
    from repro.compile.program import compile_graph
    from repro.core import compat
    from repro.core.distributed import run_program_sharded
    from repro.core.graphs import GridMRF, bn_repository_replica
    from repro.launch import hlo_cost

    # compiled fused BN color-round program: the Pallas round kernel
    # (interpret mode off-TPU) must surface nonzero static cost off the
    # *optimized* HLO, and the walker must scale it with the sweep count
    prog = compile_graph(bn_repository_replica("survey"))
    ex = prog.schedule_executable()

    def lower_bn(n_iters):
        return backend_mod._run_bn_rounds.lower(
            ex.cbn, ex.round_groups, jax.random.key(0), None, None, None,
            n_chains=8, n_iters=n_iters, burn_in=8, sampler="lut_ky",
            thin=1, return_state=False, fused=True, interpret=True,
        )

    lo = hlo_cost.analyze(lower_bn(16).compile().as_text())
    hi = hlo_cost.analyze(lower_bn(32).compile().as_text())
    assert lo.hbm_bytes > 0, lo
    assert lo.flops > 0, lo  # fused kernels lower real dot ops
    # trip-count awareness: doubling n_iters must roughly double the
    # sweep-proportional flops (band absorbs the shared burn-in loop)
    ratio = hi.flops / lo.flops
    assert 1.5 <= ratio <= 2.6, (lo.flops, hi.flops, ratio)
    # single-host bucket entry: no collectives in the lowered module
    assert lo.collective_bytes == 0, lo.collective_by_op

    # ppermute-sharded MRF schedule program: the checkerboard halo
    # exchange must show up as collective-permute bytes
    mprog = compile_graph(GridMRF(8, 8, 3, theta=1.1, h=1.8, name="grid8"))
    mprog.schedule_executable()  # first-lowering cross-check runs concrete
    mesh = compat.make_mesh((4, 2), ("model", "data"))

    def sharded(ev, key):
        return run_program_sharded(
            mprog, key, mesh, n_chains=8, n_iters=4,
            evidence=ev, backend="schedule",
        )

    comp = jax.jit(sharded).lower(
        jnp.zeros((8, 8), jnp.int32), jax.random.key(0)).compile()
    cs = hlo_cost.analyze(comp.as_text())
    assert cs.collective_by_op.get("collective-permute", 0) > 0, \\
        cs.collective_by_op
    assert cs.collective_bytes > 0, cs
    print("HLO_COST_COMPILED_OK")
    """
)


@pytest.mark.slow
def test_hlo_cost_compiled_programs():
    """Static costs of real compiled artifacts: fused BN color rounds
    carry nonzero trip-scaled cost, and the ppermute-sharded schedule
    lowers to nonzero collective-permute bytes (the signal obs.profile's
    comm rows and the static-cost drift gate are built on)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _COMPILED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "HLO_COST_COMPILED_OK" in res.stdout
