"""repro.compile: IR hashing, pass-pipeline determinism, schedule legality,
program-cache behavior, and compiled-vs-eager bit-exactness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import (
    compile_graph,
    cache_stats,
    clear_program_cache,
    run_pipeline,
)
from repro.compile import ir as compile_ir
from repro.compile.passes import random_baseline_pipeline
from repro.compile.schedule import verify_schedule
from repro.core import bayesnet as bnet
from repro.core import mrf as mrf_mod
from repro.core.graphs import GridMRF, bn_repository_replica, random_bayesnet


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_program_cache()
    yield
    clear_program_cache()


# ---------------------------------------------------------------------------
# IR canonicalization + stable hashing
# ---------------------------------------------------------------------------


def test_ir_hash_deterministic():
    """Same graph -> same program hash, across independent constructions."""
    a = compile_ir.from_bayesnet(random_bayesnet(12, seed=3), {1: 0})
    b = compile_ir.from_bayesnet(random_bayesnet(12, seed=3), {1: 0})
    assert a.ir_key == b.ir_key
    m1 = compile_ir.from_mrf(GridMRF(8, 8, 3, theta=1.2))
    m2 = compile_ir.from_mrf(GridMRF(8, 8, 3, theta=1.2))
    assert m1.ir_key == m2.ir_key


def test_ir_hash_sensitivity():
    """Structure, parameters, and evidence all feed the hash."""
    base = compile_ir.from_bayesnet(random_bayesnet(12, seed=3))
    other_seed = compile_ir.from_bayesnet(random_bayesnet(12, seed=4))
    with_ev = compile_ir.from_bayesnet(random_bayesnet(12, seed=3), {1: 0})
    keys = {base.ir_key, other_seed.ir_key, with_ev.ir_key}
    assert len(keys) == 3
    assert (
        compile_ir.from_mrf(GridMRF(8, 8, 3, theta=1.2)).ir_key
        != compile_ir.from_mrf(GridMRF(8, 8, 3, theta=1.3)).ir_key
    )


def test_ir_conflict_graph_matches_moral_graph():
    bn = random_bayesnet(15, max_parents=3, seed=2)
    assert compile_ir.from_bayesnet(bn).adjacency() == bn.moral_adjacency()


def test_mrf_evidence_rejected_at_compile_time():
    with pytest.raises(ValueError):
        compile_ir.canonicalize(GridMRF(4, 4, 2), {0: 1})


def test_evidence_with_pre_canonicalized_ir_rejected():
    """Regression: compile_graph(SamplingGraph, evidence) used to drop the
    evidence silently and compile a different program than requested."""
    bn = random_bayesnet(8, seed=1)
    graph = compile_ir.from_bayesnet(bn)  # no evidence baked in
    with pytest.raises(ValueError):
        compile_graph(graph, {2: 0})
    # evidence baked at canonicalization stays the supported path
    with_ev = compile_graph(compile_ir.from_bayesnet(bn, {2: 0}))
    assert dict(with_ev.ir.evidence) == {2: 0}


def test_ir_key_no_field_boundary_collision():
    """Regression: field byte-streams used to be hashed back-to-back, so an
    edge list ending where an evidence list began produced the same digest.
    Construct that exact re-split and require distinct keys."""
    bn = random_bayesnet(4, max_parents=0, seed=0)  # edgeless moral graph
    base = compile_ir.from_bayesnet(bn)
    as_edge = dataclasses.replace(base, edges=((0, 1),), evidence=())
    as_evidence = dataclasses.replace(base, edges=(), evidence=((0, 1),))
    assert as_edge.ir_key != as_evidence.ir_key
    # and moving bytes across the cards/edges boundary must differ too
    a = dataclasses.replace(base, cards=(2, 2, 2, 2), edges=((0, 1),))
    b = dataclasses.replace(base, cards=(2, 2, 2, 2, 0, 1), edges=())
    assert a.ir_key != b.ir_key


# ---------------------------------------------------------------------------
# Pass pipeline + schedule
# ---------------------------------------------------------------------------


def test_pipeline_deterministic():
    """Two runs of the pipeline agree on every artifact (same program)."""
    graph = compile_ir.from_bayesnet(bn_repository_replica("insurance"))
    c1 = run_pipeline(graph)
    c2 = run_pipeline(graph)
    np.testing.assert_array_equal(c1.colors, c2.colors)
    np.testing.assert_array_equal(
        c1.placement.placement, c2.placement.placement
    )
    assert c1.schedule == c2.schedule
    assert set(c1.pass_times_s) == {
        "moralize", "dsatur", "greedy_map", "schedule", "verify"
    }


@pytest.mark.parametrize("workload", ["alarm", "hepar2"])
def test_schedule_legality_bn(workload):
    """No round may contain two adjacent RVs; rounds partition free RVs."""
    graph = compile_ir.from_bayesnet(bn_repository_replica(workload), {0: 0})
    ctx = run_pipeline(graph)
    verify_schedule(graph, ctx.schedule)  # raises on violation
    adj = graph.adjacency()
    for r in ctx.schedule.rounds:
        s = set(r.nodes)
        assert all(not (adj[u] & s) for u in r.nodes)


def test_schedule_legality_mrf_checkerboard():
    """A 4-connected grid schedules as exactly two checkerboard rounds."""
    mrf = GridMRF(8, 8, 2)
    graph = compile_ir.from_mrf(mrf)
    ctx = run_pipeline(graph)
    verify_schedule(graph, ctx.schedule)
    assert len(ctx.schedule.rounds) == 2
    parity = mrf.checkerboard_colors().reshape(-1)
    for r in ctx.schedule.rounds:
        assert len({parity[v] for v in r.nodes}) == 1


def test_schedule_comm_ops_name_paper_mechanisms():
    bn_ctx = run_pipeline(
        compile_ir.from_bayesnet(bn_repository_replica("alarm")))
    mrf_ctx = run_pipeline(compile_ir.from_mrf(GridMRF(8, 8, 3)))
    bn_ops = [op for r in bn_ctx.schedule.rounds for op in r.comm]
    mrf_ops = [op for r in mrf_ctx.schedule.rounds for op in r.comm]
    assert bn_ops and all(op.mechanism == "psum_broadcast" for op in bn_ops)
    assert mrf_ops and all(op.mechanism == "ppermute_halo" for op in mrf_ops)
    cost = bn_ctx.schedule.cost()
    assert cost["total_bytes"] > 0 and cost["total_cycles"] > 0


def test_compute_cycles_follow_actual_placement():
    """Regression: Round.compute_cycles used to charge the balanced share
    ceil(n/n_cores) regardless of placement, so clumping every node of a
    round onto one core reported the same cost as spreading them."""
    from repro.compile.schedule import build_schedule
    from repro.core.mapping import MeshPlacement

    graph = compile_ir.from_mrf(GridMRF(8, 8, 2))
    ctx = run_pipeline(graph)
    colors = ctx.colors
    n = graph.n_nodes
    clumped = MeshPlacement(np.zeros(n, np.int64), (4, 4))
    spread = ctx.placement
    s_clumped = build_schedule(graph, colors, clumped)
    s_spread = build_schedule(graph, colors, spread)
    for r_c, r_s in zip(s_clumped.rounds, s_spread.rounds):
        assert max(r_c.core_load) == len(r_c.nodes)  # all on core 0
        assert r_c.compute_cycles(16) == len(r_c.nodes)
        assert r_s.compute_cycles(16) < r_c.compute_cycles(16)
    assert (
        s_clumped.cost()["compute_cycles"] > s_spread.cost()["compute_cycles"]
    )


def test_greedy_schedule_beats_random_placement():
    """Acceptance: compiled schedule comm-cost <= random-placement baseline."""
    for graph in (
        compile_ir.from_bayesnet(bn_repository_replica("alarm")),
        compile_ir.from_mrf(GridMRF(16, 16, 3)),
    ):
        greedy = run_pipeline(graph).schedule.cost()
        rand = [
            run_pipeline(graph, passes=random_baseline_pipeline(s))
            .schedule.cost()
            for s in range(3)
        ]
        assert greedy["total_hop_bytes"] <= min(
            c["total_hop_bytes"] for c in rand
        )


# ---------------------------------------------------------------------------
# CompiledProgram: cache + bit-exactness vs the eager engines
# ---------------------------------------------------------------------------


def test_program_cache_hits_and_keying():
    bn = random_bayesnet(10, seed=5)
    p1 = compile_graph(bn)
    p2 = compile_graph(bn)
    assert p2 is p1
    assert cache_stats()["hits"] == 1
    p3 = compile_graph(bn, evidence={2: 0})  # different program
    assert p3 is not p1
    stats = cache_stats()
    assert stats["misses"] == 2 and stats["size"] == 2
    assert stats["hit_rate"] == pytest.approx(1 / 3)
    assert compile_graph(bn, cache=False) is not p1  # bypass


def test_compiled_bn_bit_exact_with_eager():
    """Same PRNG key: compiled program == eager chromatic Gibbs, bit for bit."""
    bn = random_bayesnet(12, max_parents=3, cards=(2, 3), seed=7)
    ev = {1: 0}
    prog = compile_graph(bn, evidence=ev)
    marg_c, vals_c = prog.run(
        jax.random.key(4), n_chains=16, n_iters=60, burn_in=10)
    cbn = bnet.compile_bayesnet(bn, evidence=ev)
    marg_e, vals_e = bnet.run_gibbs(
        cbn, jax.random.key(4), n_chains=16, n_iters=60, burn_in=10)
    np.testing.assert_array_equal(np.asarray(vals_c), np.asarray(vals_e))
    np.testing.assert_array_equal(np.asarray(marg_c), np.asarray(marg_e))


def test_compiled_mrf_bit_exact_with_eager():
    mrf = GridMRF(16, 16, 3, theta=1.2, h=2.0)
    _, noisy = mrf_mod.make_denoising_problem(16, 16, 3, 0.25, seed=0)
    ev = jnp.asarray(noisy)
    prog = compile_graph(mrf)
    lab_c = prog.run(jax.random.key(2), n_chains=2, n_iters=15, evidence=ev)
    lab_e = mrf_mod.run_mrf_gibbs(
        mrf, ev, jax.random.key(2), n_chains=2, n_iters=15)
    np.testing.assert_array_equal(np.asarray(lab_c), np.asarray(lab_e))


def test_program_run_argument_validation():
    prog_bn = compile_graph(random_bayesnet(6, seed=0))
    with pytest.raises(ValueError):
        prog_bn.run(jax.random.key(0), evidence=jnp.zeros((2, 2), jnp.int32))
    prog_mrf = compile_graph(GridMRF(4, 4, 2))
    with pytest.raises(ValueError):
        prog_mrf.run(jax.random.key(0))
    with pytest.raises(ValueError):  # burn_in has no MRF meaning: not dropped
        prog_mrf.run(
            jax.random.key(0), burn_in=5,
            evidence=jnp.zeros((4, 4), jnp.int32),
        )


def test_schedule_rounds_match_backend_groups():
    """The cross-check the program relies on for bit-exactness."""
    bn = bn_repository_replica("insurance")
    prog = compile_graph(bn, evidence={3: 1})
    assert len(prog.cbn.groups) == len(prog.schedule.rounds)
    for g, r in zip(prog.cbn.groups, prog.schedule.rounds):
        assert tuple(int(v) for v in np.asarray(g.nodes)) == r.nodes


@pytest.mark.slow
def test_program_run_sharded_8dev():
    """run_sharded executes the same program via shard_map (subprocess with
    8 simulated host devices, mirroring test_distributed_pm)."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.compile import compile_graph
        from repro.core import bayesnet as bnet
        from repro.core.distributed import bn_gibbs_sharded
        from repro.core.graphs import random_bayesnet

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bn = random_bayesnet(12, max_parents=3, cards=(2, 3), seed=3)
        prog = compile_graph(bn, evidence={1: 0})
        marg_p, vals_p = prog.run_sharded(jax.random.key(1), mesh,
                                          n_chains=16, n_iters=50, burn_in=10)
        cbn = bnet.compile_bayesnet(bn, evidence={1: 0})
        marg_e, vals_e = bn_gibbs_sharded(cbn, jax.random.key(1), mesh,
                                          n_chains=16, n_iters=50, burn_in=10,
                                          placement=prog.placement)
        assert (np.asarray(vals_p) == np.asarray(vals_e)).all()
        assert (np.asarray(marg_p) == np.asarray(marg_e)).all()
        marg_s, vals_s = prog.run_sharded(jax.random.key(1), mesh,
                                          n_chains=16, n_iters=50, burn_in=10,
                                          backend="schedule")
        assert (np.asarray(vals_s) == np.asarray(vals_e)).all()
        assert (np.asarray(marg_s) == np.asarray(marg_e)).all()
        print("PROGRAM_SHARDED_OK")
        """
    )
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "PROGRAM_SHARDED_OK" in res.stdout
