"""Fused Pallas BN color-round kernel: bit-exactness matrix against the
unfused engines (samplers x backends x carry-state slice boundaries x
runtime evidence clamps, all under interpret mode), the loud-failure
guarantee for unsupported samplers, the first-use fused cross-check, the
fused serving route, and the chain-state buffer-donation satellite."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import (
    FUSED_BN_SAMPLERS,
    BackendMismatch,
    canonicalize,
    clear_program_cache,
    compile_graph,
    cross_check_fused,
    lower_schedule,
)
from repro.core import bayesnet as bnet
from repro.core.draws import SAMPLERS
from repro.core.graphs import bn_repository_replica, random_bayesnet
from repro.kernels import bn_gibbs
from repro.runtime import Engine, EngineConfig, Query, bucket_key, \
    execute_bucket, zipf_trace


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_program_cache()
    yield
    clear_program_cache()


# ---------------------------------------------------------------------------
# Kernel-level: fused_gibbs_sweep == gibbs_sweep, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampler", FUSED_BN_SAMPLERS)
@pytest.mark.parametrize("workload", ["survey", "alarm"])
def test_fused_sweep_bit_exact(workload, sampler):
    """The tentpole invariant at its smallest scope: one fused sweep (all
    rounds in one pallas_call, values VMEM-resident) equals the unfused
    sweep's bits — same key, same gather tensors."""
    cbn = bnet.compile_bayesnet(bn_repository_replica(workload))
    fr = bn_gibbs.build_fused_rounds(cbn.groups)
    vals, _ = bnet.init_chain_values(cbn, jax.random.key(0), 3)
    key = jax.random.key(11)
    ref = bnet.gibbs_sweep(cbn, vals, key, sampler)
    fus = bn_gibbs.fused_gibbs_sweep(cbn, fr, vals, key, sampler,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fus))


def test_fused_sweep_wide_cards_bit_exact():
    """Heterogeneous cardinalities exercise the NEG_INF card mask and the
    per-node rejection-bin placement."""
    bn = random_bayesnet(14, max_parents=3, cards=(2, 6), seed=9)
    cbn = bnet.compile_bayesnet(bn)
    fr = bn_gibbs.build_fused_rounds(cbn.groups)
    vals, _ = bnet.init_chain_values(cbn, jax.random.key(1), 4)
    for sampler in FUSED_BN_SAMPLERS:
        key = jax.random.key(23)
        ref = bnet.gibbs_sweep(cbn, vals, key, sampler)
        fus = bn_gibbs.fused_gibbs_sweep(cbn, fr, vals, key, sampler,
                                         interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(fus))


# ---------------------------------------------------------------------------
# Program-level matrix: fused vs both unfused backends, clamps, slices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampler", FUSED_BN_SAMPLERS)
@pytest.mark.parametrize("workload", ["survey", "alarm"])
def test_bn_fused_run_bit_exact(workload, sampler):
    prog = compile_graph(bn_repository_replica(workload), evidence={0: 0})
    kwargs = dict(n_chains=3, n_iters=8, burn_in=2, sampler=sampler)
    marg_e, vals_e = prog.run(jax.random.key(9), backend="eager", **kwargs)
    marg_s, vals_s = prog.run(jax.random.key(9), backend="schedule",
                              **kwargs)
    marg_f, vals_f = prog.run(jax.random.key(9), backend="schedule",
                              fused=True, **kwargs)
    for other_v, other_m in ((vals_e, marg_e), (vals_s, marg_s)):
        np.testing.assert_array_equal(np.asarray(vals_f), np.asarray(other_v))
        np.testing.assert_array_equal(np.asarray(marg_f), np.asarray(other_m))
    assert sampler in prog._fused_checked  # first-use cross-check ran


@pytest.mark.parametrize("sampler", FUSED_BN_SAMPLERS)
def test_bn_fused_clamped_and_sliced_bit_exact(sampler):
    """The full serving shape at once: runtime evidence clamps + a slice
    boundary mid-burn-in + thinning mid-stride, fused == unfused == the
    uninterrupted run, marginals included."""
    bn = random_bayesnet(10, max_parents=2, cards=(2, 3), seed=3)
    prog = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    kw = dict(n_chains=3, burn_in=4, thin=2, sampler=sampler,
              evidence={1: 0, 5: 1})
    m_ref, v_ref = prog.run(jax.random.key(1), n_iters=9, **kw)
    m_f, v_f = prog.run(jax.random.key(1), n_iters=9, fused=True, **kw)
    np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(m_f), np.asarray(m_ref))
    # slice the fused run at 3 + 6 (burn-in still in progress at the cut)
    _, _, st = prog.run(jax.random.key(1), n_iters=3, return_state=True,
                        fused=True, **kw)
    m_s, v_s = prog.run(None, n_iters=6, carry_state=st, fused=True, **kw)
    np.testing.assert_array_equal(np.asarray(v_s), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_ref))


def test_fused_unsupported_sampler_raises():
    """fused=True on a sampler the kernel does not implement must raise —
    at run(), and in the loop itself — never silently fall back to the
    unfused path (the caller asked for an execution route, not a hint)."""
    prog = compile_graph(bn_repository_replica("survey"))
    for sampler in set(SAMPLERS) - set(FUSED_BN_SAMPLERS):
        with pytest.raises(ValueError, match="fused BN rounds"):
            prog.run(jax.random.key(0), n_chains=2, n_iters=2,
                     backend="schedule", fused=True, sampler=sampler)
        with pytest.raises(ValueError, match="fused BN rounds"):
            bnet.gibbs_run_loop(
                prog.cbn, prog.cbn.groups,
                jnp.zeros((2, prog.ir.n_nodes), jnp.int32),
                jax.random.key(0), 2, 0, sampler, fused=True,
            )
    with pytest.raises(ValueError):  # fused still needs the schedule backend
        prog.run(jax.random.key(0), backend="eager", fused=True)


def test_fused_cross_check_catches_divergence():
    """The first-use guard really guards: an executable whose rounds were
    corrupted (reversed order => different key-to-round pairing) must be
    flagged as a backend mismatch before fused execution ever serves."""
    prog = compile_graph(bn_repository_replica("alarm"), evidence={0: 0})
    ex = lower_schedule(prog)
    ex.round_groups = list(reversed(ex.round_groups))
    with pytest.raises(BackendMismatch, match="fused"):
        cross_check_fused(prog, ex)


# ---------------------------------------------------------------------------
# Serving: fused buckets, engine route, donation
# ---------------------------------------------------------------------------


def test_fused_bucket_bit_exact_and_eligibility():
    bn = random_bayesnet(9, max_parents=2, cards=(2, 3), seed=5)
    graph = canonicalize(bn, evidence_mode="runtime")
    prog = compile_graph(graph, pipeline="runtime")
    mk = lambda qid, seed, sampler="lut_ky": Query(
        qid=qid, model="m", evidence={1: 0}, n_chains=2, n_iters=6,
        burn_in=2, seed=seed, sampler=sampler,
    )
    qs = [mk(0, 11), mk(1, 22)]
    key_u = bucket_key(qs[0], graph, "schedule")
    key_f = bucket_key(qs[0], graph, "schedule", fused=True)
    assert not key_u.fused and key_f.fused
    ref = execute_bucket(prog, key_u, qs)
    fus = execute_bucket(prog, key_f, qs)
    for r, f in zip(ref, fus):
        np.testing.assert_array_equal(r.final_state, f.final_state)
        np.testing.assert_array_equal(r.marginals, f.marginals)
    # ineligible signatures demote to the unfused route instead of failing
    # mixed traffic (the run() API raises; the bucket router serves)
    assert not bucket_key(mk(2, 3, "cdf"), graph, "schedule",
                          fused=True).fused
    assert not bucket_key(mk(3, 4), graph, "eager", fused=True).fused


def test_engine_fused_matches_unfused():
    """An engine with fused=True serves byte-identical posteriors — the
    knob is pure service time, never an answer change."""
    out = {}
    for fused in (False, True):
        clear_program_cache()
        models, queries = zipf_trace(10, quick=True, seed=0)
        eng = Engine(models, EngineConfig(fused=fused, slice_iters=8))
        eng.submit(queries)
        out[fused] = eng.run()
    assert out[False].keys() == out[True].keys()
    for qid in out[False]:
        a, b = out[False][qid], out[True][qid]
        np.testing.assert_array_equal(a.final_state, b.final_state)
        if a.marginals is not None:
            np.testing.assert_array_equal(a.marginals, b.marginals)


def test_engine_fused_requires_schedule_backend():
    models, _ = zipf_trace(2, quick=True, seed=0)
    with pytest.raises(ValueError, match="schedule"):
        Engine(models, EngineConfig(backend="eager", fused=True))


def test_carry_donation_no_copy():
    """Donation satellite: resuming from a carried chain state consumes it
    in place (no per-slice copy).  On platforms with buffer donation the
    donated leaves are deleted; either way the resumed bits must equal the
    uninterrupted run's."""
    bn = random_bayesnet(8, max_parents=2, cards=(2, 3), seed=1)
    prog = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    kw = dict(n_chains=2, burn_in=0, sampler="lut_ky")
    m_ref, v_ref = prog.run(jax.random.key(4), n_iters=7, **kw)
    _, _, st = prog.run(jax.random.key(4), n_iters=3, return_state=True,
                        **kw)
    donated_vals = st.vals
    m2, v2 = prog.run(None, n_iters=4, carry_state=st, **kw)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(m_ref))
    # CPU/TPU/GPU all support donation in the supported jax range; the
    # (B, n) vals leaf aliases the output, so the input must be gone
    assert donated_vals.is_deleted()


def test_stacked_bucket_carry_survives_donation():
    """The bucket executables donate the *stacked* carry, which is built
    fresh per dispatch — the per-query chain states must stay live so a
    continuation can be replayed into a different bucket."""
    bn = random_bayesnet(8, max_parents=2, cards=(2, 3), seed=2)
    graph = canonicalize(bn, evidence_mode="runtime")
    prog = compile_graph(graph, pipeline="runtime")
    q = Query(qid=0, model="m", evidence={1: 0}, n_chains=2, n_iters=8,
              burn_in=2, seed=7)
    skey = bucket_key(q, graph, "schedule", slice_iters=4)
    r = execute_bucket(prog, skey, [q], return_state=True)[0]
    cont = dataclasses.replace(q, carry=r.carry, n_iters=4)
    rkey = bucket_key(cont, graph, "schedule", slice_iters=4)
    a = execute_bucket(prog, rkey, [cont])[0]
    # the same carry again, in a two-query bucket: still usable, same bits
    b = execute_bucket(prog, rkey, [cont, cont])[0]
    np.testing.assert_array_equal(a.final_state, b.final_state)
