"""repro.runtime.executor subsystem: chain-state carry-over bit-exactness
(sliced == uninterrupted for every sampler x backend x fused), resumed
slices batched into foreign buckets, the multi-worker pool, measured-time
calibration, token-bucket admission + bounded queues, and the engine-level
continuous-batching guarantees."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compile import canonicalize, clear_program_cache, compile_graph
from repro.compile import ir as compile_ir
from repro.core import mrf as mrf_mod
from repro.core.draws import SAMPLERS
from repro.core.graphs import GridMRF, bn_repository_replica, random_bayesnet
from repro.runtime import (
    AdmissionConfig,
    AdmissionController,
    Calibrator,
    Engine,
    EngineConfig,
    Executor,
    ExecutorConfig,
    Query,
    RuntimeMetrics,
    WorkerPool,
    bucket_key,
    bursty_trace,
    execute_bucket,
    sig_of,
    zipf_trace,
)
from repro.runtime.admission import ADMIT, DEFER, SHED
from repro.runtime.metrics import BatchRecord, percentile


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_program_cache()
    yield
    clear_program_cache()


# ---------------------------------------------------------------------------
# Chain-state carry-over: sliced == uninterrupted, asserted bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampler", SAMPLERS)
@pytest.mark.parametrize("backend", ["eager", "schedule"])
def test_bn_sliced_run_bit_exact(sampler, backend):
    """The tentpole guarantee: a BN run sliced at an arbitrary boundary —
    burn-in still in progress, thinning mid-stride — equals the
    uninterrupted run bit for bit, marginals included."""
    bn = random_bayesnet(10, max_parents=2, cards=(2, 3), seed=3)
    prog = compile_graph(canonicalize(bn, evidence_mode="runtime"))
    kw = dict(n_chains=3, burn_in=4, thin=2, sampler=sampler,
              backend=backend, evidence={1: 0, 5: 1})
    m_full, v_full = prog.run(jax.random.key(1), n_iters=11, **kw)
    m1, v1, st = prog.run(
        jax.random.key(1), n_iters=3, return_state=True, **kw
    )
    m2, v2, st2 = prog.run(
        None, n_iters=5, carry_state=st, return_state=True, **kw
    )
    m3, v3 = prog.run(None, n_iters=3, carry_state=st2, **kw)
    np.testing.assert_array_equal(np.asarray(v_full), np.asarray(v3))
    np.testing.assert_array_equal(np.asarray(m_full), np.asarray(m3))


@pytest.mark.parametrize("sampler", SAMPLERS)
@pytest.mark.parametrize("backend,fused", [
    ("eager", False), ("schedule", False), ("schedule", True),
])
def test_mrf_sliced_run_bit_exact(sampler, backend, fused):
    """Same guarantee on the grid path, fused Pallas rounds included."""
    if fused and sampler != "lut_ky":
        pytest.skip("fused rounds implement the lut_ky datapath only")
    mrf = GridMRF(8, 8, 3, theta=1.1, h=1.5)
    prog = compile_graph(compile_ir.from_mrf(mrf))
    _, noisy = mrf_mod.make_denoising_problem(8, 8, 3, 0.25, seed=0)
    kw = dict(n_chains=2, sampler=sampler, evidence=jnp.asarray(noisy),
              backend=backend, fused=fused, pins={3: 1})
    full = prog.run(jax.random.key(5), n_iters=8, **kw)
    _, st = prog.run(jax.random.key(5), n_iters=3, return_state=True, **kw)
    resumed = prog.run(None, n_iters=5, carry_state=st, **kw)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(resumed))


def test_carry_state_validation():
    bn_prog = compile_graph(canonicalize(random_bayesnet(6, seed=0),
                                         evidence_mode="runtime"))
    mrf_prog = compile_graph(compile_ir.from_mrf(GridMRF(4, 4, 2)))
    img = jnp.zeros((4, 4), jnp.int32)
    _, _, bn_state = bn_prog.run(
        jax.random.key(0), n_chains=2, n_iters=2, burn_in=0,
        return_state=True,
    )
    with pytest.raises(TypeError):  # MRF state into a BN program
        _, mrf_state = mrf_prog.run(
            jax.random.key(0), n_chains=2, n_iters=2, evidence=img,
            return_state=True,
        )
        bn_prog.run(None, n_iters=2, burn_in=0, carry_state=mrf_state)
    with pytest.raises(TypeError):  # BN state into an MRF program
        mrf_prog.run(None, n_iters=2, evidence=img, carry_state=bn_state)
    with pytest.raises(ValueError):  # fresh run with no key
        bn_prog.run(None, n_iters=2)


def test_resumed_slice_in_foreign_bucket_bit_exact():
    """Satellite gate: a resumed slice batched with a *different* set of
    companions (it landed in another bucket than its first slice) still
    produces the uninterrupted run's bits — vmap lanes are independent and
    the carry is the whole chain state."""
    bn = random_bayesnet(9, max_parents=2, cards=(2, 3), seed=5)
    graph = canonicalize(bn, evidence_mode="runtime")
    prog = compile_graph(graph, pipeline="runtime")
    mk = lambda qid, seed: Query(
        qid=qid, model="m", evidence={1: 0, 4: 1}, n_chains=2,
        n_iters=10, burn_in=2, seed=seed,
    )
    qa, qb = mk(0, 11), mk(1, 22)
    # uninterrupted reference for A, alone in its bucket
    ref = execute_bucket(
        prog, bucket_key(qa, graph, "schedule"), [qa]
    )[0]
    # slice A and B separately (different buckets: A alone, B alone)
    sliced_key = bucket_key(qa, graph, "schedule", slice_iters=6)
    ra = execute_bucket(prog, sliced_key, [qa], return_state=True)[0]
    rb = execute_bucket(prog, sliced_key, [qb], return_state=True)[0]
    conta = dataclasses.replace(qa, carry=ra.carry, n_iters=4)
    contb = dataclasses.replace(qb, carry=rb.carry, n_iters=4)
    # resume A *batched with B* — a bucket neither slice ever saw
    rkey = bucket_key(conta, graph, "schedule", slice_iters=6)
    assert rkey.resumed and rkey.n_iters == 4
    out = execute_bucket(prog, rkey, [conta, contb])
    np.testing.assert_array_equal(out[0].final_state, ref.final_state)
    np.testing.assert_array_equal(out[0].marginals, ref.marginals)
    # and B equals ITS standalone resume, companions notwithstanding
    solo_b = execute_bucket(prog, rkey, [contb])[0]
    np.testing.assert_array_equal(out[1].final_state, solo_b.final_state)


# ---------------------------------------------------------------------------
# WorkerPool + Executor
# ---------------------------------------------------------------------------


def test_worker_pool_overlaps_and_is_deterministic():
    pool = WorkerPool(3)
    w0, s0 = pool.assign(0.0)
    assert w0 == (0,) and s0 == 0.0
    pool.commit(w0, s0, 5.0)
    w1, s1 = pool.assign(1.0)
    assert w1 == (1,) and s1 == 1.0  # overlaps with worker 0's dispatch
    pool.commit(w1, s1, 4.0)
    w2, s2 = pool.assign(1.0)
    assert w2 == (2,)
    pool.commit(w2, 1.0, 2.0)
    # all busy: earliest-free wins, queued behind its finish
    w3, s3 = pool.assign(1.5)
    assert w3 == (2,) and s3 == 2.0
    assert pool.busy_s == [5.0, 3.0, 1.0]


def test_worker_pool_slice_assignment():
    pool = WorkerPool(4)
    workers, start = pool.assign(0.0, width=2)
    assert workers == (0, 1) and start == 0.0
    pool.commit(workers, 0.0, 3.0)
    workers, start = pool.assign(0.0, width=2)
    assert workers == (2, 3)  # the free slice, not the busy one
    pool.commit(workers, 0.0, 1.0)
    workers, start = pool.assign(0.0, width=4)
    assert workers == (0, 1, 2, 3) and start == 3.0  # waits for the slowest


def test_executor_config_validation():
    with pytest.raises(ValueError):
        ExecutorConfig(n_workers=0)
    with pytest.raises(ValueError):  # sharded route needs a real slice
        ExecutorConfig(n_workers=2, shard_width=1, shard_min_sites=16)
    with pytest.raises(ValueError):  # slice can't exceed the pool
        ExecutorConfig(n_workers=2, shard_width=4, shard_min_sites=16)


def test_executor_routing_rules():
    cal = Calibrator()
    ex = Executor(
        ExecutorConfig(n_workers=4, shard_width=2, shard_min_sites=64),
        cal, (8,),
    )
    mrf_prog = compile_graph(compile_ir.from_mrf(GridMRF(8, 8, 2)))
    bn_graph = canonicalize(random_bayesnet(6, seed=1),
                            evidence_mode="runtime")
    bn_prog = compile_graph(bn_graph)
    img = np.zeros((8, 8), np.int32)
    q = Query(qid=0, model="g", image=img, n_chains=2, n_iters=2)
    mrf_key = bucket_key(q, compile_ir.from_mrf(GridMRF(8, 8, 2)), "schedule")
    assert ex.route(mrf_prog, mrf_key) == "sharded"  # 64 sites >= 64
    pinned = dataclasses.replace(q, evidence={0: 1})
    pkey = bucket_key(pinned, compile_ir.from_mrf(GridMRF(8, 8, 2)),
                      "schedule")
    assert ex.route(mrf_prog, pkey) == "vmap"  # pins never shard
    bq = Query(qid=1, model="b", n_chains=2, n_iters=2)
    assert ex.route(bn_prog, bucket_key(bq, bn_graph, "schedule")) == "vmap"
    # resumed buckets never shard (carry-over is a vmap-route concept)
    rq = dataclasses.replace(q, carry=object())
    rkey = bucket_key(rq, compile_ir.from_mrf(GridMRF(8, 8, 2)), "schedule")
    assert ex.route(mrf_prog, rkey) == "vmap"
    # too-small grids stay on one device
    small = Executor(
        ExecutorConfig(n_workers=4, shard_width=2, shard_min_sites=1000),
        cal, (8,),
    )
    assert small.route(mrf_prog, mrf_key) == "vmap"


def test_executor_sharded_dispatch_occupies_the_slice():
    """A sharded-routed dispatch books every worker in its mesh slice and
    bills compute/width + comm (on a one-device host the math falls back
    to the vmap executable, but the clock must model the slice)."""
    cal = Calibrator()
    ex = Executor(
        ExecutorConfig(n_workers=4, shard_width=2, shard_min_sites=64),
        cal, (4,),
    )
    prog = compile_graph(compile_ir.from_mrf(GridMRF(8, 8, 2)))
    img = np.zeros((8, 8), np.int32)
    qs = [Query(qid=i, model="g", image=img, n_chains=2, n_iters=2, seed=i)
          for i in range(2)]
    key = bucket_key(qs[0], compile_ir.from_mrf(GridMRF(8, 8, 2)),
                     "schedule")
    batch, rec = ex.dispatch(prog, key, qs, 0.0)
    assert rec.route == "sharded" and rec.n_workers == 2
    assert ex.pool.busy_until[0] == ex.pool.busy_until[1] == rec.finish_s
    assert ex.pool.busy_until[2] == 0.0
    assert len(batch) == 2
    # the sharded line model is cheaper per sweep than the serial one
    sig = sig_of(key, "sharded")
    assert cal.line_s(prog, sig, 2, shard_width=2) < \
        cal.line_s(prog, sig, 2, shard_width=1)
    # a batch whose queries continue past this slice must NOT shard: the
    # sharded path cannot return the chain state the continuations need
    long_qs = [dataclasses.replace(q, n_iters=8) for q in qs]
    sliced_key = bucket_key(
        long_qs[0], compile_ir.from_mrf(GridMRF(8, 8, 2)), "schedule",
        slice_iters=2,
    )
    _, rec2 = ex.dispatch(prog, sliced_key, long_qs, 10.0,
                          return_state=True)
    assert rec2.route == "vmap" and rec2.n_workers == 1


# ---------------------------------------------------------------------------
# Calibrator
# ---------------------------------------------------------------------------


def test_calibrator_cold_fallback_and_measured_override():
    cal = Calibrator()
    prog = compile_graph(canonicalize(random_bayesnet(6, seed=2),
                                      evidence_mode="runtime"))
    q = Query(qid=0, model="m", n_chains=4, n_iters=8)
    sig = sig_of(bucket_key(q, prog.ir, "schedule"))
    cold, src = cal.predict(prog, sig, 4)
    assert src == "line" and cold == cal.line_s(prog, sig, 4)
    cal.record(sig, 4, 0.125)
    warm, src = cal.predict(prog, sig, 4)
    assert src == "measured" and warm == 0.125
    # pad scaling: within one chain wave the prediction is flat; past the
    # wave boundary it scales by the wave ratio
    same_wave, _ = cal.predict(prog, sig, 8)
    assert same_wave == 0.125
    big = dataclasses.replace(sig, n_chains=256)
    cal.record(big, 1, 0.1)
    two_waves, _ = cal.predict(prog, big, 2)
    assert two_waves == pytest.approx(0.2)


def test_engine_calibrate_freezes_measurements_and_stays_deterministic():
    models, queries = zipf_trace(16, quick=True, seed=3,
                                 mean_interarrival_s=1e-4)
    keep = {"survey", "cancer"}
    models = {k: v for k, v in models.items() if k in keep}
    queries = [q for q in queries if q.model in keep]
    eng = Engine(models, EngineConfig(pad_sizes=(4,), max_batch=4))
    eng.submit(queries)
    cal = eng.calibrate(queries)
    assert len(cal.measured) > 0
    for _, seconds in cal.measured.values():
        assert seconds > 0
    res1 = eng.run()
    s1 = eng.metrics.summary()
    assert all(b.service_src == "measured"
               for b in eng.metrics.batch_records)
    # replay with the SAME frozen table: identical sim metrics
    eng2 = Engine(models, EngineConfig(pad_sizes=(4,), max_batch=4),
                  calibrator=cal)
    eng2.submit(queries)
    res2 = eng2.run()
    s2 = eng2.metrics.summary()
    for k in s1:
        if k not in ("wall_s", "calib_median_err"):
            assert s1[k] == s2[k], k
    for qid in res1:
        assert res1[qid].finish_s == res2[qid].finish_s


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(policy="drop")
    with pytest.raises(ValueError):
        AdmissionConfig(rate_qps=0)
    with pytest.raises(ValueError):
        AdmissionConfig(queue_limit=0)


def test_token_bucket_admits_defers_and_sheds():
    ctl = AdmissionController(AdmissionConfig(rate_qps=10.0, burst=2,
                                              max_defer_s=1.0))
    assert ctl.decide(0.0, 0.0)[0] == ADMIT
    assert ctl.decide(0.0, 0.0)[0] == ADMIT  # burst depth 2
    decision, retry = ctl.decide(0.0, 0.0)
    assert decision == DEFER and retry == pytest.approx(0.1)
    # the deferred query re-arrives exactly when its token exists: admitted
    # (the 1e-9 tolerance — without it this would spin forever)
    assert ctl.decide(retry, 0.0)[0] == ADMIT
    assert ctl.defers == 1 and ctl.shed_tokens == 0
    # past the defer budget: shed
    decision, _ = ctl.decide(retry, retry - 1.0)
    assert decision == SHED and ctl.shed_tokens == 1


def test_token_bucket_shed_policy_and_open_admission():
    ctl = AdmissionController(AdmissionConfig(rate_qps=1.0, burst=1,
                                              policy="shed"))
    assert ctl.decide(0.0, 0.0)[0] == ADMIT
    assert ctl.decide(0.0, 0.0)[0] == SHED  # no second chances
    open_ctl = AdmissionController(None)
    for i in range(100):
        assert open_ctl.decide(0.0, 0.0)[0] == ADMIT


def test_queue_bounds():
    ctl = AdmissionController(AdmissionConfig(queue_limit=3))
    assert not ctl.queue_full(2)
    assert ctl.queue_full(3)
    ctl.record_shed(7, by_queue=True)
    assert ctl.sheds == 1 and ctl.shed_queue == 1
    assert AdmissionController(None).queue_full(10 ** 9) is False


def test_engine_bounded_queues_and_shed_accounting():
    """Saturating bursty arrivals against a bounded engine: every pending
    queue stays within the limit, sheds are reported, and served + shed
    covers every submitted query."""
    models, queries = bursty_trace(30, quick=True, seed=2)
    keep = {"survey", "grid"}
    models = {k: v for k, v in models.items() if k in keep}
    queries = [q for q in queries if q.model in keep]
    cfg = EngineConfig(
        pad_sizes=(4,), max_batch=4,
        admission=AdmissionConfig(rate_qps=2000.0, burst=4, queue_limit=3,
                                  policy="shed"),
    )
    eng = Engine(models, cfg)
    eng.submit(queries)
    res = eng.run()
    s = eng.metrics.summary()
    assert s["sheds"] > 0  # the burst actually saturated the bucket
    assert len(res) + s["sheds"] == len(queries)
    assert set(eng.shed_qids).isdisjoint(res)
    assert s["max_queue_depth"] <= 3
    assert s["shed_rate"] == pytest.approx(s["sheds"] / len(queries))
    # determinism under backpressure: replay from a cold program cache
    # reproduces every counter
    clear_program_cache()
    eng2 = Engine(models, cfg)
    models2, queries2 = bursty_trace(30, quick=True, seed=2)
    eng2.submit([q for q in queries2 if q.model in keep])
    res2 = eng2.run()
    s2 = eng2.metrics.summary()
    for k in s:
        if k not in ("wall_s", "calib_median_err"):
            assert s[k] == s2[k], k
    assert sorted(res2) == sorted(res)


# ---------------------------------------------------------------------------
# Engine: multi-worker overlap + continuous batching
# ---------------------------------------------------------------------------


def _zoo(seed=7, n=24):
    models, queries = zipf_trace(n, quick=True, seed=seed,
                                 mean_interarrival_s=5e-5)
    keep = {"survey", "cancer", "grid"}
    models = {k: v for k, v in models.items() if k in keep}
    return models, [q for q in queries if q.model in keep]


def test_multi_worker_qps_beats_serial_and_preserves_bits():
    m1, q1 = _zoo()
    e1 = Engine(m1, EngineConfig(pad_sizes=(4,), max_batch=4, n_workers=1))
    e1.submit(q1)
    r1 = e1.run()
    m4, q4 = _zoo()
    e4 = Engine(m4, EngineConfig(pad_sizes=(4,), max_batch=4, n_workers=4))
    e4.submit(q4)
    r4 = e4.run()
    s1, s4 = e1.metrics.summary(), e4.metrics.summary()
    assert s4["throughput_qps"] > s1["throughput_qps"]
    assert s4["latency_p95_s"] <= s1["latency_p95_s"]
    # worker count changes the clock, never the posterior
    for qid in r1:
        np.testing.assert_array_equal(r1[qid].final_state,
                                      r4[qid].final_state)
    assert len(s4["worker_util"]) == 4
    assert sum(e4.metrics.worker_busy_s) > 0


def test_engine_sliced_serving_bit_exact_with_unsliced():
    m_a, q_a = _zoo(seed=9)
    e_a = Engine(m_a, EngineConfig(pad_sizes=(4,), max_batch=4))
    e_a.submit(q_a)
    r_a = e_a.run()
    m_b, q_b = _zoo(seed=9)
    e_b = Engine(m_b, EngineConfig(pad_sizes=(4,), max_batch=4,
                                   slice_iters=5))
    e_b.submit(q_b)
    r_b = e_b.run()
    assert sorted(r_a) == sorted(r_b)
    assert e_b.metrics.summary()["n_batches"] > \
        e_a.metrics.summary()["n_batches"]
    for qid in r_a:
        np.testing.assert_array_equal(r_a[qid].final_state,
                                      r_b[qid].final_state)
        if r_a[qid].marginals is not None:
            np.testing.assert_array_equal(r_a[qid].marginals,
                                          r_b[qid].marginals)


def test_slicing_interleaves_short_queries_between_long_slices():
    """The continuous-batching win itself: a short query that arrives while
    a long query is mid-flight finishes earlier when the long query is
    sliced, because its slices yield the (single) worker."""
    bn = bn_repository_replica("survey")
    long_q = Query(qid=0, model="m", evidence={0: 1}, n_chains=2,
                   n_iters=24, burn_in=0, seed=1, arrival_s=0.0)
    short_q = Query(qid=1, model="m", evidence={0: 1}, n_chains=2,
                    n_iters=4, burn_in=0, seed=2, arrival_s=1e-5)

    def serve(slice_iters):
        eng = Engine({"m": bn}, EngineConfig(
            pad_sizes=(2,), max_batch=2, window_s=1e-6,
            slice_iters=slice_iters,
        ))
        eng.submit([dataclasses.replace(long_q),
                    dataclasses.replace(short_q)])
        return eng.run()

    unsliced = serve(None)
    sliced = serve(4)
    assert sliced[1].finish_s < unsliced[1].finish_s
    # and the long query still gets its exact bits
    np.testing.assert_array_equal(unsliced[0].final_state,
                                  sliced[0].final_state)


def test_continuations_respect_queue_bound_without_starving():
    """A continuation that re-arrives to a full bucket (queue_limit below
    max_batch, so the bucket cannot fill-flush its way clear) waits for the
    bucket's flush horizon instead of shedding or spinning — every query
    still completes, and the bound holds throughout."""
    bn = bn_repository_replica("survey")
    queries = [
        Query(qid=i, model="m", evidence={0: 1}, n_chains=2,
              n_iters=12, burn_in=0, seed=i, arrival_s=1e-6 * i)
        for i in range(6)
    ]
    eng = Engine({"m": bn}, EngineConfig(
        pad_sizes=(4,), max_batch=4, window_s=5e-4, slice_iters=4,
        admission=AdmissionConfig(queue_limit=2),
    ))
    eng.submit(queries)
    res = eng.run()
    s = eng.metrics.summary()
    # sheds may hit fresh arrivals (the bound is real), but every *served*
    # query ran all its slices and every continuation survived
    assert len(res) + s["sheds"] == len(queries)
    assert s["max_queue_depth"] <= 2
    ref = Engine({"m": bn}, EngineConfig(pad_sizes=(4,), max_batch=4))
    ref.submit([dataclasses.replace(q) for q in queries])
    whole = ref.run()
    for qid in res:
        np.testing.assert_array_equal(res[qid].final_state,
                                      whole[qid].final_state)


def test_lone_overflow_continuation_terminates():
    """Regression: a single continuation meeting a full bucket while the
    heap is otherwise empty and a worker is free must not stall the event
    loop (a heap-parked retry used to suppress the `not heap` drain rule
    and ulp-step the clock toward the window expiry — an effective hang)."""
    bn = bn_repository_replica("survey")
    queries = [
        Query(qid=0, model="m", evidence={0: 1}, n_chains=2, n_iters=8,
              burn_in=0, seed=1, arrival_s=0.0),
        Query(qid=1, model="m", evidence={0: 1}, n_chains=2, n_iters=8,
              burn_in=0, seed=2, arrival_s=0.0),
        Query(qid=2, model="m", evidence={0: 1}, n_chains=2, n_iters=8,
              burn_in=0, seed=3, arrival_s=3e-4),
    ]
    eng = Engine({"m": bn}, EngineConfig(
        pad_sizes=(4,), max_batch=4, window_s=2e-4, slice_iters=4,
        n_workers=2, admission=AdmissionConfig(queue_limit=2),
    ))
    eng.submit(queries)
    res = eng.run()
    s = eng.metrics.summary()
    assert len(res) + s["sheds"] == 3
    assert s["max_queue_depth"] <= 2


# ---------------------------------------------------------------------------
# Metrics hardening
# ---------------------------------------------------------------------------


def test_percentiles_refuse_tiny_samples():
    assert percentile([], 50) is None
    assert percentile([1.0], 95) is None
    assert percentile([1.0, 3.0], 50) == 2.0


def test_summary_reports_na_on_empty_and_singleton_runs():
    m = RuntimeMetrics()
    s = m.summary()  # empty run: no crash, no invented latencies
    assert s["latency_p50_s"] is None and s["latency_p95_s"] is None
    assert s["latency_mean_s"] is None and s["throughput_qps"] == 0.0
    # zero dispatched batches: no mean batch size either (satellite fix —
    # this used to divide by a clamped denominator and report 0.0)
    assert s["mean_batch"] is None
    assert "n/a" in m.table()
    from repro.runtime.batcher import QueryResult

    m.record_queries([QueryResult(
        qid=0, model="m", kind="bn", marginals=None,
        final_state=np.zeros(1), arrival_s=0.0, start_s=1.0, finish_s=2.0,
    )])
    m.record_batch(BatchRecord(model="m", kind="bn", n_real=1, n_padded=1,
                               service_s=1.0, clamp_lowerings=0))
    s = m.summary()  # singleton: a mean exists, percentiles do not
    assert s["latency_p50_s"] is None and s["latency_p95_s"] is None
    # seconds end to end: the summary never pre-converts to ms (the old
    # double conversion reported ms-of-ms in table())
    assert s["latency_mean_s"] == pytest.approx(2.0)
    assert s["n_queries"] == 1


def test_summary_surfaces_workers_and_backpressure():
    m = RuntimeMetrics()
    m.worker_busy_s = (1.0, 3.0)
    m.sheds, m.shed_queue, m.defers, m.max_queue_depth = 2, 1, 5, 7
    s = m.summary()
    assert s["n_workers"] == 2 and len(s["worker_util"]) == 2
    assert s["sheds"] == 2 and s["defers"] == 5
    assert s["max_queue_depth"] == 7
    assert "| 2 | 5 | 7 |" in m.table()


# ---------------------------------------------------------------------------
# Multi-device sharded serving (advisory CI job)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engine_sharded_route_8dev():
    """The executor's sharded route really executes through run_sharded
    when the host has enough devices (subprocess with 8 simulated host
    devices, mirroring test_distributed_pm)."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from repro.runtime import Engine, EngineConfig, zipf_trace

        models, queries = zipf_trace(20, quick=True, seed=4,
                                     mean_interarrival_s=1e-4)
        models = {k: v for k, v in models.items() if k == "grid"}
        queries = [q for q in queries if q.model == "grid"]
        for q in queries:
            q.evidence = None  # pins never shard; exercise the route
        eng = Engine(models, EngineConfig(
            pad_sizes=(4,), max_batch=4, n_workers=8, shard_width=4,
            shard_min_sites=64,
        ))
        eng.submit(queries)
        res = eng.run()
        s = eng.metrics.summary()
        assert len(res) == len(queries)
        assert s["sharded_batches"] > 0, s
        assert any(b.route == "sharded" and b.n_workers == 4
                   for b in eng.metrics.batch_records)
        print("SHARDED_SERVING_OK")
        """
    )
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARDED_SERVING_OK" in res.stdout


@pytest.mark.slow
def test_engine_batched_sliced_sharded_fused_8dev():
    """The tentpole acceptance: one bucket simultaneously batched, sliced,
    sharded, AND fused — every dispatch (continuations included) runs the
    one-shard_map-body fused datapath, the quality accumulator rides the
    carry, the shard_map executable attributes in the profile join, and
    the results are bit-exact with a single-device vmap engine."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        from repro import obs
        from repro.compile import clear_program_cache
        from repro.core import mrf as mrf_mod
        from repro.core.graphs import GridMRF
        from repro.obs import export
        from repro.obs import profile as profile_mod
        from repro.runtime import Engine, EngineConfig, Query

        mrf = GridMRF(8, 8, 3, theta=1.1, h=1.5)
        imgs = [np.asarray(
                    mrf_mod.make_denoising_problem(8, 8, 3, 0.25, seed=s)[1])
                for s in range(3)]

        def queries():
            return [Query(qid=i, model="g", image=imgs[i % 3], n_chains=2,
                          n_iters=8, seed=i, arrival_s=1e-5 * i)
                    for i in range(6)]

        tr = obs.enable()
        reg = profile_mod.enable()
        eng = Engine({"g": mrf}, EngineConfig(
            pad_sizes=(4,), max_batch=4, n_workers=8, shard_width=4,
            shard_min_sites=64, fused=True, diagnostics=True, slice_iters=3,
        ))
        eng.submit(queries())
        res = eng.run()
        recs = eng.metrics.batch_records
        assert len(res) == 6
        # 8 sweeps in slices of 3: every query resumed twice, and every
        # dispatch — fresh or resumed — kept the fused sharded route
        assert len(recs) > 2
        assert all(r.route == "sharded" and r.n_workers == 4 for r in recs)
        assert all(res[q].quality is not None for q in res)

        # the shard_map executable was captured under the dispatch
        # signature: zero unattributed, collective bytes on a sharded row
        events = export.events_as_dicts(list(tr.events))
        joined = profile_mod.join_dispatches(reg.profiles, events)
        assert joined["unattributed"] == [], joined["unattributed"]
        assert joined["n_sharded"] == len(recs)
        assert any(p.meta.get("route") == "sharded"
                   and p.collective_bytes > 0
                   for p in reg.profiles.values())

        # bit-exact with the single-device vmap engine, unsliced
        obs.disable()
        profile_mod.disable()
        clear_program_cache()
        ref = Engine({"g": mrf}, EngineConfig(pad_sizes=(4,), max_batch=4,
                                              fused=True, diagnostics=True))
        ref.submit(queries())
        whole = ref.run()
        for qid in res:
            np.testing.assert_array_equal(res[qid].final_state,
                                          whole[qid].final_state)
            qa, qb = res[qid].quality, whole[qid].quality
            assert qa.keys() == qb.keys()
            for k in qa:
                x, y = qa[k], qb[k]
                assert x == y or (x != x and y != y), (k, x, y)
        print("SHARDED_FUSED_ENGINE_OK")
        """
    )
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARDED_FUSED_ENGINE_OK" in res.stdout
