"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs (harness deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as tfm

B, S = 2, 16


def make_batch(cfg, key=None):
    key = key or jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    s_tok = S - (cfg.frontend_len if cfg.frontend else 0)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, s_tok), 0, cfg.vocab,
                                     jnp.int32)
    }
    if cfg.frontend:
        batch["features"] = jax.random.normal(
            ks[1], (B, cfg.frontend_len, tfm.FRONTEND_DIM), jnp.float32
        )
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab,
                                         jnp.int32)
    return batch


def _finite(t):
    return bool(jnp.isfinite(jnp.asarray(t, jnp.float32)).all())


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux, _ = tfm.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert _finite(logits) and _finite(aux)
    loss, grads = jax.value_and_grad(
        lambda p: tfm.train_loss(p, cfg, batch)
    )(params)
    assert _finite(loss) and 1.0 < float(loss) < 20.0
    assert all(_finite(g) for g in jax.tree.leaves(grads))
    # at least one gradient is non-zero for every block family used
    gnorms = [float(jnp.abs(g.astype(jnp.float32)).max())
              for g in jax.tree.leaves(grads)]
    assert max(gnorms) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    params = tfm.init_model(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg)
    logits_last, caches = tfm.prefill(params, cfg, batch)
    assert logits_last.shape == (B, cfg.vocab) and _finite(logits_last)
    caches = tfm.grow_attn_caches(caches, cfg, 4)
    tok = jnp.argmax(logits_last, -1)[:, None].astype(jnp.int32)
    lg, caches2 = tfm.decode_step(
        params, cfg, tok, caches, jnp.asarray(S, jnp.int32)
    )
    assert lg.shape == (B, cfg.vocab) and _finite(lg)
    # caches keep their shapes
    for a, b_ in zip(jax.tree.leaves(caches), jax.tree.leaves(caches2)):
        assert a.shape == b_.shape


def test_full_attn_decode_matches_forward():
    """Decode with growing cache reproduces teacher-forced forward logits."""
    cfg = get_config("yi-9b").reduced()
    params = tfm.init_model(jax.random.PRNGKey(2), cfg)
    batch = make_batch(cfg)
    logits, _, _ = tfm.forward(params, cfg, batch)
    caches = tfm.init_decode_caches(cfg, B, S)
    outs = []
    for t in range(S):
        lg, caches = tfm.decode_step(
            params, cfg, batch["tokens"][:, t : t + 1], caches,
            jnp.asarray(t, jnp.int32),
        )
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(dec - logits).max())
    assert err < 0.15, err  # bf16 activations, two execution orders


def test_param_counts_match_analytic():
    for arch in ("yi-9b", "mistral-large-123b", "qwen2-moe-a2.7b"):
        cfg = get_config(arch)
        analytic = cfg.n_params()
        shapes = jax.eval_shape(
            lambda k: tfm.init_model(k, cfg), jax.random.PRNGKey(0)
        )
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert abs(actual - analytic) / analytic < 0.02, (
            arch, actual, analytic
        )
