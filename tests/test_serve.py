"""launch/serve.py: the mesh argument actually reaches the step factories,
and degenerate --gen budgets report throughput as n/a instead of 0.0."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import mesh as mesh_lib, serve, steps as steps_lib
from repro.models import transformer as tfm


@pytest.fixture(scope="module")
def reduced_lm():
    cfg = get_config("musicgen-medium").reduced()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    features = jnp.asarray(
        rng.normal(0, 1, (2, cfg.frontend_len, tfm.FRONTEND_DIM)), jnp.float32
    )
    return cfg, params, prompts, features


def test_generate_routes_mesh_to_step_factories(reduced_lm, monkeypatch):
    """Regression: generate() accepted mesh but built both steps with
    mesh=None.  Spy on the factories and require the mesh to arrive."""
    cfg, params, prompts, features = reduced_lm
    seen = []
    real_prefill, real_serve = (
        steps_lib.make_prefill_step, steps_lib.make_serve_step
    )
    monkeypatch.setattr(
        serve.steps_lib, "make_prefill_step",
        lambda cfg, mesh: seen.append(("prefill", mesh))
        or real_prefill(cfg, mesh),
    )
    monkeypatch.setattr(
        serve.steps_lib, "make_serve_step",
        lambda cfg, mesh, sampler="ky": seen.append(("serve", mesh))
        or real_serve(cfg, mesh, sampler=sampler),
    )
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    toks, _ = serve.generate(
        cfg, params, prompts, 3, features=features, mesh=mesh
    )
    assert toks.shape == (2, 9)
    assert dict(seen) == {"prefill": mesh, "serve": mesh}


def test_generate_mesh_matches_unsharded(reduced_lm):
    """One-device mesh: same computation, same tokens as the plain jit path."""
    cfg, params, prompts, features = reduced_lm
    t0, _ = serve.generate(cfg, params, prompts, 3, features=features)
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    t1, _ = serve.generate(
        cfg, params, prompts, 3, features=features, mesh=mesh
    )
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


def test_main_reports_na_throughput_for_short_gen(reduced_lm, capsys,
                                                 monkeypatch):
    """--gen 1 leaves no steady-state decode step to time: the report must
    say n/a, not 0.0 tok/s."""
    monkeypatch.setattr(
        serve.tfm, "init_model",
        lambda key, cfg: reduced_lm[1],  # reuse the module-scoped params
    )
    serve.main([
        "--arch", "musicgen-medium", "--reduced", "--batch", "2",
        "--prompt-len", "6", "--gen", "1", "--sampler", "greedy",
    ])
    out = capsys.readouterr().out
    assert "decode throughput n/a" in out
    assert "0.0 tok/s" not in out
