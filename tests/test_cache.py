"""Program-cache behavior: LRU eviction order under capacity pressure,
stats lifecycle across clears, capacity as a runtime knob, and the
structure-only `ir_key` stability that runtime-evidence serving relies on."""

import pytest

from repro.compile import (
    cache_stats,
    canonicalize,
    clear_program_cache,
    compile_graph,
    set_cache_capacity,
)
from repro.compile import ir as compile_ir
from repro.core.graphs import GridMRF, random_bayesnet


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_program_cache()
    prev = set_cache_capacity(128)
    yield
    set_cache_capacity(prev)
    clear_program_cache()


def _bn(seed):
    return random_bayesnet(6, max_parents=2, seed=seed)


# ---------------------------------------------------------------------------
# LRU eviction order
# ---------------------------------------------------------------------------


def test_eviction_order_under_capacity_pressure():
    """Least-recently-used falls out first; a hit refreshes recency."""
    set_cache_capacity(2)
    p0 = compile_graph(_bn(0))
    p1 = compile_graph(_bn(1))
    assert compile_graph(_bn(0)) is p0  # refresh bn0: LRU order is now 1, 0
    compile_graph(_bn(2))  # evicts bn1, not bn0
    stats = cache_stats()
    assert stats["evictions"] == 1 and stats["size"] == 2
    assert compile_graph(_bn(0)) is p0  # still resident
    assert compile_graph(_bn(1)) is not p1  # was evicted: fresh compile
    assert cache_stats()["evictions"] == 2  # bn2 fell out re-admitting bn1


def test_shrinking_capacity_evicts_immediately():
    for s in range(4):
        compile_graph(_bn(s))
    assert cache_stats()["size"] == 4
    prev = set_cache_capacity(2)
    assert prev == 128  # the fixture's setting comes back for restoration
    stats = cache_stats()
    assert stats["size"] == 2 and stats["evictions"] == 2
    assert stats["capacity"] == 2
    # the survivors are the most recently inserted
    assert cache_stats()["hits"] == 0
    compile_graph(_bn(3))
    assert cache_stats()["hits"] == 1


def test_capacity_validation():
    with pytest.raises(ValueError):
        set_cache_capacity(0)


# ---------------------------------------------------------------------------
# stats lifecycle
# ---------------------------------------------------------------------------


def test_stats_reset_after_clear():
    compile_graph(_bn(0))
    compile_graph(_bn(0))
    set_cache_capacity(1)
    compile_graph(_bn(1))
    stats = cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 2
    assert stats["evictions"] == 1
    clear_program_cache()
    stats = cache_stats()
    assert stats["hits"] == stats["misses"] == stats["evictions"] == 0
    assert stats["size"] == 0 and stats["hit_rate"] == 0.0
    assert stats["capacity"] == 1  # capacity is a knob, not a counter


# ---------------------------------------------------------------------------
# structure-only keying (the serving-path invariant)
# ---------------------------------------------------------------------------


def test_structure_only_key_stable_across_evidence_variations():
    """Runtime-mode IRs hash structure only: every evidence variation maps
    to one cached program, where baked mode forces one program each."""
    bn = _bn(3)
    rt = canonicalize(bn, evidence_mode="runtime")
    assert rt.ir_key == canonicalize(bn, evidence_mode="runtime").ir_key
    prog = compile_graph(rt)
    for ev in ({0: 1}, {0: 0}, {2: 1, 4: 0}):
        assert compile_graph(canonicalize(bn, evidence_mode="runtime")) is prog
        # ...while baking the same dicts creates distinct programs
        assert compile_graph(bn, evidence=ev) is not prog
    stats = cache_stats()
    assert stats["hits"] == 3  # the three runtime re-submissions
    assert stats["misses"] == 4  # structure-only + three baked variants


def test_runtime_and_baked_modes_never_share_a_slot():
    bn = _bn(5)
    baked = compile_ir.from_bayesnet(bn)  # no evidence, but baked-mode
    rt = compile_ir.from_bayesnet(bn, evidence_mode="runtime")
    assert baked.ir_key != rt.ir_key
    assert compile_graph(baked) is not compile_graph(rt)


def test_mrf_pins_key_like_bn_evidence():
    mrf = GridMRF(4, 4, 2)
    plain = compile_ir.from_mrf(mrf)
    pinned = compile_ir.from_mrf(mrf, pinned={0: 1})
    assert plain.evidence_mode == "runtime"
    assert pinned.evidence_mode == "baked"
    assert plain.ir_key != pinned.ir_key


def test_pipeline_name_is_part_of_the_cache_key():
    bn = _bn(7)
    d = compile_graph(bn)
    r = compile_graph(bn, pipeline="runtime")
    assert d is not r
    assert compile_graph(bn) is d
    assert compile_graph(bn, pipeline="runtime") is r
    assert cache_stats()["size"] == 2
    with pytest.raises(ValueError):
        compile_graph(bn, pipeline="nonesuch")
