"""Gradient-compression collectives under shard_map (8 simulated devices)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import compat
    from repro.optim.compression import tree_psum_compressed, init_residuals

    mesh = compat.make_mesh((8,), ("data",))
    g_global = jax.random.normal(jax.random.key(0), (8, 64, 32))
    want = np.asarray(g_global.sum(0))

    def body(mode):
        def f(g):
            red, _ = tree_psum_compressed({"g": g[0]}, "data", mode)
            return red["g"]
        return jax.jit(compat.shard_map(
            f, mesh=mesh, in_specs=P("data", None, None),
            out_specs=P(None, None), check_vma=False))

    exact = np.asarray(body("none")(g_global))
    # f32 all-reduce order differs across jax versions/backends: ~1e-4 rel
    np.testing.assert_allclose(exact, want, rtol=2e-4)

    bf = np.asarray(body("bf16")(g_global))
    rel = np.abs(bf - want).max() / np.abs(want).max()
    assert rel < 0.03, rel

    i8 = np.asarray(body("int8")(g_global))
    rel8 = np.abs(i8 - want).max() / np.abs(want).max()
    assert rel8 < 0.08, rel8

    # error feedback: averaged over steps, int8 bias telescopes away
    def f_res(g, r):
        red, new_r = tree_psum_compressed({"g": g[0]}, "data", "int8",
                                          {"g": r[0]})
        return red["g"], new_r["g"][None]  # restore the sharded leading axis
    step = jax.jit(compat.shard_map(
        f_res, mesh=mesh,
        in_specs=(P("data", None, None), P("data", None, None)),
        out_specs=(P(None, None), P("data", None, None)),
        check_vma=False))
    r = jnp.zeros_like(g_global)
    acc = 0.0
    for _ in range(16):
        red, r = step(g_global, r)
        acc = acc + np.asarray(red)
    rel_fb = np.abs(acc / 16 - want).max() / np.abs(want).max()
    assert rel_fb < 0.02, rel_fb
    print("COMPRESSION_OK")
    """
)


@pytest.mark.slow
def test_compression_collectives():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "COMPRESSION_OK" in res.stdout
