"""Distributed (shard_map) chromatic Gibbs — runs in a subprocess with 8
simulated host devices so the main test process keeps a single device."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    from repro.core.graphs import GridMRF, random_bayesnet
    from repro.core import mrf as mrf_mod
    from repro.core import bayesnet as bnet
    from repro.core.distributed import bn_gibbs_sharded, mrf_gibbs_sharded
    from repro.core.exact import ve_marginal

    # MRF: halo-exchange Gibbs must denoise as well as single-device
    clean, noisy = mrf_mod.make_denoising_problem(32, 32, 3, 0.25, seed=1)
    m = GridMRF(32, 32, 3, theta=1.2, h=2.0)
    lab = mrf_gibbs_sharded(m, jnp.asarray(noisy), jax.random.key(0), mesh,
                            n_chains=4, n_iters=30)
    assert lab.shape == (4, 32, 32)
    err = (np.asarray(lab[0]) != clean).mean()
    base = (noisy != clean).mean()
    assert err < base / 2, (err, base)

    # determinism given the key
    lab2 = mrf_gibbs_sharded(m, jnp.asarray(noisy), jax.random.key(0), mesh,
                             n_chains=4, n_iters=30)
    assert (np.asarray(lab) == np.asarray(lab2)).all()

    # BN: sharded chromatic Gibbs converges to exact marginals
    bn = random_bayesnet(12, max_parents=3, cards=(2, 3), seed=3)
    ev = {1: 0}
    cbn = bnet.compile_bayesnet(bn, evidence=ev)
    marg, vals = bn_gibbs_sharded(cbn, jax.random.key(1), mesh,
                                  n_chains=32, n_iters=400, burn_in=100)
    marg = np.asarray(marg)
    tv = max(0.5 * np.abs(marg[q][:bn.cards[q]] - ve_marginal(bn, q, ev)).sum()
             for q in range(12) if q not in ev)
    assert tv < 0.05, tv
    vals = np.asarray(vals)
    assert (vals[:, 1] == 0).all()  # evidence respected on every shard
    print("DISTRIBUTED_PM_OK")
    """
)


@pytest.mark.slow
def test_distributed_pm_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "DISTRIBUTED_PM_OK" in res.stdout


_BOUNDARY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp

    from repro.compile import compile_graph
    from repro.compile import ir as compile_ir
    from repro.core import compat
    from repro.core.graphs import GridMRF, random_bayesnet

    mesh = compat.make_mesh((2, 4), ("data", "model"))

    # MRF (lut_ky, the fused grid sampler): a query sliced across the
    # shard-route boundary — sharded first leg, vmap second — equals the
    # unsliced sharded run bit for bit, because both legs execute the one
    # fused Pallas datapath and the carry is the whole chain state
    mrf = GridMRF(8, 16, 4, theta=1.1)
    prog = compile_graph(compile_ir.from_mrf(mrf))
    ev = jnp.zeros((8, 16), jnp.int32)
    key = jax.random.key(7)
    full = prog.run_sharded(key, mesh, evidence=ev, n_chains=4, n_iters=5,
                            fused=True)
    _, st = prog.run_sharded(key, mesh, evidence=ev, n_chains=4, n_iters=2,
                             fused=True, return_state=True)
    resumed = prog.run(None, evidence=ev, n_chains=4, n_iters=3, fused=True,
                       carry_state=st)
    assert (np.asarray(full) == np.asarray(resumed)).all()
    # and the reverse crossing: vmap first leg, sharded second
    _, st2 = prog.run(key, evidence=ev, n_chains=4, n_iters=2, fused=True,
                      return_state=True)
    resumed2 = prog.run_sharded(None, mesh, evidence=ev, n_chains=4,
                                n_iters=3, fused=True, carry_state=st2)
    assert (np.asarray(full) == np.asarray(resumed2)).all()
    print("MRF_BOUNDARY_OK")

    # BN: both fused samplers cross the boundary bit-exactly, marginals
    # (burn-in and thinning mid-stride) included
    bn = random_bayesnet(12, seed=3)
    pbn = compile_graph(compile_ir.from_bayesnet(bn))
    for sampler in ("lut_ky", "exact_ky"):
        base = dict(n_chains=4, burn_in=2, thin=2, sampler=sampler,
                    fused=True)
        kb = jax.random.key(11)
        m_full, v_full = pbn.run_sharded(kb, mesh, n_iters=6, **base)
        _, _, st = pbn.run_sharded(kb, mesh, n_iters=3, return_state=True,
                                   **base)
        m2, v2 = pbn.run(None, n_iters=3, carry_state=st, **base)
        assert (np.asarray(v_full) == np.asarray(v2)).all()
        assert (np.asarray(m_full) == np.asarray(m2)).all()
        print(f"BN_BOUNDARY_{sampler}_OK")
    print("SHARD_BOUNDARY_OK")
    """
)


@pytest.mark.slow
def test_fused_shard_route_boundary_8dev():
    """Satellite gate: chain state carried across the sharded/vmap route
    boundary reproduces the unsliced sharded run's bits, for every fused
    sampler (grid lut_ky; BN lut_ky and exact_ky)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _BOUNDARY_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARD_BOUNDARY_OK" in res.stdout
