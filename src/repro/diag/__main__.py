"""Sampling-quality CLI: convergence + exact-marginal audit over the zoo.

    python -m repro.diag                          # full sweep, text report
    python -m repro.diag --quick                  # CI budget (survey only)
    python -m repro.diag --format json --out quality-snapshot.json
    python -m repro.diag --models survey alarm    # restrict the sweep
    python -m repro.diag --variants unfused       # skip the fused backend
    python -m repro.diag --rhat-threshold 1.05    # tighten the gate

Runs every selected bench BN through `CompiledProgram.run(diagnostics=True)`
on each backend variant and audits the result three ways:

  1. convergence — the streaming accumulator's split-chain R-hat and
     batch-means ESS (`diag.accum`), gated against `--rhat-threshold`
     and `--ess-floor`;
  2. faithfulness — total-variation / max-abs error of the empirical
     marginals against variable elimination (`diag.oracle`), gated
     against `--tv-threshold`; models whose min-fill VE cost estimate
     exceeds `--ve-limit` are *declared* `n/a` (a warning finding), never
     silently skipped;
  3. trustworthiness — the accumulator's own overflow/nonfinite flags.

Exit status is the report's: nonzero iff any error-severity finding —
the same CI contract as `python -m repro.analysis`.  The threshold flags
double as the breach-injection mechanism the acceptance tests use (pass
an impossible threshold, expect exit 1).

Default model set is the VE-tractable zoo plus `water` (whose cost
estimate sits just above the default limit — it exercises the declared
`n/a` path).  `hepar2`/`pigs` are selectable via `--models` but excluded
by default: the fused backend runs in Pallas interpret mode off-TPU and
a 441-node sweep is minutes of wall per variant.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

import jax

from repro.analysis import Finding, Report
from repro.compile.program import compile_graph
from repro.core.graphs import bn_repository_replica
from repro.diag import oracle as oracle_mod

# default sweep: the tractable zoo (survey/alarm/insurance under the
# default VE limit) + water for the declared-n/a oracle path
BENCH_BNS = ("survey", "alarm", "insurance", "water")
VARIANTS = ("unfused", "fused")

# full-budget defaults: 128 chains x 800 kept draws clears both gates
# with ~2x margin on every default model — alarm, the slowest mixer and
# the coarsest-quantized (lut_ky per-CPT TV floor ~0.010), lands at
# R-hat ~1.04 and TV-vs-VE ~0.009.  Width beats length here: cross-chain
# averaging shrinks marginal noise faster than longer (autocorrelated)
# chains do, and it parallelizes for free under vmap
DEFAULT_N_CHAINS = 128
DEFAULT_N_ITERS = 1000
DEFAULT_BURN_IN = 200
# --quick (the CI budget): survey only, 300 kept — ~30s wall including
# the fused interpret-mode variant
QUICK_N_ITERS = 400
QUICK_BURN_IN = 100

DEFAULT_RHAT = 1.1
DEFAULT_TV = 0.02
DEFAULT_ESS_FLOOR = 100.0
DEFAULT_SEED = 0xA1A


def quality_sweep(
    models=BENCH_BNS,
    variants=VARIANTS,
    *,
    n_chains: int = DEFAULT_N_CHAINS,
    n_iters: int = DEFAULT_N_ITERS,
    burn_in: int = DEFAULT_BURN_IN,
    sampler: str = "lut_ky",
    seed: int = DEFAULT_SEED,
    rhat_threshold: float = DEFAULT_RHAT,
    tv_threshold: float = DEFAULT_TV,
    ess_floor: float = DEFAULT_ESS_FLOOR,
    ve_limit: int = oracle_mod.DEFAULT_VE_LIMIT,
) -> Report:
    """Run the quality sweep and fold every audit into one Report.

    One row per (model, variant) lands in `report.meta["rows"]` — the
    schema `repro.launch.report.quality_table` renders — and the full
    accumulator snapshots in `report.meta["snapshots"]` keyed
    "model/variant" (the CI artifact the regression gate diffs)."""
    report = Report(meta={
        "rows": [],
        "snapshots": {},
        "budget": {
            "n_chains": n_chains, "n_iters": n_iters, "burn_in": burn_in,
            "sampler": sampler, "seed": seed,
        },
        "thresholds": {
            "rhat": rhat_threshold, "tv": tv_threshold,
            "ess_floor": ess_floor, "ve_limit": ve_limit,
        },
    })
    for name in models:
        bn = bn_repository_replica(name)
        prog = compile_graph(bn)
        # per-model, variant-independent: worst-case KY-quantization TV —
        # the error floor the sampler's integer pmf imposes before any
        # sampling noise (fused and unfused share the quantized tables)
        ky_tv = float(oracle_mod.ky_quantization_tv(bn, sampler)["tv_max"])
        for variant in variants:
            loc = f"{name}/{variant}"
            t0 = time.perf_counter()
            marginals, _, snap = prog.run(
                key=jax.random.key(seed),
                n_chains=n_chains,
                n_iters=n_iters,
                burn_in=burn_in,
                sampler=sampler,
                fused=variant == "fused",
                diagnostics=True,
            )
            wall_s = time.perf_counter() - t0
            brief = snap.brief()
            audit = oracle_mod.oracle_audit(bn, marginals, limit=ve_limit)

            if brief["overflow_risk"] or not brief["finite"]:
                why = ("kept-draw count near int32/f32 exactness headroom"
                       if brief["overflow_risk"]
                       else "non-finite accumulator statistics")
                report.extend([Finding(
                    "diag-accum-overflow", loc,
                    f"quality accumulator untrustworthy: {why}",
                    fixit="shorten the run or widen the accumulator dtypes",
                )])
            rhat = brief["rhat_max"]
            if rhat is not None and rhat > rhat_threshold:
                report.extend([Finding(
                    "diag-threshold-breach", loc,
                    f"split R-hat {rhat:.4f} exceeds threshold "
                    f"{rhat_threshold} — chains not converged",
                    fixit="raise n_iters/burn_in or inspect the schedule",
                )])
            ess = brief["ess_min"]
            if ess is not None and ess < ess_floor:
                report.extend([Finding(
                    "diag-threshold-breach", loc,
                    f"min per-site ESS {ess:.0f} below floor "
                    f"{ess_floor:.0f} — draws too autocorrelated",
                    fixit="raise n_iters or thin less aggressively",
                )])
            if audit["status"] == "ok":
                if audit["tv_max"] > tv_threshold:
                    report.extend([Finding(
                        "diag-threshold-breach", loc,
                        f"worst-node TV vs exact marginals "
                        f"{audit['tv_max']:.4f} exceeds threshold "
                        f"{tv_threshold} — sampler unfaithful at this "
                        "budget",
                        fixit="raise the budget; if ky_tv dominates, raise "
                              "the KY quantization bits",
                    )])
            else:
                report.extend([Finding(
                    "diag-oracle-unavailable", loc,
                    f"exact-marginal audit n/a: min-fill VE cost estimate "
                    f"{audit['ve_cost']} exceeds limit {ve_limit}",
                    fixit="raise --ve-limit to force the audit",
                )])

            row = {
                "model": name,
                "variant": variant,
                "n_nodes": int(bn.n_nodes),
                "n_chains": n_chains,
                "kept": int(brief["kept"]),
                "rhat_max": rhat,
                "ess_min": ess,
                "oracle": audit["status"],
                "tv_max": audit.get("tv_max"),
                "maxabs_max": audit.get("maxabs_max"),
                "ky_tv": ky_tv,
                "wall_s": round(wall_s, 3),
            }
            report.meta["rows"].append(row)
            report.meta["snapshots"][loc] = snap.to_dict()
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.diag",
        description="sampling-quality sweep: R-hat/ESS convergence + "
                    "exact-marginal audit over the bench zoo",
    )
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", help="also write the JSON report to this path")
    ap.add_argument(
        "--models", nargs="*", default=None,
        help=f"bench BNs to sweep (default: {' '.join(BENCH_BNS)})",
    )
    ap.add_argument(
        "--variants", nargs="*", default=None, choices=VARIANTS,
        help="backend variants to run (default: both)",
    )
    ap.add_argument("--n-chains", type=int, default=DEFAULT_N_CHAINS)
    ap.add_argument("--n-iters", type=int, default=None)
    ap.add_argument("--burn-in", type=int, default=None)
    ap.add_argument("--sampler", default="lut_ky",
                    choices=("lut_ky", "exact_ky"))
    ap.add_argument("--seed", type=lambda s: int(s, 0), default=DEFAULT_SEED)
    ap.add_argument("--rhat-threshold", type=float, default=DEFAULT_RHAT)
    ap.add_argument("--tv-threshold", type=float, default=DEFAULT_TV)
    ap.add_argument("--ess-floor", type=float, default=DEFAULT_ESS_FLOOR)
    ap.add_argument("--ve-limit", type=int,
                    default=oracle_mod.DEFAULT_VE_LIMIT)
    ap.add_argument(
        "--quick", action="store_true",
        help="CI budget: survey only, short run, both variants",
    )
    args = ap.parse_args(argv)

    models = tuple(args.models) if args.models is not None else (
        ("survey",) if args.quick else BENCH_BNS
    )
    variants = tuple(args.variants) if args.variants else VARIANTS
    n_iters = args.n_iters if args.n_iters is not None else (
        QUICK_N_ITERS if args.quick else DEFAULT_N_ITERS
    )
    burn_in = args.burn_in if args.burn_in is not None else (
        QUICK_BURN_IN if args.quick else DEFAULT_BURN_IN
    )

    report = quality_sweep(
        models, variants,
        n_chains=args.n_chains,
        n_iters=n_iters,
        burn_in=burn_in,
        sampler=args.sampler,
        seed=args.seed,
        rhat_threshold=args.rhat_threshold,
        tv_threshold=args.tv_threshold,
        ess_floor=args.ess_floor,
        ve_limit=args.ve_limit,
    )

    if args.out:
        pathlib.Path(args.out).write_text(report.to_json())
    if args.format == "json":
        print(report.to_json())
    else:
        from repro.launch.report import quality_table

        print(quality_table(report.meta["rows"]))
        print()
        print(report.render_text())
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
