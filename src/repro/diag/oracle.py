"""Oracle audits: how far are the sampled marginals from the truth, and
how much of that gap is the KY quantization's fault?

Two independent error sources meet in a served posterior:

  * *mixing* error — finite chains / finite sweeps (what R-hat and ESS in
    `diag.accum` watch), and
  * *quantization* error — the LUT-exp int8 weights (lut_ky) or 15-bit
    weight grid (exact_ky) sample a slightly different conditional than
    the CPT's (paper Sec. III-D; rejection-KY draws *exactly*
    proportionally to the integer weights, so the quantized pmf is the
    true target of the hardware datapath).

This module bounds both.  `oracle_audit` compares a run's marginal
estimate against `core/exact.py` variable elimination — but only where
the elimination is tractable: `ve_cost_estimate` replays the min-fill
order symbolically and prices the largest intermediate factor, and an
intractable model is declared "n/a" (a visible verdict the CLI turns into
a `diag-oracle-unavailable` warning), never silently skipped.
`ky_quantization_tv` computes, per node, the worst total-variation gap
between the quantized conditional and the true CPT row over all parent
configurations — the irreducible floor the mixing error sits on top of.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core.graphs import DiscreteBayesNet

# elimination-cost ceiling (entries in the largest intermediate factor)
# above which VE is declared intractable.  1e6 float64 entries ~ 8 MB and
# sub-second; the bench zoo splits cleanly (pigs/hepar2 blow through it).
DEFAULT_VE_LIMIT = 1_000_000


def ve_cost_estimate(
    bn: DiscreteBayesNet, evidence: dict[int, int] | None = None
) -> int:
    """Largest intermediate-factor size (entries) a min-fill variable
    elimination of every non-evidence variable would materialize.

    Mirrors `exact._min_fill_order`'s greedy choice on the moralized
    factor graph but runs purely on scopes — no tables are built — so
    pricing an intractable model costs microseconds, not memory."""
    evidence = dict(evidence or {})
    cards = np.asarray(bn.cards, np.int64)
    scopes = []
    for i, ps in enumerate(bn.parents):
        scope = {v for v in (tuple(ps) + (i,)) if v not in evidence}
        if scope:
            scopes.append(scope)
    elim = set(range(bn.n_nodes)) - set(evidence)
    adj: dict[int, set[int]] = {v: set() for v in elim}
    for s in scopes:
        for a, b in itertools.combinations(sorted(s), 2):
            adj[a].add(b)
            adj[b].add(a)
    worst = 1
    alive = set(adj)
    remaining = set(elim)
    while remaining:
        best, best_fill = None, None
        for v in sorted(remaining):
            nbrs = adj[v] & alive - {v}
            fill = sum(
                1
                for a, b in itertools.combinations(sorted(nbrs), 2)
                if b not in adj[a]
            )
            if best_fill is None or fill < best_fill:
                best, best_fill = v, fill
        nbrs = adj[best] & alive - {best}
        size = int(cards[best]) * int(np.prod(cards[sorted(nbrs)], initial=1))
        worst = max(worst, size)
        for a, b in itertools.combinations(sorted(nbrs), 2):
            adj[a].add(b)
            adj[b].add(a)
        remaining.remove(best)
        alive.remove(best)
    return worst


def ve_tractable(
    bn: DiscreteBayesNet,
    evidence: dict[int, int] | None = None,
    limit: int = DEFAULT_VE_LIMIT,
) -> bool:
    return ve_cost_estimate(bn, evidence) <= limit


def oracle_audit(
    bn: DiscreteBayesNet,
    p_hat: np.ndarray,
    evidence: dict[int, int] | None = None,
    limit: int = DEFAULT_VE_LIMIT,
) -> dict:
    """Audit estimated marginals ((n, V) rows, padded slots ignored)
    against exact VE marginals.  Returns a dict with `status` "ok" or
    "n/a" (intractable — the caller must surface it, not drop it); on
    "ok", per-node total-variation distances, the max TV, and the max
    absolute per-entry error."""
    from repro.core import exact

    evidence = dict(evidence or {})
    cost = ve_cost_estimate(bn, evidence)
    if cost > limit:
        return {
            "status": "n/a",
            "ve_cost": cost,
            "ve_limit": limit,
            "reason": (
                f"min-fill elimination needs a {cost}-entry intermediate "
                f"factor (limit {limit})"
            ),
        }
    p_hat = np.asarray(p_hat, np.float64)
    truth = exact.all_marginals(bn, evidence)
    tv = np.zeros(bn.n_nodes)
    maxabs = np.zeros(bn.n_nodes)
    for i, p_true in enumerate(truth):
        est = p_hat[i, : len(p_true)]
        diff = np.abs(est - p_true)
        tv[i] = 0.5 * diff.sum()
        maxabs[i] = diff.max()
    free = np.array([i not in evidence for i in range(bn.n_nodes)])
    sel = tv[free] if free.any() else tv
    return {
        "status": "ok",
        "ve_cost": cost,
        "ve_limit": limit,
        "tv": tv,
        "maxabs": maxabs,
        "tv_max": float(sel.max()) if sel.size else 0.0,
        "maxabs_max": float((maxabs[free] if free.any() else maxabs).max())
        if maxabs.size else 0.0,
    }


# ---------------------------------------------------------------------------
# KY-quantization error attribution
# ---------------------------------------------------------------------------


def quantized_pmf(
    logp: np.ndarray,
    sampler: str,
    exp_table=None,
    exp_spec=None,
) -> np.ndarray:
    """The pmf a KY sampler actually draws from for one (..., V) row of
    unnormalized log-potentials — the integer-weight quantization of
    `core/draws.py`, normalized (rejection restarts make KY sampling
    exactly proportional to the weights, so this IS the target pmf).

    Replicates the draws.py weight derivation operation for operation:
    shift by the row max, then LUT-interpolated exp rounded to int8
    (lut_ky) or exact exp on a 15-bit grid (exact_ky)."""
    import jax.numpy as jnp

    from repro.core import ky as ky_core
    from repro.core.interp import build_exp_weight_lut, interp_ref

    logp = jnp.asarray(logp, jnp.float32)
    z = logp - jnp.max(logp, axis=-1, keepdims=True)
    if sampler == "lut_ky":
        if exp_table is None:
            exp_table, exp_spec = build_exp_weight_lut()
        w = jnp.maximum(jnp.round(interp_ref(z, exp_table, exp_spec)), 0.0)
        w = w.astype(jnp.int32)
    elif sampler == "exact_ky":
        w = ky_core.quantize_probs(jnp.exp(z), bits=15)
    else:
        raise ValueError(
            f"quantized pmf is a KY concept; sampler {sampler!r} draws from "
            "the float distribution directly"
        )
    w = np.asarray(w, np.float64)
    denom = w.sum(axis=-1, keepdims=True)
    # an all-zero weight row cannot occur (the row max always quantizes to
    # the top weight), but guard the division all the same
    return w / np.maximum(denom, 1.0)


def ky_quantization_tv(
    bn: DiscreteBayesNet,
    sampler: str = "lut_ky",
    exp_table=None,
    exp_spec=None,
) -> dict:
    """Per-node worst-case quantization error: for every parent
    configuration of every CPT, the total-variation distance between the
    true conditional row and the pmf the KY datapath actually samples.

    This is the *attribution* bound: a marginal-error audit (TV vs VE)
    that exceeds mixing noise but sits near this floor is quantization's
    fault; one far above it is a mixing (or correctness) problem."""
    tv = np.zeros(bn.n_nodes)
    for i, cpt in enumerate(bn.cpts):
        rows = np.asarray(cpt, np.float64).reshape(-1, cpt.shape[-1])
        with np.errstate(divide="ignore"):
            logp = np.log(rows)
        q = quantized_pmf(logp, sampler, exp_table, exp_spec)
        tv[i] = float(np.max(0.5 * np.abs(q - rows).sum(-1)))
    return {
        "sampler": sampler,
        "tv": tv,
        "tv_max": float(tv.max()) if tv.size else 0.0,
    }
