"""Streaming sampling-quality accumulators — the "are the answers right"
half of observability (PR 6's tracer is the "where does time go" half).

One `QualityAccum` pytree rides inside the Gibbs iteration loops
(`bayesnet.gibbs_run_loop`, `mrf.mrf_gibbs_loop`, and the schedule
backend's round cores) and ingests the same per-sweep one-hot tensor the
marginal histogram already computes — a pure-jax Welford update, no host
sync, no randomness consumed, so enabling diagnostics never changes a
draw stream.  The accumulator lives in the chain-state carry
(`BNChainState.quality` / `MRFChainState.quality`), which makes it
carry-over safe: a run sliced at any boundaries accumulates bit-identical
statistics to an uninterrupted one, because the kept-draw index is derived
from the accumulator's own counters, never from where a slice started.

What it tracks, per chain, per node, per value of the one-hot marginal
indicator x = 1[X_node = v]:

  * split-chain mean/variance (Welford, two halves at `split_at` — the
    kept-index midpoint of the query's *total* budget, fixed at
    accumulator creation so every slice agrees where the split falls);
    `summarize` folds the 2B sub-chains into Gelman-Rubin split R-hat.
  * batch-means autocorrelation state (`batch_len`-draw batches, Welford
    over batch means) -> effective sample size per chain,
    ESS = kept * Var(x) / (L * Var(batch means)), summed over chains.
  * the pooled mean itself is the streaming marginal estimate `p_hat`
    (cross-checked against the histogram-based marginals in tests).

`summarize` runs on the host (numpy) at the end of a run — the jit side
only ever carries the raw moments.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# batch length for the batch-means ESS estimator: long enough to absorb
# the few-sweep autocorrelation of chromatic Gibbs on the bench nets,
# short enough that quick CI budgets still complete >= 2 batches
DEFAULT_BATCH_LEN = 8

# kept*chains headroom before the int32 histogram in BNChainState.hist
# (and the float32 Welford counts) start losing exactness
_INT32_HEADROOM = 2**30


@dataclasses.dataclass
class QualityAccum:
    """Raw streaming moments; every field is pytree *data* (no statics), so
    one jitted program serves every budget/split/batch-length setting."""

    counts: jax.Array  # (2,) int32 kept draws per split half
    mean: jax.Array  # (2, B, S, V) f32 Welford mean per half/chain/site/value
    m2: jax.Array  # (2, B, S, V) f32 Welford sum of squared deviations
    split_at: jax.Array  # () int32 kept index where half 1 begins
    batch_len: jax.Array  # () int32 batch-means batch length
    bm_count: jax.Array  # () int32 completed batches
    bm_mean: jax.Array  # (B, S, V) f32 Welford mean over batch means
    bm_m2: jax.Array  # (B, S, V) f32 Welford m2 over batch means
    cur_sum: jax.Array  # (B, S, V) f32 running sum of the open batch
    cur_n: jax.Array  # () int32 kept draws in the open batch


jax.tree_util.register_dataclass(
    QualityAccum,
    ["counts", "mean", "m2", "split_at", "batch_len", "bm_count",
     "bm_mean", "bm_m2", "cur_sum", "cur_n"],
    [],
)


def make_accum(
    n_chains: int,
    n_sites: int,
    n_values: int,
    total_kept,
    batch_len: int = DEFAULT_BATCH_LEN,
) -> QualityAccum:
    """Fresh accumulator for a run that will keep `total_kept` draws in
    total (the *whole* query budget, not the current slice — the split
    point must be the same wherever the run is sliced).  `total_kept` may
    be a traced scalar: it enters as data, so per-lane totals vmap."""
    shape2 = (2, n_chains, n_sites, n_values)
    shape1 = (n_chains, n_sites, n_values)
    total_kept = jnp.asarray(total_kept, jnp.int32)
    return QualityAccum(
        counts=jnp.zeros(2, jnp.int32),
        mean=jnp.zeros(shape2, jnp.float32),
        m2=jnp.zeros(shape2, jnp.float32),
        split_at=jnp.maximum(total_kept // 2, 1),
        batch_len=jnp.asarray(batch_len, jnp.int32),
        bm_count=jnp.zeros((), jnp.int32),
        bm_mean=jnp.zeros(shape1, jnp.float32),
        bm_m2=jnp.zeros(shape1, jnp.float32),
        cur_sum=jnp.zeros(shape1, jnp.float32),
        cur_n=jnp.zeros((), jnp.int32),
    )


def kept_count(n_iters, burn_in: int, thin: int):
    """Kept draws of a fresh run: |{t in [0, n_iters) : t >= burn_in and
    (t - burn_in) % thin == 0}| — the loop's own keep gate, counted."""
    n_iters = jnp.asarray(n_iters, jnp.int32)
    return jnp.maximum((n_iters - burn_in + thin - 1) // thin, 0)


def update(q: QualityAccum, onehot: jax.Array, keep) -> QualityAccum:
    """Fold one sweep's one-hot indicators ((B, S, V), any numeric dtype)
    into the accumulator.  `keep` is the loop's burn-in/thinning gate; a
    masked-out sweep leaves every statistic bit-identical (computed with
    `where`, never with control flow, so the update traces once)."""
    x = onehot.astype(jnp.float32)
    keep = jnp.asarray(keep, bool)
    kept_idx = q.counts[0] + q.counts[1]
    half = (kept_idx >= q.split_at).astype(jnp.int32)
    sel = (jnp.arange(2, dtype=jnp.int32) == half) & keep  # (2,)
    counts = q.counts + sel.astype(jnp.int32)
    selb = sel[:, None, None, None]
    denom = jnp.maximum(counts, 1).astype(jnp.float32)[:, None, None, None]
    delta = x[None] - q.mean
    mean_new = q.mean + delta / denom
    m2_new = q.m2 + delta * (x[None] - mean_new)
    mean = jnp.where(selb, mean_new, q.mean)
    m2 = jnp.where(selb, m2_new, q.m2)
    # batch-means: accumulate the open batch; fold its mean into the
    # batch-level Welford stats when it fills
    cur_sum = jnp.where(keep, q.cur_sum + x, q.cur_sum)
    cur_n = q.cur_n + keep.astype(jnp.int32)
    fold = keep & (cur_n >= q.batch_len)
    bmean = cur_sum / jnp.maximum(q.batch_len, 1).astype(jnp.float32)
    bm_count = q.bm_count + fold.astype(jnp.int32)
    bdenom = jnp.maximum(bm_count, 1).astype(jnp.float32)
    bdelta = bmean - q.bm_mean
    bm_mean_new = q.bm_mean + bdelta / bdenom
    bm_m2_new = q.bm_m2 + bdelta * (bmean - bm_mean_new)
    bm_mean = jnp.where(fold, bm_mean_new, q.bm_mean)
    bm_m2 = jnp.where(fold, bm_m2_new, q.bm_m2)
    cur_sum = jnp.where(fold, jnp.zeros_like(cur_sum), cur_sum)
    cur_n = jnp.where(fold, 0, cur_n)
    return QualityAccum(
        counts=counts, mean=mean, m2=m2, split_at=q.split_at,
        batch_len=q.batch_len, bm_count=bm_count, bm_mean=bm_mean,
        bm_m2=bm_m2, cur_sum=cur_sum, cur_n=cur_n,
    )


# ---------------------------------------------------------------------------
# host-side summary
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QualitySnapshot:
    """Host-side reduction of a `QualityAccum`: per-node convergence
    diagnostics plus the scalar roll-ups the serving metrics and the CLI
    thresholds consume.  `rhat`/`ess` are NaN where undefined (a node with
    no varying value — e.g. clamped evidence — has nothing to diagnose);
    `rhat` is +inf where chains are stuck in disjoint modes (zero within-
    chain variance, nonzero between), which is exactly the breach the
    split-initialization test injects."""

    rhat: np.ndarray  # (S,) worst split R-hat over the node's values
    ess: np.ndarray | None  # (S,) total ESS over chains; None if < 2 batches
    p_hat: np.ndarray  # (S, V) pooled streaming marginal estimate
    kept: int
    n_chains: int
    split_at: int
    batch_len: int
    n_batches: int
    rhat_max: float | None
    ess_min: float | None
    overflow_risk: bool
    finite: bool

    def brief(self) -> dict:
        """The scalar row serving metrics / trace instants carry around."""
        return {
            "rhat_max": self.rhat_max,
            "ess_min": self.ess_min,
            "kept": self.kept,
            "n_chains": self.n_chains,
            "n_batches": self.n_batches,
            "overflow_risk": self.overflow_risk,
            "finite": self.finite,
        }

    def to_dict(self) -> dict:
        d = self.brief()
        d["split_at"] = self.split_at
        d["batch_len"] = self.batch_len
        d["rhat"] = [None if not np.isfinite(r) and not np.isinf(r)
                     else (float(r) if np.isfinite(r) else "inf")
                     for r in self.rhat]
        if self.ess is not None:
            d["ess"] = [None if np.isnan(e) else float(e) for e in self.ess]
        return d


def _combine_welford(na, ma, m2a, nb, mb, m2b):
    """Chan et al. parallel-variance merge of two Welford states."""
    n = na + nb
    safe = np.maximum(n, 1)
    delta = mb - ma
    mean = ma + delta * (nb / safe)
    m2 = m2a + m2b + delta * delta * (na * nb / safe)
    return n, mean, m2


def summarize(
    q: QualityAccum,
    cards=None,
    free_mask=None,
    total_kept: int | None = None,
) -> QualitySnapshot:
    """Reduce raw moments to the quality snapshot (host numpy).

    `cards` ((S,) value cardinalities) masks padded value slots out of the
    diagnostics; `free_mask` ((S,) bool) restricts the rhat_max / ess_min
    roll-ups to unclamped nodes (clamped nodes are constant and carry NaN
    diagnostics either way, but an explicit mask keeps intent visible).
    `total_kept` (the query's whole budget) flags an accumulator that was
    summarized mid-run — callers that slice pass it so `kept` mismatches
    surface as `finite=False` rather than silently under-counting."""
    counts = np.asarray(q.counts, np.int64)  # (2,)
    mean = np.asarray(q.mean, np.float64)  # (2, B, S, V)
    m2 = np.asarray(q.m2, np.float64)
    _, n_chains, n_sites, n_values = mean.shape
    kept = int(counts.sum())

    value_ok = np.ones((n_sites, n_values), bool)
    if cards is not None:
        cards = np.asarray(cards)
        value_ok = np.arange(n_values)[None, :] < cards[:, None]
    node_ok = np.ones(n_sites, bool)
    if free_mask is not None:
        node_ok = np.asarray(free_mask, bool)

    # ---- split R-hat over the 2B sub-chains -------------------------------
    active = [h for h in (0, 1) if counts[h] >= 2]
    rhat_nv = np.full((n_sites, n_values), np.nan)
    if active:
        n_sub = int(counts[active].min())
        # (M, S, V) sub-chain means and (unbiased) variances
        sub_mean = mean[active].reshape(-1, n_sites, n_values)
        sub_var = (m2[active] / np.maximum(counts[active, None, None, None]
                                           - 1, 1)
                   ).reshape(-1, n_sites, n_values)
        w = sub_var.mean(0)
        b = n_sub * sub_mean.var(0, ddof=1) if sub_mean.shape[0] > 1 else (
            np.zeros_like(w))
        var_plus = (n_sub - 1) / n_sub * w + b / n_sub
        tiny = 1e-12
        varies = (w > tiny) | (b > tiny)
        with np.errstate(divide="ignore", invalid="ignore"):
            r = np.sqrt(var_plus / w)
        # stuck-apart chains: no within variance, real between variance
        r = np.where((w <= tiny) & (b > tiny), np.inf, r)
        rhat_nv = np.where(varies & value_ok, r, np.nan)

    with np.errstate(invalid="ignore"):
        rhat_node = np.full(n_sites, np.nan)
        has = ~np.all(np.isnan(rhat_nv), axis=1)
        rhat_node[has] = np.nanmax(rhat_nv[has], axis=1)

    # ---- batch-means ESS --------------------------------------------------
    bm_count = int(np.asarray(q.bm_count))
    batch_len = int(np.asarray(q.batch_len))
    ess_node = None
    if bm_count >= 2 and kept >= 2:
        var_bm = np.asarray(q.bm_m2, np.float64) / (bm_count - 1)  # (B, S, V)
        # whole-run per-chain variance: merge the two split halves
        _, _, m2c = _combine_welford(
            counts[0], mean[0], m2[0], counts[1], mean[1], m2[1]
        )
        s2 = m2c / max(kept - 1, 1)  # (B, S, V)
        tiny = 1e-12
        with np.errstate(divide="ignore", invalid="ignore"):
            ess = kept * s2 / (batch_len * var_bm)
        ess = np.where(s2 <= tiny, np.nan, np.minimum(ess, kept))
        # anticorrelated-beyond-batch case: zero batch variance with real
        # within variance — every kept draw is effectively independent
        ess = np.where((s2 > tiny) & (var_bm <= tiny), float(kept), ess)
        # sum over chains; a constant (stuck) chain contributes zero
        # effective samples, and the cell is undefined only when *every*
        # chain is constant there
        ess_nv = np.where(np.isnan(ess), 0.0, ess).sum(0)
        ess_nv = np.where(np.isnan(ess).all(0) | ~value_ok, np.nan, ess_nv)
        with np.errstate(invalid="ignore"):
            ess_node = np.full(n_sites, np.nan)
            has = ~np.all(np.isnan(ess_nv), axis=1)
            ess_node[has] = np.nanmin(ess_nv[has], axis=1)

    # ---- pooled marginal estimate -----------------------------------------
    weight = counts[:, None, None, None].astype(np.float64)
    pooled = (mean * weight).sum(0) / max(kept, 1)  # (B, S, V)
    p_hat = np.where(value_ok, pooled.mean(0), 0.0)

    finite = bool(
        np.isfinite(mean).all() and np.isfinite(m2).all()
        and np.isfinite(np.asarray(q.bm_m2)).all()
    )
    if total_kept is not None and kept != int(total_kept):
        finite = False
    overflow_risk = kept * n_chains >= _INT32_HEADROOM

    sel = node_ok & ~np.isnan(rhat_node)
    rhat_max = float(np.max(rhat_node[sel])) if sel.any() else None
    ess_min = None
    if ess_node is not None:
        sel = node_ok & ~np.isnan(ess_node)
        ess_min = float(np.min(ess_node[sel])) if sel.any() else None
    return QualitySnapshot(
        rhat=rhat_node,
        ess=ess_node,
        p_hat=p_hat,
        kept=kept,
        n_chains=n_chains,
        split_at=int(np.asarray(q.split_at)),
        batch_len=batch_len,
        n_batches=bm_count,
        rhat_max=rhat_max,
        ess_min=ess_min,
        overflow_risk=overflow_risk,
        finite=finite,
    )
