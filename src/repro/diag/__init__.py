"""`repro.diag` — streaming sampling-quality observability.

PR 6's `repro.obs` answers *where time goes*; this package answers
*whether the answers are right* — the other half of the paper's
samples-per-joule-at-equal-quality claim (Table IV compares MCMC against
exact inference; the KY quantization is an approximation whose error must
be watched, not assumed).

  * `diag.accum`  — chain-axis-vectorized streaming accumulators
    (Welford mean/variance over per-node one-hot marginals, split-chain
    R-hat, batch-means ESS) that ride inside the Gibbs loops as a
    pure-jax update on the chain-state carry — no host sync, no extra
    randomness, carry-over safe under sliced serving.
  * `diag.oracle` — total-variation / max-abs marginal audits against
    `core/exact.py` variable elimination where the elimination cost
    permits (declared "n/a" where it does not), plus the per-node
    KY-quantization TV floor that attributes error to quantize vs mixing.
  * `python -m repro.diag` — the quality CLI: sweeps the bench zoo on
    both backends (fused + unfused), audits against the oracle, writes a
    quality snapshot, and exits nonzero on R-hat/TV threshold breach
    using the shared `repro.analysis` Finding/Report schema
    (`diag-*` rule ids).

Entry points elsewhere: `CompiledProgram.run(diagnostics=True)`,
`EngineConfig(diagnostics=True)` -> `QueryResult.quality`, the
`rhat_max`/`ess_min` columns in `runtime.metrics`, and the
`benchmarks/check_regression.py` perf+quality gate.
"""

from __future__ import annotations

from repro.diag.accum import (  # noqa: F401
    DEFAULT_BATCH_LEN,
    QualityAccum,
    QualitySnapshot,
    kept_count,
    make_accum,
    summarize,
    update,
)
from repro.diag.oracle import (  # noqa: F401
    DEFAULT_VE_LIMIT,
    ky_quantization_tv,
    oracle_audit,
    quantized_pmf,
    ve_cost_estimate,
    ve_tractable,
)
