"""Fused Pallas kernel: one full Bayes-net color round per grid step.

This is the paper's fused C1+C2 datapath on its headline workload: where
the unfused BN engine runs each color round as ~6 separate XLA kernels —
`group_log_conditionals` materializes a (B, n_c, F, V) address/log-prob
tensor in HBM, `draw_from_logits` re-reads it, a scatter writes the state —
this kernel executes the whole round on VMEM-resident state:

  1. flat-CPT gather              — addresses computed in-kernel from the
     (base, stride, scope_var) tensors against the log-CPT arena, reading
     the chain values straight out of the resident value block (the
     paper's shared-RF access, C4-adjacent);
  2. LUT-exp weight interpolation — `interp_eval` on the same (1, L) table
     layout as the MRF kernel (C2; exact_ky runs the exact-exp ablation);
  3. non-normalized rejection-KY  — the early-exit `ddg_walk` from
     `ky_sampler.py` over all (chain, node) rows of the round at once (C1);
  4. in-place scatter             — a one-hot MXU matmul writes the drawn
     labels back into the value block (no dynamic lane scatter on TPU).

The grid iterates over schedule rounds ("arbitrary" semantics); the value
block's index map is constant, so the chain state stays in VMEM across the
*entire sweep* and is written back to HBM once — zero HBM round-trips for
the per-round conditionals, the paper's private-RF locality argument.

Random words are derived exactly as `draw_from_logits` derives them (one
`ky_core.random_words` stream per round over the round's *real* row count),
so lut_ky outputs are bit-identical to the unfused `gibbs_sweep` under the
same key — asserted by `tests/test_bn_fused.py` and by the backend's
first-use cross-check (`compile/backend.cross_check_fused`).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.core import ky as ky_core
from repro.core.bayesnet import NEG_INF, CompiledBayesNet
from repro.kernels.interp_lut import interp_eval
from repro.kernels.ky_sampler import LANES, argmax_fallback, ddg_walk, \
    preprocess_lanes

pl = compat.pallas()

# The samplers whose draw pipeline this kernel implements; anything else
# must be rejected loudly by the callers (never silently fall back).
FUSED_BN_SAMPLERS = ("lut_ky", "exact_ky")


def check_fused_sampler(sampler: str) -> None:
    """The fused-BN sampler gate, shared by every entry layer (program.run,
    the backend wrappers, the run loop, the kernel itself): cdf/gumbel draw
    from a different random stream entirely, so a silent fallback would
    change which engine served without anyone noticing."""
    if sampler not in FUSED_BN_SAMPLERS:
        raise ValueError(
            f"fused BN rounds implement the {'/'.join(FUSED_BN_SAMPLERS)} "
            f"datapaths only, got sampler={sampler!r}"
        )


@dataclasses.dataclass
class BNFusedRounds:
    """A round-group list padded and stacked for one-kernel execution.

    Per-round gather tensors are padded to the common (c_max, f_max, s_max)
    envelope and stacked on a leading rounds axis so one `pallas_call` grid
    step can slice round r with a BlockSpec.  Padding reuses the dummy-slot
    convention of `bayesnet.build_color_group`: base/stride/scope 0 rows
    address the arena's zero entry and contribute log-prob 0.0, padded node
    lanes carry node id -1 so the scatter one-hot drops them."""

    nodes: jax.Array  # (R, C) int32; -1 = padded lane
    cards: jax.Array  # (R, C) int32; 0 = padded lane
    base: jax.Array  # (R, C*F) int32
    stride: jax.Array  # (R, C*F*S) int32
    scope_var: jax.Array  # (R, C*F*S) int32
    is_self: jax.Array  # (R, C*F*S) int32 (0/1)
    n_c: tuple[int, ...]  # static: real node count per round
    c_max: int
    f_max: int
    s_max: int


jax.tree_util.register_dataclass(
    BNFusedRounds,
    ["nodes", "cards", "base", "stride", "scope_var", "is_self"],
    ["n_c", "c_max", "f_max", "s_max"],
)


def build_fused_rounds(groups) -> BNFusedRounds:
    """Stack a `ColorGroup` list into the fused kernel's padded layout.

    Pure jnp (shapes are static), so it runs at trace time inside the
    jitted run loops — the fused tensors are a deterministic function of
    the groups pytree and never need a separate compile-time artifact."""
    c_max = max(g.nodes.shape[0] for g in groups)
    f_max = max(g.base.shape[1] for g in groups)
    s_max = max(g.stride.shape[2] for g in groups)

    def pad2(x, fill=0):
        c, f = x.shape
        return jnp.pad(x, ((0, c_max - c), (0, f_max - f)),
                       constant_values=fill).reshape(-1)

    def pad3(x):
        c, f, s = x.shape
        return jnp.pad(
            x, ((0, c_max - c), (0, f_max - f), (0, s_max - s))
        ).reshape(-1)

    return BNFusedRounds(
        nodes=jnp.stack([
            jnp.pad(g.nodes, (0, c_max - g.nodes.shape[0]),
                    constant_values=-1)
            for g in groups
        ]),
        cards=jnp.stack([
            jnp.pad(g.cards, (0, c_max - g.cards.shape[0])) for g in groups
        ]),
        base=jnp.stack([pad2(g.base) for g in groups]),
        stride=jnp.stack([pad3(g.stride) for g in groups]),
        scope_var=jnp.stack([pad3(g.scope_var) for g in groups]),
        is_self=jnp.stack([pad3(g.is_self.astype(jnp.int32)) for g in groups]),
        n_c=tuple(int(g.nodes.shape[0]) for g in groups),
        c_max=c_max,
        f_max=f_max,
        s_max=s_max,
    )


def bn_round_step(
    vals_ref, nodes_ref, cards_ref, base_ref, stride_ref, scope_ref,
    self_ref, words_ref, logf_ref, tab_ref, out_ref, *,
    n_chains: int, n_nodes: int, c_max: int, f_max: int, s_max: int,
    v_max: int, n_words: int, sampler: str, x0: float, dx: float,
    lut_size: int, weight_bits: int, precision: int, total_steps: int,
):
    """One full color round on the VMEM-resident value block (grid step r).

    The op order mirrors `group_log_conditionals` + `draw_from_logits`
    exactly — same gather addresses, same reduction axes, same float
    expressions — which is what makes the fused path bit-exact rather than
    merely statistically equivalent."""
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _():
        out_ref[...] = vals_ref[...]

    vals = out_ref[...]  # (B, n) chain state, resident across rounds
    nodes = nodes_ref[0, :]  # (C,)
    cards = cards_ref[0, :]
    base = base_ref[0, :].reshape(c_max, f_max)
    stride = stride_ref[0, :].reshape(c_max, f_max, s_max)
    scope = scope_ref[0, :].reshape(c_max, f_max, s_max)
    is_self = self_ref[0, :].reshape(c_max, f_max, s_max) != 0

    # --- inline flat-CPT gather (C4-adjacent shared-RF read + C3 layout) ---
    sv = jnp.take(vals, scope.reshape(-1), axis=1).reshape(
        n_chains, c_max, f_max, s_max
    )
    v_range = jnp.arange(v_max, dtype=jnp.int32)
    val_or_v = jnp.where(
        is_self[None, ..., None], v_range, sv[..., None]
    )  # (B, C, F, S, V)
    addr = base[None, :, :, None] + jnp.sum(
        stride[None, ..., None] * val_or_v, axis=-2
    )  # (B, C, F, V) int32 — exact, padded slots address arena entry 0
    logf = logf_ref[0, :]
    logp = jnp.sum(
        jnp.take(logf, addr.reshape(-1)).reshape(addr.shape), axis=-2
    )  # (B, C, V)
    logp = jnp.where(v_range < cards[None, :, None], logp, NEG_INF)

    # --- C2: LUT-exp (or exact-exp ablation) -> integer weights -----------
    flat = logp.reshape(n_chains * c_max, v_max)
    z = flat - jnp.max(flat, axis=-1, keepdims=True)
    if sampler == "lut_ky":
        w = jnp.maximum(jnp.round(interp_eval(z, tab_ref, x0, dx, lut_size)),
                        0.0)
        w = w.astype(jnp.int32)
    else:  # exact_ky — the exact-exp ablation, same fn as draw_from_logits
        w = ky_core.quantize_probs(jnp.exp(z), bits=weight_bits)
    w = jnp.concatenate(
        [w, jnp.zeros((n_chains * c_max, LANES - v_max), jnp.int32)], axis=1
    )

    # --- C1: early-exit rejection-KY walk over every (chain, node) row ----
    words = words_ref[...].reshape(n_chains * c_max, n_words)
    m_ext = preprocess_lanes(w, v_max, precision)
    label, _, _, done = ddg_walk(
        m_ext, words, n_bins=v_max, precision=precision,
        total_steps=total_steps,
    )
    labels = argmax_fallback(w, label, done, v_max).reshape(n_chains, c_max)

    # --- in-place scatter via one-hot MXU matmul (padded lanes: node -1) --
    onehot = (
        nodes[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (c_max, n_nodes), 1)
    ).astype(jnp.int32)
    scattered = jax.lax.dot_general(
        labels, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    sel = jnp.max(onehot, axis=0)[None, :] > 0
    out_ref[...] = jnp.where(sel, scattered, vals)


def fused_round_words(
    fr: BNFusedRounds, key: jax.Array, n_chains: int, n_words: int
) -> jax.Array:
    """Per-round packed random words in the kernel's stacked row layout.

    Round r's stream is `ky_core.random_words(keys[r], (B * n_c_r,), W)` —
    byte-for-byte what `draw_from_logits` would draw for that round's
    (B, n_c_r, V) logits — reshaped to (B, n_c_r, W), padded to c_max (pad
    rows read zero bits; their lanes are discarded), and packed as one
    (R*B, c_max*W) array so a (B, c_max*W) block slices round r."""
    keys = jax.random.split(key, len(fr.n_c))
    rows = []
    for r, nc in enumerate(fr.n_c):
        wr = ky_core.random_words(keys[r], (n_chains * nc,), n_words)
        wr = wr.reshape(n_chains, nc, n_words)
        wr = jnp.pad(wr, ((0, 0), (0, fr.c_max - nc), (0, 0)))
        rows.append(wr.reshape(n_chains, fr.c_max * n_words))
    return jnp.concatenate(rows, axis=0)


def fused_gibbs_sweep(
    cbn: CompiledBayesNet,
    fr: BNFusedRounds,
    vals: jax.Array,
    key: jax.Array,
    sampler: str = "lut_ky",
    *,
    precision: int = 16,
    max_retries: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Drop-in for `bayesnet.gibbs_sweep` on the fused samplers: one
    pallas_call executes every round of the sweep with the chain values
    VMEM-resident throughout, bit-exact with the unfused sweep.

    Raises on samplers outside `FUSED_BN_SAMPLERS` (`check_fused_sampler`)
    — never a silent fallback."""
    check_fused_sampler(sampler)
    b, n = vals.shape
    v = cbn.max_card
    if v >= LANES:  # raised, not asserted: must hold under `python -O`
        raise ValueError(
            f"max_card {v} >= {LANES} KY lanes; pad wider alphabets "
            "hierarchically (token_sampler)"
        )
    weight_bits = 8 if sampler == "lut_ky" else 15
    # match draw_from_logits' precision widening for the weight-sum bound
    precision = max(precision, weight_bits + (v - 1).bit_length() + 1)
    total_steps = precision * max_retries
    n_words = -(-total_steps // 32)
    words = fused_round_words(fr, key, b, n_words)
    logf = jnp.reshape(cbn.log_flat, (1, -1))
    tab = jnp.reshape(cbn.exp_table, (1, -1)).astype(jnp.float32)
    n_rounds = len(fr.n_c)

    kernel = functools.partial(
        bn_round_step, n_chains=b, n_nodes=n, c_max=fr.c_max,
        f_max=fr.f_max, s_max=fr.s_max, v_max=v, n_words=n_words,
        sampler=sampler, x0=cbn.exp_spec.x0, dx=cbn.exp_spec.dx,
        lut_size=cbn.exp_spec.size, weight_bits=weight_bits,
        precision=precision, total_steps=total_steps,
    )
    vmem = compat.pallas_vmem()

    def per_round(cols):
        return pl.BlockSpec((1, cols), lambda i: (i, 0), memory_space=vmem)

    def resident(rows, cols, space=vmem):
        return pl.BlockSpec((rows, cols), lambda i: (0, 0),
                            memory_space=space)

    cfs = fr.c_max * fr.f_max * fr.s_max
    return pl.pallas_call(
        kernel,
        grid=(n_rounds,),
        in_specs=[
            resident(b, n),  # initial chain values (read at step 0 only)
            per_round(fr.c_max),  # nodes
            per_round(fr.c_max),  # cards
            per_round(fr.c_max * fr.f_max),  # base
            per_round(cfs),  # stride
            per_round(cfs),  # scope_var
            per_round(cfs),  # is_self
            # random words: rows [r*B, (r+1)*B) belong to round r
            pl.BlockSpec((b, fr.c_max * n_words), lambda i: (i, 0),
                         memory_space=vmem),
            # log-CPT arena, resident for the whole sweep
            resident(1, logf.shape[1]),
            resident(1, tab.shape[1]),  # exp-weight LUT (C2 table)
        ],
        out_specs=resident(b, n),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(vals, fr.nodes, fr.cards, fr.base, fr.stride, fr.scope_var,
      fr.is_self, words, logf, tab)


def fused_color_round(
    vals: jax.Array,  # (B, n) chain values
    nodes: jax.Array,  # (C,) local node ids; id >= n marks a pad slot
    cards: jax.Array,  # (C,) cards; 0 = pad
    base: jax.Array,  # (C, F)
    stride: jax.Array,  # (C, F, S)
    scope_var: jax.Array,
    is_self: jax.Array,
    words: jax.Array,  # (B, C, n_words) uint32
    logf: jax.Array,  # (1, L) log-CPT arena
    tab: jax.Array,  # (1, T) exp-weight LUT
    *,
    sampler: str,
    exp_spec,
    v_max: int,
    n_words: int,
    weight_bits: int,
    precision: int,
    total_steps: int,
    interpret: bool = False,
) -> jax.Array:
    """One fused color round as a standalone grid=(1,) `pallas_call`.

    The sharded engine (`core/distributed.py`) cannot place `lax`
    collectives inside a kernel, so its one-shard_map-body route runs one
    `bn_round_step` per schedule round with the `psum_broadcast` merge in
    between.  Reusing the exact sweep kernel (its r==0 branch seeds the
    resident value block from `vals`) keeps the per-round datapath — and
    therefore every draw — bit-identical to `fused_gibbs_sweep`'s grid
    steps; only how halo state moves differs."""
    check_fused_sampler(sampler)
    b, n = vals.shape
    c_max, f_max, s_max = stride.shape
    kernel = functools.partial(
        bn_round_step, n_chains=b, n_nodes=n, c_max=c_max, f_max=f_max,
        s_max=s_max, v_max=v_max, n_words=n_words, sampler=sampler,
        x0=exp_spec.x0, dx=exp_spec.dx, lut_size=exp_spec.size,
        weight_bits=weight_bits, precision=precision,
        total_steps=total_steps,
    )
    vmem = compat.pallas_vmem()

    def resident(rows, cols):
        return pl.BlockSpec((rows, cols), lambda i: (0, 0),
                            memory_space=vmem)

    cfs = c_max * f_max * s_max
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            resident(b, n),
            resident(1, c_max),  # nodes
            resident(1, c_max),  # cards
            resident(1, c_max * f_max),  # base
            resident(1, cfs),  # stride
            resident(1, cfs),  # scope_var
            resident(1, cfs),  # is_self
            resident(b, c_max * n_words),  # random words
            resident(1, logf.shape[1]),  # log-CPT arena
            resident(1, tab.shape[1]),  # exp-weight LUT
        ],
        out_specs=resident(b, n),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(vals, nodes.reshape(1, -1).astype(jnp.int32),
      cards.reshape(1, -1).astype(jnp.int32), base.reshape(1, -1),
      stride.reshape(1, -1), scope_var.reshape(1, -1),
      is_self.reshape(1, -1).astype(jnp.int32),
      words.reshape(b, c_max * n_words), logf, tab)
