"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(exact equality for the integer/bit-deterministic paths, allclose for the
float paths).  They are also the CPU fallback used by the PM engines when
running outside interpret mode is not desired.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ky as ky_core
from repro.core.interp import LUTSpec, interp_ref


def ky_sample(
    weights: jax.Array,
    words: jax.Array,
    *,
    n_bins: int,
    precision: int = 16,
    max_retries: int = 8,
):
    """Oracle for kernels.ky_sampler — bit-deterministic given `words`.

    Accepts either exact-width (B, n_bins) or lane-padded (B, 128) weights.
    """
    w = weights[..., :n_bins]
    return ky_core.ky_sample_ref(
        w, words, n_bins=n_bins, precision=precision, max_retries=max_retries
    )


def interp(x: jax.Array, table: jax.Array, spec: LUTSpec) -> jax.Array:
    """Oracle for kernels.interp_lut."""
    return interp_ref(x, jnp.ravel(table)[: spec.size], spec)


def mrf_gibbs_half_step(
    labels: jax.Array,
    evidence: jax.Array,
    words: jax.Array,
    *,
    parity: int,
    theta: float,
    h: float,
    n_labels: int,
    exp_table: jax.Array,
    exp_spec: LUTSpec,
    data_cost: str = "potts",
    precision: int = 16,
    max_retries: int = 8,
) -> jax.Array:
    """Oracle for kernels.mrf_gibbs: one checkerboard half-step of chromatic
    Gibbs on a Potts/Ising grid MRF (paper Eqn. 7, Alg. 2 with K=2 colors).

    labels, evidence: (H, W) int32 in [0, n_labels); words: (H, W, n_words)
    uint32; parity selects the color (checkerboard) being updated.

    Energy of assigning value v at site (i,j):
        E(v) = theta * sum_{nbr} [v == label_nbr] + datacost(v, e_ij)
    P(v) ∝ exp(E(v)); exp is evaluated through the integer-weight LUT (C2)
    and the draw uses rejection-KY (C1) — normalization-free end to end.
    Op-for-op identical to the fused kernel so equality is exact.
    """
    hh, ww = labels.shape

    def nbr(shift, axis):
        rolled = jnp.roll(labels, shift, axis=axis)
        # out-of-grid neighbors marked -1 (no value matches)
        idx = jnp.arange(labels.shape[axis])
        edge = idx == (0 if shift == 1 else labels.shape[axis] - 1)
        edge = jnp.expand_dims(edge, axis=1 - axis)
        return jnp.where(edge, -1, rolled)

    up, down, left, right = nbr(1, 0), nbr(-1, 0), nbr(1, 1), nbr(-1, 1)
    energies = []
    for v in range(n_labels):
        cnt = (
            ((up == v).astype(jnp.float32) + (down == v).astype(jnp.float32))
            + (left == v).astype(jnp.float32)
        ) + (right == v).astype(jnp.float32)
        if data_cost == "potts":
            data = h * (evidence == v).astype(jnp.float32)
        else:
            diff = (evidence - v).astype(jnp.float32)
            data = -h * diff * diff
        energies.append(theta * cnt + data)
    e = jnp.stack(energies, axis=-1)  # (H, W, V)
    z = e - e.max(axis=-1, keepdims=True)
    tab = jnp.ravel(exp_table)[: exp_spec.size]
    w_int = jnp.maximum(jnp.round(interp_ref(z, tab, exp_spec)), 0.0)
    w_int = w_int.astype(jnp.int32)

    new, _ = ky_core.ky_sample_ref(
        w_int.reshape(-1, n_labels),
        words.reshape(hh * ww, -1),
        n_bins=n_labels,
        precision=precision,
        max_retries=max_retries,
    )
    new = new.reshape(hh, ww)
    ii = jnp.arange(hh)[:, None] + jnp.arange(ww)[None, :]
    return jnp.where((ii % 2) == parity, new, labels)
