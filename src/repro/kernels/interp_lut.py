"""Pallas TPU kernel for the LUT linear-interpolation unit (paper C2).

The hardware unit fetches Y[i], Y[i+1] and computes the lerp in one cycle.
On TPU there is no fast per-lane VMEM gather, so the <=32-entry table gather
is unrolled into `size` lane-selects against scalar table entries — constant
work per element, fully fused with the surrounding arithmetic, no HBM access.
This preserves the unit's contract: "nonlinear f() at table cost, one op".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.core.interp import LUTSpec

pl = compat.pallas()

DEFAULT_BLOCK_M = 256


def interp_eval(
    x: jax.Array, tab_row, x0: float, dx: float, size: int
) -> jax.Array:
    """Fused LUT lerp on an in-VMEM value array; tab_row is a (1, L) ref or
    array whose scalar entries are read per unrolled step (size <= 32).
    Shared by this kernel and the fused mrf_gibbs kernel."""
    u = jnp.clip((x - x0) / dx, 0.0, float(size - 1))
    idx = jnp.minimum(u.astype(jnp.int32), size - 2)
    frac = u - idx.astype(u.dtype)
    y0 = jnp.zeros_like(x)
    y1 = jnp.zeros_like(x)
    for l in range(size - 1):  # unrolled table walk (size <= 32)
        sel = idx == l
        y0 = jnp.where(sel, tab_row[0, l], y0)
        y1 = jnp.where(sel, tab_row[0, l + 1], y1)
    return y0 + frac * (y1 - y0)


def _interp_kernel(x_ref, tab_ref, y_ref, *, x0: float, dx: float, size: int):
    y_ref[...] = interp_eval(x_ref[...], tab_ref, x0, dx, size)


@functools.partial(
    jax.jit, static_argnames=("spec", "block_m", "interpret")
)
def interp_kernel(
    x: jax.Array,
    table: jax.Array,
    *,
    spec: LUTSpec,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = False,
) -> jax.Array:
    """x (M, N) f32, table (1, size_padded) f32 -> (M, N) f32.

    N must be a multiple of 128 (ops.interp pads); rows are tiled block_m at
    a time with the table block broadcast to every grid step (VMEM-resident,
    the private-RF analogue)."""
    m, n = x.shape
    if n % 128 != 0:  # raised, not asserted: must hold under `python -O`
        raise ValueError(
            f"lane axis {n} not a multiple of 128; pad it (use ops.interp)"
        )
    block_m = min(block_m, m)
    grid = (pl.cdiv(m, block_m),)
    kernel = functools.partial(
        _interp_kernel, x0=spec.x0, dx=spec.dx, size=spec.size
    )
    vmem = compat.pallas_vmem()
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, n), lambda i: (i, 0),
                         memory_space=vmem),
            pl.BlockSpec((1, table.shape[1]), lambda i: (0, 0),
                         memory_space=vmem),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0),
                               memory_space=vmem),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, table)
