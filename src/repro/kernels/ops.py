"""Public jit'd entry points for the Pallas kernels.

These wrappers own all layout plumbing (lane padding, batch padding, random
word generation, interpret-mode auto-detection) so callers see clean shapes.
On non-TPU backends the kernels run in interpret mode (Python evaluation of
the kernel body) — the TPU lowering path is identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ky as ky_core
from repro.core.interp import LUTSpec
from repro.kernels import interp_lut as _interp_lut
from repro.kernels import ky_sampler as _ky

LANES = 128


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_axis(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def ky_sample(
    weights: jax.Array,
    key: jax.Array,
    *,
    precision: int = 16,
    max_retries: int = 8,
    block_b: int = _ky.DEFAULT_BLOCK_B,
    interpret: bool | None = None,
    return_stats: bool = False,
):
    """Draw one exact sample per row from unnormalized int32 weights.

    weights: (B, N) int32, N < 128 (wider distributions: use token_sampler's
    hierarchical path).  Returns labels (B,) int32 [, stats].
    """
    b, n_bins = weights.shape
    if n_bins >= LANES:  # raised, not asserted: must hold under `python -O`
        raise ValueError(
            f"KY kernel handles <={LANES - 1} bins, got {n_bins}; "
            "see token_sampler"
        )
    wpad = _pad_axis(weights.astype(jnp.int32), 1, LANES)
    n_words = -(-precision * max_retries // 32)
    words = ky_core.random_words(key, (b,), n_words)
    # pad batch to the block size so every grid block is full
    bb = min(block_b, b)
    wpad = _pad_axis(wpad, 0, bb, value=1)
    words_p = _pad_axis(words, 0, bb)
    labels, stats = _ky.ky_sample_kernel(
        wpad,
        words_p,
        n_bins=n_bins,
        precision=precision,
        max_retries=max_retries,
        block_b=bb,
        interpret=_auto_interpret(interpret),
    )
    labels = labels[:b]
    if return_stats:
        return labels, jax.tree.map(lambda s: s[:b], stats)
    return labels


def interp(
    x: jax.Array,
    table: jax.Array,
    spec: LUTSpec,
    *,
    block_m: int = _interp_lut.DEFAULT_BLOCK_M,
    interpret: bool | None = None,
) -> jax.Array:
    """Vectorized LUT lerp over an arbitrary-shaped float array."""
    shape = x.shape
    flat = x.reshape(-1)
    total = flat.shape[0]
    # lay out as (M, 128·k) tiles
    n = LANES
    m = -(-total // n)
    flat = _pad_axis(flat, 0, m * n).reshape(m, n)
    mb = min(block_m, m)
    flat = _pad_axis(flat, 0, mb)
    tab = _pad_axis(table.reshape(1, -1).astype(jnp.float32), 1, LANES)
    y = _interp_lut.interp_kernel(
        flat, tab, spec=spec, block_m=mb, interpret=_auto_interpret(interpret)
    )
    return y.reshape(-1)[:total].reshape(shape)


def lut_exp_weights(
    log_potentials: jax.Array,
    exp_table: jax.Array,
    exp_spec: LUTSpec,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused C2 stage of the sampling pipeline: max-subtracted log-potentials
    -> LUT-exp -> integer KY weights (no softmax, no normalization)."""
    z = log_potentials - jax.lax.stop_gradient(
        jnp.max(log_potentials, axis=-1, keepdims=True)
    )
    w = interp(z, exp_table, exp_spec, interpret=interpret)
    return jnp.maximum(jnp.round(w), 0.0).astype(jnp.int32)
