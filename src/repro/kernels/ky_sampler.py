"""Pallas TPU kernel for the rejection-based Knuth-Yao sampler (paper C1).

One kernel invocation draws one exact sample per batch row from an
unnormalized int32 weight vector, consuming packed random bits.  The paper's
per-cycle datapath (Fig. 5) maps onto the TPU as:

  hardware AIA                          this kernel
  ------------------------------------  -------------------------------------
  32-bin distribution in private RF     (block_b, 128) int32 weights in VMEM
  per-cycle DDG column read (SU.B)      on-the-fly shift of the weight lanes
  parallel-prefix adder over bins       cumsum via lower-triangular MXU matmul
  LFSR random bit                       packed jax.random words in VMEM
  FSM rejection-restart                 masked lane-wise restart, early-exit
                                        while_loop => O(H) expected levels

Batching over VPU sublanes replaces AIA's 16 parallel cores: all same-color
RVs / serving requests walk their DDG trees in lock-step, each with private
state, exactly like the paper's asynchronous cores between barriers.

Block layout: bins live on the 128-wide lane axis (N + rejection bin <= 128;
wider distributions are handled hierarchically by token_sampler.py), batch on
the sublane axis.  The whole working set (weights, bit words, walk state) for
a block is VMEM-resident; the distribution is produced, walked and discarded
without an HBM round-trip — the paper's private-RF locality argument.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compat

pl = compat.pallas()

LANES = 128
DEFAULT_BLOCK_B = 256


def _cumsum_lanes(x: jax.Array) -> jax.Array:
    """Inclusive cumsum along the last (lane) axis via triangular matmul.

    TPU Pallas has no native 1-pass lane scan; an (N, N) lower-triangular
    int32 matmul on the MXU is the idiomatic replacement (the paper uses a
    parallel-prefix adder for the same reduction over its 32 bins).
    """
    n = x.shape[-1]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
           <= jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)).astype(jnp.int32)
    return jax.lax.dot_general(
        x, tri, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def preprocess_lanes(m: jax.Array, n_bins: int, precision: int) -> jax.Array:
    """In-VMEM preprocessing on lane-padded weights (b, LANES): clamp ->
    scale-to-fill -> write the rejection bin into lane `n_bins` (Eqns. 8-9)."""
    b = m.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (b, LANES), 1)
    m = jnp.maximum(m, 0)
    m = jnp.where(lane < n_bins, m, 0)
    s = jnp.sum(m, axis=-1, keepdims=True)
    m = jnp.where(s > 0, m, jnp.where(lane < n_bins, 1, 0))
    s = jnp.sum(m, axis=-1, keepdims=True)
    k = jnp.maximum((1 << precision) // s, 1)
    m = m * k
    rej = (1 << precision) - jnp.sum(m, axis=-1, keepdims=True)
    return jnp.where(lane == n_bins, rej, m)


def ddg_walk(
    m_ext: jax.Array, words: jax.Array, *, n_bins: int, precision: int,
    total_steps: int,
):
    """Early-exit batched DDG walk on prepared lane-padded weights.

    m_ext (b, LANES) int32 summing to 2^precision (rejection in lane n_bins),
    words (b, n_words) uint32.  Returns (labels, bits, rejs, done), all
    (b, 1); labels is -1 where the bit budget ran out (caller applies the
    argmax fallback).  Runs inside Pallas kernel bodies and plain jit alike.
    """
    b = m_ext.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (b, LANES), 1)
    zi = jnp.zeros((b, 1), jnp.int32)

    def cond(carry):
        t, d, level, label, done, bits, rejs = carry
        return (t < total_steps) & jnp.any(~done)

    def body(carry):
        t, d, level, label, done, bits, rejs = carry
        word = jax.lax.dynamic_slice_in_dim(words, t // 32, 1, axis=1)
        shift = jnp.asarray(t % 32).astype(words.dtype)
        one = jnp.asarray(1, words.dtype)
        bit = (jnp.right_shift(word, shift) & one).astype(jnp.int32)  # (b, 1)
        active = ~done
        d = jnp.where(active, 2 * d + bit, d)
        col = (m_ext >> (precision - 1 - level)) & 1  # (b, LANES)
        c = _cumsum_lanes(col)
        total = c[:, LANES - 1:LANES]
        hit = c > d
        # first hit lane = min lane index among hits
        idx = jnp.min(jnp.where(hit, lane, LANES), axis=-1, keepdims=True)
        terminated = active & (total > d)
        is_rej = idx >= n_bins
        accept = terminated & ~is_rej
        reject = terminated & is_rej
        cont = active & ~terminated
        return (
            t + 1,
            jnp.where(reject, 0, jnp.where(cont, d - total, d)),
            jnp.where(reject, 0, jnp.where(cont, level + 1, level)),
            jnp.where(accept, idx, label),
            done | accept,
            bits + active.astype(jnp.int32),
            rejs + reject.astype(jnp.int32),
        )

    t0 = jnp.zeros((), jnp.int32)
    carry = (t0, zi, zi, zi - 1, jnp.zeros((b, 1), bool), zi, zi)
    _, d, level, label, done, bits, rejs = jax.lax.while_loop(cond, body, carry)
    return label, bits, rejs, done


def argmax_fallback(
    m: jax.Array, labels: jax.Array, done: jax.Array, n_bins: int
) -> jax.Array:
    """Fallback for the (<2^-max_retries) bit-exhaustion case: argmax weight."""
    b = m.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (b, LANES), 1)
    m = jnp.where(lane < n_bins, m, -1)
    mx = jnp.max(m, axis=-1, keepdims=True)
    amax = jnp.min(jnp.where(m == mx, lane, LANES), axis=-1, keepdims=True)
    return jnp.where(done, labels, amax)


def _ky_kernel(
    w_ref, words_ref, labels_ref, bits_ref, rej_ref, fb_ref,
    *, n_bins: int, precision: int, total_steps: int,
):
    m_ext = preprocess_lanes(w_ref[...], n_bins, precision)
    label, bits, rejs, done = ddg_walk(
        m_ext, words_ref[...], n_bins=n_bins, precision=precision,
        total_steps=total_steps,
    )
    labels_ref[...] = argmax_fallback(w_ref[...], label, done, n_bins)
    bits_ref[...] = bits
    rej_ref[...] = rejs
    fb_ref[...] = (~done).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_bins", "precision", "max_retries", "block_b", "interpret"),
)
def ky_sample_kernel(
    weights: jax.Array,
    words: jax.Array,
    *,
    n_bins: int,
    precision: int = 16,
    max_retries: int = 8,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    """Draw one sample per row. weights (B, LANES) int32 (bins padded to 128,
    lane `n_bins` reserved for the rejection bin), words (B, n_words) uint32.

    Returns (labels (B,), stats dict) — bit-exact vs core.ky.ky_sample_ref.
    """
    # raised, not asserted: these must hold under `python -O` too — a
    # stripped bits check would let the walk read past the random stream
    if weights.shape[-1] != LANES:
        raise ValueError(
            f"weights have {weights.shape[-1]} lanes; pad bins to {LANES} "
            "(ops.ky_sample)"
        )
    if n_bins >= LANES:
        raise ValueError(f"n_bins {n_bins} needs a free rejection lane")
    b, n_words = words.shape[0], words.shape[1]
    total_steps = precision * max_retries
    if n_words * 32 < total_steps:
        raise ValueError(
            f"not enough random bits: {n_words} words < {total_steps} steps"
        )
    block_b = min(block_b, b)
    grid = (pl.cdiv(b, block_b),)

    kernel = functools.partial(
        _ky_kernel, n_bins=n_bins, precision=precision, total_steps=total_steps
    )
    out_shape = [jax.ShapeDtypeStruct((b, 1), jnp.int32)] * 4
    spec_b = lambda shp: pl.BlockSpec(shp, lambda i: (i, 0),
                                      memory_space=compat.pallas_vmem())
    labels, bits, rejs, fb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_b((block_b, LANES)), spec_b((block_b, n_words))],
        out_specs=[spec_b((block_b, 1))] * 4,
        out_shape=out_shape,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(weights, words)
    stats = {
        "bits_used": bits[:, 0],
        "rejections": rejs[:, 0],
        "fallback": fb[:, 0].astype(bool),
    }
    return labels[:, 0], stats
