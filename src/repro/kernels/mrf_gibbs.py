"""Fused Pallas kernel: one checkerboard Gibbs half-step on a grid MRF.

This is the AIA inner loop (paper Sec. III "Approximate Inference Overview")
as a single VMEM-resident pipeline, fusing all four innovations:

  1. neighbor-label exchange (C4)  — halo rows come from the adjacent row
     blocks (BlockSpec index maps i-1 / i / i+1), the intra-tile shifts are
     VMEM slices; across devices, distributed.py replaces the halo load
     with a `ppermute` — the mesh-neighbor register read, ICI-native;
  2. energy computation (programmable ALU) — Potts smoothness + data cost;
  3. LUT-exp via the interpolation unit (C2) — `interp_eval`, int8 weights;
  4. rejection-KY draw (C1) — `ddg_walk` over V<=32 lanes per site.

The conditional distribution of every site is produced, sampled and
discarded inside the tile — zero HBM round-trips for intermediates, the
paper's private-RF locality argument. Bit-exact against ref.mrf_gibbs_half_step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.core import ky as ky_core
from repro.core.interp import LUTSpec
from repro.kernels.interp_lut import interp_eval
from repro.kernels.ky_sampler import LANES, argmax_fallback, ddg_walk, \
    preprocess_lanes

pl = compat.pallas()

DEFAULT_BLOCK_H = 32


def _mrf_tile_body(
    lab, up_halo, down_halo, ev, words, tab_ref, gr0,
    *, parity: int, theta: float, h: float, n_labels: int, data_cost: str,
    x0: float, dx: float, lut_size: int, precision: int, total_steps: int,
    block_h: int, width: int,
):
    """The fused half-step pipeline on one (block_h, W) tile: energies ->
    LUT-exp -> KY walk -> checkerboard scatter.  `up_halo`/`down_halo` are
    the tile's boundary neighbor rows ((1, W); -1 where the grid ends) and
    `gr0` the tile's global row offset — the single-device and sharded-slab
    kernels differ only in how they produce these three, so sharing the
    body keeps the two datapaths bit-identical by construction."""
    up = jnp.concatenate([up_halo, lab[:-1, :]], axis=0)
    down = jnp.concatenate([lab[1:, :], down_halo], axis=0)
    neg_col = jnp.full((block_h, 1), -1, jnp.int32)
    left = jnp.concatenate([neg_col, lab[:, :-1]], axis=1)
    right = jnp.concatenate([lab[:, 1:], neg_col], axis=1)

    s = block_h * width

    # --- energies per candidate value, same op order as the ref oracle -----
    z_cols = []
    e_max = jnp.full((block_h, width), -jnp.inf, jnp.float32)
    energies = []
    for v in range(n_labels):
        cnt = (
            ((up == v).astype(jnp.float32) + (down == v).astype(jnp.float32))
            + (left == v).astype(jnp.float32)
        ) + (right == v).astype(jnp.float32)
        if data_cost == "potts":
            data = h * (ev == v).astype(jnp.float32)
        else:
            diff = (ev - v).astype(jnp.float32)
            data = -h * diff * diff
        e = theta * cnt + data
        energies.append(e)
        e_max = jnp.maximum(e_max, e)
    for v in range(n_labels):
        z_cols.append((energies[v] - e_max).reshape(s, 1))

    # --- C2: LUT-exp -> int8 weights on the (site, value) layout -----------
    z = jnp.concatenate(z_cols, axis=1)  # (s, V)
    w = jnp.maximum(jnp.round(interp_eval(z, tab_ref, x0, dx, lut_size)), 0.0)
    w = w.astype(jnp.int32)
    pad = jnp.zeros((s, LANES - n_labels), jnp.int32)
    w = jnp.concatenate([w, pad], axis=1)  # (s, LANES)

    # --- C1: rejection-KY walk over all sites of the tile ------------------
    words = words.reshape(s, -1)
    m_ext = preprocess_lanes(w, n_labels, precision)
    label, bits, rejs, done = ddg_walk(
        m_ext, words, n_bins=n_labels, precision=precision,
        total_steps=total_steps,
    )
    new = argmax_fallback(w, label, done, n_labels).reshape(block_h, width)

    # --- checkerboard scatter (only this color updates) --------------------
    gr = gr0 + jax.lax.broadcasted_iota(jnp.int32, (block_h, width), 0)
    gc = jax.lax.broadcasted_iota(jnp.int32, (block_h, width), 1)
    mask = ((gr + gc) % 2) == parity
    return jnp.where(mask, new, lab)


def _mrf_kernel(
    lab_prev_ref, lab_ref, lab_next_ref, ev_ref, words_ref, tab_ref, out_ref,
    *, parity: int, theta: float, h: float, n_labels: int, data_cost: str,
    x0: float, dx: float, lut_size: int, precision: int, total_steps: int,
    block_h: int, n_blocks: int, width: int,
):
    i = pl.program_id(0)
    lab = lab_ref[...]  # (block_h, W)
    neg = jnp.full((1, width), -1, jnp.int32)

    # --- C4: neighbor labels; halo rows from adjacent blocks ---------------
    up_halo = jnp.where(i > 0, lab_prev_ref[block_h - 1 : block_h, :], neg)
    down_halo = jnp.where(i < n_blocks - 1, lab_next_ref[0:1, :], neg)
    out_ref[...] = _mrf_tile_body(
        lab, up_halo, down_halo, ev_ref[...], words_ref[...], tab_ref,
        i * block_h, parity=parity, theta=theta, h=h, n_labels=n_labels,
        data_cost=data_cost, x0=x0, dx=dx, lut_size=lut_size,
        precision=precision, total_steps=total_steps, block_h=block_h,
        width=width,
    )


def _mrf_halo_kernel(
    off_ref, up_ref, down_ref, lab_prev_ref, lab_ref, lab_next_ref, ev_ref,
    words_ref, tab_ref, out_ref,
    *, parity: int, theta: float, h: float, n_labels: int, data_cost: str,
    x0: float, dx: float, lut_size: int, precision: int, total_steps: int,
    block_h: int, n_blocks: int, width: int,
):
    """The sharded-slab variant: the slab's outermost halo rows come in as
    explicit (1, W) inputs (the caller's `lax.ppermute` exchange — the C4
    mesh-neighbor register read), interior tiles still read them from the
    adjacent row blocks, and the checkerboard parity is computed against
    the slab's global row offset (`off_ref`, a traced (1, 1) scalar)."""
    i = pl.program_id(0)
    lab = lab_ref[...]  # (block_h, W)
    up_halo = jnp.where(
        i > 0, lab_prev_ref[block_h - 1 : block_h, :], up_ref[...]
    )
    down_halo = jnp.where(
        i < n_blocks - 1, lab_next_ref[0:1, :], down_ref[...]
    )
    out_ref[...] = _mrf_tile_body(
        lab, up_halo, down_halo, ev_ref[...], words_ref[...], tab_ref,
        off_ref[0, 0] + i * block_h, parity=parity, theta=theta, h=h,
        n_labels=n_labels, data_cost=data_cost, x0=x0, dx=dx,
        lut_size=lut_size, precision=precision, total_steps=total_steps,
        block_h=block_h, width=width,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "parity", "theta", "h", "n_labels", "data_cost", "spec",
        "precision", "max_retries", "block_h", "interpret",
    ),
)
def mrf_half_step_kernel(
    labels: jax.Array,
    evidence: jax.Array,
    words: jax.Array,
    exp_table: jax.Array,
    *,
    parity: int,
    theta: float,
    h: float,
    n_labels: int,
    spec: LUTSpec,
    data_cost: str = "potts",
    precision: int = 16,
    max_retries: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    interpret: bool = False,
) -> jax.Array:
    """labels, evidence: (H, W) int32; words: (H, W * n_words) uint32 (row-
    major (H, W, n_words) flattened); exp_table: (1, L) f32 weight table."""
    height, width = labels.shape
    # raised, not asserted: shape gates must hold under `python -O` too
    if n_labels >= LANES:
        raise ValueError(f"n_labels {n_labels} >= {LANES} KY lanes")
    block_h = min(block_h, height)
    if height % block_h != 0:
        raise ValueError(
            f"height {height} not a multiple of block_h {block_h}; pad H"
        )
    n_blocks = height // block_h
    total_steps = precision * max_retries
    want_words = (height, width * (-(-total_steps // 32)))
    if words.shape != want_words:
        raise ValueError(
            f"random words shaped {words.shape}, kernel needs {want_words}"
        )

    kernel = functools.partial(
        _mrf_kernel, parity=parity, theta=theta, h=h, n_labels=n_labels,
        data_cost=data_cost, x0=spec.x0, dx=spec.dx, lut_size=spec.size,
        precision=precision, total_steps=total_steps, block_h=block_h,
        n_blocks=n_blocks, width=width,
    )

    vmem = compat.pallas_vmem()

    def blk(idx_fn, cols):
        return pl.BlockSpec((block_h, cols), idx_fn, memory_space=vmem)

    n_words_cols = words.shape[1]
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            blk(lambda i: (jnp.maximum(i - 1, 0), 0), width),  # halo above
            blk(lambda i: (i, 0), width),
            blk(lambda i: (jnp.minimum(i + 1, n_blocks - 1), 0), width),
            blk(lambda i: (i, 0), width),  # evidence
            blk(lambda i: (i, 0), n_words_cols),  # random words
            pl.BlockSpec((1, exp_table.shape[1]), lambda i: (0, 0),
                         memory_space=vmem),
        ],
        out_specs=blk(lambda i: (i, 0), width),
        out_shape=jax.ShapeDtypeStruct((height, width), jnp.int32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(labels, labels, labels, evidence, words, exp_table)


def mrf_round_step(
    mrf,
    labels: jax.Array,  # (B, H, W) int32
    evidence: jax.Array,  # (H, W) int32
    key: jax.Array,
    parity: int,
    exp_table: jax.Array,
    exp_spec: LUTSpec,
    *,
    precision: int = 16,
    max_retries: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """One schedule round (single checkerboard parity) through the fused
    kernel, vmapped over the chains axis — the `repro.compile.backend`
    entry point for `fused=True` MRF execution.

    Random words come from `ky_core.random_words(key, (B, H, W), n_words)`,
    the same stream `draw_from_logits` consumes for the (B, H, W, V) logits
    of the eager half-step, so lut_ky outputs are bit-identical to
    `mrf.half_step` under the same key."""
    b, height, width = labels.shape
    # match draw_from_logits' precision widening for the weight sum bound
    precision = max(precision, 8 + (mrf.n_labels - 1).bit_length() + 1)
    n_words = -(-precision * max_retries // 32)
    words = ky_core.random_words(key, (b, height, width), n_words)
    tab = jnp.reshape(exp_table, (1, -1)).astype(jnp.float32)
    # largest divisor of H that fits the default tile (the kernel requires
    # H % block_h == 0)
    block_h = next(
        bh for bh in range(min(DEFAULT_BLOCK_H, height), 0, -1)
        if height % bh == 0
    )
    step = functools.partial(
        mrf_half_step_kernel,
        parity=parity, theta=mrf.theta, h=mrf.h, n_labels=mrf.n_labels,
        spec=exp_spec, data_cost=mrf.data_cost, precision=precision,
        max_retries=max_retries, block_h=block_h, interpret=interpret,
    )
    return jax.vmap(
        lambda lab, wds: step(lab, evidence, wds.reshape(height, -1), tab)
    )(labels, words)


@functools.partial(
    jax.jit,
    static_argnames=(
        "parity", "theta", "h", "n_labels", "data_cost", "spec",
        "precision", "max_retries", "block_h", "interpret",
    ),
)
def mrf_halo_half_step_kernel(
    labels: jax.Array,
    up_halo: jax.Array,
    down_halo: jax.Array,
    row0: jax.Array,
    evidence: jax.Array,
    words: jax.Array,
    exp_table: jax.Array,
    *,
    parity: int,
    theta: float,
    h: float,
    n_labels: int,
    spec: LUTSpec,
    data_cost: str = "potts",
    precision: int = 16,
    max_retries: int = 8,
    block_h: int = DEFAULT_BLOCK_H,
    interpret: bool = False,
) -> jax.Array:
    """`mrf_half_step_kernel` over a local row *slab* of a sharded grid:
    labels/evidence/words cover the (h_loc, W) slab only, `up_halo` /
    `down_halo` ((1, W) int32; -1 beyond the global boundary) are the
    neighbor shards' border rows, and `row0` ((1, 1) int32, traced) is the
    slab's global row offset for the checkerboard parity."""
    height, width = labels.shape
    if n_labels >= LANES:
        raise ValueError(f"n_labels {n_labels} >= {LANES} KY lanes")
    block_h = min(block_h, height)
    if height % block_h != 0:
        raise ValueError(
            f"slab height {height} not a multiple of block_h {block_h}"
        )
    n_blocks = height // block_h
    total_steps = precision * max_retries
    want_words = (height, width * (-(-total_steps // 32)))
    if words.shape != want_words:
        raise ValueError(
            f"random words shaped {words.shape}, kernel needs {want_words}"
        )

    kernel = functools.partial(
        _mrf_halo_kernel, parity=parity, theta=theta, h=h, n_labels=n_labels,
        data_cost=data_cost, x0=spec.x0, dx=spec.dx, lut_size=spec.size,
        precision=precision, total_steps=total_steps, block_h=block_h,
        n_blocks=n_blocks, width=width,
    )

    vmem = compat.pallas_vmem()

    def blk(idx_fn, cols):
        return pl.BlockSpec((block_h, cols), idx_fn, memory_space=vmem)

    def resident(rows, cols):
        return pl.BlockSpec((rows, cols), lambda i: (0, 0),
                            memory_space=vmem)

    n_words_cols = words.shape[1]
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            resident(1, 1),  # global row offset of the slab
            resident(1, width),  # up halo from the mesh neighbor
            resident(1, width),  # down halo from the mesh neighbor
            blk(lambda i: (jnp.maximum(i - 1, 0), 0), width),  # halo above
            blk(lambda i: (i, 0), width),
            blk(lambda i: (jnp.minimum(i + 1, n_blocks - 1), 0), width),
            blk(lambda i: (i, 0), width),  # evidence
            blk(lambda i: (i, 0), n_words_cols),  # random words
            pl.BlockSpec((1, exp_table.shape[1]), lambda i: (0, 0),
                         memory_space=vmem),
        ],
        out_specs=blk(lambda i: (i, 0), width),
        out_shape=jax.ShapeDtypeStruct((height, width), jnp.int32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(row0, up_halo, down_halo, labels, labels, labels, evidence, words,
      exp_table)


def mrf_sharded_round_step(
    mrf,
    labels: jax.Array,  # (B_loc, h_loc, W) int32 local row slab
    evidence: jax.Array,  # (h_loc, W) int32 local evidence rows
    key: jax.Array,
    parity: int,
    exp_table: jax.Array,
    exp_spec: LUTSpec,
    *,
    row0: jax.Array,  # () int32, traced: global row of labels[:, 0]
    chain0: jax.Array,  # () int32, traced: global index of chain 0
    n_chains_total: int,
    up_halo: jax.Array,  # (B_loc, 1, W) int32 neighbor-shard border rows
    down_halo: jax.Array,
    precision: int = 16,
    max_retries: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """One schedule round on a sharded row slab — `mrf_round_step` inside a
    `shard_map` body.  The random stream is generated over the FULL grid
    (and full chain batch) on every device and sliced to the local slab, so
    each site consumes exactly the words the single-device fused round
    would hand it: outputs are bit-identical shard-count-independently.
    Halo rows come from the caller's `lax.ppermute` exchange (the
    `ppermute_halo` comm mechanism)."""
    b_loc, h_loc, width = labels.shape
    height = mrf.height
    # match draw_from_logits' precision widening for the weight sum bound
    precision = max(precision, 8 + (mrf.n_labels - 1).bit_length() + 1)
    n_words = -(-precision * max_retries // 32)
    words = ky_core.random_words(
        key, (n_chains_total, height, width), n_words
    )
    words = jax.lax.dynamic_slice(
        words, (chain0, row0, jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32)),
        (b_loc, h_loc, width, n_words),
    )
    tab = jnp.reshape(exp_table, (1, -1)).astype(jnp.float32)
    block_h = next(
        bh for bh in range(min(DEFAULT_BLOCK_H, h_loc), 0, -1)
        if h_loc % bh == 0
    )
    row0_arr = jnp.reshape(row0, (1, 1)).astype(jnp.int32)
    step = functools.partial(
        mrf_halo_half_step_kernel,
        parity=parity, theta=mrf.theta, h=mrf.h, n_labels=mrf.n_labels,
        spec=exp_spec, data_cost=mrf.data_cost, precision=precision,
        max_retries=max_retries, block_h=block_h, interpret=interpret,
    )
    return jax.vmap(
        lambda lab, uh, dh, wds: step(
            lab, uh, dh, row0_arr, evidence, wds.reshape(h_loc, -1), tab
        )
    )(labels, up_halo, down_halo, words)
