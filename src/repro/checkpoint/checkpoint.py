"""Fault-tolerant checkpointing: device-agnostic npz shards + JSON manifest.

Design goals (1000-node posture, DESIGN.md Sec. 6):

* **atomic** — writes go to ``<dir>/tmp.<step>`` and are renamed into place,
  so a preemption mid-write never corrupts the latest checkpoint;
* **device-agnostic / elastic** — leaves are stored unsharded by flattened
  pytree path; restore() returns host arrays the caller re-shards onto
  whatever mesh exists now (different chip count than at save time is fine);
* **rotated** — keep_last bounds disk usage;
* **resumable end-to-end** — the trainer stores step, optimizer state and the
  data-pipeline cursor in the same checkpoint, so restart is exact.

On a real multi-host pod each host would write only its addressable shards
(same manifest format, per-host shard files); this container is single-host
so save() gathers.  The format already carries per-leaf shape/dtype to make
that split mechanical.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

MANIFEST = "manifest.json"


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save(base_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomically write checkpoint `step`.  Returns the final directory."""
    os.makedirs(base_dir, exist_ok=True)
    tmp = os.path.join(base_dir, f"tmp.{step}")
    final = os.path.join(base_dir, f"ckpt_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": [],
    }
    arrays = {}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = f"leaf_{i:05d}"
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"].append(
            {
                "key": key,
                "path": _path_str(path),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        )
    np.savez(os.path.join(tmp, "shard_host0.npz"), **arrays)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(base_dir: str) -> int | None:
    if not os.path.isdir(base_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(base_dir)
        if d.startswith("ckpt_") and os.path.isfile(
            os.path.join(base_dir, d, MANIFEST)
        )
    ]
    return max(steps) if steps else None


def restore(base_dir: str, step: int, like=None):
    """Load checkpoint `step`.  With `like` (a pytree of arrays or
    ShapeDtypeStructs), leaves are validated and returned in that treedef;
    otherwise returns (manifest, {path: array})."""
    d = os.path.join(base_dir, f"ckpt_{step:010d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_host0.npz"))
    by_path = {
        rec["path"]: data[rec["key"]] for rec in manifest["leaves"]
    }
    if like is None:
        return manifest, by_path
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_with_paths:
        key = _path_str(path)
        arr = by_path[key]
        want = tuple(leaf.shape)
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        out.append(arr.astype(leaf.dtype))
    return manifest, jax.tree_util.tree_unflatten(treedef, out)


def rotate(base_dir: str, keep_last: int = 3) -> None:
    if not os.path.isdir(base_dir):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(base_dir)
        if d.startswith("ckpt_")
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(base_dir, f"ckpt_{s:010d}"),
                      ignore_errors=True)
