"""`repro.obs` — zero-dependency structured tracing for the whole stack.

Spans and counters from the compile chain (per-pass spans, lowering and
cross-check costs), the serving runtime (flush/admission/dispatch on the
deterministic simulated clock, one lane per executor worker), the batcher
(pad decisions), calibration warmup, and the kernel dispatch entries —
recorded into an in-memory ring buffer and exported two ways:

  * a deterministic JSONL event log (wall fields stripped; same-seed runs
    are byte-identical — `tests/test_obs.py` pins it), and
  * a Chrome/Perfetto `trace_event` timeline (open ui.perfetto.dev).

Tracing is off by default and compiles to a single attribute check on
every instrumented path; enable with `REPRO_TRACE=1` or:

    from repro import obs

    obs.enable()
    ...                                  # run the engine / compile chain
    obs.export.write_perfetto("trace.json", obs.get().events)
    obs.export.write_jsonl("trace.jsonl", obs.get().events)
    rows, gaps = obs.attrib.attribution(
        obs.export.events_as_dicts(obs.get().events))

`python -m repro.runtime --trace-out trace.json` wires all of that into
the serving CLI; `python -m repro.obs trace.jsonl` re-checks a saved log's
attribution coverage (the CI step).

Two sibling layers build on the trace:

  * `obs.profile` — compiled-artifact roofline profiler: static
    flops/bytes/collective costs per bucket executable (cached by
    signature, joined against measured dispatch spans); enable with
    `REPRO_PROFILE=1` / `profile.enable()`, or `--profile-out` on the
    runtime CLI.  `python -m repro.obs --profile profile.json`
    re-validates a saved artifact.
  * `obs.timeseries` — deterministic sim-clock metrics series
    (counters/gauges/histograms) always recorded by the engine into
    `metrics.series`; `--profile-out x.json` also writes
    `x.series.jsonl`, byte-identical across same-seed runs.
"""

from repro.obs import attrib, export, profile, timeseries, tracer
from repro.obs.tracer import (
    DEFAULT_CAPACITY,
    Event,
    Tracer,
    counter,
    disable,
    enable,
    enabled,
    get,
    instant,
    sim_span,
    span,
)

__all__ = [
    "attrib",
    "export",
    "profile",
    "timeseries",
    "tracer",
    "DEFAULT_CAPACITY",
    "Event",
    "Tracer",
    "counter",
    "disable",
    "enable",
    "enabled",
    "get",
    "instant",
    "sim_span",
    "span",
]
