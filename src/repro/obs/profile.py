"""Compiled-artifact roofline profiler for bucket/program executables.

The runtime traces *when* dispatches run (``obs.tracer`` spans) and the
diag layer checks *whether* samples are correct; this module answers
*what the compiled code actually does*.  At first jit of a bucket
executable (hooked in ``runtime/batcher.py``) or a schedule program
(hooked in ``compile/program.py``), the profiler:

  1. lowers + AOT-compiles the exact call about to execute,
  2. runs the trip-count-aware ``launch/hlo_cost.analyze()`` over the
     optimized HLO and ``compiled.cost_analysis()`` for XLA's own view,
  3. classifies the roofline bottleneck (compute / memory / collective)
     from ``launch/roofline.py`` terms,
  4. caches the result by executable signature and emits an
     ``hlo_cost`` instant into the trace.

``join_dispatches`` then joins the cached static costs against measured
``dispatch`` span walls (via the ``profile_sig`` arg the executor stamps
on every span) to report achieved-vs-peak per bucket and per comm
mechanism.  ``static_profile_sweep`` compiles a fixed model zoo at a
tiny budget — the rows ``benchmarks/run.py`` records in the baseline and
``benchmarks/check_regression.py`` diffs as the static-cost drift gate.

Module state mirrors ``obs.tracer``: profiling is off by default
(``enable()`` / ``disable()``, or the ``REPRO_PROFILE`` env var), and
the batcher/program hooks are no-ops while disabled.  Signature strings
and static costs contain no wall-clock terms; only the per-capture
``capture_s`` diagnostic does, and it is excluded from deterministic
exports.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time

from repro.launch import hlo_cost as hlo_cost_mod
from repro.launch import roofline as roofline_mod
from repro.obs import tracer

# optimized-HLO collective op -> the schedule comm mechanism it lowers
# from (the reverse of compile/backend.py MECHANISM_COLLECTIVES)
HLO_OP_MECHANISM = {
    "all-reduce": "psum_broadcast",
    "collective-permute": "ppermute_halo",
    "all-gather": "all_gather",
    "all-to-all": "all_to_all",
    "reduce-scatter": "reduce_scatter",
}

BOTTLENECKS = ("compute", "memory", "collective")


def bucket_signature(key, n_padded: int, route: str = "vmap",
                     shard_width: int = 1) -> str:
    """Deterministic signature of a batcher bucket executable.

    One signature per distinct jit specialization: every field that is a
    static argument (or shapes one, like the pad width and clamp set)
    participates.  A sharded-route dispatch executes a different
    specialization (the shard_map body over a mesh slice), so the route
    and slice width extend the signature there; the vmap format is
    unchanged.  Pure string math — safe to stamp on every dispatch span
    whether or not profiling is enabled.
    """
    clamp = ",".join(str(n) for n in key.clamp_nodes)
    parts = [
        "bucket", key.program_key[:16], key.kind, key.backend, key.sampler,
        f"pad{n_padded}", f"ch{key.n_chains}", f"it{key.n_iters}",
        f"bi{key.burn_in}", f"th{key.thin}", f"cl[{clamp}]",
        f"pins{int(key.has_pins)}", f"fused{int(key.fused)}",
        f"res{int(key.resumed)}", f"diag{int(key.diagnostics)}",
    ]
    if route != "vmap":
        parts += [route, f"sh{shard_width}"]
    return "|".join(parts)


def program_signature(program, *, n_chains, n_iters, burn_in, thin,
                      sampler, fused) -> str:
    """Signature of a whole-program (unbatched ``run()``) executable."""
    return "|".join([
        "run", program.program_key[:16], program.kind, sampler,
        f"ch{n_chains}", f"it{n_iters}", f"bi{burn_in}", f"th{thin}",
        f"fused{int(fused)}",
    ])


@dataclasses.dataclass
class BucketProfile:
    """Static cost + roofline classification of one compiled executable."""

    sig: str
    meta: dict
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_op: dict
    xla_flops: float
    xla_bytes: float
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    bottleneck: str
    capture_s: float  # wall time of the AOT compile+analysis (diagnostic)

    @property
    def roofline_s(self) -> float:
        return max(self.t_compute_s, self.t_memory_s, self.t_collective_s)

    def as_dict(self, deterministic: bool = True) -> dict:
        d = {
            "sig": self.sig,
            "meta": dict(self.meta),
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_op": dict(self.collective_by_op),
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
            "t_compute_s": self.t_compute_s,
            "t_memory_s": self.t_memory_s,
            "t_collective_s": self.t_collective_s,
            "roofline_s": self.roofline_s,
            "bottleneck": self.bottleneck,
        }
        if not deterministic:
            d["capture_s"] = round(self.capture_s, 6)
        return d


class ProfileRegistry:
    """Cache of :class:`BucketProfile` keyed by executable signature."""

    def __init__(self):
        self.profiles: dict = {}
        self.hits = 0
        self.errors: dict = {}

    def capture(self, sig: str, lower, *, n_chips: int = 1,
                **meta) -> BucketProfile:
        """Profile the executable ``lower()`` lowers, once per signature.

        ``lower`` is a zero-arg thunk returning a jax ``Lowered`` (so
        cache hits never trace).  The AOT ``.compile()`` here is
        separate from the jit's own executable cache — one extra XLA
        compile per signature is the cost of profiling.
        """
        prof = self.profiles.get(sig)
        if prof is not None:
            self.hits += 1
            return prof
        t0 = time.perf_counter()
        compiled = lower().compile()
        cost = hlo_cost_mod.analyze(compiled.as_text())
        xla_flops = xla_bytes = 0.0
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):  # older jax: per-device list
                ca = ca[0] if ca else {}
            xla_flops = float(ca.get("flops", 0.0) or 0.0)
            xla_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
        except Exception as e:  # backend without cost_analysis support
            self.errors[sig] = f"cost_analysis: {e}"
        roof = roofline_mod.Roofline(
            flops=cost.flops, hbm_bytes=cost.hbm_bytes,
            collective_bytes=cost.collective_bytes, n_chips=n_chips,
        )
        prof = BucketProfile(
            sig=sig, meta=dict(meta),
            flops=cost.flops, hbm_bytes=cost.hbm_bytes,
            collective_bytes=cost.collective_bytes,
            collective_by_op={
                k: v for k, v in sorted(cost.collective_by_op.items()) if v
            },
            xla_flops=xla_flops, xla_bytes=xla_bytes,
            t_compute_s=roof.t_compute, t_memory_s=roof.t_memory,
            t_collective_s=roof.t_collective, bottleneck=roof.bottleneck,
            capture_s=time.perf_counter() - t0,
        )
        self.profiles[sig] = prof
        if tracer.enabled():
            tracer.instant(
                "hlo_cost", cat="cost", sig=sig,
                flops=prof.flops, hbm_bytes=prof.hbm_bytes,
                collective_bytes=prof.collective_bytes,
                bottleneck=prof.bottleneck,
                **{k: meta[k] for k in ("model", "kind", "program")
                   if meta.get(k) is not None},
            )
        return prof

    def rows(self, deterministic: bool = True) -> list:
        return [self.profiles[s].as_dict(deterministic)
                for s in sorted(self.profiles)]


# -- module state (mirrors obs.tracer) --------------------------------------

_REGISTRY = None


def enabled() -> bool:
    return _REGISTRY is not None


def get() -> "ProfileRegistry | None":
    return _REGISTRY


def enable() -> ProfileRegistry:
    global _REGISTRY
    _REGISTRY = ProfileRegistry()
    return _REGISTRY


def disable() -> None:
    global _REGISTRY
    _REGISTRY = None


# -- capture hooks ----------------------------------------------------------

def capture_bucket(program, key, n_padded, jitted, args, kwargs, *,
                   model=None) -> "BucketProfile | None":
    """Batcher hook: profile the bucket call about to execute.

    Called with the exact ``(args, kwargs)`` of the jitted bucket entry;
    ``jitted.lower(*args, **kwargs)`` only traces (donation happens at
    execution), so the subsequent real call is untouched.
    """
    reg = get()
    if reg is None:
        return None
    sig = bucket_signature(key, n_padded)
    return reg.capture(
        sig, lambda: jitted.lower(*args, **kwargs),
        model=model, kind=key.kind, program=key.program_key,
        sampler=key.sampler, backend=key.backend, fused=key.fused,
        resumed=key.resumed, n_padded=n_padded,
        n_chains=key.n_chains, n_iters=key.n_iters, route="vmap",
    )


def capture_program(program, *, n_chains, n_iters, burn_in=50, thin=1,
                    sampler="lut_ky", fused=False,
                    registry=None) -> "BucketProfile | None":
    """Profile a whole-program schedule executable (``program.run()``).

    Lowers the same ``compile/backend.py`` jitted entry the run would
    execute, with placeholder evidence/carry (None — the no-clamp/no-pin
    specialization ``run()`` uses on the profiled branches).  The
    backend import is deferred so ``repro.obs`` never drags the compile
    chain in at import time.
    """
    import jax
    import jax.numpy as jnp

    from repro.compile import backend as backend_mod

    reg = registry if registry is not None else get()
    if reg is None:
        return None
    sig = program_signature(
        program, n_chains=n_chains, n_iters=n_iters, burn_in=burn_in,
        thin=thin, sampler=sampler, fused=fused,
    )
    if sig in reg.profiles:
        reg.hits += 1
        return reg.profiles[sig]
    ex = program.schedule_executable()
    interpret = jax.default_backend() != "tpu"
    if program.kind == "bn":
        def lower():
            return backend_mod._run_bn_rounds.lower(
                ex.cbn, ex.round_groups, jax.random.key(0), None, None,
                None, n_chains=n_chains, n_iters=n_iters, burn_in=burn_in,
                sampler=sampler, thin=thin, return_state=False,
                fused=fused, interpret=interpret,
            )
    else:
        ev = jnp.zeros((ex.mrf.height, ex.mrf.width), jnp.int32)

        def lower():
            return backend_mod._run_mrf_rounds.lower(
                ex.mrf, ex.parities, ev, jax.random.key(0), None, None,
                None, n_chains=n_chains, n_iters=n_iters, sampler=sampler,
                fused=fused, interpret=interpret, return_state=False,
            )
    return reg.capture(
        sig, lower, model=program.ir.name, kind=program.kind,
        program=program.program_key, sampler=sampler, fused=fused,
        n_chains=n_chains, n_iters=n_iters, route="run",
    )


# -- joining static costs against measured dispatch walls -------------------

def join_dispatches(profiles, events) -> dict:
    """Join cached static costs against measured ``dispatch`` spans.

    ``profiles`` maps sig -> :class:`BucketProfile` (or its dict form);
    ``events`` is a list of event dicts (``export.events_as_dicts`` with
    wall fields kept, or ``export.load_jsonl`` output).  Returns rows
    aggregated per signature with achieved-vs-peak ratios, per-mechanism
    comm rows, and the dispatches no profile covered.  Sharded-route
    dispatches attribute like any other: the executor stamps their
    route-qualified ``profile_sig`` and the sharded engines capture the
    shard_map executable under the same signature, so a sharded dispatch
    without a profile is an unattributed finding, not a skip.
    """
    rows: dict = {}
    unattributed: dict = {}
    n_dispatches = 0
    n_sharded = 0
    for ev in events:
        if ev.get("name") != "dispatch":
            continue
        a = ev.get("args") or {}
        w = ev.get("wargs") or {}
        n_dispatches += 1
        if a.get("route") != "vmap":
            n_sharded += 1
        sig = a.get("profile_sig")
        prof = profiles.get(sig)
        if prof is None:
            u = unattributed.setdefault(sig or "<unsigned>", {
                "sig": sig, "model": a.get("model"),
                "program": a.get("program"), "n_dispatches": 0,
            })
            u["n_dispatches"] += 1
            continue
        pd = prof.as_dict() if isinstance(prof, BucketProfile) else dict(prof)
        row = rows.get(sig)
        if row is None:
            row = rows[sig] = {
                **pd, "n_dispatches": 0, "n_measured": 0,
                "measured_total_s": 0.0, "service_total_s": 0.0,
            }
        row["n_dispatches"] += 1
        row["service_total_s"] += float(a.get("service_s") or 0.0)
        ms = w.get("measured_s")
        if ms is not None:
            row["n_measured"] += 1
            row["measured_total_s"] += float(ms)
    out_rows = []
    comm: dict = {}
    for sig in sorted(rows):
        row = rows[sig]
        meas = (row["measured_total_s"] / row["n_measured"]
                if row["n_measured"] else None)
        row["measured_mean_s"] = meas
        row["service_total_s"] = round(row["service_total_s"], 9)
        row["measured_total_s"] = round(row["measured_total_s"], 9)
        if meas and meas > 0:
            row["achieved_flops"] = row["flops"] / meas
            row["achieved_hbm_bw"] = row["hbm_bytes"] / meas
            row["peak_frac"] = min(1.0, row["roofline_s"] / meas)
        else:
            row["achieved_flops"] = row["achieved_hbm_bw"] = None
            row["peak_frac"] = None
        for op, nbytes in row.get("collective_by_op", {}).items():
            mech = HLO_OP_MECHANISM.get(op, op)
            c = comm.setdefault(mech, {
                "mechanism": mech, "hlo_op": op, "bytes_per_dispatch": 0.0,
                "total_bytes": 0.0, "measured_total_s": 0.0,
                "n_dispatches": 0,
            })
            c["bytes_per_dispatch"] += nbytes
            c["total_bytes"] += nbytes * row["n_dispatches"]
            c["measured_total_s"] += row["measured_total_s"]
            c["n_dispatches"] += row["n_dispatches"]
        out_rows.append(row)
    comm_rows = []
    for mech in sorted(comm):
        c = comm[mech]
        c["measured_total_s"] = round(c["measured_total_s"], 9)
        c["achieved_bw"] = (
            c["total_bytes"] / c["measured_total_s"]
            if c["measured_total_s"] > 0 else None
        )
        c["peak_frac"] = (
            min(1.0, c["achieved_bw"] / roofline_mod.ICI_BW)
            if c["achieved_bw"] else None
        )
        comm_rows.append(c)
    return {
        "rows": out_rows,
        "comm": comm_rows,
        "unattributed": [unattributed[k] for k in sorted(unattributed)],
        "n_dispatches": n_dispatches,
        "n_sharded": n_sharded,
    }


def write_profile(path, registry, events) -> dict:
    """Join + write the ``profile.json`` artifact; returns the record."""
    joined = join_dispatches(registry.profiles, events)
    rec = {
        "schema": 1,
        "peaks": {"flops": roofline_mod.PEAK_FLOPS,
                  "hbm_bw": roofline_mod.HBM_BW,
                  "ici_bw": roofline_mod.ICI_BW},
        "buckets": registry.rows(deterministic=False),
        "capture_hits": registry.hits,
        "capture_errors": dict(sorted(registry.errors.items())),
        "joined": joined,
    }
    pathlib.Path(path).write_text(json.dumps(rec, indent=1, sort_keys=True))
    return rec


def validate_profile(rec: dict) -> list:
    """Sanity problems in a saved ``profile.json`` ('' when healthy)."""
    problems = []
    if rec.get("schema") != 1:
        problems.append(f"unknown profile schema {rec.get('schema')!r}")
        return problems
    buckets = rec.get("buckets", [])
    if not buckets:
        problems.append("no captured bucket profiles")
    for b in buckets:
        if b.get("bottleneck") not in BOTTLENECKS:
            problems.append(
                f"{b.get('sig')}: bad bottleneck {b.get('bottleneck')!r}")
        if not b.get("hbm_bytes", 0) > 0:
            problems.append(f"{b.get('sig')}: hbm_bytes must be > 0")
    joined = rec.get("joined", {})
    for u in joined.get("unattributed", []):
        problems.append(
            f"unattributed dispatches: sig={u.get('sig')!r} "
            f"x{u.get('n_dispatches')}")
    return problems


# -- static sweep for the baseline / drift gate -----------------------------

# fixed tiny budget: the gate compares static HLO costs, not wall time,
# so the sweep only needs each executable's *shape*, cheaply
SWEEP_BUDGET = dict(n_chains=8, n_iters=32, burn_in=8, thin=1)
SWEEP_BN_MODELS = ("survey", "alarm")
SWEEP_GRID = 8


def static_profile_sweep(quick: bool = False) -> list:
    """Per-signature static costs over a fixed model zoo.

    Deterministic rows (signatures embed the content-hash program key)
    recorded by ``benchmarks/run.py`` into the baseline and re-derived
    by ``check_regression.py`` — flops/hbm_bytes/collective_bytes drift
    per signature fails CI without needing hardware.
    """
    from repro.compile import compile_graph
    from repro.core.graphs import GridMRF, bn_repository_replica

    reg = ProfileRegistry()
    models = SWEEP_BN_MODELS[:1] if quick else SWEEP_BN_MODELS
    progs = [compile_graph(bn_repository_replica(name)) for name in models]
    progs.append(compile_graph(GridMRF(
        SWEEP_GRID, SWEEP_GRID, 3, theta=1.1, h=1.8,
        name=f"grid{SWEEP_GRID}",
    )))
    for prog in progs:
        for fused in (False, True):
            capture_program(prog, sampler="lut_ky", fused=fused,
                            registry=reg, **SWEEP_BUDGET)
    return reg.rows(deterministic=True)


# honor the environment once at import, like tracer's REPRO_TRACE
if os.environ.get("REPRO_PROFILE", "") not in ("", "0"):
    enable()
