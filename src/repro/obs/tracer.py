"""Structured tracing: nestable spans + typed counters in a ring buffer.

The whole stack (compile passes, the runtime event loop, the executor's
dispatches, kernel entry points) calls into this module unconditionally;
when tracing is *off* — the default — every entry point is a single
module-attribute check that returns a shared no-op object, so the serving
hot path pays no allocation and no branch beyond `if _TRACER is None`.
Enable via the `REPRO_TRACE=1` environment variable (checked once at
import) or `repro.obs.enable()`.

Two clocks, deliberately:

  * **wall** — `time.perf_counter()` at span open/close.  Real, noisy,
    machine-dependent; stripped from the deterministic JSONL export and
    kept for the Perfetto timeline and calibration-error attribution.
  * **sim** — the runtime engine's deterministic simulated clock, attached
    explicitly by the instrumentation (`sim_span(name, t0, t1)`).  Same
    trace, same sim timestamps, every run — which is what makes the JSONL
    event log byte-identical across same-seed replays and therefore
    testable.

Event payloads follow the same split: `args` holds deterministic values
(bucket statics, predicted cycles, pad decisions), `wargs` holds
wall-derived ones (measured dispatch seconds).  `export.to_jsonl` drops
wall timestamps and `wargs`; `export.to_perfetto` keeps everything.

The buffer is a bounded deque (default 64Ki events): a runaway trace
evicts its *oldest* events rather than growing without bound; `dropped`
reports how many fell off so exports can say so instead of silently
presenting a truncated run as complete.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time

DEFAULT_CAPACITY = 1 << 16


@dataclasses.dataclass
class Event:
    """One trace record.  `kind` is "span" | "instant" | "counter"."""

    seq: int
    kind: str
    name: str
    cat: str
    track: str | None
    wall_t0: float | None  # perf_counter seconds; wall — stripped from JSONL
    wall_t1: float | None
    sim_t0: float | None  # simulated seconds; deterministic
    sim_t1: float | None
    args: dict  # deterministic payload
    wargs: dict  # wall-derived payload — stripped from JSONL


class Tracer:
    """Ring buffer of `Event`s with a deterministic sequence counter."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.events: collections.deque[Event] = collections.deque(
            maxlen=capacity
        )
        self.n_emitted = 0
        self._seq = 0

    def emit(
        self,
        kind: str,
        name: str,
        cat: str,
        track: str | None = None,
        wall_t0: float | None = None,
        wall_t1: float | None = None,
        sim_t0: float | None = None,
        sim_t1: float | None = None,
        args: dict | None = None,
        wargs: dict | None = None,
    ) -> Event:
        ev = Event(
            seq=self._seq, kind=kind, name=name, cat=cat, track=track,
            wall_t0=wall_t0, wall_t1=wall_t1, sim_t0=sim_t0, sim_t1=sim_t1,
            args=args if args is not None else {},
            wargs=wargs if wargs is not None else {},
        )
        self._seq += 1
        self.n_emitted += 1
        self.events.append(ev)
        return ev

    @property
    def dropped(self) -> int:
        """Events evicted by the ring (emitted minus retained)."""
        return self.n_emitted - len(self.events)

    def clear(self) -> None:
        self.events.clear()
        self.n_emitted = 0
        self._seq = 0


class _NullSpan:
    """The shared off-path span: every method is a no-op, one instance
    serves every disabled `span()` call (no allocation on the hot path)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        pass

    def set_wall(self, **wargs) -> None:
        pass


NULL_SPAN = _NullSpan()


class _Span:
    """A live wall-clocked span (context manager).  `set()` attaches
    deterministic attributes, `set_wall()` wall-derived ones."""

    __slots__ = ("_tracer", "name", "cat", "track", "args", "wargs", "_t0")

    def __init__(self, tracer: Tracer, name: str, cat: str,
                 track: str | None, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args
        self.wargs: dict = {}
        self._t0 = 0.0

    def __enter__(self):
        # the wall half of the span's dual timestamps (see module docstring)
        self._t0 = time.perf_counter()  # lint: allow[wallclock-in-sim]
        return self

    def set(self, **args) -> None:
        self.args.update(args)

    def set_wall(self, **wargs) -> None:
        self.wargs.update(wargs)

    def __exit__(self, *exc):
        t1 = time.perf_counter()  # lint: allow[wallclock-in-sim]
        self._tracer.emit(
            "span", self.name, self.cat, self.track,
            wall_t0=self._t0, wall_t1=t1, args=self.args, wargs=self.wargs,
        )
        return False


_TRACER: Tracer | None = None


def enabled() -> bool:
    return _TRACER is not None


def get() -> Tracer | None:
    return _TRACER


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Install a fresh tracer (any previous buffer is discarded) and
    return it."""
    global _TRACER
    _TRACER = Tracer(capacity)
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def span(name: str, cat: str = "host", track: str | None = None, **args):
    """Context manager timing a wall-clocked span.  Off: returns the
    shared no-op span."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return _Span(t, name, cat, track, args)


def instant(
    name: str, cat: str = "host", track: str | None = None,
    sim_t: float | None = None, wargs: dict | None = None, **args,
) -> None:
    """A point event (shed/defer decisions, flush markers, round costs)."""
    t = _TRACER
    if t is None:
        return
    t.emit("instant", name, cat, track, sim_t0=sim_t, sim_t1=sim_t,
           args=args, wargs=wargs)


def sim_span(
    name: str, t0: float, t1: float, cat: str = "sim",
    track: str | None = None, wargs: dict | None = None, **args,
) -> None:
    """A retrospective span on the *simulated* clock (the engine knows a
    dispatch's start/finish only after booking the worker pool)."""
    t = _TRACER
    if t is None:
        return
    t.emit("span", name, cat, track, sim_t0=t0, sim_t1=t1,
           args=args, wargs=wargs)


def counter(
    name: str, value, sim_t: float | None = None,
    track: str | None = None, cat: str = "sim",
) -> None:
    """A typed counter sample (queue depth, token-bucket level)."""
    t = _TRACER
    if t is None:
        return
    t.emit("counter", name, cat, track, sim_t0=sim_t, sim_t1=sim_t,
           args={"value": value})


# honor the environment once at import: REPRO_TRACE=1 (anything but ""/"0")
if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    enable()
