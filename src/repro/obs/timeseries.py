"""Deterministic metrics time-series: typed counters, gauges, histograms.

The runtime engine is a deterministic simulation, so its metrics can be
*series*, not just end-of-run scalars — every sample is stamped with the
sim clock, and two same-seed runs emit byte-identical JSONL.  Three
series types:

  * ``Counter`` — monotone cumulative count; ``inc(t, v)`` records the
    new running total at sim time ``t``.
  * ``Gauge`` — instantaneous value; ``sample(t, v)`` records ``v``.
  * ``Histogram`` — fixed bucket boundaries chosen at creation (an
    exponential ladder by default, via :func:`exp_boundaries`);
    ``observe(t, v)`` increments the bucket whose upper bound first
    covers ``v``.  Quantiles come from bucket upper bounds, so they are
    conservative (an upper bound on the true quantile) and — like
    ``runtime.metrics.percentile`` — refuse to answer with fewer than
    two observations.

Everything here is pure Python on purpose: no jax, no wall clock, no
randomness.  Determinism rests on (a) callers stamping samples with the
sim clock, (b) a registry-global emission sequence number ordering the
exported lines, and (c) ``json.dumps(..., sort_keys=True)``.
"""

from __future__ import annotations

import json
import pathlib


def exp_boundaries(start: float, growth: float, n: int) -> tuple:
    """``n`` exponential bucket upper bounds: start, start*growth, ..."""
    if start <= 0 or growth <= 1 or n < 1:
        raise ValueError("need start > 0, growth > 1, n >= 1")
    return tuple(start * growth ** i for i in range(n))


# 100us .. ~7min in x2 steps: covers calibrated bucket service times and
# end-to-end sim latencies for every committed trace.
DEFAULT_LATENCY_BOUNDARIES = exp_boundaries(1e-4, 2.0, 23)

# pad efficiency lives in (0, 1]: sixteen linear buckets
PAD_EFF_BOUNDARIES = tuple((i + 1) / 16 for i in range(16))


class _Series:
    kind = "series"

    def __init__(self, name: str, registry: "SeriesRegistry"):
        self.name = name
        self._registry = registry
        self.samples: list = []  # (seq, t, value)

    def _record(self, t: float, value) -> None:
        self.samples.append((self._registry._next_seq(), float(t), value))

    def __len__(self) -> int:
        return len(self.samples)


class Counter(_Series):
    kind = "counter"

    def __init__(self, name, registry):
        super().__init__(name, registry)
        self.total = 0

    def inc(self, t: float, v: int = 1) -> None:
        self.total += v
        self._record(t, self.total)


class Gauge(_Series):
    kind = "gauge"

    def __init__(self, name, registry):
        super().__init__(name, registry)
        self.last = None

    def sample(self, t: float, v) -> None:
        self.last = v
        self._record(t, v)


class Histogram(_Series):
    kind = "histogram"

    def __init__(self, name, registry, boundaries=DEFAULT_LATENCY_BOUNDARIES):
        super().__init__(name, registry)
        if list(boundaries) != sorted(boundaries) or len(boundaries) < 2:
            raise ValueError("boundaries must be sorted, length >= 2")
        self.boundaries = tuple(float(b) for b in boundaries)
        # one count per boundary + one overflow bucket
        self.bucket_counts = [0] * (len(self.boundaries) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None

    def _bucket(self, v: float) -> int:
        for i, b in enumerate(self.boundaries):
            if v <= b:
                return i
        return len(self.boundaries)

    def observe(self, t: float, v: float) -> None:
        v = float(v)
        i = self._bucket(v)
        self.bucket_counts[i] += 1
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        self._record(t, i)  # samples store the bucket index, not the value

    def quantile(self, q: float):
        """Upper bound on the q-th percentile (q in 0..100).

        ``None`` with fewer than two observations — same refusal as
        ``runtime.metrics.percentile``: one sample has no distribution.
        Overflow-bucket hits report the observed max (the only honest
        upper bound available there).
        """
        if self.count < 2:
            return None
        rank = max(1, min(self.count, round(q / 100 * (self.count - 1)) + 1))
        seen = 0
        for i, c in enumerate(self.bucket_counts):
            seen += c
            if seen >= rank:
                if i < len(self.boundaries):
                    return self.boundaries[i]
                return self.vmax
        return self.vmax

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "total": round(self.total, 9),
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.quantile(50),
            "p95": self.quantile(95),
            "p99": self.quantile(99),
        }


class SeriesRegistry:
    """Named series with a global emission order for deterministic export."""

    def __init__(self):
        self.series: dict = {}
        self._seq = 0

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _get(self, name: str, cls, **kw):
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = cls(name, self, **kw)
        elif not isinstance(s, cls):
            raise TypeError(
                f"series {name!r} already registered as {s.kind}"
            )
        return s

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  boundaries=DEFAULT_LATENCY_BOUNDARIES) -> Histogram:
        return self._get(name, Histogram, boundaries=boundaries)

    def snapshot(self) -> dict:
        """Deterministic end-of-run summary keyed by series name."""
        out = {}
        for name in sorted(self.series):
            s = self.series[name]
            rec = {"kind": s.kind, "n_samples": len(s)}
            if isinstance(s, Histogram):
                rec.update(s.snapshot())
            elif isinstance(s, Counter):
                rec["total"] = s.total
            else:
                rec["last"] = s.last
            out[name] = rec
        return out

    def to_jsonl(self) -> str:
        """One line per sample, in global emission (seq) order.

        Sample values are sim-clock-stamped and derived from the
        deterministic event loop, so same-seed runs produce the same
        bytes — asserted by ``tests/test_profile.py``.
        """
        rows = []
        for name in sorted(self.series):
            s = self.series[name]
            for seq, t, v in s.samples:
                rows.append((seq, {
                    "seq": seq, "series": name, "kind": s.kind,
                    "t": round(t, 9), "value": v,
                }))
        rows.sort(key=lambda r: r[0])
        return "".join(
            json.dumps(rec, sort_keys=True) + "\n" for _, rec in rows
        )

    def write_jsonl(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(self.to_jsonl())
        return path
