"""Trace exports: deterministic JSONL and a Chrome/Perfetto timeline.

Two serializations of the same event buffer, with opposite priorities:

  * `to_jsonl` — the *testable* log.  Wall timestamps and wall-derived
    payloads (`Event.wargs`) are stripped, keys are sorted, events keep
    their deterministic emit order — so two same-seed engine runs produce
    byte-identical files and a CI diff of the two is a real regression
    signal, not timestamp noise.
  * `to_perfetto` — the *viewable* timeline (chrome://tracing or
    https://ui.perfetto.dev).  Everything survives: simulated-clock lanes
    (one per engine worker, plus the engine's own lane and counter tracks
    for queue depth / token bucket) render under the "sim" process, and
    wall-clocked host spans (compile passes, lowering/cross-check, kernel
    dispatch entries, calibration warmup) under the "host" process.

The two processes intentionally use different timebases — simulated
seconds vs wall seconds since the first event — because gluing them onto
one axis would draw a lie: the sim clock advances by calibrated service
times, not by the wall.
"""

from __future__ import annotations

import json

# deterministic JSONL field order is handled by sort_keys; these are the
# event fields it keeps (everything else is wall-derived)
_JSONL_FIELDS = ("seq", "kind", "name", "cat", "track", "sim_t0", "sim_t1")

SIM_PID = 1
HOST_PID = 2


def event_dict(ev, strip_wall: bool = True) -> dict:
    """One `Event` -> a plain JSON-friendly dict.  With `strip_wall` (the
    JSONL contract) wall timestamps and `wargs` are dropped."""
    rec = {
        "seq": ev.seq, "kind": ev.kind, "name": ev.name, "cat": ev.cat,
    }
    if ev.track is not None:
        rec["track"] = ev.track
    if ev.sim_t0 is not None:
        rec["sim_t0"] = ev.sim_t0
    if ev.sim_t1 is not None:
        rec["sim_t1"] = ev.sim_t1
    if ev.args:
        rec["args"] = dict(ev.args)
    if not strip_wall:
        if ev.wall_t0 is not None:
            rec["wall_t0"] = ev.wall_t0
        if ev.wall_t1 is not None:
            rec["wall_t1"] = ev.wall_t1
        if ev.wargs:
            rec["wargs"] = dict(ev.wargs)
    return rec


def events_as_dicts(events, strip_wall: bool = False) -> list[dict]:
    """The full buffer as plain dicts (analysis-friendly: `attrib` and the
    tests consume this form, and JSONL round-trips to it)."""
    return [event_dict(ev, strip_wall=strip_wall) for ev in events]


def to_jsonl(events) -> str:
    """Deterministic JSONL: one sorted-key JSON object per line, wall
    fields stripped.  Same trace => byte-identical string."""
    lines = [
        json.dumps(event_dict(ev, strip_wall=True), sort_keys=True)
        for ev in events
    ]
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(path: str, events) -> None:
    with open(path, "w") as f:
        f.write(to_jsonl(events))


def load_jsonl(path: str) -> list[dict]:
    """Parse a JSONL event log back into the dict form `attrib` consumes."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# Perfetto / Chrome trace_event JSON
# ---------------------------------------------------------------------------


def _meta(pid: int, tid: int, name: str, what: str) -> dict:
    return {"ph": "M", "pid": pid, "tid": tid, "name": what,
            "args": {"name": name}}


def to_perfetto(events) -> dict:
    """Events -> a Chrome trace_event JSON object.

    Lanes: sim-clock events with `track="workerN"` land on one thread per
    engine worker under the "sim (deterministic clock)" process (a
    `run_start` instant's `n_workers` arg pre-declares every worker lane,
    so idle workers still show as empty lanes); other sim tracks (engine,
    counters) get their own threads.  Wall-clocked spans group by `cat`
    under the "host (wall clock)" process, timebased at the first wall
    event."""
    events = list(events)
    trace: list[dict] = []
    trace.append(_meta(SIM_PID, 0, "sim (deterministic clock)",
                       "process_name"))
    trace.append(_meta(HOST_PID, 0, "host (wall clock)", "process_name"))

    # -- lane assignment ---------------------------------------------------
    n_workers = 0
    for ev in events:
        if ev.name == "run_start":
            n_workers = max(n_workers, int(ev.args.get("n_workers", 0)))
        if ev.track and ev.track.startswith("worker"):
            try:
                n_workers = max(n_workers, int(ev.track[6:]) + 1)
            except ValueError:
                pass
    sim_tids: dict[str, int] = {"engine": 1}
    for w in range(n_workers):
        sim_tids[f"worker{w}"] = 10 + w
    host_tids: dict[str, int] = {}

    def sim_tid(track: str | None) -> int:
        track = track or "engine"
        if track not in sim_tids:
            sim_tids[track] = 100 + len(sim_tids)
        return sim_tids[track]

    def host_tid(cat: str) -> int:
        if cat not in host_tids:
            host_tids[cat] = 1 + len(host_tids)
        return host_tids[cat]

    walls = [ev.wall_t0 for ev in events if ev.wall_t0 is not None]
    wall0 = min(walls) if walls else 0.0

    for ev in events:
        args = {**ev.args, **ev.wargs}
        if ev.sim_t0 is not None:
            # simulated-clock lane (microseconds of sim time)
            pid, tid = SIM_PID, sim_tid(ev.track)
            ts = ev.sim_t0 * 1e6
            if ev.kind == "counter":
                trace.append({
                    "ph": "C", "pid": pid, "tid": tid, "ts": ts,
                    "name": ev.name,
                    "args": {"value": ev.args.get("value", 0)},
                })
            elif ev.kind == "span":
                trace.append({
                    "ph": "X", "pid": pid, "tid": tid, "ts": ts,
                    "dur": max(0.0, (ev.sim_t1 - ev.sim_t0) * 1e6),
                    "name": ev.name, "cat": ev.cat, "args": args,
                })
            else:
                trace.append({
                    "ph": "i", "s": "t", "pid": pid, "tid": tid, "ts": ts,
                    "name": ev.name, "cat": ev.cat, "args": args,
                })
        elif ev.wall_t0 is not None:
            pid, tid = HOST_PID, host_tid(ev.cat)
            ts = (ev.wall_t0 - wall0) * 1e6
            if ev.kind == "span":
                trace.append({
                    "ph": "X", "pid": pid, "tid": tid, "ts": ts,
                    "dur": max(0.0, (ev.wall_t1 - ev.wall_t0) * 1e6),
                    "name": ev.name, "cat": ev.cat, "args": args,
                })
            else:
                trace.append({
                    "ph": "i", "s": "t", "pid": pid, "tid": tid, "ts": ts,
                    "name": ev.name, "cat": ev.cat, "args": args,
                })
        # events with neither clock (pure markers) are metadata-only; skip

    for track, tid in sorted(sim_tids.items(), key=lambda kv: kv[1]):
        trace.append(_meta(SIM_PID, tid, track, "thread_name"))
    for cat, tid in sorted(host_tids.items(), key=lambda kv: kv[1]):
        trace.append(_meta(HOST_PID, tid, cat, "thread_name"))
    return {"traceEvents": trace, "displayTimeUnit": "ms"}


def write_perfetto(path: str, events) -> None:
    with open(path, "w") as f:
        json.dump(to_perfetto(events), f, indent=1)
