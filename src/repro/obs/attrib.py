"""Post-run cost attribution: join predicted round costs with dispatches.

`calib_median_err` says *how wrong* the service predictions are on median;
this module says *where*.  The executor emits, per program, one
`round_cost` instant per schedule round (the cost model's compute/comm
cycles under the actual placement) and, per microbatch, one `dispatch`
span carrying the calibrated prediction (`service_s`, deterministic) next
to the measured dispatch wall (`measured_s`, wall-derived).  `attribution`
joins the two:

  * each dispatch's predicted seconds and measured wall are allocated
    across its program's rounds proportionally to the rounds' modeled
    cycles — the per-round drill-down behind the single advisory number;
  * comm is attributed separately per mechanism (`ppermute_halo` /
    `psum_broadcast`) from the rounds' comm-cycle shares, which is the
    comm-vs-compute breakdown the paper's figures hinge on.

Coverage is a checked property, not an aspiration: a dispatch whose
program has no `round_cost` events is a *gap*, returned explicitly so CI
can fail on silent attribution holes.  Measured walls are optional — an
attribution computed from the deterministic JSONL (wall fields stripped)
reports predicted columns and leaves measured ones empty.
"""

from __future__ import annotations


def _args(ev: dict) -> dict:
    return ev.get("args") or {}


def _wargs(ev: dict) -> dict:
    return ev.get("wargs") or {}


def attribution(events) -> tuple[list[dict], list[dict]]:
    """Join `round_cost` and `dispatch` events into attribution rows.

    `events` is an iterable of event dicts (`export.events_as_dicts` /
    `export.load_jsonl`).  Returns `(rows, gaps)`:

      * `rows` — per (model, program, round) dicts with the round's modeled
        cycles, its share of the sweep, the predicted seconds allocated to
        it across every dispatch, and (when walls were recorded) the
        measured seconds and relative error; plus one `kind="comm"` row per
        (model, program, mechanism) aggregating the comm-cycle share.
      * `gaps` — dispatches whose program has no recorded round costs
        (attribution holes; CI asserts this list is empty).
    """
    rounds: dict[str, dict[int, dict]] = {}
    dispatches: list[dict] = []
    for ev in events:
        name = ev.get("name")
        if name == "round_cost":
            a = _args(ev)
            rounds.setdefault(a["program"], {})[int(a["round"])] = a
        elif name == "dispatch" and ev.get("kind") == "span":
            dispatches.append(ev)

    rows: dict[tuple, dict] = {}
    comm_rows: dict[tuple, dict] = {}
    gaps: dict[str, dict] = {}
    for ev in dispatches:
        a = _args(ev)
        prog = a.get("program", "?")
        model = a.get("model", "?")
        rr = rounds.get(prog)
        if not rr:
            gap = gaps.setdefault(prog, {
                "program": prog, "model": model, "n_dispatches": 0,
            })
            gap["n_dispatches"] += 1
            continue
        total_cycles = sum(
            r["compute_cycles"] + r["comm_cycles"] for r in rr.values()
        )
        pred_s = float(a.get("service_s", 0.0))
        meas_s = _wargs(ev).get("measured_s")
        for idx in sorted(rr):
            r = rr[idx]
            cyc = r["compute_cycles"] + r["comm_cycles"]
            share = cyc / total_cycles if total_cycles else 0.0
            row = rows.setdefault((model, prog, idx), {
                "kind": "round", "model": model, "program": prog,
                "round": idx, "n_nodes": r["n_nodes"],
                "compute_cycles": r["compute_cycles"],
                "comm_cycles": r["comm_cycles"],
                "mechanism": r.get("mechanism"),
                "share": share, "n_dispatches": 0,
                "pred_s": 0.0, "meas_s": 0.0, "n_measured": 0,
            })
            row["n_dispatches"] += 1
            row["pred_s"] += pred_s * share
            if meas_s is not None:
                row["meas_s"] += float(meas_s) * share
                row["n_measured"] += 1
            mech = r.get("mechanism")
            if mech and r["comm_cycles"]:
                cshare = (r["comm_cycles"] / total_cycles
                          if total_cycles else 0.0)
                crow = comm_rows.setdefault((model, prog, mech), {
                    "kind": "comm", "model": model, "program": prog,
                    "mechanism": mech,
                    "comm_cycles": 0, "comm_bytes": 0, "n_comm_ops": 0,
                    "share": 0.0, "n_dispatches": 0,
                    "pred_s": 0.0, "meas_s": 0.0, "n_measured": 0,
                })
                crow["pred_s"] += pred_s * cshare
                if meas_s is not None:
                    crow["meas_s"] += float(meas_s) * cshare
        # static comm aggregates + dispatch counts (once per dispatch)
        for (m, p, mech), crow in comm_rows.items():
            if p != prog:
                continue
            crow["n_dispatches"] += 1
            if meas_s is not None:
                crow["n_measured"] += 1
    # static comm totals (independent of dispatches)
    for (model, prog, mech), crow in comm_rows.items():
        rr = rounds.get(prog, {})
        tot = sum(r["compute_cycles"] + r["comm_cycles"] for r in rr.values())
        crow["comm_cycles"] = sum(
            r["comm_cycles"] for r in rr.values()
            if r.get("mechanism") == mech
        )
        crow["comm_bytes"] = sum(
            r.get("comm_bytes", 0) for r in rr.values()
            if r.get("mechanism") == mech
        )
        crow["n_comm_ops"] = sum(
            r.get("n_comm_ops", 0) for r in rr.values()
            if r.get("mechanism") == mech
        )
        crow["share"] = crow["comm_cycles"] / tot if tot else 0.0

    def err(row):
        if row["n_measured"] and row["meas_s"] > 0:
            return abs(row["pred_s"] - row["meas_s"]) / row["meas_s"]
        return None

    out = []
    for key in sorted(rows):
        row = rows[key]
        row["rel_err"] = err(row)
        out.append(row)
    for key in sorted(comm_rows):
        row = comm_rows[key]
        row["rel_err"] = err(row)
        out.append(row)
    return out, sorted(gaps.values(), key=lambda g: g["program"])


def coverage(events) -> dict:
    """Reconciliation summary: dispatch spans seen, programs with round
    costs, and any attribution gaps — the CI assertion payload."""
    rows, gaps = attribution(events)
    n_dispatch = sum(
        1 for ev in events
        if ev.get("name") == "dispatch" and ev.get("kind") == "span"
    )
    return {
        "n_dispatch_spans": n_dispatch,
        "n_round_rows": sum(1 for r in rows if r["kind"] == "round"),
        "n_comm_rows": sum(1 for r in rows if r["kind"] == "comm"),
        "n_gaps": len(gaps),
        "gaps": gaps,
    }
