"""Attribution checker CLI: validate a saved trace's cost attribution.

    python -m repro.obs trace.jsonl          # recompute from the event log
    python -m repro.obs trace.attrib.json    # validate a saved attribution

Parses the artifact, renders the predicted-vs-measured attribution table
(`launch/report.py`), and exits non-zero when the attribution has *gaps* —
dispatched rounds no `round_cost` event covers — or no dispatches at all.
CI runs this against the bursty-smoke trace artifact so a silent
attribution hole fails the build instead of shipping.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.launch.report import attribution_table
from repro.obs import attrib, export


def check_rows(rows: list[dict], gaps: list[dict]) -> int:
    n_rounds = sum(1 for r in rows if r.get("kind") == "round")
    if not rows or n_rounds == 0:
        print("[obs] ERROR: attribution is empty (no dispatched rounds)")
        return 2
    print(attribution_table(rows))
    total_disp = max((r.get("n_dispatches", 0) for r in rows), default=0)
    print(f"\n[obs] {n_rounds} round rows, "
          f"{sum(1 for r in rows if r.get('kind') == 'comm')} comm rows, "
          f"{total_disp} dispatches attributed")
    if gaps:
        for g in gaps:
            print(f"[obs] ERROR: attribution gap — program "
                  f"{g['program'][:16]} (model {g['model']}) dispatched "
                  f"{g['n_dispatches']}x with no recorded round costs")
        return 2
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs")
    ap.add_argument("path", help="trace .jsonl event log or .attrib.json")
    args = ap.parse_args(argv)
    if args.path.endswith(".jsonl"):
        events = export.load_jsonl(args.path)
        rows, gaps = attrib.attribution(events)
    else:
        with open(args.path) as f:
            rec = json.load(f)
        rows, gaps = rec.get("rows", []), rec.get("gaps", [])
    return check_rows(rows, gaps)


if __name__ == "__main__":
    sys.exit(main())
