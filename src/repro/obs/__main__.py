"""Attribution/profile checker CLI: validate saved obs artifacts.

    python -m repro.obs trace.jsonl            # recompute from the event log
    python -m repro.obs trace.attrib.json      # validate a saved attribution
    python -m repro.obs --profile profile.json # validate a saved profile

Parses the artifact, renders the predicted-vs-measured attribution table
(or the roofline profile table) from `launch/report.py`, and exits
non-zero when the artifact has holes: attribution *gaps* (dispatched
rounds no `round_cost` event covers), no dispatches at all, or — in
`--profile` mode — unattributed dispatches, empty captures, or invalid
roofline rows.  A saved attribution that recorded tracer ring-buffer
drops prints an `obs-trace-dropped` warning (coverage is suspect but not
necessarily broken).  CI runs this against the bursty-smoke artifacts so
a silent hole fails the build instead of shipping.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import Finding
from repro.launch.report import attribution_table, profile_table
from repro.obs import attrib, export
from repro.obs import profile as profile_mod


def check_rows(rows: list[dict], gaps: list[dict]) -> int:
    n_rounds = sum(1 for r in rows if r.get("kind") == "round")
    if not rows or n_rounds == 0:
        print("[obs] ERROR: attribution is empty (no dispatched rounds)")
        return 2
    print(attribution_table(rows))
    total_disp = max((r.get("n_dispatches", 0) for r in rows), default=0)
    print(f"\n[obs] {n_rounds} round rows, "
          f"{sum(1 for r in rows if r.get('kind') == 'comm')} comm rows, "
          f"{total_disp} dispatches attributed")
    if gaps:
        for g in gaps:
            print(f"[obs] ERROR: attribution gap — program "
                  f"{g['program'][:16]} (model {g['model']}) dispatched "
                  f"{g['n_dispatches']}x with no recorded round costs")
        return 2
    return 0


def check_profile(rec: dict, path: str) -> int:
    problems = profile_mod.validate_profile(rec)
    joined = rec.get("joined", {})
    rows = joined.get("rows", [])
    if rows or joined.get("comm"):
        print(profile_table(rows, joined.get("comm", [])))
    print(f"\n[obs] {len(rec.get('buckets', []))} captured executables, "
          f"{joined.get('n_dispatches', 0)} dispatches "
          f"({joined.get('n_sharded', 0)} sharded), "
          f"{len(joined.get('unattributed', []))} unattributed")
    for p in problems:
        print(f"[obs] ERROR: {p}")
    return 2 if problems else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs")
    ap.add_argument("path", help="trace .jsonl event log, .attrib.json, or "
                                 "(with --profile) profile.json")
    ap.add_argument("--profile", action="store_true",
                    help="validate a saved obs.profile artifact instead of "
                         "an attribution")
    args = ap.parse_args(argv)
    if args.profile:
        with open(args.path) as f:
            return check_profile(json.load(f), args.path)
    if args.path.endswith(".jsonl"):
        events = export.load_jsonl(args.path)
        rows, gaps = attrib.attribution(events)
        dropped = 0
    else:
        with open(args.path) as f:
            rec = json.load(f)
        rows, gaps = rec.get("rows", []), rec.get("gaps", [])
        dropped = rec.get("dropped", 0)
    if dropped:
        print("[obs] " + Finding(
            "obs-trace-dropped", f"trace:{args.path}",
            f"{dropped} events were dropped by the tracer ring buffer; "
            "attribution coverage may be incomplete",
            fixit="re-record with obs.enable(capacity=...) raised",
        ).render())
    return check_rows(rows, gaps)


if __name__ == "__main__":
    sys.exit(main())
