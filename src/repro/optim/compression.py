"""Gradient-compression collectives (distributed-optimization substrate).

Two mechanisms, both honest about what actually moves over the wire:

* `psum_bf16` — reduce gradients in bf16 instead of f32: halves the DP
  all-reduce bytes, the standard TPU trade (error ~1e-3 relative).
* `psum_int8` — per-tensor-scaled int8 quantization with **error feedback**:
  each participant quantizes (grad + residual), the reduction runs over the
  int8 payloads (upcast int32 on-chip for the sum — the wire format of a
  ring all-reduce is the int8 payload on the first hop and grows toward
  int32; we report the honest ~2-4x saving, not 4x), and the quantization
  residual is carried to the next step so the bias telescopes away.

Both are pure functions usable inside `shard_map` bodies; the trainer wires
them in for the replicated-parameter (non-FSDP) configuration where the DP
all-reduce is explicit and under our control.

Repo convention (enforced by `repro.analysis.source_lint`): this module
sticks to stable `jax.lax` collectives — anything from `jax.experimental`
(pallas, shard_map entry points, TPU compiler params) must route through
`core/compat.py` so version churn lands in one file.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def psum_bf16(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(x.dtype)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_int8(
    x: jax.Array, axis_name: str, residual: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce.  Returns (reduced, new_residual).

    A common scale (pmax over participants) keeps the integer sums
    commensurable; the local quantization error is returned so the caller
    can add it to the next step's gradient (1-bit-Adam-style telescoping).
    """
    if residual is not None:
        x = x + residual.astype(x.dtype)
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)) / 127.0 + 1e-20, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_residual = (x - q.astype(x.dtype) * scale).astype(jnp.float32)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_residual


def tree_psum_compressed(
    grads, axis_name: str, mode: str = "none", residuals=None
):
    """Apply the selected compression to every leaf.  Returns
    (reduced_grads, new_residuals)."""
    if mode == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads), None
    if mode == "bf16":
        return jax.tree.map(lambda g: psum_bf16(g, axis_name), grads), None
    if mode == "int8":
        flat, tdef = jax.tree.flatten(grads)
        res = (jax.tree.leaves(residuals) if residuals is not None
               else [None] * len(flat))
        outs = [psum_int8(g, axis_name, r) for g, r in zip(flat, res)]
        return (
            jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]),
        )
    raise ValueError(mode)


def init_residuals(grads_shape):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape
    )
