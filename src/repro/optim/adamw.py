"""In-house AdamW (+ global-norm clipping, warmup-cosine schedule).

Implemented directly on pytrees (no optax in this container).  Moments can be
kept in bf16 (`moment_dtype`) for the very large models where 12 bytes/param
of f32 optimizer state would not fit a 16 GB v5e chip even fully sharded
(jamba-398b on a single 256-chip pod) — the standard memory/precision trade.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params: Any, cfg: AdamWConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) * (1 - lr * decay) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
