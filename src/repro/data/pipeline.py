"""Token data pipeline: deterministic, shard-aware, exactly resumable.

Batches are a pure function of (seed, step), so restart-from-checkpoint
reproduces the stream bit-for-bit with zero pipeline state beyond the step
counter — the simplest correct fault-tolerance story, and the one that keeps
working when the mesh shape changes on elastic restart (the global batch is
laid out identically; only its device placement differs).

Sources:
* `SyntheticLM` — a seeded Zipf-ish stream with local structure (copy/shift
  patterns) so a ~100M model trained for a few hundred steps shows a clearly
  decreasing loss (examples/train_lm.py);
* `BinCorpus` — memory-mapped flat token file (uint16/uint32) with
  wrap-around sampling, for real corpora.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        b, s = self.global_batch, self.seq_len
        # Zipf marginals + short-range copy structure => learnable bigrams
        base = rng.zipf(1.3, size=(b, s + 1)) % self.vocab
        shift = np.roll(base, 3, axis=1)
        mask = rng.random((b, s + 1)) < 0.5
        toks = np.where(mask, shift, base).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class BinCorpus:
    path: str
    vocab: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        assert len(self._data) > self.seq_len + 1, "corpus too small"

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        n = len(self._data) - self.seq_len - 1
        starts = rng.integers(0, n, size=self.global_batch)
        rows = np.stack(
            [self._data[s : s + self.seq_len + 1] for s in starts]
        ).astype(np.int32) % self.vocab
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def place_batch(batch: dict[str, np.ndarray], shardings: dict):
    """Host batch -> device arrays with the given NamedShardings."""
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings
        else jax.device_put(v)
        for k, v in batch.items()
    }
