"""Render the dry-run and compile-chain result JSONs into the EXPERIMENTS.md
tables (`benchmarks/results/dryrun/` and `benchmarks/results/compile/`, the
latter written by `benchmarks/bench_compile.py`)."""

from __future__ import annotations

import glob
import json
import os

CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str, opt: str = "baseline") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, f"*__{opt}.json"))):
        out.append(json.load(open(f)))
    return out


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    rows = [
        "| arch | cell | t_compute | t_memory | t_collective | bottleneck | "
        "useful FLOPs | mem GiB/chip | fits16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    recs = [r for r in recs if r["mesh"] == mesh]
    recs.sort(key=lambda r: (r["arch"], CELL_ORDER.index(r["cell"])))
    for r in recs:
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['cell']} | — | — | — | skipped | — | — "
                f"| — |"
            )
            continue
        rf = r["roofline"]
        mem = (r["memory"]["temp_size_in_bytes"]
               + r["memory"]["argument_size_in_bytes"]) / 2**30
        rows.append(
            f"| {r['arch']} | {r['cell']} | {_fmt_s(rf['t_compute_s'])} "
            f"| {_fmt_s(rf['t_memory_s'])} | {_fmt_s(rf['t_collective_s'])} "
            f"| {rf['bottleneck']} | {rf['useful_flops_ratio']:.3f} "
            f"| {mem:.1f} | {'yes' if mem <= 16 else 'NO'} |"
        )
    return "\n".join(rows)


def dryrun_table(recs: list[dict]) -> str:
    rows = [
        "| arch | cell | mesh | status | compile s | args GiB | temp GiB | "
        "AG GiB | AR GiB | RS GiB | A2A GiB | CP GiB |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    recs = sorted(recs, key=lambda r: (r["arch"],
                                       CELL_ORDER.index(r["cell"]),
                                       r["mesh"]))
    for r in recs:
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['cell']} | {r['mesh']} | skipped "
                f"({r['reason'][:40]}...) " + "| — " * 8 + "|"
            )
            continue
        c = r["collectives"]["bytes_by_op"]
        g = 2**30
        rows.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | ok "
            f"| {r['compile_s']:.0f} "
            f"| {r['memory']['argument_size_in_bytes']/g:.2f} "
            f"| {r['memory']['temp_size_in_bytes']/g:.2f} "
            f"| {c.get('all-gather',0)/g:.2f} | {c.get('all-reduce',0)/g:.2f} "
            f"| {c.get('reduce-scatter',0)/g:.2f} "
            f"| {c.get('all-to-all',0)/g:.2f} "
            f"| {c.get('collective-permute',0)/g:.2f} |"
        )
    return "\n".join(rows)


def load_compile(results_dir: str) -> list[dict]:
    return [
        json.load(open(f))
        for f in sorted(glob.glob(os.path.join(results_dir, "*.json")))
    ]


def compile_table(recs: list[dict]) -> str:
    """Per-workload view of the `repro.compile` chain: compile cost, cache
    behavior (hit rate, evictions, resident size/capacity), the schedule
    the passes chose vs a random placement, and the eager-vs-schedule
    backend wall-clock per sweep."""
    rows = [
        "| workload | kind | nodes | colors | compile cold | cache hit | "
        "hit rate | evict | cached | sweep cycles | vs random | hop-bytes | "
        "vs random | eager sweep | schedule sweep |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["kind"], r["n_nodes"])):
        cyc_win = r["random_sweep_cycles"] / max(r["sweep_cycles"], 1)
        hop_win = r["random_hop_bytes"] / max(r["comm_hop_bytes"], 1)
        eager = r.get("eager_sweep_s")
        sched = r.get("schedule_sweep_s")
        evict = r.get("cache_evictions")
        size, cap = r.get("cache_size"), r.get("cache_capacity")
        cached = f"{size}/{cap}" if size is not None else "—"
        rows.append(
            f"| {r['workload']} | {r['kind']} | {r['n_nodes']} "
            f"| {r['n_colors']} | {r['compile_cold_ms']:.1f}ms "
            f"| {r['compile_warm_us']:.0f}us | {r['cache_hit_rate']:.2f} "
            f"| {evict if evict is not None else '—'} | {cached} "
            f"| {r['sweep_cycles']} | {cyc_win:.2f}x "
            f"| {r['comm_hop_bytes']} | {hop_win:.2f}x "
            f"| {_fmt_s(eager) if eager is not None else '—'} "
            f"| {_fmt_s(sched) if sched is not None else '—'} |"
        )
    return "\n".join(rows)


def _fmt_q(x, spec: str) -> str:
    return "n/a" if x is None else format(x, spec)


def runtime_table(recs: list[dict]) -> str:
    """Serving-runtime view (`benchmarks/bench_runtime.py`): batched engine
    vs the one-query-at-a-time baseline on the same trace.  The quality
    columns (worst split R-hat / smallest ESS over served queries) are
    populated when the trace ran with engine diagnostics on; older result
    JSONs without the fields render "n/a"."""
    rows = [
        "| trace | backend | models | queries | mean batch | batched qps | "
        "serial qps | speedup | hit rate | evict | recompiles | sim p95 | "
        "sim p99 | rhat max | ess min | dropped |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["trace"], r["backend"])):
        p99 = r.get("sim_latency_p99_ms")
        rows.append(
            f"| {r['trace']} | {r['backend']} | {r['n_models']} "
            f"| {r['n_queries']} | {r['mean_batch']:.2f} "
            f"| {r['batched_qps']:.1f} | {r['serial_qps']:.1f} "
            f"| {r['speedup']:.2f}x | {r['cache_hit_rate']:.3f} "
            f"| {r['cache_evictions']} | {r['recompiles']} "
            f"| {r['sim_latency_p95_ms']:.2f}ms "
            f"| {'n/a' if p99 is None else f'{p99:.2f}ms'} "
            f"| {_fmt_q(r.get('rhat_max'), '.3f')} "
            f"| {_fmt_q(r.get('ess_min'), '.0f')} "
            f"| {_fmt_q(r.get('trace_dropped'), 'd')} |"
        )
    g = next((r for r in recs if "workers_speedup" in r), None)
    if g:
        rows += [
            "",
            f"executor gates: 4-worker sim speedup "
            f"{g['workers_speedup']:.2f}x · sliced serving bit-exact "
            f"({g['slicing_batches']} sliced batches) · calibration median "
            f"err {g['calib_median_err']:.1%} · bursty max queue depth "
            f"{g['bursty_max_queue_depth']} at shed rate "
            f"{g['bursty_shed_rate']:.1%} ({g['bursty_defers']} defers)",
        ]
    return "\n".join(rows)


def verification_table(rows: list[dict]) -> str:
    """Static-verification sweep view (`python -m repro.analysis`): one row
    per (model, pipeline) with the rules run, findings raised, round count,
    and verifier wall time — the summary the CLI prints above its findings
    and the CI job archives alongside the JSON report."""
    out = [
        "| model | kind | pipeline | nodes | rounds | rules | findings | "
        "verify |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        status = str(r["n_findings"]) if r["n_findings"] else "clean"
        out.append(
            f"| {r['model']} | {r['kind']} | {r['pipeline']} "
            f"| {r['n_nodes']} | {r['n_rounds']} | {r['n_rules']} "
            f"| {status} | {_fmt_s(r['verify_s'])} |"
        )
    return "\n".join(out)


def quality_table(rows: list[dict]) -> str:
    """Sampling-quality sweep view (`python -m repro.diag`): one row per
    (model, backend variant) with the convergence diagnostics (worst split
    R-hat, smallest per-site ESS), the exact-marginal audit (total-variation
    and max-abs error vs variable elimination, or "n/a" when the min-fill
    cost estimate ruled VE intractable), kept-draw count, and sweep wall
    time.  This is the table the diag CLI prints above its findings and the
    CI quality job archives next to the JSON snapshot."""
    out = [
        "| model | variant | nodes | chains | kept | rhat max | ess min | "
        "oracle | tv max | maxabs | ky tv | wall |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['model']} | {r['variant']} | {r['n_nodes']} "
            f"| {r['n_chains']} | {r['kept']} "
            f"| {_fmt_q(r.get('rhat_max'), '.4f')} "
            f"| {_fmt_q(r.get('ess_min'), '.0f')} "
            f"| {r['oracle']} | {_fmt_q(r.get('tv_max'), '.4f')} "
            f"| {_fmt_q(r.get('maxabs_max'), '.4f')} "
            f"| {_fmt_q(r.get('ky_tv'), '.2e')} "
            f"| {_fmt_s(r['wall_s'])} |"
        )
    return "\n".join(out)


def attribution_table(rows: list[dict]) -> str:
    """Predicted-vs-measured cost attribution (`repro.obs.attrib`): one row
    per schedule round with its modeled compute/comm cycles, its share of
    the sweep, and the predicted seconds the dispatches allocated to it —
    next to the measured wall when the trace recorded one — followed by the
    per-mechanism comm rows.  Rendered by `python -m repro.obs` and the
    runtime CLI's `--trace-out` path."""

    def ms(row, field):
        if row["n_measured"] == 0 and field == "meas_s":
            return "n/a"
        return f"{row[field] * 1e3:.2f}ms"

    def err(row):
        e = row.get("rel_err")
        return "n/a" if e is None else f"{e:.1%}"

    out = [
        "| model | kind | round | nodes | mechanism | compute cyc | "
        "comm cyc | share | disp | pred | meas | err |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["kind"] != "round":
            continue
        out.append(
            f"| {r['model']} | round | {r['round']} | {r['n_nodes']} "
            f"| {r['mechanism'] or '—'} | {r['compute_cycles']} "
            f"| {r['comm_cycles']} | {r['share']:.1%} "
            f"| {r['n_dispatches']} | {ms(r, 'pred_s')} | {ms(r, 'meas_s')} "
            f"| {err(r)} |"
        )
    for r in rows:
        if r["kind"] != "comm":
            continue
        out.append(
            f"| {r['model']} | comm | — | — | {r['mechanism']} | — "
            f"| {r['comm_cycles']} | {r['share']:.1%} "
            f"| {r['n_dispatches']} | {ms(r, 'pred_s')} | {ms(r, 'meas_s')} "
            f"| {err(r)} |"
        )
    return "\n".join(out)


def profile_table(rows: list[dict], comm: list[dict] | None = None) -> str:
    """Compiled-artifact roofline view (`repro.obs.profile`): one row per
    bucket-executable signature with its static HLO costs (trip-count-aware
    flops / HBM bytes / collective bytes), the roofline bottleneck, the
    roofline lower bound, and the measured dispatch mean with
    achieved-vs-peak — followed by per-comm-mechanism rows.  Rendered by
    the runtime CLI's `--profile-out` path and
    `python -m repro.obs --profile`."""

    def num(x):
        return "0" if not x else f"{x:.3g}"

    out = [
        "| model | kind | sampler | fused | pad | iters x chains | disp | "
        "flops | hbm B | coll B | bottleneck | roofline | meas mean | "
        "peak frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        m = r.get("meta", {})
        meas = r.get("measured_mean_s")
        frac = r.get("peak_frac")
        out.append(
            f"| {m.get('model', '—')} | {m.get('kind', '—')} "
            f"| {m.get('sampler', '—')} | {int(bool(m.get('fused')))} "
            f"| {m.get('n_padded', '—')} "
            f"| {m.get('n_iters', '—')}x{m.get('n_chains', '—')} "
            f"| {r.get('n_dispatches', 0)} "
            f"| {num(r['flops'])} | {num(r['hbm_bytes'])} "
            f"| {num(r['collective_bytes'])} | {r['bottleneck']} "
            f"| {_fmt_s(r['roofline_s'])} "
            f"| {_fmt_s(meas) if meas is not None else 'n/a'} "
            f"| {_fmt_q(frac, '.2%')} |"
        )
    for c in comm or []:
        bw = c.get("achieved_bw")
        out.append(
            f"| comm | {c['mechanism']} | {c['hlo_op']} | — | — | — "
            f"| {c['n_dispatches']} | — | — | {num(c['total_bytes'])} "
            f"| collective | — "
            f"| {_fmt_s(c['measured_total_s'])} "
            f"| {'n/a' if bw is None else f'{bw / 1e9:.3g}GB/s'} |"
        )
    return "\n".join(out)


def bottleneck_notes(recs: list[dict]) -> str:
    """One sentence per (arch, cell) on what would move the dominant term."""
    notes = {
        ("memory", "train"): "dominant term is HBM traffic: raise arithmetic "
        "intensity (larger per-chip batch, fused kernels, bf16 residuals).",
        ("memory", "prefill"): "KV/activation traffic bound: shard sequence, "
        "fuse attention stages, avoid f32 intermediates in the scan.",
        ("memory", "decode"): "decode is weight-streaming bound (every step "
        "reads all weights): batch more sequences per chip or quantize "
        "weights.",
        ("collective", "train"): "TP all-reduces of activations dominate: "
        "overlap with compute, reduce-scatter+all-gather (sequence-parallel) "
        "instead of all-reduce, or shrink TP degree for this size.",
        ("collective", "prefill"): "same as train: sequence-parallel "
        "collective schedule.",
        ("collective", "decode"): "per-token all-reduces dominate at tiny "
        "per-step compute: fold TP collectives, wider decode batch.",
        ("compute", "train"): "compute-bound — already near the roofline "
        "knee; reduce remat recompute or improve causal-block skipping.",
    }
    out = []
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        key = (r["roofline"]["bottleneck"], r["kind"])
        out.append(f"* **{r['arch']} / {r['cell']}** — "
                   f"{notes.get(key, 'see table.')}")
    return "\n".join(out)


if __name__ == "__main__":
    import sys

    d = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))),
        "benchmarks", "results", "dryrun")
    recs = load(d)
    print("## Roofline (single pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Dry-run detail\n")
    print(dryrun_table(recs))
    cdir = os.path.join(os.path.dirname(d), "compile")
    crecs = load_compile(cdir) if os.path.isdir(cdir) else []
    if crecs:
        print("\n## Compile chain (repro.compile)\n")
        print(compile_table(crecs))
    rdir = os.path.join(os.path.dirname(d), "runtime")
    rrecs = load_compile(rdir) if os.path.isdir(rdir) else []
    if rrecs:
        print("\n## Serving runtime (repro.runtime)\n")
        print(runtime_table(rrecs))
