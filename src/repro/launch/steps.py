"""Jitted step builders (train / prefill / serve) + per-cell input specs.

This is the single source of truth for what each (architecture x input
shape) dry-run cell lowers:

  train_4k    -> train_step   (loss + AdamW update, global_batch=256, S=4096)
  prefill_32k -> prefill_step (forward + cache build, gb=32, S=32768)
  decode_32k  -> serve_step   (1 new token against a 32768 KV/state cache,
                               gb=128, KY token sampling inside the step)
  long_500k   -> serve_step   (S_cache=524288, gb=1; sub-quadratic archs only)

`abstract_*` functions produce ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for the dry-run; the same builders produce the
runnable jitted functions for the examples on small meshes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_lib
from repro.launch import sharding
from repro.models import sampling as tok_sampling
from repro.models import transformer as tfm
from repro.optim import adamw

SHAPE_CELLS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_applicable(cfg: ModelConfig, cell: str) -> tuple[bool, str]:
    if cell == "long_500k" and not cfg.long_context:
        return False, (
            "pure full-attention arch: 500k decode requires sub-quadratic "
            "attention (skip documented in DESIGN.md Sec. 5)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# abstract inputs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: tfm.init_model(k, cfg), jax.random.PRNGKey(0)
    )


def abstract_batch(cfg: ModelConfig, seq: int, batch: int) -> dict[str, Any]:
    front = cfg.frontend_len if cfg.frontend else 0
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq - front), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.frontend:
        out["features"] = jax.ShapeDtypeStruct(
            (batch, front, tfm.FRONTEND_DIM), jnp.float32
        )
    return out


def abstract_caches(cfg: ModelConfig, batch: int, s_max: int):
    return jax.eval_shape(
        functools.partial(tfm.init_decode_caches, cfg, batch, s_max)
    )


def abstract_opt_state(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), params)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def act_partition(mesh, cfg: ModelConfig, batch_dim: int) -> P | None:
    """Residual-stream (B, S, d) constraint: batch over DP, d over TP."""
    if mesh is None:
        return None
    dp = mesh_lib.dp_axes(mesh)
    tp = mesh_lib.tp_axis(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    b_ax = (dp if len(dp) > 1 else dp[0]) if batch_dim % dp_size == 0 else None
    d_ax = tp if tp and cfg.d_model % mesh.shape[tp] == 0 else None
    return P(b_ax, None, d_ax)


def _set_moe_ctx(mesh) -> None:
    """In-layer MoE sharding constraints need the mesh axes at trace time."""
    from repro.models import moe as moe_mod

    if mesh is None:
        moe_mod.clear_moe_mesh()
        return
    tp = mesh_lib.tp_axis(mesh)
    moe_mod.set_moe_mesh(
        mesh_lib.dp_axes(mesh), tp, mesh.shape[tp] if tp else 1
    )


def default_opt_cfg(cfg: ModelConfig) -> adamw.AdamWConfig:
    # bf16 moments when the f32 optimizer would not fit a 16 GB chip
    moment = "bfloat16" if cfg.n_params() > 2e11 else "float32"
    return adamw.AdamWConfig(moment_dtype=moment)


def make_train_step(
    cfg: ModelConfig,
    mesh,
    opt_cfg: adamw.AdamWConfig | None = None,
    remat_policy: str = "nothing",
    jit: bool = True,
):
    """Returns (step_fn, shardings dict).  step_fn(params, opt_state, batch)
    -> (params, opt_state, metrics)."""
    opt_cfg = opt_cfg or default_opt_cfg(cfg)

    aspec = None

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tfm.train_loss(p, cfg, batch,
                                     remat_policy=remat_policy,
                                     act_spec=aspec)
        )(params)
        params, opt_state, metrics = adamw.update(
            params, grads, opt_state, opt_cfg
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    if mesh is None:
        return (jax.jit(step, donate_argnums=(0, 1)) if jit else step), None

    pspecs = sharding.param_specs(mesh, cfg, abstract_params(cfg))
    ospecs = sharding.opt_specs(mesh, cfg,
                                abstract_opt_state(cfg, opt_cfg))
    shardings = {"params": pspecs, "opt": ospecs}

    def with_batch(batch_shape):
        nonlocal aspec
        aspec = act_partition(mesh, cfg, batch_shape["tokens"].shape[0])
        _set_moe_ctx(mesh)
        bspecs = sharding.batch_specs(mesh, cfg, batch_shape)
        fn = jax.jit(
            step,
            in_shardings=(
                sharding.to_named(mesh, pspecs),
                sharding.to_named(mesh, ospecs),
                sharding.to_named(mesh, bspecs),
            ),
            out_shardings=(
                sharding.to_named(mesh, pspecs),
                sharding.to_named(mesh, ospecs),
                None,
            ),
            donate_argnums=(0, 1),
        )
        return fn, bspecs

    return with_batch, shardings


def make_prefill_step(cfg: ModelConfig, mesh):
    aspec = None

    def step(params, batch):
        return tfm.prefill(params, cfg, batch, act_spec=aspec)

    if mesh is None:
        return jax.jit(step)

    pspecs = sharding.param_specs(mesh, cfg, abstract_params(cfg))

    def with_batch(batch_shape):
        nonlocal aspec
        aspec = act_partition(mesh, cfg, batch_shape["tokens"].shape[0])
        _set_moe_ctx(mesh)
        bspecs = sharding.batch_specs(mesh, cfg, batch_shape)
        return jax.jit(
            step,
            in_shardings=(
                sharding.to_named(mesh, pspecs),
                sharding.to_named(mesh, bspecs),
            ),
        )

    return with_batch


def make_serve_step(
    cfg: ModelConfig, mesh, sampler: str = "ky"
):
    """serve_step(params, tokens (B,1), caches, pos, key) ->
    (next_tokens (B,), logits (B,V), caches).  Token sampling (the paper's
    C1+C2 pipeline for sampler='ky') happens INSIDE the step."""

    def step(params, tokens, caches, pos, key):
        logits, caches = tfm.decode_step(params, cfg, tokens, caches, pos)
        if sampler == "greedy":
            toks = tok_sampling.greedy_token(logits)
        else:
            toks = tok_sampling.sample_tokens(logits, key, sampler)
        return toks, logits, caches

    if mesh is None:
        return jax.jit(step, donate_argnums=(2,))

    pspecs = sharding.param_specs(mesh, cfg, abstract_params(cfg))

    def with_caches(cache_shape, batch: int):
        _set_moe_ctx(mesh)
        cspecs = sharding.cache_specs(mesh, cfg, cache_shape)
        dp = mesh_lib.dp_axes(mesh)
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        tok_spec = P(dp if len(dp) > 1 else dp[0], None) \
            if batch % dp_size == 0 else P(None, None)
        out_tok = P(tok_spec[0]) if batch % dp_size == 0 else P(None)
        fn = jax.jit(
            step,
            in_shardings=(
                sharding.to_named(mesh, pspecs),
                NamedSharding(mesh, tok_spec),
                sharding.to_named(mesh, cspecs),
                None,
                None,
            ),
            out_shardings=(
                NamedSharding(mesh, out_tok),
                None,
                sharding.to_named(mesh, cspecs),
            ),
            donate_argnums=(2,),
        )
        return fn, cspecs

    return with_caches
