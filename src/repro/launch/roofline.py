"""Roofline-term derivation from a compiled dry-run artifact.

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective_bytes is parsed out of the post-SPMD optimized HLO
(``compiled.as_text()``) by summing the result sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (harness-specified).

Known-limits note (documented, accounted for in the tables): XLA's HLO cost
analysis reports a while-loop body ONCE, not multiplied by its trip count.
Our layer stack is a scan over n_super superblocks, so we scale loop-body
costs by the known trip counts, which we recover by matching
``while`` trip counts in the HLO (see `_scan_correction`).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array shape in an HLO type string (handles
    tuples like (f32[8,16], f32[8,16]))."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of collective ops in optimized HLO, scaling ops that
    live inside while-loop bodies by the loop trip count."""
    bytes_by: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    count_by: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    # computation name -> trip count for scan bodies
    trips = _body_trip_counts(hlo_text)
    current_comp = ""
    for line in hlo_text.splitlines():
        comp = re.match(r"\s*%?([\w\.\-]+)\s*\([^)]*\)\s*->", line)
        if line.strip().startswith(("ENTRY", "%")) and "{" in line and "->" in line:
            m = re.search(r"%?([\w\.\-]+)\s*\(", line)
            if m:
                current_comp = m.group(1)
        for op in _COLLECTIVES:
            # match "= <type> <op>(" and "<op>-start(" variants
            if re.search(rf"=\s+[^=]*\b{op}(-start)?\(", line):
                lhs = line.split("=", 1)[1]
                type_part = lhs.strip().split(op)[0]
                b = _shape_bytes(type_part)
                mult = trips.get(current_comp, 1)
                bytes_by[op] += b * mult
                count_by[op] += mult
    return CollectiveStats(bytes_by, count_by)


def _body_trip_counts(hlo_text: str) -> dict[str, int]:
    """Best-effort: map while-body computation names to trip counts.

    XLA scan loops carry an iteration counter compared against a constant;
    we find `while` ops, their body names, and look for the constant bound
    in the loop condition computation."""
    # condition computations: name -> bound
    cond_bounds: dict[str, int] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"%?([\w\.\-]+)\s*\([^)]*\)\s*->\s*pred\[\]", line.strip())
        if m:
            cur = m.group(1)
        if cur and ("compare" in line and "LT" in line):
            consts = re.findall(r"constant\((\d+)\)", line)
        if cur and "constant(" in line:
            c = re.findall(r"constant\((\d+)\)", line)
            if c:
                cond_bounds.setdefault(cur, int(c[-1]))
        if line.strip() == "}":
            cur = None
    trips: dict[str, int] = {}
    for m in re.finditer(
        r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
        hlo_text,
    ):
        cond, body = m.group(1), m.group(2)
        if cond in cond_bounds:
            trips[body] = max(1, cond_bounds[cond])
    return trips


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.n_chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(cfg, cell_kind: str, seq: int, batch: int) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D prefill,
    2·N per token decode; N = active params (MoE-aware)."""
    n = cfg.n_active_params()
    if cell_kind == "train":
        return 6.0 * n * seq * batch
    if cell_kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch  # decode: one token per sequence
