"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \
        --steps 200 --ckpt-dir /tmp/ckpt --mesh 1x1

Features (DESIGN.md Sec. 6):
  * any registered --arch (full or --reduced smoke geometry);
  * arbitrary mesh (--mesh DxM), elastic restart: checkpoints are
    device-count-agnostic, resume re-shards onto the current mesh;
  * atomic rotated checkpoints every --ckpt-every steps; the data pipeline
    needs no state beyond the step counter (deterministic batches);
  * preemption-safe: SIGTERM/SIGINT trigger a final checkpoint before exit;
  * optional gradient compression (--compress bf16|int8) for the explicit-DP
    configuration (--no-fsdp, parameters replicated over "data").
"""

from __future__ import annotations

import argparse
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch import mesh as mesh_lib
from repro.launch import sharding, steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw


def build(cfg, mesh, opt_cfg, seq, global_batch):
    params_h = tfm.init_model(jax.random.PRNGKey(0), cfg)
    opt_h = adamw.init(params_h, opt_cfg)
    if mesh is None:
        step_fn, _ = steps_lib.make_train_step(cfg, None, opt_cfg)
        return params_h, opt_h, step_fn, None
    with_batch, specs = steps_lib.make_train_step(cfg, mesh, opt_cfg)
    batch_abs = steps_lib.abstract_batch(cfg, seq, global_batch)
    step_fn, bspecs = with_batch(batch_abs)
    pshard = sharding.to_named(mesh, specs["params"])
    oshard = sharding.to_named(mesh, specs["opt"])
    params = jax.device_put(params_h, pshard)
    opt_state = jax.device_put(opt_h, oshard)
    bshard = sharding.to_named(mesh, bspecs)
    return params, opt_state, step_fn, bshard


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--mesh", default="",
                    help="DxM data x model, e.g. 2x4 ('' = single device)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps,
        moment_dtype=steps_lib.default_opt_cfg(cfg).moment_dtype,
    )
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        if d * m > 1:
            mesh = mesh_lib.make_mesh((d, m), ("data", "model"))

    data = SyntheticLM(cfg.vocab, args.seq, args.global_batch)
    params, opt_state, step_fn, bshard = build(
        cfg, mesh, opt_cfg, args.seq, args.global_batch
    )

    start_step = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            like = {"params": jax.tree.map(np.asarray, jax.device_get(params)),
                    "opt": jax.tree.map(np.asarray, jax.device_get(opt_state))}
            manifest, tree = ckpt.restore(args.ckpt_dir, last, like)
            params = jax.device_put(
                tree["params"],
                jax.tree.map(lambda x: x.sharding, params)) \
                if mesh else jax.device_put(tree["params"])
            opt_state = jax.device_put(
                tree["opt"],
                jax.tree.map(lambda x: x.sharding, opt_state)) \
                if mesh else jax.device_put(tree["opt"])
            start_step = manifest["step"]
            print(f"[train] resumed from step {start_step}")

    stop = {"now": False}

    def _sig(_sig, _frm):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    def save(step):
        if not args.ckpt_dir:
            return
        tree = {"params": params, "opt": opt_state}
        ckpt.save(args.ckpt_dir, step, tree, extra={"arch": cfg.name})
        ckpt.rotate(args.ckpt_dir, args.keep)

    def make_frontend_batch(b):
        if not cfg.frontend:
            return b
        rng = np.random.default_rng(1234)
        s_f = cfg.frontend_len
        b = dict(b)
        b["tokens"] = b["tokens"][:, : args.seq - s_f]
        b["features"] = rng.normal(
            0, 1, (args.global_batch, s_f, tfm.FRONTEND_DIM)
        ).astype(np.float32)
        return b

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = make_frontend_batch(data.batch(step))
        if bshard is not None:
            batch = {k: jax.device_put(v, bshard[k]) for k, v in
                     batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(step + 1)
        if stop["now"]:
            print("[train] preemption signal: checkpoint + exit")
            save(step + 1)
            sys.exit(0)
    save(args.steps)
    print(f"[train] done: first/last logged loss "
          f"{losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
