"""Logical->mesh sharding rules for parameters, optimizer state, batches and
decode caches (2-D TP x FSDP layout, MaxText-style).

Conventions:
  * TP ("model" axis): d_ff, attention heads (or head_dim when heads don't
    divide), vocab, experts (EP when E divides the axis, else the expert
    hidden dim);
  * FSDP ("data" axis): the other large dimension of every big matrix —
    GSPMD all-gathers weights per scanned layer, the standard ZeRO-3 trade;
    never across pods (DCN);
  * scanned ("super"-stacked) leaves get a leading None;
  * any rule that does not divide the dimension degrades to None (so the
    same rules serve the (2,4) test mesh and the (16,16) pod).

Every rule is keyed on the leaf's dict-key name — the parameter pytree is
the schema.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_lib


def _div(mesh, axis: str | None, dim: int):
    """axis if it divides dim, else None (graceful degradation)."""
    if axis is None:
        return None
    size = int(np.prod([mesh.shape[a] for a in (
        axis if isinstance(axis, tuple) else (axis,))]))
    return axis if dim % size == 0 else None


def param_spec(
    mesh, cfg: ModelConfig, name: str, shape: tuple[int, ...],
    scanned: bool,
) -> P:
    tp = mesh_lib.tp_axis(mesh)
    fs = mesh_lib.fsdp_axis(mesh)
    s = shape[1:] if scanned else shape
    r = len(s)
    dv = lambda axis, dim: _div(mesh, axis, dim)
    spec = None

    if name in ("wg", "wu", "wd"):
        if r == 3:  # moe expert stack (E, d, f) / (E, f, d)
            # TP on the expert hidden dim f, FSDP on d (dense-FFN-style).
            # EP (experts over "model") was measured and rejected: the
            # dispatch scatter then conflicts with the d contraction and
            # GSPMD replicates expert activations (EXPERIMENTS.md §Perf).
            hid = 2 if name in ("wg", "wu") else 1
            other = 3 - hid
            spec = [None, None, None]
            spec[hid] = dv(tp, s[hid])
            spec[other] = dv(fs, s[other])
            spec = tuple(spec)
        elif r == 2:  # dense mlp (d, ff) / (ff, d)
            spec = ((dv(tp, s[0]), dv(fs, s[1])) if name == "wd"
                    else (dv(fs, s[0]), dv(tp, s[1])))
    elif name == "embed" and r == 2:
        spec = (dv(tp, s[0]), dv(fs, s[1]))
    elif name == "head" and r == 2:
        spec = (dv(fs, s[0]), dv(tp, s[1]))
    elif name == "frontend_proj" and r == 2:
        spec = (None, dv(tp, s[1]))
    elif name in ("wq", "wk", "wv") and r == 3:
        spec = (dv(fs, s[0]), dv(tp, s[1]), None)
    elif name == "wo" and r == 3:
        spec = (dv(tp, s[0]), None, dv(fs, s[2]))
    elif name in ("bq", "bk", "bv") and r == 2:
        spec = (dv(tp, s[0]), None)
    elif name == "router" and r == 2:
        spec = (dv(fs, s[0]), None)
    elif name == "in_proj" and r == 2:
        spec = (dv(fs, s[0]), dv(tp, s[1]))
    elif name == "conv_w" and r == 2:
        spec = (None, dv(tp, s[1]))
    elif name in ("conv_b", "dt_bias", "d_skip") and r == 1:
        spec = (dv(tp, s[0]),)
    elif name == "x_proj" and r == 2:
        spec = (dv(tp, s[0]), None)
    elif name == "dt_proj" and r == 2:
        spec = (None, dv(tp, s[1]))
    elif name == "a_log" and r == 2:
        spec = (dv(tp, s[0]), None)
    elif name in ("wi", "wf") and r == 2:
        spec = (dv(fs, s[0]), None)
    elif name == "out_proj" and r == 2:
        spec = (dv(tp, s[0]), dv(fs, s[1]))
    elif name in ("wo_gate", "out") and r == 2:
        spec = (dv(fs, s[0]), dv(tp, s[1]))
    elif name == "w_in" and r == 4:
        spec = (dv(fs, s[0]), None, None, dv(tp, s[3]))
    elif name == "r" and r == 4:
        spec = (None, dv(tp, s[1]), None, None)

    if spec is None:  # norms, small biases, unknown leaves: replicated
        spec = (None,) * r
    if scanned:
        spec = (None,) + tuple(spec)
    return P(*spec)


def _named_tree(mesh, cfg, tree, spec_fn):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        keys = [getattr(p, "key", None) for p in path]
        name = next(
            (k for k in reversed(keys) if isinstance(k, str)), ""
        )
        scanned = "super" in keys
        out.append(spec_fn(name, tuple(leaf.shape), scanned))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_specs(mesh, cfg: ModelConfig, params_shape) -> Any:
    """Pytree of PartitionSpecs matching a params (shape) pytree."""
    return _named_tree(
        mesh, cfg, params_shape,
        lambda n, s, sc: param_spec(mesh, cfg, n, s, sc),
    )


def opt_specs(mesh, cfg: ModelConfig, opt_shape) -> Any:
    """Optimizer moments shard like their parameters; step is replicated."""
    def fn(n, s, sc):
        if n == "step" or len(s) == 0:
            return P()
        return param_spec(mesh, cfg, n, s, sc)

    return _named_tree(mesh, cfg, opt_shape, fn)


def batch_specs(mesh, cfg: ModelConfig, batch_shape) -> Any:
    """Batch (tokens/labels/features) over the DP axes; if the global batch
    is too small (long-context cells), shard the sequence axis instead."""
    dp = mesh_lib.dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    out = {}
    for k, v in batch_shape.items():
        b, s = v.shape[0], v.shape[1]
        if b % dp_size == 0:
            out[k] = P(dp if len(dp) > 1 else dp[0], *(None,) * (v.ndim - 1))
        elif s % dp_size == 0 and v.ndim >= 2:
            out[k] = P(None, dp if len(dp) > 1 else dp[0],
                       *(None,) * (v.ndim - 2))
        else:
            out[k] = P(*(None,) * v.ndim)
    return out


def cache_specs(mesh, cfg: ModelConfig, cache_shape) -> Any:
    """Decode caches: batch over DP when divisible; the long axis (KV
    sequence / d_inner / head_dim) over TP; leading n_super axis unsharded.

    Leaf name conventions: attention k/v (n_super, B, S, KVH, HD); mamba
    conv/h; mlstm C/n/m; slstm c/n/h/m."""
    dp = mesh_lib.dp_axes(mesh)
    tp = mesh_lib.tp_axis(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    dp_spec = dp if len(dp) > 1 else dp[0]

    def fn(name, s, scanned):
        # s includes the leading n_super axis here (cache trees are stacked)
        bdim = s[1]
        bspec = dp_spec if bdim % dp_size == 0 else None
        rest = [None] * (len(s) - 2)
        if name in ("k", "v") and len(s) == 5:
            # (L, B, S_cache, KVH, HD): sequence over model (+data if free)
            seq_axes = tuple(a for a in ((tp,) if tp else ())
                             if s[2] % mesh.shape[a] == 0)
            if bspec is None:
                both = tuple(list(dp) + [tp]) if tp else dp
                size = int(np.prod([mesh.shape[a] for a in both]))
                if s[2] % size == 0:
                    rest[0] = both
                elif seq_axes:
                    rest[0] = seq_axes[0]
            elif seq_axes:
                rest[0] = seq_axes[0]
        elif name in ("conv", "ssm") and len(s) == 4:
            # mamba conv (L,B,K-1,di) / ssm (L,B,di,n)
            di_dim = 3 if name == "conv" else 2
            if tp and s[di_dim] % mesh.shape[tp] == 0:
                rest[di_dim - 2] = tp
        elif name in ("C", "n", "m", "c", "h") and tp:
            # mlstm/slstm states (L,B,H,...): shard trailing head_dim
            for dim in range(len(s) - 1, 1, -1):
                if s[dim] % mesh.shape[tp] == 0 and dim >= 3:
                    rest[dim - 2] = tp
                    break
        return P(None, bspec, *rest)

    return _named_tree(mesh, cfg, cache_shape, fn)


def to_named(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
