"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE,
which silently undercounts any scan-over-layers program by ~n_layers.  This
module re-derives the three roofline inputs by walking the HLO text:

  * flops            — 2·numel(result)·prod(contracting dims) per dot,
                        multiplied by the loop multiplier of its computation;
  * hbm_bytes        — operand+result bytes of top-level fusions / dots /
                        copies / reduces / collectives (fusion internals are
                        register/VMEM-resident by construction), x multiplier;
  * collective_bytes — result bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        x multiplier.

Loop multipliers: `while(...) condition=%c body=%b` contributes
trip_count(c) to b; fusion `calls=`/`to_apply=` edges contribute 1; the
multiplier graph is a DAG rooted at ENTRY and resolved by fixed-point
propagation.  Trip counts are read from the `constant(N)` feeding the
condition's `compare(..., LT)` — exact for lax.scan/fori_loop loops (which
is all this codebase emits); `while_loop`s with data-dependent bounds (the
KY early-exit walk) fall back to their static upper bound, making the
roofline conservative for the sampler (documented in EXPERIMENTS.md).

Validated in tests/test_hlo_cost.py against analytically-known programs.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRAFFIC_OPS = ("fusion", "dot", "copy", "reduce", "convolution",
                "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
                "sort", "concatenate", "transpose", "broadcast", "iota",
                "convert", "slice", "pad", "reshape", "select", "rng",
                "add", "multiply", "subtract", "divide", "exponential",
                "compare", "maximum", "minimum", "tanh", "custom-call",
                ) + _COLLECTIVES


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    is_entry: bool = False


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z]+[0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-\$]+)\("
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{")


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc and not line.lstrip().startswith("//"):
            cur = Computation(mc.group(2), [], is_entry=bool(mc.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        mi = _DEF_RE.match(line)
        if mi:
            cur.instructions.append(
                Instruction(mi.group(1), mi.group(2), mi.group(3), line)
            )
        if line.strip() == "}":
            cur = None
    return comps


def _trip_count(cond: Computation) -> int:
    """Bound constant of an `i < N` loop condition (1 if unknown)."""
    consts = []
    for ins in cond.instructions:
        consts += [int(c) for c in re.findall(r"constant\((\d+)\)", ins.line)]
    # the compare bound is the constant actually fed to the comparison; with
    # wrapped fusions we cannot see inside, so take the max s32 constant —
    # exact for scan/fori conditions, an upper bound otherwise
    return max(consts) if consts else 1


def multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution-count multiplier per computation (ENTRY = 1)."""
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for comp in comps.values():
        for ins in comp.instructions:
            m = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                          ins.line)
            if ins.opcode == "while" and m:
                cond_name, body_name = m.group(1), m.group(2)
                trips = _trip_count(comps[cond_name]) if cond_name in comps \
                    else 1
                edges[comp.name].append((body_name, float(max(trips, 1))))
                edges[comp.name].append((cond_name, float(max(trips, 1))))
                continue
            for attr in ("calls", "to_apply", "body", "branch_computations"):
                for mm in re.finditer(rf"{attr}=%?([\w\.\-{{}}, ]+)",
                                      ins.line):
                    for name in re.findall(r"[\w\.\-]+", mm.group(1)):
                        if name in comps:
                            edges[comp.name].append((name, 1.0))

    mult: dict[str, float] = {
        c.name: (1.0 if c.is_entry else 0.0) for c in comps.values()
    }
    # fixed-point over the call DAG (depth is small)
    for _ in range(50):
        changed = False
        new = {c: (1.0 if comps[c].is_entry else 0.0) for c in comps}
        for src, outs in edges.items():
            for dst, w in outs:
                new[dst] = new.get(dst, 0.0) + mult.get(src, 0.0) * w
        for c in comps:
            if abs(new[c] - mult[c]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return mult


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_op: dict[str, float]
    collective_counts: dict[str, float]
    xla_flops_once: float = 0.0


def analyze(hlo: str) -> HloCost:
    comps = parse_module(hlo)
    mult = multipliers(comps)

    flops = 0.0
    hbm = 0.0
    coll_b: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    coll_n: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        symtab = {i.name: i.type_str for i in comp.instructions}
        fused = comp.name.startswith("fused_") or "fused_computation" in \
            comp.name or "wrapped_" in comp.name
        for ins in comp.instructions:
            # ---- flops: dots wherever they live --------------------------
            if ins.opcode == "dot":
                ops = re.findall(r"\(%([\w\.\-]+)(?:,\s*%([\w\.\-]+))?\)",
                                 ins.line.split("dot(")[1])
                args = re.match(r"([^)]*)\)", ins.line.split("dot(")[1])
                names = re.findall(r"%([\w\.\-]+)", args.group(1)) if args \
                    else []
                lhs_dims = []
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               ins.line)
                if mm and names and names[0] in symtab:
                    shape = _shape_dims(symtab[names[0]])
                    for dstr in mm.group(1).split(","):
                        if dstr and int(dstr) < len(shape):
                            lhs_dims.append(shape[int(dstr)])
                k = 1
                for d in lhs_dims:
                    k *= d
                out_elems = max(_type_bytes(ins.type_str), 1)
                # element count: bytes / dtype size
                dt = _SHAPE_RE.search(ins.type_str)
                esize = _DTYPE_BYTES.get(dt.group(1), 4) if dt else 4
                flops += m * 2.0 * (out_elems / esize) * k
            elif ins.opcode == "convolution":
                out_elems = _type_bytes(ins.type_str) / 4
                flops += m * 2.0 * out_elems  # lower bound; convs are rare

            # ---- memory traffic: top-level (non-fused) ops ---------------
            if not fused and ins.opcode in _TRAFFIC_OPS:
                b = _type_bytes(ins.type_str)
                arg_part = ins.line.split("(", 1)[1]
                for nm in re.findall(r"%([\w\.\-]+)", arg_part):
                    b += _type_bytes(symtab.get(nm, ""))
                hbm += m * b

            # ---- collectives ---------------------------------------------
            for c in _COLLECTIVES:
                if ins.opcode in (c, f"{c}-start"):
                    coll_b[c] += m * _type_bytes(ins.type_str)
                    coll_n[c] += m
    return HloCost(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=sum(coll_b.values()),
        collective_by_op=coll_b,
        collective_counts=coll_n,
    )
