import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (harness deliverable e).

For every (architecture x input shape) cell, lower + compile the appropriate
step (train_step / prefill_step / serve_step) on the production mesh —
(16,16)=("data","model") single-pod and (2,16,16)=("pod","data","model")
multi-pod — and record:

  * compiled.memory_analysis()   (fits-per-device proof)
  * compiled.cost_analysis()     (FLOPs / bytes for the roofline)
  * collective bytes parsed from the optimized HLO (roofline 3rd term)

Results land in benchmarks/results/dryrun/<arch>__<cell>__<mesh>.json;
the driver mode (--all) runs each cell in a fresh subprocess so one cell's
failure or memory blow-up cannot poison the sweep, and completed cells are
skipped on re-run (resumable).

NOTE the XLA_FLAGS line above MUST precede any jax import — jax locks the
device count on first init.  Only this module sets it; tests and benchmarks
see the single real CPU device.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "results", "dryrun",
)


def run_cell(arch: str, cell: str, mesh_kind: str, out_dir: str,
             opt_tag: str = "baseline") -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch import roofline as rl
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    spec = steps_lib.SHAPE_CELLS[cell]
    ok, why = steps_lib.cell_applicable(cfg, cell)
    if not ok:
        rec = {"arch": arch, "cell": cell, "mesh": mesh_kind,
               "opt": opt_tag, "status": "skipped", "reason": why}
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}__{cell}__{mesh_kind}__{opt_tag}.json"),
                "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] {arch} {cell} {mesh_kind}: SKIPPED ({why[:60]})")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    seq, batch = spec["seq"], spec["batch"]
    t0 = time.time()

    with mesh:
        if spec["kind"] == "train":
            with_batch, _ = steps_lib.make_train_step(cfg, mesh)
            batch_abs = steps_lib.abstract_batch(cfg, seq, batch)
            fn, _ = with_batch(batch_abs)
            args = (
                steps_lib.abstract_params(cfg),
                steps_lib.abstract_opt_state(
                    cfg, steps_lib.default_opt_cfg(cfg)),
                batch_abs,
            )
        elif spec["kind"] == "prefill":
            with_batch = steps_lib.make_prefill_step(cfg, mesh)
            batch_abs = steps_lib.abstract_batch(cfg, seq, batch)
            del batch_abs["labels"]
            fn = with_batch(batch_abs)
            args = (steps_lib.abstract_params(cfg), batch_abs)
        else:  # decode
            with_caches = steps_lib.make_serve_step(cfg, mesh, sampler="ky")
            caches_abs = steps_lib.abstract_caches(cfg, batch, seq)
            fn, _ = with_caches(caches_abs, batch)
            args = (
                steps_lib.abstract_params(cfg),
                jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                caches_abs,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    cost = compiled.cost_analysis() or {}
    cost_rec = {
        k: float(cost[k]) for k in ("flops", "bytes accessed",
                                    "transcendentals") if k in cost
    }

    # trip-count-aware walk of the optimized (post-SPMD, per-device) HLO —
    # XLA's cost_analysis counts while bodies once (see hlo_cost docstring)
    from repro.launch import hlo_cost

    hlo = compiled.as_text()
    hc = hlo_cost.analyze(hlo)
    roof = rl.Roofline(
        flops=hc.flops,
        hbm_bytes=hc.hbm_bytes,
        collective_bytes=hc.collective_bytes,
        n_chips=1,  # the walked program is the per-device SPMD program
        model_flops=rl.model_flops(cfg, spec["kind"], seq, batch) / n_chips,
    )
    coll = rl.CollectiveStats(
        {k: int(v) for k, v in hc.collective_by_op.items()},
        {k: int(v) for k, v in hc.collective_counts.items()},
    )
    rec = {
        "arch": arch,
        "cell": cell,
        "mesh": mesh_kind,
        "opt": opt_tag,
        "status": "ok",
        "n_chips": int(n_chips),
        "seq": seq,
        "batch": batch,
        "kind": spec["kind"],
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_rec,
        "cost": cost_rec,
        "collectives": {
            "bytes_by_op": coll.bytes_by_op,
            "count_by_op": coll.count_by_op,
            "total_bytes": coll.total_bytes,
        },
        "roofline": roof.as_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{arch}__{cell}__{mesh_kind}__{opt_tag}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {arch} {cell} {mesh_kind}: OK "
          f"(compile {t_compile:.0f}s, temp "
          f"{mem_rec.get('temp_size_in_bytes', 0)/2**30:.2f} GiB, "
          f"bottleneck {roof.bottleneck})")
    return rec


def drive_all(meshes, archs, cells, out_dir, tag="baseline"):
    """Run every pending cell in a fresh subprocess (resumable, isolated)."""
    from repro.configs import list_archs
    from repro.launch.steps import SHAPE_CELLS

    archs = archs or list_archs()
    cells = cells or list(SHAPE_CELLS)
    todo = []
    for mesh_kind in meshes:
        for arch in archs:
            for cell in cells:
                f = os.path.join(out_dir,
                                 f"{arch}__{cell}__{mesh_kind}__{tag}.json")
                if os.path.exists(f):
                    continue
                todo.append((arch, cell, mesh_kind))
    print(f"[dryrun] {len(todo)} cells to run")
    failures = []
    for i, (arch, cell, mesh_kind) in enumerate(todo):
        print(f"[dryrun] ({i+1}/{len(todo)}) {arch} {cell} {mesh_kind}",
              flush=True)
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--cell", cell, "--mesh", mesh_kind, "--out", out_dir,
             "--tag", tag],
            capture_output=True, text=True, timeout=7200,
        )
        if r.returncode != 0:
            failures.append((arch, cell, mesh_kind))
            err_file = os.path.join(
                out_dir, f"{arch}__{cell}__{mesh_kind}__{tag}.err")
            with open(err_file, "w") as f:
                f.write(r.stdout[-5000:] + "\n---\n" + r.stderr[-10000:])
            print(f"[dryrun]   FAILED (log: {err_file})", flush=True)
        else:
            print(r.stdout.strip().splitlines()[-1] if r.stdout.strip()
                  else "[dryrun]   ok", flush=True)
    print(f"[dryrun] done: {len(todo) - len(failures)} ok, "
          f"{len(failures)} failed")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None,
                    choices=[None, "train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="driver mode: subprocess per pending cell")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        drive_all(meshes, [args.arch] if args.arch else None,
                  [args.cell] if args.cell else None, args.out, args.tag)
        return
    assert args.arch and args.cell
    try:
        run_cell(args.arch, args.cell, meshes[0], args.out, args.tag)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
