"""Batched serving driver: prefill a batch of prompts, then decode with the
paper's normalization-free KY token sampler (C1+C2) inside the jitted step.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium \
        --reduced --batch 4 --prompt-len 16 --gen 32 --sampler ky
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import mesh as mesh_lib, steps as steps_lib
from repro.models import transformer as tfm


def generate(cfg, params, prompts, gen_len, sampler="ky", mesh=None,
             features=None, key=None):
    """prompts (B, S0) int32 -> (B, S0+gen_len) tokens (greedy prompt echo +
    sampled continuation).  Returns (tokens, per-step seconds).

    With a `mesh`, prefill and decode run through the sharded step factories
    (params/caches partitioned per launch/sharding.py, executed inside the
    mesh context); without one, both steps are plain single-device jits."""
    if mesh is not None:
        with mesh:
            return _generate(cfg, params, prompts, gen_len, sampler, mesh,
                             features, key)
    return _generate(cfg, params, prompts, gen_len, sampler, None,
                     features, key)


def _generate(cfg, params, prompts, gen_len, sampler, mesh, features, key):
    key = key if key is not None else jax.random.key(0)
    b, s0 = prompts.shape
    batch = {"tokens": prompts}
    if cfg.frontend:
        batch["features"] = features
    total0 = s0 + (cfg.frontend_len if cfg.frontend else 0)

    prefill_fn = steps_lib.make_prefill_step(cfg, mesh)
    if mesh is not None:
        prefill_fn = prefill_fn(batch)  # sharded factory: bind batch specs
    logits, caches = prefill_fn(params, batch)
    caches = tfm.grow_attn_caches(caches, cfg, gen_len)

    serve_fn = steps_lib.make_serve_step(cfg, mesh, sampler=sampler)
    if mesh is not None:
        serve_fn, _ = serve_fn(caches, b)  # bind cache specs + batch
    from repro.models.sampling import sample_tokens

    tok = sample_tokens(logits, key, sampler)[:, None] if sampler != "greedy" \
        else jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [prompts, tok]
    times = []
    for t in range(gen_len - 1):
        key, sub = jax.random.split(key)
        t0 = time.time()
        tok_next, _, caches = serve_fn(
            params, tok, caches, jnp.asarray(total0 + t, jnp.int32), sub
        )
        tok_next.block_until_ready()
        times.append(time.time() - t0)
        tok = tok_next[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1), times


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sampler", default="ky",
                    choices=["ky", "gumbel", "greedy"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    features = None
    if cfg.frontend:
        features = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.frontend_len, tfm.FRONTEND_DIM)
        ), jnp.float32)

    toks, times = generate(cfg, params, prompts, args.gen,
                           sampler=args.sampler, features=features)
    # the first timed step includes jit compile; with --gen too short to
    # leave any steady-state step, report n/a rather than a bogus 0.0
    tput = f"{args.batch / np.mean(times[1:]):.1f} tok/s" \
        if len(times) > 1 else "n/a"
    print(f"[serve] arch={cfg.name} sampler={args.sampler} "
          f"generated {toks.shape} tokens; "
          f"decode throughput {tput} (batch {args.batch})")
    print("[serve] sample row:", np.asarray(toks[0])[: args.prompt_len + 8])
    return toks


if __name__ == "__main__":
    main()
