"""Production mesh construction (harness MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state."""

from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests/examples (e.g. (2, 4) on 8 host devices)."""
    return compat.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod','data') multi-pod, ('data',) single-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: jax.sharding.Mesh) -> str | None:
    """Parameters/optimizer shard over 'data' within a pod (never across
    pods — cross-pod all-gathers would ride the slow DCN every layer)."""
    return "data" if "data" in mesh.axis_names else None


def tp_axis(mesh: jax.sharding.Mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
