"""Admission control and backpressure for the serving engine.

Once the executor's service times are calibrated (measured, not modeled —
see `calibrate.py`), a saturating trace stops being an accounting exercise
and becomes a policy question: which queries do we delay, and which do we
refuse, so the ones we accept still meet their latency promise?  This
module answers it with the two classic mechanisms, both in *simulated*
time so the event loop stays deterministic:

  * a **token bucket** at the front door: tokens refill at `rate_qps` up to
    a burst depth; a query arriving to an empty bucket is *deferred* to the
    simulated instant a token will exist (re-entering the arrival queue,
    competing again) or *shed* outright — `policy` picks, and a deferral
    that would exceed `max_defer_s` past the original arrival sheds anyway,
    because serving a stale answer late is the worst of both.
  * **bounded per-bucket queues**: a query whose bucket already holds
    `queue_limit` pending queries is shed at admission — the queue bound is
    what keeps worst-case latency finite when a burst outruns the workers.

Slice continuations (chain-state carry-over) bypass both mechanisms: their
query was already admitted once, and half-running a posterior helps nobody.

Everything here is pure simulated-time arithmetic on the deterministic
clock — no wall time, no randomness — so shed/defer decisions replay
exactly and the engine's determinism guarantee survives saturation.
"""

from __future__ import annotations

import dataclasses

ADMIT = "admit"
DEFER = "defer"
SHED = "shed"


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Front-door policy.  The defaults disable everything (open
    admission), so an engine without explicit backpressure behaves exactly
    as before this module existed."""

    rate_qps: float | None = None  # token refill rate; None = unlimited
    burst: int = 16  # token bucket depth (and the max burst admitted)
    queue_limit: int | None = None  # max pending queries per bucket
    policy: str = "defer"  # "defer" | "shed" on an empty token bucket
    max_defer_s: float = 0.050  # defer budget past the original arrival

    def __post_init__(self):
        if self.policy not in (DEFER, SHED):
            raise ValueError(f"unknown admission policy {self.policy!r}")
        if self.rate_qps is not None and self.rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {self.rate_qps}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if self.queue_limit is not None and self.queue_limit < 1:
            raise ValueError(
                f"queue_limit must be >= 1, got {self.queue_limit}"
            )


class AdmissionController:
    """Deterministic token-bucket + queue-bound bookkeeping.

    The engine consults `decide()` for every arrival (in nondecreasing
    simulated-arrival order — the refill integrates elapsed time) and
    `queue_full()` before enqueueing into a bucket; counters feed the
    metrics dashboards."""

    def __init__(self, config: AdmissionConfig | None = None):
        self.config = config or AdmissionConfig()
        self.tokens = float(self.config.burst)
        self._last_t = 0.0
        self.defers = 0  # deferral *events* (one query may defer repeatedly)
        self.shed_qids: list[int] = []
        self.shed_tokens = 0  # shed by the token bucket / defer budget
        self.shed_queue = 0  # shed by a full bucket queue
        self.max_queue_depth = 0

    # -- token bucket -------------------------------------------------------

    def _refill(self, t: float) -> None:
        if t > self._last_t:
            self.tokens = min(
                float(self.config.burst),
                self.tokens + (t - self._last_t) * self.config.rate_qps,
            )
            self._last_t = t

    def decide(self, t: float, first_arrival_t: float) -> tuple[str, float]:
        """(ADMIT, t) | (DEFER, retry_t) | (SHED, t) for an arrival at
        simulated time `t` whose original arrival was `first_arrival_t`
        (they differ for a re-arriving deferred query)."""
        cfg = self.config
        if cfg.rate_qps is None:
            return ADMIT, t
        self._refill(t)
        # the 1e-9 tolerance matters: a deferred query retries at the exact
        # instant the refill integral reaches 1.0, and float rounding can
        # land it at 0.999...; without the tolerance it would re-defer by a
        # zero-width wait forever
        if self.tokens >= 1.0 - 1e-9:
            self.tokens -= 1.0
            return ADMIT, t
        retry_t = t + (1.0 - self.tokens) / cfg.rate_qps
        if (
            cfg.policy == SHED
            or retry_t - first_arrival_t > cfg.max_defer_s
            or retry_t <= t  # no representable progress: shed, don't spin
        ):
            self.shed_tokens += 1
            return SHED, t
        self.defers += 1
        return DEFER, retry_t

    # -- bounded queues -----------------------------------------------------

    def queue_full(self, depth: int) -> bool:
        """True if a bucket already holding `depth` queries must shed the
        next one."""
        limit = self.config.queue_limit
        return limit is not None and depth >= limit

    def note_depth(self, depth: int) -> None:
        self.max_queue_depth = max(self.max_queue_depth, depth)

    def record_shed(self, qid: int, by_queue: bool) -> None:
        self.shed_qids.append(qid)
        if by_queue:
            self.shed_queue += 1

    @property
    def sheds(self) -> int:
        return len(self.shed_qids)
