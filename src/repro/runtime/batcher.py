"""Query batching: group, pad, and vmap posterior queries onto programs.

The unit of execution is a *bucket*: every pending query that resolves to
the same compiled program AND the same static execution signature (BN
observed-node set, chain/iteration budget, sampler, backend).  Within a
bucket only per-query *data* varies — evidence values, pin masks,
observation images, PRNG seeds — so the whole microbatch runs as one
`jax.vmap` over one jitted executable: one dispatch answers Q queries.

Buckets are padded up to a fixed ladder of sizes (1, 2, 4, ...) so the jit
cache holds a handful of shapes per bucket signature instead of one per
occupancy; pad lanes replicate query 0 and their results are dropped.

vmap is semantics-preserving in JAX, so a query's draw stream inside a
microbatch is bit-identical to running it alone — asserted by
tests/test_runtime.py, which is what makes batched serving a pure
throughput win, never an answer change.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import kernel_lint
from repro.compile import backend as backend_mod
from repro.core import mrf as mrf_mod
from repro.obs import profile as profile_mod
from repro.obs import tracer
from repro.kernels.bn_gibbs import FUSED_BN_SAMPLERS

PAD_SIZES = (1, 2, 4, 8, 16, 32)


def fused_eligible(
    kind: str, sampler: str, backend: str,
    graph=None, n_chains: int | None = None, shard_width: int = 1,
) -> bool:
    """Whether a bucket's static signature can route onto the fused Pallas
    executables: schedule backend + a sampler the kernels implement (BN:
    lut_ky/exact_ky; MRF: lut_ky).  Eligibility is decided here — per
    bucket, from statics alone — so an engine with `fused=True` serves
    eligible buckets fused and the rest unfused, instead of rejecting
    mixed traffic the way the single-program `run(fused=True)` API does.

    With `graph` and `n_chains` (the `bucket_key` route supplies both),
    eligibility additionally requires the static VMEM estimate to fit the
    budget (`analysis.kernel_lint.fused_fits`): an oversized bucket —
    wide replica × deep chain width — is demoted to the unfused route
    here, on estimate, instead of OOMing on device at dispatch.  The
    verdict is memoized per (ir_key, n_chains, sampler, width, budget),
    so the steady-state per-query cost is a dict hit.

    `shard_width > 1` (a bucket the engine will route sharded) budgets
    the *per-shard* envelope — each device holds its local row slab plus
    two halo rows (MRF) or its owned node slice (BN), not the whole
    model — which is the estimate the shard_map body actually allocates
    under.  (The too-few-devices fallback then runs the full-envelope
    vmap executable; the estimator is upper-ish enough that this only
    matters for models near the budget edge.)"""
    if backend != "schedule":
        return False
    if kind == "bn":
        if sampler not in FUSED_BN_SAMPLERS:
            return False
    elif sampler != "lut_ky":
        return False
    if graph is not None and n_chains is not None:
        return kernel_lint.fused_fits(
            graph, n_chains, sampler, shard_width=shard_width
        )
    return True


@dataclasses.dataclass
class Query:
    """One posterior-sampling request against a registered model.

    `carry` is engine-internal: a slice continuation is the same query
    re-entering the arrival queue with its chain state attached and
    `n_iters` counting the *remaining* sweeps — user-submitted queries
    leave it None."""

    qid: int
    model: str
    evidence: dict | None = None  # BN: {node: value} clamps; MRF: pins
    image: np.ndarray | None = None  # MRF observation image (H, W)
    n_chains: int = 8
    n_iters: int = 40
    burn_in: int = 10  # BN marginal accumulation only; ignored for MRF
    thin: int = 1  # BN marginal accumulation only; ignored for MRF
    sampler: str = "lut_ky"
    seed: int = 0
    arrival_s: float = 0.0
    carry: object = None  # chain state of a slice continuation


@dataclasses.dataclass
class QueryResult:
    """What the engine hands back: the posterior payload plus the timeline
    the simulated clock assigned to this query."""

    qid: int
    model: str
    kind: str  # "bn" | "mrf"
    marginals: np.ndarray | None  # BN: (n, V) streaming marginal estimate
    final_state: np.ndarray  # BN: (B, n) vals; MRF: (B, H, W) labels
    arrival_s: float = 0.0
    start_s: float = 0.0
    finish_s: float = 0.0
    batch_size: int = 1
    carry: object = None  # chain state, when the bucket ran return_state
    # diag.accum.QualitySnapshot.brief() of this lane's accumulator, when
    # the bucket ran with diagnostics (intermediate slices carry the
    # snapshot as-of-that-slice; the final slice's is the query's verdict)
    quality: dict | None = None

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Everything that must be *static* across a microbatch.

    `n_iters` is the sweeps *this dispatch* runs — under slicing that is
    one slice, not the query's whole budget, which is how a long query's
    second slice can share a bucket with another long query that asked for
    a different total.  `resumed` separates fresh buckets (executable
    initializes chains from seeds) from continuation buckets (executable
    resumes carried chain state) — they are different jit programs.
    `fused` routes the bucket through the fused Pallas round kernels
    (bit-exact with unfused, but a different jit program — and a different
    calibration signature, since its service time differs).  `diagnostics`
    threads the streaming quality accumulator through the bucket (also a
    different jit program: the chain-state pytree grows the accumulator
    subtree) — per-lane draw streams stay bit-identical either way."""

    program_key: str
    kind: str
    clamp_nodes: tuple[int, ...]  # BN observed-node set; () for MRF
    has_pins: bool  # MRF: whether pin arrays ride along
    n_chains: int
    n_iters: int
    burn_in: int
    thin: int
    sampler: str
    backend: str
    resumed: bool = False
    fused: bool = False
    diagnostics: bool = False


def bucket_key(
    query: Query, graph, backend: str, slice_iters: int | None = None,
    fused: bool = False, diagnostics: bool = False, shard_width: int = 1,
) -> BucketKey:
    """The bucket a query lands in, derived without compiling anything
    (`graph` is the model's structure-only IR from engine registration).

    MRF execution has no burn-in/thinning concept (it returns final
    states), so those fields are normalized to 0/1 for MRF queries — both
    to make the "ignored" semantics explicit and so queries differing only
    in dead fields share a bucket instead of splintering microbatches.

    With `slice_iters`, a query whose remaining budget exceeds it lands in
    a bucket that runs exactly one slice; the engine re-enqueues the rest
    as a continuation (`query.carry` set, `n_iters` = what remains).

    `fused=True` (the engine config knob) routes *eligible* buckets onto
    the fused Pallas executables (`fused_eligible`); ineligible buckets
    keep the unfused route — never a silent answer change, since fused and
    unfused are bit-exact for every eligible signature.  `shard_width`
    (the engine supplies the slice width when the bucket will route
    sharded) makes the VMEM eligibility check budget the per-shard
    envelope instead of the whole model."""
    if graph.kind == "bn":
        clamp = tuple(sorted(int(k) for k in (query.evidence or {})))
        has_pins = False
        burn_in, thin = query.burn_in, query.thin
    else:
        clamp = ()
        has_pins = bool(query.evidence)
        burn_in, thin = 0, 1
    n_iters = query.n_iters
    if slice_iters is not None:
        n_iters = min(n_iters, slice_iters)
    return BucketKey(
        program_key=graph.ir_key,
        kind=graph.kind,
        clamp_nodes=clamp,
        has_pins=has_pins,
        n_chains=query.n_chains,
        n_iters=n_iters,
        burn_in=burn_in,
        thin=thin,
        sampler=query.sampler,
        backend=backend,
        resumed=query.carry is not None,
        fused=fused and fused_eligible(
            graph.kind, query.sampler, backend,
            graph=graph, n_chains=query.n_chains, shard_width=shard_width,
        ),
        diagnostics=diagnostics,
    )


def pad_size(n: int, sizes=PAD_SIZES) -> int:
    """Next bucket-ladder size >= n.  Beyond the ladder the batch runs at
    its exact occupancy — correct, but each distinct size is its own XLA
    compile, which is why the engine refuses max_batch > max(pad_sizes)."""
    for s in sizes:
        if n <= s:
            return s
    return n


def _seed_array(queries) -> jax.Array:
    """Per-query PRNG seeds, shipped as one uint32 array; the bucket
    executables derive `jax.random.key(seed)` per lane *inside* jit (one
    transfer instead of Q typed-key dispatches, same bits as the
    single-query path creating its key on the host)."""
    return jnp.asarray([q.seed for q in queries], jnp.uint32)


# ---------------------------------------------------------------------------
# vmapped bucket executables (jitted once per bucket signature + pad size)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_chains", "n_iters", "burn_in", "thin", "sampler", "return_state",
        "fused", "interpret",
    ),
    # the stacked carry is built fresh per dispatch (`_stack_carries`), so
    # donating it costs callers nothing and spares the per-slice state copy
    donate_argnames=("carry_q",),
)
def _bn_bucket(
    cbn, groups, ev_vals_q, ev_mask, seeds_q, carry_q, totals_q=None, *,
    n_chains, n_iters, burn_in, thin, sampler, return_state,
    fused=False, interpret=False,
):
    """One vmapped BN microbatch.  `carry_q` is a lane-stacked
    `BNChainState` for a resumed (continuation) bucket — then the seeds are
    dead lanes and chains resume instead of initializing; fresh buckets
    pass carry_q=None.  Either way the per-lane bits equal the single-query
    path with the same carry/seed — fused buckets included (the Pallas
    round kernel vmaps like any other jax computation).

    `totals_q` ((Q,) int32, fresh diagnostics buckets only) carries each
    lane's *total* sweep budget — the accumulator's split point must come
    from the query's whole budget even when this dispatch runs one slice
    of it.  Totals are lane data, so lanes with different budgets share
    the bucket like they always did."""

    def one(ev_vals, seed, carry, diag_total=None):
        return backend_mod.bn_rounds_core(
            cbn, groups, jax.random.key(seed), n_chains=n_chains,
            n_iters=n_iters, burn_in=burn_in, sampler=sampler, thin=thin,
            clamp_vals=ev_vals, clamp_mask=ev_mask,
            carry=carry, return_state=return_state,
            fused=fused, interpret=interpret, diag_total=diag_total,
        )

    if carry_q is None and totals_q is None:
        return jax.vmap(lambda e, s: one(e, s, None))(ev_vals_q, seeds_q)
    if carry_q is None:
        return jax.vmap(
            lambda e, s, t: one(e, s, None, t)
        )(ev_vals_q, seeds_q, totals_q)
    return jax.vmap(one)(ev_vals_q, seeds_q, carry_q)


@functools.partial(
    jax.jit,
    static_argnames=(
        "mrf", "parities", "n_chains", "n_iters", "sampler", "fused",
        "interpret", "eager", "return_state",
    ),
    # see _bn_bucket: the stacked carry is dispatch-local, donate it
    donate_argnames=("carry_q",),
)
def _mrf_bucket(
    mrf, parities, imgs_q, seeds_q, pmask_q, pvals_q, carry_q,
    totals_q=None, *,
    n_chains, n_iters, sampler, fused, interpret, eager, return_state,
):
    def one(img, seed, pm, pv, carry, diag_total=None):
        key = jax.random.key(seed)
        if eager:
            return mrf_mod.mrf_gibbs_loop(
                mrf, img, key, n_chains, n_iters, sampler,
                pin_mask=pm, pin_vals=pv,
                carry=carry, return_state=return_state,
                diag_total=diag_total,
            )
        return backend_mod.mrf_rounds_core(
            mrf, parities, img, key, n_chains=n_chains, n_iters=n_iters,
            sampler=sampler, fused=fused, interpret=interpret,
            pin_mask=pm, pin_vals=pv,
            carry=carry, return_state=return_state,
            diag_total=diag_total,
        )

    if carry_q is None and totals_q is not None:
        if pmask_q is None:
            return jax.vmap(
                lambda i, s, t: one(i, s, None, None, None, t)
            )(imgs_q, seeds_q, totals_q)
        return jax.vmap(
            lambda i, s, pm, pv, t: one(i, s, pm, pv, None, t)
        )(imgs_q, seeds_q, pmask_q, pvals_q, totals_q)
    if pmask_q is None and carry_q is None:
        return jax.vmap(
            lambda i, s: one(i, s, None, None, None)
        )(imgs_q, seeds_q)
    if pmask_q is None:
        return jax.vmap(
            lambda i, s, c: one(i, s, None, None, c)
        )(imgs_q, seeds_q, carry_q)
    if carry_q is None:
        return jax.vmap(
            lambda i, s, pm, pv: one(i, s, pm, pv, None)
        )(imgs_q, seeds_q, pmask_q, pvals_q)
    return jax.vmap(one)(imgs_q, seeds_q, pmask_q, pvals_q, carry_q)


# ---------------------------------------------------------------------------
# bucket execution
# ---------------------------------------------------------------------------


def _stack_carries(padded: list[Query]):
    """Lane-stack the per-query chain states of a resumed bucket (pad lanes
    replicate query 0's state, mirroring the seed/evidence padding)."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[q.carry for q in padded]
    )


def _lane_state(states, i: int):
    """Un-stack lane i of a vmapped chain-state pytree."""
    return jax.tree_util.tree_map(lambda x: x[i], states)


def execute_bucket(
    program,
    key: BucketKey,
    queries: list[Query],
    pad_sizes=PAD_SIZES,
    return_state: bool = False,
) -> list[QueryResult]:
    """Run one microbatch through its program and unpack per-query results.

    Pads the query list up to the bucket ladder (replicating query 0 —
    their lanes compute but are discarded), stacks the per-query runtime
    data, and dispatches a single vmapped executable.

    A `resumed` bucket stacks the queries' carried chain states and resumes
    them instead of seeding fresh chains; `return_state=True` attaches each
    lane's post-run chain state to its `QueryResult.carry`, which is how
    the engine slices long queries (continuous batching).  Both are
    bit-preserving: a lane resumed here equals the same query resumed
    standalone, whatever its batch-mates.

    A `diagnostics` bucket additionally threads the streaming quality
    accumulator through every lane and summarizes it into
    `QueryResult.quality` (the chain state is requested internally either
    way, but only attached to `carry` when the caller asked)."""
    n_real = len(queries)
    n_pad = pad_size(n_real, pad_sizes)
    with tracer.span(
        "execute_bucket", cat="batch",
        kind=key.kind, sampler=key.sampler, fused=key.fused,
        diagnostics=key.diagnostics,
        resumed=key.resumed, n_real=n_real, n_padded=n_pad,
        pad_efficiency=round(n_real / n_pad, 6) if n_pad else 0.0,
        n_iters=key.n_iters, n_chains=key.n_chains,
    ):
        return _execute_bucket(
            program, key, queries, n_real, n_pad, return_state
        )


def _lane_quality(states, i: int, cards=None, free_mask=None) -> dict:
    """Summarize lane i's quality accumulator into the brief scalar dict."""
    from repro.diag import accum as diag_accum

    lane = _lane_state(states, i)
    return diag_accum.summarize(
        lane.quality, cards=cards, free_mask=free_mask
    ).brief()


def _execute_bucket(
    program, key: BucketKey, queries: list[Query],
    n_real: int, n_pad: int, return_state: bool,
) -> list[QueryResult]:
    padded = list(queries) + [queries[0]] * (n_pad - n_real)
    seeds_q = _seed_array(padded)
    carry_q = _stack_carries(padded) if key.resumed else None
    # diagnostics needs the post-run chain state (the accumulator lives
    # there) even when the caller doesn't want the carry back
    run_state = return_state or key.diagnostics
    totals_q = None
    if key.diagnostics and not key.resumed:
        # each lane's accumulator splits at its query's *total* budget —
        # a fresh query's n_iters is that total (the engine rewrites
        # n_iters only on continuation re-enqueues)
        totals_q = jnp.asarray([q.n_iters for q in padded], jnp.int32)
    if key.kind == "bn":
        n = program.ir.n_nodes
        ev_mask = np.zeros(n, bool)
        ev_mask[list(key.clamp_nodes)] = True
        ev_vals = np.zeros((n_pad, n), np.int64)
        for i, q in enumerate(padded):
            for node, val in (q.evidence or {}).items():
                ev_vals[i, int(node)] = int(val)
        groups = program.clamped_executable(key.clamp_nodes, key.backend)
        if key.fused:
            # same first-use guarantee the single-program path gets
            program.ensure_fused_cross_check(key.sampler)
        a = (
            program.cbn, groups, jnp.asarray(ev_vals, jnp.int32),
            jnp.asarray(ev_mask), seeds_q, carry_q, totals_q,
        )
        kw = dict(
            n_chains=key.n_chains, n_iters=key.n_iters, burn_in=key.burn_in,
            thin=key.thin, sampler=key.sampler, return_state=run_state,
            fused=key.fused, interpret=jax.default_backend() != "tpu",
        )
        if profile_mod.enabled():
            profile_mod.capture_bucket(
                program, key, n_pad, _bn_bucket, a, kw,
                model=queries[0].model,
            )
        out = _bn_bucket(*a, **kw)
        marg, vals = out[0], out[1]
        states = out[2] if run_state else None
        marg, vals = np.asarray(marg), np.asarray(vals)
        cards = np.asarray(program.cbn.cards)
        return [
            QueryResult(
                qid=q.qid, model=q.model, kind="bn", marginals=marg[i],
                final_state=vals[i], arrival_s=q.arrival_s,
                batch_size=n_real,
                carry=_lane_state(states, i) if return_state else None,
                quality=_lane_quality(states, i, cards=cards,
                                      free_mask=~ev_mask)
                if key.diagnostics else None,
            )
            for i, q in enumerate(queries)
        ]
    mrf = program.mrf
    imgs = jnp.asarray(
        np.stack([np.asarray(q.image, np.int32) for q in padded])
    )
    pmask_q = pvals_q = None
    if key.has_pins:
        masks, vals = [], []
        for q in padded:
            m, v = backend_mod.pin_arrays(mrf, q.evidence or {})
            masks.append(m)
            vals.append(v)
        pmask_q, pvals_q = jnp.stack(masks), jnp.stack(vals)
    if key.fused:
        # same first-use guarantee the single-program path gets
        program.ensure_fused_cross_check(key.sampler)
    if key.backend == "schedule":
        ex = program.schedule_executable()
        parities, eager = ex.parities, False
    else:
        parities, eager = (0, 1), True
    a = (mrf, parities, imgs, seeds_q, pmask_q, pvals_q, carry_q, totals_q)
    kw = dict(
        n_chains=key.n_chains, n_iters=key.n_iters, sampler=key.sampler,
        fused=key.fused, interpret=jax.default_backend() != "tpu",
        eager=eager, return_state=run_state,
    )
    if profile_mod.enabled():
        profile_mod.capture_bucket(
            program, key, n_pad, _mrf_bucket, a, kw, model=queries[0].model,
        )
    out = _mrf_bucket(*a, **kw)
    labels, states = (out if run_state else (out, None))
    labels = np.asarray(labels)

    def mrf_free(i):
        if pmask_q is None:
            return None
        return ~np.asarray(pmask_q[i]).reshape(-1)

    return [
        QueryResult(
            qid=q.qid, model=q.model, kind="mrf", marginals=None,
            final_state=labels[i], arrival_s=q.arrival_s, batch_size=n_real,
            carry=_lane_state(states, i) if return_state else None,
            quality=_lane_quality(states, i, free_mask=mrf_free(i))
            if key.diagnostics else None,
        )
        for i, q in enumerate(queries)
    ]
