"""Query batching: group, pad, and vmap posterior queries onto programs.

The unit of execution is a *bucket*: every pending query that resolves to
the same compiled program AND the same static execution signature (BN
observed-node set, chain/iteration budget, sampler, backend).  Within a
bucket only per-query *data* varies — evidence values, pin masks,
observation images, PRNG seeds — so the whole microbatch runs as one
`jax.vmap` over one jitted executable: one dispatch answers Q queries.

Buckets are padded up to a fixed ladder of sizes (1, 2, 4, ...) so the jit
cache holds a handful of shapes per bucket signature instead of one per
occupancy; pad lanes replicate query 0 and their results are dropped.

vmap is semantics-preserving in JAX, so a query's draw stream inside a
microbatch is bit-identical to running it alone — asserted by
tests/test_runtime.py, which is what makes batched serving a pure
throughput win, never an answer change.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile import backend as backend_mod
from repro.core import mrf as mrf_mod

PAD_SIZES = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass
class Query:
    """One posterior-sampling request against a registered model."""

    qid: int
    model: str
    evidence: dict | None = None  # BN: {node: value} clamps; MRF: pins
    image: np.ndarray | None = None  # MRF observation image (H, W)
    n_chains: int = 8
    n_iters: int = 40
    burn_in: int = 10  # BN marginal accumulation only; ignored for MRF
    thin: int = 1  # BN marginal accumulation only; ignored for MRF
    sampler: str = "lut_ky"
    seed: int = 0
    arrival_s: float = 0.0


@dataclasses.dataclass
class QueryResult:
    """What the engine hands back: the posterior payload plus the timeline
    the simulated clock assigned to this query."""

    qid: int
    model: str
    kind: str  # "bn" | "mrf"
    marginals: np.ndarray | None  # BN: (n, V) streaming marginal estimate
    final_state: np.ndarray  # BN: (B, n) vals; MRF: (B, H, W) labels
    arrival_s: float = 0.0
    start_s: float = 0.0
    finish_s: float = 0.0
    batch_size: int = 1

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Everything that must be *static* across a microbatch."""

    program_key: str
    kind: str
    clamp_nodes: tuple[int, ...]  # BN observed-node set; () for MRF
    has_pins: bool  # MRF: whether pin arrays ride along
    n_chains: int
    n_iters: int
    burn_in: int
    thin: int
    sampler: str
    backend: str


def bucket_key(query: Query, graph, backend: str) -> BucketKey:
    """The bucket a query lands in, derived without compiling anything
    (`graph` is the model's structure-only IR from engine registration).

    MRF execution has no burn-in/thinning concept (it returns final
    states), so those fields are normalized to 0/1 for MRF queries — both
    to make the "ignored" semantics explicit and so queries differing only
    in dead fields share a bucket instead of splintering microbatches."""
    if graph.kind == "bn":
        clamp = tuple(sorted(int(k) for k in (query.evidence or {})))
        has_pins = False
        burn_in, thin = query.burn_in, query.thin
    else:
        clamp = ()
        has_pins = bool(query.evidence)
        burn_in, thin = 0, 1
    return BucketKey(
        program_key=graph.ir_key,
        kind=graph.kind,
        clamp_nodes=clamp,
        has_pins=has_pins,
        n_chains=query.n_chains,
        n_iters=query.n_iters,
        burn_in=burn_in,
        thin=thin,
        sampler=query.sampler,
        backend=backend,
    )


def pad_size(n: int, sizes=PAD_SIZES) -> int:
    """Next bucket-ladder size >= n.  Beyond the ladder the batch runs at
    its exact occupancy — correct, but each distinct size is its own XLA
    compile, which is why the engine refuses max_batch > max(pad_sizes)."""
    for s in sizes:
        if n <= s:
            return s
    return n


def _seed_array(queries) -> jax.Array:
    """Per-query PRNG seeds, shipped as one uint32 array; the bucket
    executables derive `jax.random.key(seed)` per lane *inside* jit (one
    transfer instead of Q typed-key dispatches, same bits as the
    single-query path creating its key on the host)."""
    return jnp.asarray([q.seed for q in queries], jnp.uint32)


# ---------------------------------------------------------------------------
# vmapped bucket executables (jitted once per bucket signature + pad size)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("n_chains", "n_iters", "burn_in", "thin", "sampler"),
)
def _bn_bucket(
    cbn, groups, ev_vals_q, ev_mask, seeds_q, *,
    n_chains, n_iters, burn_in, thin, sampler,
):
    def one(ev_vals, seed):
        return backend_mod.bn_rounds_core(
            cbn, groups, jax.random.key(seed), n_chains=n_chains,
            n_iters=n_iters, burn_in=burn_in, sampler=sampler, thin=thin,
            clamp_vals=ev_vals, clamp_mask=ev_mask,
        )

    return jax.vmap(one)(ev_vals_q, seeds_q)


@functools.partial(
    jax.jit,
    static_argnames=(
        "mrf", "parities", "n_chains", "n_iters", "sampler", "fused",
        "interpret", "eager",
    ),
)
def _mrf_bucket(
    mrf, parities, imgs_q, seeds_q, pmask_q, pvals_q, *,
    n_chains, n_iters, sampler, fused, interpret, eager,
):
    def one(img, seed, pm, pv):
        key = jax.random.key(seed)
        if eager:
            return mrf_mod.mrf_gibbs_loop(
                mrf, img, key, n_chains, n_iters, sampler,
                pin_mask=pm, pin_vals=pv,
            )
        return backend_mod.mrf_rounds_core(
            mrf, parities, img, key, n_chains=n_chains, n_iters=n_iters,
            sampler=sampler, fused=fused, interpret=interpret,
            pin_mask=pm, pin_vals=pv,
        )

    if pmask_q is None:
        return jax.vmap(lambda i, s: one(i, s, None, None))(imgs_q, seeds_q)
    return jax.vmap(one)(imgs_q, seeds_q, pmask_q, pvals_q)


# ---------------------------------------------------------------------------
# bucket execution
# ---------------------------------------------------------------------------


def execute_bucket(
    program, key: BucketKey, queries: list[Query], pad_sizes=PAD_SIZES
) -> list[QueryResult]:
    """Run one microbatch through its program and unpack per-query results.

    Pads the query list up to the bucket ladder (replicating query 0 —
    their lanes compute but are discarded), stacks the per-query runtime
    data, and dispatches a single vmapped executable."""
    n_real = len(queries)
    n_pad = pad_size(n_real, pad_sizes)
    padded = list(queries) + [queries[0]] * (n_pad - n_real)
    seeds_q = _seed_array(padded)
    if key.kind == "bn":
        n = program.ir.n_nodes
        ev_mask = np.zeros(n, bool)
        ev_mask[list(key.clamp_nodes)] = True
        ev_vals = np.zeros((n_pad, n), np.int64)
        for i, q in enumerate(padded):
            for node, val in (q.evidence or {}).items():
                ev_vals[i, int(node)] = int(val)
        groups = program.clamped_executable(key.clamp_nodes, key.backend)
        marg, vals = _bn_bucket(
            program.cbn, groups, jnp.asarray(ev_vals, jnp.int32),
            jnp.asarray(ev_mask), seeds_q,
            n_chains=key.n_chains, n_iters=key.n_iters, burn_in=key.burn_in,
            thin=key.thin, sampler=key.sampler,
        )
        marg, vals = np.asarray(marg), np.asarray(vals)
        return [
            QueryResult(
                qid=q.qid, model=q.model, kind="bn", marginals=marg[i],
                final_state=vals[i], arrival_s=q.arrival_s,
                batch_size=n_real,
            )
            for i, q in enumerate(queries)
        ]
    mrf = program.mrf
    imgs = jnp.asarray(
        np.stack([np.asarray(q.image, np.int32) for q in padded])
    )
    pmask_q = pvals_q = None
    if key.has_pins:
        masks, vals = [], []
        for q in padded:
            m, v = backend_mod.pin_arrays(mrf, q.evidence or {})
            masks.append(m)
            vals.append(v)
        pmask_q, pvals_q = jnp.stack(masks), jnp.stack(vals)
    if key.backend == "schedule":
        ex = program.schedule_executable()
        parities, eager = ex.parities, False
    else:
        parities, eager = (0, 1), True
    labels = _mrf_bucket(
        mrf, parities, imgs, seeds_q, pmask_q, pvals_q,
        n_chains=key.n_chains, n_iters=key.n_iters, sampler=key.sampler,
        fused=False, interpret=jax.default_backend() != "tpu", eager=eager,
    )
    labels = np.asarray(labels)
    return [
        QueryResult(
            qid=q.qid, model=q.model, kind="mrf", marginals=None,
            final_state=labels[i], arrival_s=q.arrival_s, batch_size=n_real,
        )
        for i, q in enumerate(queries)
    ]
