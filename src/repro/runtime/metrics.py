"""Serving metrics for the runtime engine: latency percentiles, throughput,
per-worker utilization, backpressure counters, and the cache behavior that
makes or breaks a sampling-as-a-service box.

Latency/throughput numbers are in *simulated* seconds (the engine's
deterministic clock — same trace, same numbers, every run, which is what
the tests pin down); `wall_s` is the only wall-clock field the determinism
comparisons must skip — `measured_s` on batch records (real dispatch wall
time, kept for calibration-error reporting) never enters the summary
except through `calib_median_err`, which is advisory.  Cache counters are
deltas over the engine run, not process-lifetime totals, so one summary
describes one trace.

Percentiles are honest about tiny samples: p50/p95 of 0 or 1 observations
is reported as None (rendered "n/a"), never a fabricated number.

Key reference (summary dict; all sim-clock unless noted):

  =================  ======================================================
  latency_p50/p95_s  exact percentiles over per-query latencies
                     (``percentile()`` — None below 2 samples)
  latency_p99_s      *histogram-derived*: upper bucket bound from the
                     ``query_latency_s`` series (conservative; None below
                     2 observations, same refusal as ``percentile()``)
  trace_dropped      ring-buffer overflow count for this run when tracing
                     was on (0 = full attribution coverage; nonzero emits
                     an ``obs-trace-dropped`` warning finding)
  calib_median_err   advisory, wall-derived — excluded from determinism
                     comparisons along with wall_s
  series             ``obs.timeseries.SeriesRegistry`` — queue_depth /
                     pad_efficiency / worker_stall_s / bucket_service_s /
                     query_latency_s sampled on the sim clock
  =================  ======================================================
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.compile import cache_stats
from repro.obs import timeseries


@dataclasses.dataclass
class BatchRecord:
    model: str
    kind: str
    n_real: int
    n_padded: int
    service_s: float  # predicted (simulated) service time
    clamp_lowerings: int
    worker: int = 0  # first worker of the dispatch's slice
    n_workers: int = 1  # slice width (1 = plain vmap dispatch)
    route: str = "vmap"  # "vmap" | "sharded"
    start_s: float = 0.0
    finish_s: float = 0.0
    measured_s: float = 0.0  # real dispatch wall time (never drives the sim)
    service_src: str = "line"  # "measured" | "line"


def percentile(samples, q) -> float | None:
    """np.percentile that refuses to invent statistics: fewer than two
    samples has no distribution to summarize, so report None ("n/a")."""
    if len(samples) < 2:
        return None
    return float(np.percentile(np.asarray(samples), q))


def fmt_ms(seconds: float | None) -> str:
    return "n/a" if seconds is None else f"{seconds * 1e3:.2f}ms"


class RuntimeMetrics:
    """Accumulates per-query and per-batch records during an engine run."""

    def __init__(self):
        self.query_records: list = []  # QueryResult, finalized
        self.batch_records: list[BatchRecord] = []
        self._cache0 = dict(cache_stats())
        self._cache_frozen: dict | None = None
        self.wall_s = 0.0
        # executor + admission state, installed by the engine at end-of-run
        self.worker_busy_s: tuple[float, ...] = (0.0,)
        # per-worker idle-while-work-waited time (the flush-window stall):
        # the slice of a worker's idle gap during which its next batch's
        # oldest query had already arrived — idle *blocked on batching*,
        # as opposed to idle with nothing to serve
        self.worker_stall_s: tuple[float, ...] = (0.0,)
        self.sheds = 0
        self.shed_tokens = 0
        self.shed_queue = 0
        self.defers = 0
        self.max_queue_depth = 0
        # sim-clock time series (always on; pure python, deterministic)
        self.series = timeseries.SeriesRegistry()
        # tracer ring-buffer overflow during this run (0 when tracing off)
        self.trace_dropped = 0

    def record_batch(self, rec: BatchRecord) -> None:
        self.batch_records.append(rec)

    def record_queries(self, results) -> None:
        self.query_records.extend(results)

    def finalize(self) -> None:
        """Freeze the cache delta at end-of-run (the engine calls this):
        cache counters are process-global, so a summary computed later —
        after other engines or baselines have run — must not absorb their
        traffic."""
        self._cache_frozen = self.cache_delta()

    def cache_delta(self) -> dict:
        if self._cache_frozen is not None:
            return dict(self._cache_frozen)
        now = cache_stats()
        delta = {
            k: now[k] - self._cache0[k]
            for k in ("hits", "misses", "evictions")
        }
        delta["size"] = now["size"]
        delta["capacity"] = now["capacity"]
        total = delta["hits"] + delta["misses"]
        delta["hit_rate"] = delta["hits"] / total if total else 0.0
        return delta

    def summary(self) -> dict:
        lat = [r.latency_s for r in self.query_records]
        cache = self.cache_delta()
        clamp_lowerings = sum(b.clamp_lowerings for b in self.batch_records)
        finish = max((r.finish_s for r in self.query_records), default=0.0)
        n = len(self.query_records)
        p50 = percentile(lat, 50)
        p95 = percentile(lat, 95)
        util = tuple(
            round(b / finish, 6) if finish else 0.0
            for b in self.worker_busy_s
        )
        stall = tuple(
            round(s / finish, 6) if finish else 0.0
            for s in self.worker_stall_s
        )
        # advisory calibration error: |predicted - measured| / measured over
        # dispatches served from the measured table (wall noise — excluded
        # from determinism comparisons along with wall_s)
        errs = [
            abs(b.service_s - b.measured_s) / b.measured_s
            for b in self.batch_records
            if b.service_src == "measured" and b.measured_s > 0
        ]
        submitted = n + self.sheds
        # quality roll-ups over served queries that carried a diagnostics
        # brief (engine diagnostics=True); None when diagnostics were off
        # or every brief was degenerate
        qual = [r.quality for r in self.query_records
                if getattr(r, "quality", None)]
        rhats = [q["rhat_max"] for q in qual if q.get("rhat_max") is not None]
        esses = [q["ess_min"] for q in qual if q.get("ess_min") is not None]
        return {
            "n_queries": n,
            "n_batches": len(self.batch_records),
            # like the percentiles, honest about the degenerate case: with
            # zero dispatched batches there is no mean batch size to report
            "mean_batch": (
                n / len(self.batch_records) if self.batch_records else None
            ),
            "pad_efficiency": (
                sum(b.n_real for b in self.batch_records)
                / max(sum(b.n_padded for b in self.batch_records), 1)
            ),
            # latencies stay in seconds end to end; `table()` formats once
            # at the edge (the old *_ms keys were converted twice)
            "latency_p50_s": p50,
            "latency_p95_s": p95,
            # histogram-derived (bucket upper bound): conservative, and
            # like percentile() it refuses below 2 observations
            "latency_p99_s": (
                self.series.histogram("query_latency_s").quantile(99)
            ),
            "latency_mean_s": float(np.mean(lat)) if n else None,
            "sim_elapsed_s": finish,
            "throughput_qps": n / finish if finish else 0.0,
            "n_workers": len(self.worker_busy_s),
            "worker_util": util,
            "worker_stall_frac": stall,
            "sharded_batches": sum(
                1 for b in self.batch_records if b.route == "sharded"
            ),
            "sheds": self.sheds,
            "shed_tokens": self.shed_tokens,
            "shed_queue": self.shed_queue,
            "shed_rate": self.sheds / submitted if submitted else 0.0,
            "defers": self.defers,
            "max_queue_depth": self.max_queue_depth,
            "calib_median_err": (
                float(np.median(errs)) if errs else None
            ),
            "calibrated_batches": len(errs),
            "cache_hits": cache["hits"],
            "cache_misses": cache["misses"],
            "cache_evictions": cache["evictions"],
            "cache_size": cache["size"],
            "cache_capacity": cache["capacity"],
            "cache_hit_rate": cache["hit_rate"],
            "recompiles": cache["misses"] + clamp_lowerings,
            "clamp_lowerings": clamp_lowerings,
            "quality_queries": len(qual),
            "rhat_max": float(max(rhats)) if rhats else None,
            "ess_min": float(min(esses)) if esses else None,
            "trace_dropped": self.trace_dropped,
            "wall_s": self.wall_s,
        }

    def table(self) -> str:
        """Render the summary as the runtime dashboard block."""
        s = self.summary()
        util = "/".join(f"{u:.2f}" for u in s["worker_util"])
        stall = "/".join(f"{u:.2f}" for u in s["worker_stall_frac"])
        mean_batch = (
            "n/a" if s["mean_batch"] is None else f"{s['mean_batch']:.2f}"
        )
        rhat = "n/a" if s["rhat_max"] is None else f"{s['rhat_max']:.3f}"
        ess = "n/a" if s["ess_min"] is None else f"{s['ess_min']:.0f}"
        rows = [
            "| queries | batches | mean batch | pad eff | p50 | p95 | p99 | "
            "sim qps | workers (util) | stall | shed | defer | maxq | "
            "hit rate | evict | recompiles | rhat max | ess min | dropped | "
            "wall |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"
            "---|---|---|---|---|",
            (
                f"| {s['n_queries']} | {s['n_batches']} "
                f"| {mean_batch} | {s['pad_efficiency']:.2f} "
                f"| {fmt_ms(s['latency_p50_s'])} "
                f"| {fmt_ms(s['latency_p95_s'])} "
                f"| {fmt_ms(s['latency_p99_s'])} "
                f"| {s['throughput_qps']:.1f} "
                f"| {s['n_workers']} ({util}) | {stall} "
                f"| {s['sheds']} | {s['defers']} | {s['max_queue_depth']} "
                f"| {s['cache_hit_rate']:.3f} "
                f"| {s['cache_evictions']} | {s['recompiles']} "
                f"| {rhat} | {ess} "
                f"| {s['trace_dropped']} "
                f"| {s['wall_s']:.2f}s |"
            ),
        ]
        return "\n".join(rows)
