"""Serving metrics for the runtime engine: latency percentiles, throughput,
and the cache behavior that makes or breaks a sampling-as-a-service box.

Latency/throughput numbers are in *simulated* seconds (the engine's
deterministic clock — same trace, same numbers, every run, which is what
the tests pin down); `wall_s` is the only wall-clock field and is excluded
from determinism comparisons.  Cache counters are deltas over the engine
run, not process-lifetime totals, so one summary describes one trace.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.compile import cache_stats


@dataclasses.dataclass
class BatchRecord:
    model: str
    kind: str
    n_real: int
    n_padded: int
    service_s: float
    clamp_lowerings: int


class RuntimeMetrics:
    """Accumulates per-query and per-batch records during an engine run."""

    def __init__(self):
        self.query_records: list = []  # QueryResult, finalized
        self.batch_records: list[BatchRecord] = []
        self._cache0 = dict(cache_stats())
        self._cache_frozen: dict | None = None
        self.wall_s = 0.0

    def record_batch(self, rec: BatchRecord) -> None:
        self.batch_records.append(rec)

    def record_queries(self, results) -> None:
        self.query_records.extend(results)

    def finalize(self) -> None:
        """Freeze the cache delta at end-of-run (the engine calls this):
        cache counters are process-global, so a summary computed later —
        after other engines or baselines have run — must not absorb their
        traffic."""
        self._cache_frozen = self.cache_delta()

    def cache_delta(self) -> dict:
        if self._cache_frozen is not None:
            return dict(self._cache_frozen)
        now = cache_stats()
        delta = {
            k: now[k] - self._cache0[k]
            for k in ("hits", "misses", "evictions")
        }
        delta["size"] = now["size"]
        delta["capacity"] = now["capacity"]
        total = delta["hits"] + delta["misses"]
        delta["hit_rate"] = delta["hits"] / total if total else 0.0
        return delta

    def summary(self) -> dict:
        lat = np.array([r.latency_s for r in self.query_records])
        cache = self.cache_delta()
        clamp_lowerings = sum(b.clamp_lowerings for b in self.batch_records)
        finish = max((r.finish_s for r in self.query_records), default=0.0)
        n = len(self.query_records)
        return {
            "n_queries": n,
            "n_batches": len(self.batch_records),
            "mean_batch": n / max(len(self.batch_records), 1),
            "pad_efficiency": (
                sum(b.n_real for b in self.batch_records)
                / max(sum(b.n_padded for b in self.batch_records), 1)
            ),
            "latency_p50_ms": float(np.percentile(lat, 50)) * 1e3 if n else 0.0,
            "latency_p95_ms": float(np.percentile(lat, 95)) * 1e3 if n else 0.0,
            "latency_mean_ms": float(lat.mean()) * 1e3 if n else 0.0,
            "sim_elapsed_s": finish,
            "throughput_qps": n / finish if finish else 0.0,
            "cache_hits": cache["hits"],
            "cache_misses": cache["misses"],
            "cache_evictions": cache["evictions"],
            "cache_size": cache["size"],
            "cache_capacity": cache["capacity"],
            "cache_hit_rate": cache["hit_rate"],
            "recompiles": cache["misses"] + clamp_lowerings,
            "clamp_lowerings": clamp_lowerings,
            "wall_s": self.wall_s,
        }

    def table(self) -> str:
        """Render the summary as the runtime dashboard block."""
        s = self.summary()
        rows = [
            "| queries | batches | mean batch | pad eff | p50 | p95 | "
            "sim qps | hit rate | evict | recompiles | wall |",
            "|---|---|---|---|---|---|---|---|---|---|---|",
            (
                f"| {s['n_queries']} | {s['n_batches']} "
                f"| {s['mean_batch']:.2f} | {s['pad_efficiency']:.2f} "
                f"| {s['latency_p50_ms']:.2f}ms | {s['latency_p95_ms']:.2f}ms "
                f"| {s['throughput_qps']:.1f} | {s['cache_hit_rate']:.3f} "
                f"| {s['cache_evictions']} | {s['recompiles']} "
                f"| {s['wall_s']:.2f}s |"
            ),
        ]
        return "\n".join(rows)
