"""The multi-worker executor: sharded dispatch over a simulated worker pool.

This is the host-RISC-V half of the AIA posture the runtime had been
missing: the chip paper's host core exists to *distribute* sampling work
across the mesh (and, in the companion multi-chip work, across chips), but
PR 3's engine dispatched every microbatch on one serial executor.  Here the
engine hands every flushed bucket to a `WorkerPool` of W simulated workers:

  * each worker is a device (or, for wide dispatches, one lane of a mesh
    slice) with a **busy-until clock**; a dispatch starts at
    `max(flush time, worker free time)` and occupies the worker for its
    predicted service time, so the deterministic event loop overlaps
    service across workers while the host-side real execution stays
    single-threaded and replayable;
  * **large MRF buckets route to `run_sharded`** across a mesh slice of
    `shard_width` workers (the multi-chip analogue: compute cycles split
    over the slice, comm cycles do not), occupying every worker in the
    slice; small buckets take the one-device vmap route exactly as before.
    When the process actually has >= shard_width JAX devices the sharded
    route really executes through `CompiledProgram.run_sharded`; otherwise
    the math falls back to the vmap executable while the *clock* still
    models the slice — route choice is config-deterministic, never
    machine-probed at dispatch time.  A **fused** sharded bucket inherits
    the whole Pallas datapath: one shard_map body runs the fused color-
    round kernels with the named collectives between them, bit-exact with
    the vmap fused executable, so slicing (chain-state carry) and the
    diagnostics accumulator ride the sharded route first-class — no label
    demotion, the `BucketKey` a dispatch executes under is the bucket's.

Service times come from the engine's `Calibrator` (measured when warm, the
line model cold); the wall time of every real dispatch is recorded next to
the prediction so the dashboards can report calibration error without the
simulated clock ever reading a wall clock.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compat
from repro.obs import profile as profile_mod
from repro.obs import tracer
from repro.runtime import batcher as batcher_mod
from repro.runtime import calibrate as calibrate_mod
from repro.runtime.batcher import BucketKey, Query, QueryResult
from repro.runtime.metrics import BatchRecord


@dataclasses.dataclass(frozen=True)
class ExecutorConfig:
    """Worker-pool shape.  The defaults (one worker, sharded route off)
    reproduce the single-serial-executor engine exactly."""

    n_workers: int = 1
    shard_width: int = 1  # mesh-slice width for sharded MRF dispatches
    shard_min_sites: int | None = None  # route grids >= this; None = never

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.shard_width < 1:
            raise ValueError(
                f"shard_width must be >= 1, got {self.shard_width}"
            )
        if self.shard_min_sites is not None and (
            self.shard_width < 2 or self.shard_width > self.n_workers
        ):
            raise ValueError(
                "the sharded route needs 2 <= shard_width <= n_workers "
                f"(got shard_width={self.shard_width}, "
                f"n_workers={self.n_workers})"
            )


class WorkerPool:
    """W busy-until clocks + per-worker busy-time accounting."""

    def __init__(self, n_workers: int):
        self.busy_until = [0.0] * n_workers
        self.busy_s = [0.0] * n_workers
        # idle-while-work-waited: the part of each worker's idle gap during
        # which its next batch's oldest query had already arrived (idle
        # blocked on the flush window / batching, not on arrivals)
        self.stall_s = [0.0] * n_workers

    @property
    def n_workers(self) -> int:
        return len(self.busy_until)

    def earliest_free(self) -> float:
        """When the next worker frees up.  The engine gates flushes on this:
        a bucket keeps accumulating queries while every worker is busy
        (adaptive batching — the batch grows exactly while it cannot run
        anyway), which with one worker reproduces the serial engine's
        flush cadence."""
        return min(self.busy_until)

    def assign(self, clock: float, width: int = 1) -> tuple[tuple[int, ...],
                                                            float]:
        """Pick the slice of `width` contiguous, slice-aligned workers that
        can start earliest (ties to the lowest index — fully deterministic).
        Returns (worker ids, start time)."""
        n = self.n_workers
        assert 1 <= width <= n
        best = None
        for w0 in range(0, n - width + 1, width):
            workers = tuple(range(w0, w0 + width))
            free = max(self.busy_until[w] for w in workers)
            if best is None or free < best[1]:
                best = (workers, free)
        workers, free = best
        return workers, max(clock, free)

    def commit(self, workers: tuple[int, ...], start: float, finish: float,
               ready_t: float = float("inf")) -> None:
        """Book a dispatch.  `ready_t` is when this batch's oldest query
        arrived: any idle between `max(free, ready_t)` and `start` is time
        the worker sat free *while this work waited* — stall charged to the
        flush window, not to the arrival process."""
        for w in workers:
            self.stall_s[w] += max(
                0.0, start - max(self.busy_until[w], ready_t)
            )
            self.busy_until[w] = finish
            self.busy_s[w] += finish - start


class Executor:
    """Routes flushed buckets onto the pool and runs them for real.

    One instance per engine run (the pool clocks are run-scoped).  The
    `calibrator` is shared across runs — that is the point of it."""

    def __init__(
        self,
        config: ExecutorConfig,
        calibrator: calibrate_mod.Calibrator,
        pad_sizes,
    ):
        self.config = config
        self.calibrator = calibrator
        self.pad_sizes = tuple(pad_sizes)
        self.pool = WorkerPool(config.n_workers)
        self._mesh = None
        self._mesh_probed = False
        self._rounds_emitted: set[str] = set()  # programs with round_cost out

    # -- routing ------------------------------------------------------------

    def route(self, program, key: BucketKey) -> str:
        """"sharded" | "vmap", from config + bucket statics alone (never
        from device availability — the simulated clock must not depend on
        the machine it replays on)."""
        cfg = self.config
        if (
            cfg.shard_min_sites is not None
            and key.kind == "mrf"
            and not key.has_pins
            # a resumed bucket stays sharded only when fused — the fused
            # shard_map body carries chain state bit-exactly; the legacy
            # sharded engines fold keys per device and carry nothing
            and (key.fused or not key.resumed)
            and program.mrf.height * program.mrf.width >= cfg.shard_min_sites
            and program.mrf.height % cfg.shard_width == 0
        ):
            return "sharded"
        return "vmap"

    def _shard_mesh(self):
        """A (1, shard_width) ("data", "model") mesh over real devices, or
        None when the process has too few — probed once, lazily."""
        if not self._mesh_probed:
            self._mesh_probed = True
            if len(jax.devices()) >= self.config.shard_width:
                self._mesh = compat.make_mesh(
                    (1, self.config.shard_width), ("data", "model")
                )
        return self._mesh

    # -- dispatch -----------------------------------------------------------

    def batch_route(self, program, key: BucketKey, qs: list[Query]) -> str:
        """The route this specific batch takes: the bucket's static route,
        demoted to vmap when any query continues past this slice on a
        *non-fused* sharded bucket — the legacy sharded engines cannot
        return chain state and a continuation must never silently restart.
        Fused sharded buckets carry state bit-exactly, so they keep the
        route through every slice."""
        route = self.route(program, key)
        if (route == "sharded" and not key.fused
                and any(q.n_iters > key.n_iters for q in qs)):
            route = "vmap"
        return route

    def execute(
        self,
        program,
        key: BucketKey,
        qs: list[Query],
        route: str,
        return_state: bool = False,
    ) -> list[QueryResult]:
        """Real execution only (no pool booking): the path `dispatch` runs
        and `Engine.calibrate`'s timed warmup re-runs, so warmup measures
        exactly what serving will pay — sharded route included."""
        if route == "sharded" and self._shard_mesh() is not None:
            return self._run_sharded(program, key, qs, return_state)
        return batcher_mod.execute_bucket(
            program, key, qs, self.pad_sizes, return_state=return_state
        )

    def dispatch(
        self,
        program,
        key: BucketKey,
        qs: list[Query],
        clock: float,
        return_state: bool = False,
    ) -> tuple[list[QueryResult], BatchRecord]:
        """Execute one microbatch and place it on the pool's timeline.

        Real execution happens now (host order = flush order, replayable);
        the simulated start/finish come from the chosen workers' busy-until
        clocks and the calibrated service prediction."""
        cfg = self.config
        route = self.batch_route(program, key, qs)
        width = cfg.shard_width if route == "sharded" else 1
        lower0 = program.clamp_lowerings
        # measured_s feeds the calibrator; it is real time by design
        wall0 = time.perf_counter()  # lint: allow[wallclock-in-sim]
        batch = self.execute(program, key, qs, route, return_state)
        measured_s = time.perf_counter() - wall0  # lint: allow[wallclock-in-sim]
        n_padded = batcher_mod.pad_size(len(qs), self.pad_sizes)
        service_s, service_src = self.calibrator.predict(
            program, calibrate_mod.sig_of(key, route), n_padded,
            shard_width=width,
        )
        ready_t = min(q.arrival_s for q in qs)
        workers, start = self.pool.assign(clock, width)
        finish = start + service_s
        self.pool.commit(workers, start, finish, ready_t=ready_t)
        for r in batch:
            r.start_s = start
            r.finish_s = finish
        if tracer.enabled():
            self._trace_dispatch(
                program, key, qs, route, workers, start, finish,
                n_padded=n_padded, service_s=service_s,
                service_src=service_src, measured_s=measured_s,
            )
        rec = BatchRecord(
            model=qs[0].model, kind=key.kind, n_real=len(qs),
            n_padded=n_padded, service_s=service_s,
            clamp_lowerings=program.clamp_lowerings - lower0,
            worker=workers[0], n_workers=len(workers), route=route,
            start_s=start, finish_s=finish, measured_s=measured_s,
            service_src=service_src,
        )
        return batch, rec

    # -- tracing ------------------------------------------------------------

    def _emit_round_costs(self, program) -> None:
        """Once per program: one `round_cost` instant per schedule round —
        the static cost model attribution joins dispatches against.
        Emitted here (not at compile time) so cache-hit programs still get
        coverage in every traced run."""
        pkey = program.program_key
        if pkey in self._rounds_emitted:
            return
        self._rounds_emitted.add(pkey)
        sched = program.schedule
        n_cores = (
            program.placement.mesh_shape[0] * program.placement.mesh_shape[1]
        )
        for idx, r in enumerate(sched.rounds):
            mech = r.comm[0].mechanism if r.comm else None
            tracer.instant(
                "round_cost", cat="cost",
                program=pkey, round=idx, color=int(r.color),
                n_nodes=len(r.nodes),
                compute_cycles=int(r.compute_cycles(n_cores)),
                comm_cycles=int(r.comm_cycles()),
                mechanism=mech,
                n_comm_ops=len(r.comm),
                comm_bytes=int(sum(op.n_bytes for op in r.comm)),
            )

    def _trace_dispatch(
        self, program, key: BucketKey, qs: list[Query], route: str,
        workers: tuple[int, ...], start: float, finish: float, *,
        n_padded: int, service_s: float, service_src: str, measured_s: float,
    ) -> None:
        """One `dispatch` sim-span on the slice's first worker lane (the
        span attribution counts), plus `dispatch_lane` spans on the rest of
        the slice so the timeline shows every occupied worker without
        double-counting the dispatch."""
        self._emit_round_costs(program)
        args = dict(
            model=qs[0].model, kind=key.kind, route=route,
            sampler=key.sampler, fused=key.fused,
            n_real=len(qs), n_padded=n_padded,
            pad_efficiency=round(len(qs) / n_padded, 6) if n_padded else 0.0,
            n_iters=key.n_iters, n_chains=key.n_chains,
            resumed=key.resumed, program=program.program_key,
            service_s=service_s, service_src=service_src,
            # joins the span against obs.profile's cached static costs;
            # pure string math, stamped whether or not profiling is on.
            # Sharded dispatches stamp the route-qualified signature the
            # shard_map capture registers under, so they attribute too.
            profile_sig=self._profile_sig(key, n_padded, route),
        )
        tracer.sim_span(
            "dispatch", start, finish, cat="runtime",
            track=f"worker{workers[0]}",
            wargs={"measured_s": measured_s}, **args,
        )
        for w in workers[1:]:
            tracer.sim_span(
                "dispatch_lane", start, finish, cat="runtime",
                track=f"worker{w}", model=qs[0].model, route=route,
                lead_worker=workers[0],
            )

    def _profile_sig(self, key: BucketKey, n_padded: int, route: str) -> str:
        width = self.config.shard_width if route == "sharded" else 1
        return profile_mod.bucket_signature(
            key, n_padded, route=route, shard_width=width
        )

    def _run_sharded(
        self, program, key: BucketKey, qs: list[Query],
        return_state: bool = False,
    ) -> list[QueryResult]:
        """The real sharded route: each query's grid rows split over the
        mesh slice via the `core/distributed.py` engines (pins never route
        here).

        Fused buckets run the one-shard_map-body fused engine — the same
        Pallas datapath as the vmap route, bit-exact with it (asserted at
        first sharded-fused use), so chain-state carries and the quality
        accumulator cross the route boundary freely.  Non-fused buckets
        keep the legacy engines, whose per-device key folding legitimately
        draws different bits — the route is part of the engine config, not
        a hidden fallback."""
        mesh = self._shard_mesh()
        if not key.fused:
            out = []
            for q in qs:
                labels = program.run_sharded(
                    jax.random.key(q.seed), mesh,
                    n_chains=key.n_chains, n_iters=key.n_iters,
                    sampler=key.sampler,
                    evidence=jnp.asarray(np.asarray(q.image, np.int32)),
                    backend=key.backend,
                )
                out.append(QueryResult(
                    qid=q.qid, model=q.model, kind="mrf", marginals=None,
                    final_state=np.asarray(labels), arrival_s=q.arrival_s,
                    batch_size=len(qs),
                ))
            return out
        from repro.core import distributed as dist_mod
        from repro.diag import accum as diag_accum

        program.ensure_fused_cross_check(key.sampler, sharded=True)
        run_state = return_state or key.diagnostics
        profile_sig = None
        if profile_mod.enabled():
            n_padded = batcher_mod.pad_size(len(qs), self.pad_sizes)
            profile_sig = self._profile_sig(key, n_padded, "sharded")
        out = []
        for q in qs:
            diag_total = None
            if key.diagnostics and not key.resumed:
                # the accumulator splits at the query's *total* budget even
                # when this dispatch runs one slice of it (mirrors the vmap
                # bucket executables' totals_q lanes)
                diag_total = jnp.asarray(q.n_iters, jnp.int32)
            res = dist_mod.run_program_sharded(
                program,
                None if key.resumed else jax.random.key(q.seed), mesh,
                n_chains=key.n_chains, n_iters=key.n_iters,
                sampler=key.sampler,
                evidence=jnp.asarray(np.asarray(q.image, np.int32)),
                backend=key.backend, fused=True,
                carry=q.carry, return_state=run_state,
                diag_total=diag_total, profile_sig=profile_sig,
            )
            state = None
            if run_state:
                labels, state = res
            else:
                labels = res
            quality = None
            if key.diagnostics:
                quality = diag_accum.summarize(state.quality).brief()
            out.append(QueryResult(
                qid=q.qid, model=q.model, kind="mrf", marginals=None,
                final_state=np.asarray(labels), arrival_s=q.arrival_s,
                batch_size=len(qs),
                carry=state if return_state else None,
                quality=quality,
            ))
        return out
