"""Trace-replay CLI for the serving runtime.

    PYTHONPATH=src python -m repro.runtime --trace zipf --quick
    PYTHONPATH=src python -m repro.runtime --trace bursty --quick --workers 4

Replays a synthetic query trace through the engine and prints the serving
dashboard (latency percentiles in simulated time, throughput, per-worker
utilization, shed/defer counters, cache and recompile behavior).  CI runs
the quick Zipf replay and a 4-worker bursty replay (admission control
enabled) as smoke jobs.
"""

from __future__ import annotations

import argparse
import json
import os

from repro import obs
from repro.launch.report import attribution_table, profile_table
from repro.obs import attrib as attrib_mod
from repro.obs import export as export_mod
from repro.obs import profile as profile_mod
from repro.runtime.admission import AdmissionConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.trace import TRACES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.runtime")
    ap.add_argument("--trace", default="zipf", choices=sorted(TRACES),
                    help="trace family to replay")
    ap.add_argument("--quick", action="store_true",
                    help="small budgets (CI smoke)")
    ap.add_argument("--queries", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="schedule",
                    choices=["schedule", "eager"],
                    help="execution backend (schedule is the global "
                         "default; eager is the escape hatch)")
    ap.add_argument("--fused", action="store_true",
                    help="route eligible buckets through the fused Pallas "
                         "round kernels (bit-exact; schedule backend only)")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="microbatch admission window, simulated ms")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=None,
                    help="program-cache capacity override")
    ap.add_argument("--workers", type=int, default=1,
                    help="simulated worker count (the executor pool)")
    ap.add_argument("--shard-width", type=int, default=1,
                    help="mesh-slice width for sharded MRF dispatches")
    ap.add_argument("--shard-min-sites", type=int, default=None,
                    help="route MRF grids with >= this many sites to "
                         "run_sharded (default: sharded route off)")
    ap.add_argument("--no-pins", action="store_true",
                    help="strip pin evidence from grid queries (pinned "
                         "grids are ineligible for the sharded route, so "
                         "the sharded smoke jobs replay pin-free)")
    ap.add_argument("--slice-iters", type=int, default=None,
                    help="serve long queries in slices of this many sweeps "
                         "(continuous batching; default: whole-query)")
    ap.add_argument("--rate-qps", type=float, default=None,
                    help="token-bucket admission rate (default: open)")
    ap.add_argument("--burst", type=int, default=16,
                    help="token-bucket depth")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bounded per-bucket queue depth (default: open)")
    ap.add_argument("--policy", default="defer", choices=["defer", "shed"],
                    help="what an empty token bucket does to an arrival")
    ap.add_argument("--calibrate", action="store_true",
                    help="timed warmup dispatches -> measured service "
                         "times (otherwise the line model serves)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto timeline to PATH, the "
                         "deterministic JSONL event log next to it "
                         "(.jsonl), and the predicted-vs-measured "
                         "attribution (.attrib.json); prints the "
                         "attribution table and fails on coverage gaps")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="capture each bucket executable's static HLO "
                         "costs + roofline bottleneck at first jit, join "
                         "them against measured dispatch spans, and write "
                         "profile.json to PATH plus the deterministic "
                         "metrics time-series (.series.jsonl); prints the "
                         "profile table and fails on unattributed "
                         "dispatches")
    args = ap.parse_args(argv)

    if args.trace_out or args.profile_out:
        obs.enable()
    if args.profile_out:
        profile_mod.enable()

    models, queries = TRACES[args.trace](
        args.queries, quick=args.quick, seed=args.seed
    )
    if args.no_pins:
        for q in queries:
            if q.image is not None:
                q.evidence = None
    # quick mode pads every microbatch to one size: each distinct batch
    # shape is a fresh XLA compile, and the CI smoke job wants the serving
    # path exercised, not the jit cache stress-tested
    pad_sizes = (args.max_batch,) if args.quick else \
        tuple(s for s in (1, 2, 4, 8, 16, 32) if s <= args.max_batch)
    admission = None
    if args.rate_qps is not None or args.queue_limit is not None:
        admission = AdmissionConfig(
            rate_qps=args.rate_qps, burst=args.burst,
            queue_limit=args.queue_limit, policy=args.policy,
        )
    engine = Engine(models, EngineConfig(
        backend=args.backend,
        fused=args.fused,
        window_s=args.window_ms * 1e-3,
        max_batch=args.max_batch,
        pad_sizes=pad_sizes,
        cache_capacity=args.capacity,
        n_workers=args.workers,
        shard_width=args.shard_width,
        shard_min_sites=args.shard_min_sites,
        slice_iters=args.slice_iters,
        admission=admission,
    ))
    engine.submit(queries)
    if args.calibrate:
        cal = engine.calibrate()
        print(f"[runtime] calibrated {len(cal.measured)} dispatch "
              "signature(s)")
    results = engine.run()
    s = engine.metrics.summary()

    gaps = []
    unattributed = []
    if args.trace_out or args.profile_out:
        tr = obs.get()
        events = list(tr.events)
        dicts = export_mod.events_as_dicts(events)
    if args.trace_out:
        base = os.path.splitext(args.trace_out)[0]
        export_mod.write_perfetto(args.trace_out, events)
        export_mod.write_jsonl(base + ".jsonl", events)
        rows, gaps = attrib_mod.attribution(dicts)
        with open(base + ".attrib.json", "w") as f:
            json.dump({
                "rows": rows, "gaps": gaps,
                "n_events": len(events), "dropped": tr.dropped,
            }, f, indent=1, sort_keys=True)
        print(f"[runtime] trace: {args.trace_out} ({len(events)} events, "
              f"{tr.dropped} dropped) + {base}.jsonl + {base}.attrib.json")
        print(attribution_table(rows))
    if args.profile_out:
        pbase = os.path.splitext(args.profile_out)[0]
        rec = profile_mod.write_profile(
            args.profile_out, profile_mod.get(), dicts
        )
        engine.metrics.series.write_jsonl(pbase + ".series.jsonl")
        joined = rec["joined"]
        unattributed = joined["unattributed"]
        print(f"[runtime] profile: {args.profile_out} "
              f"({len(rec['buckets'])} executables, "
              f"{joined['n_dispatches']} dispatches, "
              f"{joined['n_sharded']} sharded) "
              f"+ {pbase}.series.jsonl")
        print(profile_table(joined["rows"], joined["comm"]))
        profile_mod.disable()
    if args.trace_out or args.profile_out:
        obs.disable()
    print(f"[runtime] trace={args.trace} backend={args.backend} "
          f"fused={args.fused} workers={args.workers} models={len(models)} "
          f"served={len(results)} shed={s['sheds']}")
    print(engine.metrics.table())
    if len(results) + s["sheds"] != len(queries):
        print(f"[runtime] ERROR: "
              f"{len(queries) - len(results) - s['sheds']} queries "
              "neither served nor shed")
        return 1
    if s["cache_hit_rate"] < 0.9:
        print(f"[runtime] ERROR: program-cache hit rate "
              f"{s['cache_hit_rate']:.3f} < 0.9 on a {args.trace} trace")
        return 1
    if s["max_queue_depth"] and engine.config.admission and \
            engine.config.admission.queue_limit is not None and \
            s["max_queue_depth"] > engine.config.admission.queue_limit:
        print(f"[runtime] ERROR: max queue depth {s['max_queue_depth']} "
              f"exceeds the configured limit")
        return 1
    if s["trace_dropped"]:
        from repro.analysis import Finding
        print("[runtime] " + Finding(
            "obs-trace-dropped", f"trace:{args.trace}",
            f"{s['trace_dropped']} events dropped by the tracer ring "
            "buffer during this run",
            fixit="re-run with obs.enable(capacity=...) raised",
        ).render())
    if gaps:
        for g in gaps:
            print(f"[runtime] ERROR: attribution gap — program "
                  f"{g['program'][:16]} dispatched {g['n_dispatches']}x "
                  "with no recorded round costs")
        return 1
    if unattributed:
        for u in unattributed:
            print(f"[runtime] ERROR: unattributed dispatches — "
                  f"sig={str(u['sig'])[:48]!r} x{u['n_dispatches']} "
                  "never captured by the profiler")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
