"""Trace-replay CLI for the serving runtime.

    PYTHONPATH=src python -m repro.runtime --trace zipf --quick

Replays a synthetic query trace through the engine and prints the serving
dashboard (latency percentiles in simulated time, throughput, cache and
recompile behavior).  CI runs the quick Zipf replay as a smoke job.
"""

from __future__ import annotations

import argparse

from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.trace import zipf_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.runtime")
    ap.add_argument("--trace", default="zipf", choices=["zipf"],
                    help="trace family to replay")
    ap.add_argument("--quick", action="store_true",
                    help="small budgets (CI smoke)")
    ap.add_argument("--queries", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="schedule",
                    choices=["schedule", "eager"],
                    help="execution backend (schedule is the runtime "
                         "default; eager is the escape hatch)")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="microbatch admission window, simulated ms")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=None,
                    help="program-cache capacity override")
    args = ap.parse_args(argv)

    models, queries = zipf_trace(
        args.queries, quick=args.quick, seed=args.seed
    )
    # quick mode pads every microbatch to one size: each distinct batch
    # shape is a fresh XLA compile, and the CI smoke job wants the serving
    # path exercised, not the jit cache stress-tested
    pad_sizes = (args.max_batch,) if args.quick else \
        tuple(s for s in (1, 2, 4, 8, 16, 32) if s <= args.max_batch)
    engine = Engine(models, EngineConfig(
        backend=args.backend,
        window_s=args.window_ms * 1e-3,
        max_batch=args.max_batch,
        pad_sizes=pad_sizes,
        cache_capacity=args.capacity,
    ))
    engine.submit(queries)
    results = engine.run()
    s = engine.metrics.summary()
    print(f"[runtime] trace={args.trace} backend={args.backend} "
          f"models={len(models)} queries={len(results)}")
    print(engine.metrics.table())
    if len(results) != len(queries):
        print(f"[runtime] ERROR: {len(queries) - len(results)} queries "
              "unanswered")
        return 1
    if s["cache_hit_rate"] < 0.9:
        print(f"[runtime] ERROR: program-cache hit rate "
              f"{s['cache_hit_rate']:.3f} < 0.9 on a Zipf trace")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
