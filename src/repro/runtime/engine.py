"""The serving engine: a deterministic event loop over batched queries.

This is the software analogue of the AIA chip's query-serving posture —
many concurrent posterior queries amortized over fixed compiled hardware,
with the host processor distributing work across the mesh.  The engine owns
a registry of models (canonicalized structure-only, so every query on a
model shares one `ir_key` and therefore one program-cache slot), admits
queries from a trace, groups them into buckets (`batcher.BucketKey`), and
flushes a bucket when it fills to `max_batch` or its oldest query has
waited out the microbatch window.

Flushed buckets dispatch onto an `executor.WorkerPool` of `n_workers`
simulated workers with per-worker busy-until clocks, so service overlaps
across workers while the loop itself stays single-threaded and replayable;
large MRF buckets can route onto a mesh slice via `run_sharded`
(`shard_min_sites`).  Long queries execute in slices of `slice_iters`
sweeps (chain-state carry-over — bit-exact with an uninterrupted run), so
short queries interleave between a long query's slices: continuous
batching.  The front door applies `admission.AdmissionConfig` token-bucket
rate limiting and bounded per-bucket queues (shed/defer) once the executor
saturates.

Time is *simulated*: the clock advances by the calibrated service time
(`calibrate.Calibrator` — measured warmup dispatches when available, the
schedule-cost line model cold), never by wall time.  That makes every
latency number deterministic — same trace, same calibration table, same
numbers, every run — while the actual sampling math still runs for real
underneath (results are genuine posteriors).

`backend="schedule"` is the global default (`CompiledProgram.run` shares
it since the runtime soak graduated it); `Engine(..., backend="eager")` is
the escape hatch back to the eager engines.
"""

from __future__ import annotations

import dataclasses
import heapq
import time

from repro.compile import compile_graph, set_cache_capacity
from repro.compile import ir as ir_mod
from repro.core.graphs import DiscreteBayesNet, GridMRF
from repro.obs import timeseries, tracer
from repro.runtime import batcher as batcher_mod
from repro.runtime.admission import (
    DEFER,
    SHED,
    AdmissionConfig,
    AdmissionController,
)
from repro.runtime.batcher import BucketKey, Query, QueryResult
from repro.runtime.calibrate import Calibrator
from repro.runtime.executor import Executor, ExecutorConfig
from repro.runtime.metrics import RuntimeMetrics


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    backend: str = "schedule"  # the global default; "eager" escape hatch
    # route eligible buckets (BN lut_ky/exact_ky, MRF lut_ky on the
    # schedule backend) through the fused Pallas round kernels — bit-exact
    # with unfused, so a pure service-time knob
    fused: bool = False
    # thread the streaming quality accumulator (repro.diag) through every
    # bucket: each served query's QueryResult.quality carries its R-hat/ESS
    # brief, the metrics grow rhat_max/ess_min columns, and the tracer
    # emits per-query `quality` instants.  Draw streams are bit-identical
    # either way, on every route — fused sharded dispatches thread the
    # accumulator through the shard_map body (its site/chain moment leaves
    # shard with the state)
    diagnostics: bool = False
    pipeline: str = "runtime"  # pass list incl. merge_small_colors
    mesh_shape: tuple[int, int] = (4, 4)
    window_s: float = 0.002  # microbatch admission window (simulated)
    max_batch: int = 8
    pad_sizes: tuple[int, ...] = batcher_mod.PAD_SIZES
    cache_capacity: int | None = None  # None: leave the global setting
    # executor: W simulated workers; large MRF buckets can shard over a
    # mesh slice of shard_width workers (None = sharded route off)
    n_workers: int = 1
    shard_width: int = 1
    shard_min_sites: int | None = None
    # continuous batching: serve long queries in slices of this many sweeps
    # (None = whole-query dispatches, the pre-slicing behavior)
    slice_iters: int | None = None
    # front-door backpressure (None = open admission)
    admission: AdmissionConfig | None = None
    # line service model (the calibrator's cold fallback): cycles -> seconds
    # at the modeled clock, one launch overhead per microbatch, one wave per
    # `chain_slots` chains
    clock_hz: float = 500e6
    launch_overhead_cycles: int = 50_000
    chain_slots: int = 256


class Engine:
    """Deterministic batched serving over the compiled-program cache."""

    def __init__(
        self,
        models: dict[str, DiscreteBayesNet | GridMRF],
        config: EngineConfig | None = None,
        calibrator: Calibrator | None = None,
        **overrides,
    ):
        if config is None:
            config = EngineConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        if config.backend not in ("eager", "schedule"):
            raise ValueError(f"unknown backend {config.backend!r}")
        if config.fused and config.backend != "schedule":
            raise ValueError("fused execution requires backend='schedule'")
        if config.max_batch > max(config.pad_sizes):
            raise ValueError(
                f"max_batch {config.max_batch} exceeds the pad ladder "
                f"{config.pad_sizes}; every flush size must pad to a ladder "
                "shape or each occupancy becomes a fresh compile"
            )
        if config.slice_iters is not None and config.slice_iters < 1:
            raise ValueError(
                f"slice_iters must be >= 1, got {config.slice_iters}"
            )
        # fail at construction, not mid-run: ExecutorConfig validates the
        # worker/slice shape
        ExecutorConfig(
            n_workers=config.n_workers, shard_width=config.shard_width,
            shard_min_sites=config.shard_min_sites,
        )
        self.config = config
        self.calibrator = calibrator
        # structure-only canonicalization: per-query evidence never touches
        # the IR, so every query on a model maps to the same program key
        self.graphs = {
            name: ir_mod.canonicalize(m, evidence_mode="runtime")
            for name, m in models.items()
        }
        if config.cache_capacity is not None:
            set_cache_capacity(config.cache_capacity)
        self.metrics = RuntimeMetrics()
        self._queue: list[Query] = []
        self.shed_qids: list[int] = []

    # -- admission ---------------------------------------------------------

    def submit(self, queries) -> None:
        """Admission-time validation: a bad query must be rejected here,
        with the same range rules `CompiledProgram.run()` enforces on the
        single-query path — inside a microbatch an out-of-range (or
        negatively indexed) clamp would otherwise feed the gathers
        silently and serve a wrong posterior."""
        for q in queries:
            if q.model not in self.graphs:
                raise KeyError(f"unregistered model {q.model!r}")
            graph = self.graphs[q.model]
            if graph.kind == "mrf" and q.image is None:
                raise ValueError(
                    f"query {q.qid}: MRF queries carry an observation image"
                )
            for node, val in (q.evidence or {}).items():
                node, val = int(node), int(val)
                if not (0 <= node < graph.n_nodes
                        and 0 <= val < graph.cards[node]):
                    what = "evidence" if graph.kind == "bn" else "pin"
                    raise ValueError(
                        f"query {q.qid}: {what} {node}={val} out of range"
                    )
            self._queue.append(q)

    # -- program + service model -------------------------------------------

    def _program(self, model: str):
        return compile_graph(
            self.graphs[model],
            mesh_shape=self.config.mesh_shape,
            pipeline=self.config.pipeline,
        )

    def _shard_width_of(self, q: Query) -> int:
        """The mesh-slice width this query's bucket would shard over, from
        config + model statics alone (the same gate `executor.route`
        applies): fused eligibility budgets VMEM per shard — local row
        slab + halo rows — when the bucket will run the shard_map body."""
        cfg = self.config
        graph = self.graphs[q.model]
        if (
            cfg.shard_min_sites is not None
            and graph.kind == "mrf"
            and not q.evidence
        ):
            mrf = graph.source
            if (mrf.height * mrf.width >= cfg.shard_min_sites
                    and mrf.height % cfg.shard_width == 0):
                return cfg.shard_width
        return 1

    def _bucket_key(self, q: Query) -> BucketKey:
        return batcher_mod.bucket_key(
            q, self.graphs[q.model], self.config.backend,
            self.config.slice_iters, fused=self.config.fused,
            diagnostics=self.config.diagnostics,
            shard_width=self._shard_width_of(q),
        )

    def _make_calibrator(self) -> Calibrator:
        cfg = self.config
        return Calibrator(
            clock_hz=cfg.clock_hz,
            launch_overhead_cycles=cfg.launch_overhead_cycles,
            chain_slots=cfg.chain_slots,
        )

    def calibrate(self, queries=None, repeats: int = 2) -> Calibrator:
        """Measured-time warmup: execute one representative microbatch per
        distinct bucket signature in `queries` (default: the submitted
        queue), wall-timed, and freeze the medians into this engine's
        calibrator (creating one if needed).

        Runs the *same* vmapped executables the serving loop will run, so
        it doubles as the jit warmup, and the frozen table keeps
        `run()` deterministic — the loop never reads a wall clock.
        Returns the calibrator (shareable across engines)."""
        cfg = self.config
        if self.calibrator is None:
            self.calibrator = self._make_calibrator()
        qs = list(self._queue if queries is None else queries)
        buckets: dict[BucketKey, list[Query]] = {}
        for q in sorted(qs, key=lambda q: (q.arrival_s, q.qid)):
            if q.carry is not None:
                continue  # continuations can't be warmed without states
            buckets.setdefault(self._bucket_key(q), []).append(q)
        return_state = cfg.slice_iters is not None
        # a throwaway executor: warmup runs the exact execution path the
        # serving loop will (vmap or sharded per the bucket's route) but
        # never books the pool
        executor = Executor(
            ExecutorConfig(
                n_workers=cfg.n_workers, shard_width=cfg.shard_width,
                shard_min_sites=cfg.shard_min_sites,
            ),
            self.calibrator, cfg.pad_sizes,
        )

        def dispatch(program, key, rep_qs, route):
            executor.execute(program, key, rep_qs, route, return_state)
            return batcher_mod.pad_size(len(rep_qs), cfg.pad_sizes)

        items = []
        for key, qlist in buckets.items():
            program = self._program(qlist[0].model)
            rep = qlist[: cfg.max_batch]
            route = executor.batch_route(program, key, rep)
            # the bucket key IS the execution key on every route (the fused
            # sharded datapath is first-class, nothing gets demoted), so
            # warmup measures exactly what serving will dispatch
            items.append((program, key, rep, route))
        self.calibrator.warmup(dispatch, items, repeats=repeats)
        return self.calibrator

    # -- the event loop ----------------------------------------------------

    def run(self) -> dict[int, QueryResult]:
        """Drain the submitted queries; returns {qid: QueryResult} for the
        queries that were served (`metrics` reports the shed ones).

        Single pass, deterministic: admission (token bucket + queue bounds)
        at the simulated clock, bucket flush on fill-or-window, dispatch
        onto the worker pool at the calibrated service time.  Long queries
        re-enter the arrival queue between slices as continuations carrying
        their chain state — bit-exact with an unsliced run."""
        cfg = self.config
        # wall-metric half of the dual clock, not the sim's event time
        wall0 = time.perf_counter()  # lint: allow[wallclock-in-sim]
        self.metrics = RuntimeMetrics()  # run-scoped cache delta
        executor = Executor(
            ExecutorConfig(
                n_workers=cfg.n_workers, shard_width=cfg.shard_width,
                shard_min_sites=cfg.shard_min_sites,
            ),
            self.calibrator or self._make_calibrator(),
            cfg.pad_sizes,
        )
        admission = AdmissionController(cfg.admission)
        series = self.metrics.series
        # delta-base for this run's ring-buffer overflow (tracer is
        # process-global; the count must describe this trace only)
        dropped0 = tracer.get().dropped if tracer.enabled() else 0
        tracer.instant(
            "run_start", cat="runtime", sim_t=0.0,
            n_workers=cfg.n_workers, backend=cfg.backend, fused=cfg.fused,
            max_batch=cfg.max_batch, window_s=cfg.window_s,
            slice_iters=cfg.slice_iters, diagnostics=cfg.diagnostics,
        )
        # heap entries (arrival_s, qid, seq, query): seq breaks ties between
        # a query's re-arrivals (defers, slice continuations) deterministically
        heap: list = []
        seq = 0
        first_arrival: dict[int, float] = {}
        for q in sorted(self._queue, key=lambda q: (q.arrival_s, q.qid)):
            first_arrival[q.qid] = q.arrival_s
            heapq.heappush(heap, (q.arrival_s, q.qid, seq, q))
            seq += 1
        self._queue = []
        pending: dict[BucketKey, list[Query]] = {}
        # continuations that met a full bucket wait here (never shed — their
        # chains are half run) and refill the bucket right after it flushes;
        # parking them outside the heap keeps `len(bucket) <= queue_limit`
        # at every instant without perturbing the heap-driven clock (a
        # heap-parked retry would suppress the `not heap` drain rule and
        # ulp-step the clock — a livelock)
        overflow: dict[BucketKey, list[Query]] = {}
        programs: dict[BucketKey, object] = {}
        clock = 0.0
        results: dict[int, QueryResult] = {}
        return_state = cfg.slice_iters is not None

        def admit():
            nonlocal seq
            while heap and heap[0][0] <= clock:
                _, _, _, q = heapq.heappop(heap)
                if q.carry is None:
                    # front door: continuations were already admitted once
                    decision, when = admission.decide(
                        q.arrival_s, first_arrival[q.qid]
                    )
                    if decision == DEFER:
                        tracer.instant(
                            "defer", cat="admission", sim_t=clock,
                            qid=q.qid, until=when,
                        )
                        # copy, never mutate: submitted Query objects may be
                        # replayed through another engine pass
                        q = dataclasses.replace(q, arrival_s=when)
                        heapq.heappush(heap, (when, q.qid, seq, q))
                        seq += 1
                        continue
                    if decision == SHED:
                        admission.record_shed(q.qid, by_queue=False)
                        tracer.instant(
                            "shed", cat="admission", sim_t=clock,
                            qid=q.qid, by="tokens",
                        )
                        continue
                key = self._bucket_key(q)
                bucket = pending.setdefault(key, [])
                if admission.queue_full(len(bucket)):
                    if q.carry is None:
                        admission.record_shed(q.qid, by_queue=True)
                        tracer.instant(
                            "shed", cat="admission", sim_t=clock,
                            qid=q.qid, by="queue",
                        )
                    else:
                        overflow.setdefault(key, []).append(q)
                    continue
                # the program cache's front door: one lookup per admitted
                # query (this is the hit rate the metrics report), and the
                # resolved program rides with the bucket to its flush
                programs[key] = self._program(q.model)
                bucket.append(q)
                admission.note_depth(len(bucket))
            depth = sum(len(b) for b in pending.values())
            series.gauge("queue_depth").sample(clock, depth)
            if tracer.enabled():
                tracer.counter("queue_depth", depth, sim_t=clock)
                if admission.config.rate_qps is not None:
                    tracer.counter(
                        "tokens", round(admission.tokens, 6), sim_t=clock
                    )

        def oldest(key):
            return min(q.arrival_s for q in pending[key])

        admit()
        while heap or pending:
            # NB: the readiness test and the idle-advance horizon must use
            # the *identical* float expressions (`oldest + window`, the
            # pool's `earliest_free`); computing one as `clock - oldest >=
            # window` lets rounding disagree with the horizon and spin the
            # loop at a frozen clock
            free_t = executor.pool.earliest_free()
            ready = [
                k for k, qs in pending.items()
                if len(qs) >= cfg.max_batch
                or clock >= oldest(k) + cfg.window_s
                or not heap
            ] if clock >= free_t else []  # all workers busy: batches grow
            if not ready:
                # idle: jump to the next *future* event — the next arrival,
                # the next window expiry, or (with work waiting) the next
                # worker coming free.  Past horizons must be filtered out:
                # a window that expired while every worker was busy would
                # otherwise pin `min(horizons)` at or before the clock and
                # freeze the loop (its bucket is not ready — the worker
                # gate vetoed it — so nothing else advances time).  The
                # case analysis guarantees a future horizon exists here:
                # arrivals <= clock were admitted, and a busy pool means
                # free_t > clock.
                horizons = [heap[0][0]] if heap else []
                horizons += [oldest(k) + cfg.window_s for k in pending]
                if pending:
                    horizons.append(free_t)
                clock = min(h for h in horizons if h > clock)
                admit()
                continue
            key = min(ready, key=lambda k: (oldest(k), repr(k)))
            qs = sorted(
                pending[key], key=lambda q: (q.arrival_s, q.qid)
            )[: cfg.max_batch]
            taken = {q.qid for q in qs}
            remaining = [q for q in pending[key] if q.qid not in taken]
            # the flush made room: parked continuations re-enter first (in
            # park order), up to the bound
            parked = overflow.get(key, [])
            while parked and not admission.queue_full(len(remaining)):
                remaining.append(parked.pop(0))
                admission.note_depth(len(remaining))
            if not parked:
                overflow.pop(key, None)
            if remaining:
                pending[key] = remaining
            else:
                del pending[key]
            tracer.instant(
                "flush", cat="runtime", sim_t=clock,
                model=qs[0].model, kind=key.kind, n_queries=len(qs),
                full=len(qs) >= cfg.max_batch,
            )
            batch, rec = executor.dispatch(
                programs[key], key, qs, clock, return_state=return_state
            )
            self.metrics.record_batch(rec)
            series.histogram(
                "pad_efficiency", boundaries=timeseries.PAD_EFF_BOUNDARIES,
            ).observe(rec.start_s, rec.n_real / max(rec.n_padded, 1))
            series.histogram("bucket_service_s").observe(
                rec.start_s, rec.service_s
            )
            # cumulative flush-window stall across the pool, sampled per
            # dispatch: the window/ladder autotuner's minimization target
            series.gauge("worker_stall_s").sample(
                rec.finish_s, round(sum(executor.pool.stall_s), 9)
            )
            done = []
            for q, r in zip(qs, batch):
                left = q.n_iters - key.n_iters
                if left > 0:
                    # continuation: same query, chain state attached, the
                    # remaining budget, re-arriving when its slice finished
                    # (a copy — submitted Query objects stay pristine)
                    cont = dataclasses.replace(
                        q, carry=r.carry, n_iters=left,
                        arrival_s=rec.finish_s,
                    )
                    heapq.heappush(heap, (rec.finish_s, cont.qid, seq, cont))
                    seq += 1
                else:
                    r.arrival_s = first_arrival[r.qid]
                    r.carry = None  # slices are internal; results are final
                    results[r.qid] = r
                    done.append(r)
                    series.histogram("query_latency_s").observe(
                        rec.finish_s, r.latency_s
                    )
                    if r.quality is not None and tracer.enabled():
                        # convergence lands on the timeline next to the
                        # dispatch lanes that produced it
                        tracer.instant(
                            "quality", cat="quality", sim_t=rec.finish_s,
                            qid=r.qid, model=r.model, **r.quality,
                        )
            self.metrics.record_queries(done)
            admit()
        # every parked continuation refilled its bucket before the loop
        # could drain (overflow[key] non-empty implies pending[key] was full
        # an instant ago); a violation here would mean lost queries, which
        # must crash, not silently under-serve
        assert not any(overflow.values()), overflow
        self.metrics.worker_busy_s = tuple(executor.pool.busy_s)
        self.metrics.worker_stall_s = tuple(executor.pool.stall_s)
        self.metrics.sheds = admission.sheds
        self.metrics.shed_tokens = admission.shed_tokens
        self.metrics.shed_queue = admission.shed_queue
        self.metrics.defers = admission.defers
        self.metrics.max_queue_depth = admission.max_queue_depth
        if tracer.enabled():
            self.metrics.trace_dropped = tracer.get().dropped - dropped0
        self.shed_qids = list(admission.shed_qids)
        self.metrics.wall_s = (  # lint: allow[wallclock-in-sim]
            time.perf_counter() - wall0
        )
        self.metrics.finalize()
        return results
