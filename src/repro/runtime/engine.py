"""The serving engine: a deterministic event loop over batched queries.

This is the software analogue of the AIA chip's query-serving posture —
many concurrent posterior queries amortized over fixed compiled hardware.
The engine owns a registry of models (canonicalized structure-only, so
every query on a model shares one `ir_key` and therefore one program-cache
slot), admits queries from a trace, groups them into buckets
(`batcher.BucketKey`), and flushes a bucket when it fills to `max_batch`
or its oldest query has waited out the microbatch window.

Time is *simulated*: the clock advances by a line-model service time
derived from the program's schedule cost (launch overhead + cycles per
sweep x iterations x chain waves), never by wall time.  That makes every
latency number deterministic — the whole loop is single-threaded and
replayable, so tests can pin p95s to the digit — while the actual sampling
math still runs for real underneath (results are genuine posteriors).

`backend="schedule"` is the default here (the runtime is the soak path the
ROADMAP wants for schedule-direct execution); `Engine(..., backend=
"eager")` is the escape hatch back to the eager engines.
"""

from __future__ import annotations

import collections
import dataclasses
import time

from repro.compile import compile_graph, set_cache_capacity
from repro.compile import ir as ir_mod
from repro.core.graphs import DiscreteBayesNet, GridMRF
from repro.runtime import batcher as batcher_mod
from repro.runtime.batcher import BucketKey, Query, QueryResult
from repro.runtime.metrics import BatchRecord, RuntimeMetrics


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    backend: str = "schedule"  # runtime default; "eager" is the escape hatch
    pipeline: str = "runtime"  # pass list incl. merge_small_colors
    mesh_shape: tuple[int, int] = (4, 4)
    window_s: float = 0.002  # microbatch admission window (simulated)
    max_batch: int = 8
    pad_sizes: tuple[int, ...] = batcher_mod.PAD_SIZES
    cache_capacity: int | None = None  # None: leave the global setting
    # line service model: cycles -> seconds at the modeled clock, one
    # launch overhead per microbatch, one wave per `chain_slots` chains
    clock_hz: float = 500e6
    launch_overhead_cycles: int = 50_000
    chain_slots: int = 256


class Engine:
    """Deterministic batched serving over the compiled-program cache."""

    def __init__(
        self,
        models: dict[str, DiscreteBayesNet | GridMRF],
        config: EngineConfig | None = None,
        **overrides,
    ):
        if config is None:
            config = EngineConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        if config.backend not in ("eager", "schedule"):
            raise ValueError(f"unknown backend {config.backend!r}")
        if config.max_batch > max(config.pad_sizes):
            raise ValueError(
                f"max_batch {config.max_batch} exceeds the pad ladder "
                f"{config.pad_sizes}; every flush size must pad to a ladder "
                "shape or each occupancy becomes a fresh compile"
            )
        self.config = config
        # structure-only canonicalization: per-query evidence never touches
        # the IR, so every query on a model maps to the same program key
        self.graphs = {
            name: ir_mod.canonicalize(m, evidence_mode="runtime")
            for name, m in models.items()
        }
        if config.cache_capacity is not None:
            set_cache_capacity(config.cache_capacity)
        self.metrics = RuntimeMetrics()
        self._queue: list[Query] = []

    # -- admission ---------------------------------------------------------

    def submit(self, queries) -> None:
        """Admission-time validation: a bad query must be rejected here,
        with the same range rules `CompiledProgram.run()` enforces on the
        single-query path — inside a microbatch an out-of-range (or
        negatively indexed) clamp would otherwise feed the gathers
        silently and serve a wrong posterior."""
        for q in queries:
            if q.model not in self.graphs:
                raise KeyError(f"unregistered model {q.model!r}")
            graph = self.graphs[q.model]
            if graph.kind == "mrf" and q.image is None:
                raise ValueError(
                    f"query {q.qid}: MRF queries carry an observation image"
                )
            for node, val in (q.evidence or {}).items():
                node, val = int(node), int(val)
                if not (0 <= node < graph.n_nodes
                        and 0 <= val < graph.cards[node]):
                    what = "evidence" if graph.kind == "bn" else "pin"
                    raise ValueError(
                        f"query {q.qid}: {what} {node}={val} out of range"
                    )
            self._queue.append(q)

    # -- program + service model -------------------------------------------

    def _program(self, model: str):
        return compile_graph(
            self.graphs[model],
            mesh_shape=self.config.mesh_shape,
            pipeline=self.config.pipeline,
        )

    def _service_s(self, program, key: BucketKey, n_padded: int) -> float:
        """Line service model (relative units, like `schedule.cost`): the
        microbatch pays one launch overhead, then every sweep costs the
        schedule's cycle estimate, repeated for each wave of chains the
        padded batch occupies."""
        cfg = self.config
        sweep = program.schedule.cost()["total_cycles"]
        waves = -(-n_padded * key.n_chains // cfg.chain_slots)
        cycles = cfg.launch_overhead_cycles + sweep * key.n_iters * waves
        return cycles / cfg.clock_hz

    # -- the event loop ----------------------------------------------------

    def run(self) -> dict[int, QueryResult]:
        """Drain the submitted queries; returns {qid: QueryResult}.

        Single pass, deterministic: admission at the simulated clock,
        bucket flush on fill-or-window, service time from the line model.
        The executor is serial (one device), so flushed batches serialize
        on the clock in flush order."""
        cfg = self.config
        wall0 = time.perf_counter()
        incoming = collections.deque(
            sorted(self._queue, key=lambda q: (q.arrival_s, q.qid))
        )
        self._queue = []
        pending: dict[BucketKey, list[Query]] = {}
        programs: dict[BucketKey, object] = {}
        clock = 0.0
        results: dict[int, QueryResult] = {}

        def admit():
            while incoming and incoming[0].arrival_s <= clock:
                q = incoming.popleft()
                key = batcher_mod.bucket_key(
                    q, self.graphs[q.model], cfg.backend
                )
                # the program cache's front door: one lookup per admitted
                # query (this is the hit rate the metrics report), and the
                # resolved program rides with the bucket to its flush
                programs[key] = self._program(q.model)
                pending.setdefault(key, []).append(q)

        def oldest(key):
            return min(q.arrival_s for q in pending[key])

        admit()
        while incoming or pending:
            # NB: the readiness test and the idle-advance horizon must use
            # the *identical* float expression `oldest + window`; computing
            # one as `clock - oldest >= window` lets rounding disagree with
            # the horizon and spin the loop at a frozen clock
            ready = [
                k for k, qs in pending.items()
                if len(qs) >= cfg.max_batch
                or clock >= oldest(k) + cfg.window_s
                or not incoming
            ]
            if not ready:
                # idle: jump to the next arrival or the next window expiry
                horizons = [incoming[0].arrival_s] if incoming else []
                horizons += [oldest(k) + cfg.window_s for k in pending]
                clock = max(clock, min(horizons))
                admit()
                continue
            key = min(ready, key=lambda k: (oldest(k), repr(k)))
            qs = sorted(
                pending[key], key=lambda q: (q.arrival_s, q.qid)
            )[: cfg.max_batch]
            taken = {q.qid for q in qs}
            remaining = [q for q in pending[key] if q.qid not in taken]
            if remaining:
                pending[key] = remaining
            else:
                del pending[key]
            results_batch = self._flush(programs[key], key, qs, clock)
            clock = results_batch[0].finish_s
            for r in results_batch:
                results[r.qid] = r
            admit()
        self.metrics.wall_s = time.perf_counter() - wall0
        self.metrics.finalize()
        return results

    def _flush(
        self, program, key: BucketKey, qs: list[Query], clock: float
    ) -> list[QueryResult]:
        lower0 = program.clamp_lowerings
        batch = batcher_mod.execute_bucket(
            program, key, qs, self.config.pad_sizes
        )
        n_padded = batcher_mod.pad_size(len(qs), self.config.pad_sizes)
        service = self._service_s(program, key, n_padded)
        for r in batch:
            r.start_s = clock
            r.finish_s = clock + service
        self.metrics.record_batch(BatchRecord(
            model=qs[0].model, kind=key.kind, n_real=len(qs),
            n_padded=n_padded, service_s=service,
            clamp_lowerings=program.clamp_lowerings - lower0,
        ))
        self.metrics.record_queries(batch)
        return batch
