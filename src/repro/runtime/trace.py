"""Synthetic serving traces: Zipf-over-models query streams.

Real sampling-as-a-service traffic is heavy-tailed over a model zoo — a few
hot models take most queries, a long tail stays warm in the cache.  The
Zipf trace models exactly that: model i is drawn with probability
proportional to 1/(i+1)^s, arrivals are a Poisson process (exponential
interarrivals), and per-query observations are sampled from a small pool of
observation *patterns* per model (real deployments re-use feature masks far
more than feature values, which is what makes clamp-set bucketing pay off).

Everything is seeded `numpy.random.default_rng` — the same (seed, quick)
pair replays the identical trace, which the engine's deterministic clock
turns into identical metrics.
"""

from __future__ import annotations

import numpy as np

from repro.core.graphs import GridMRF, bn_repository_replica
from repro.core.mrf import make_denoising_problem
from repro.runtime.batcher import Query


def zipf_models(quick: bool = False) -> dict:
    """The model zoo, hottest first (rank order = Zipf rank).  The quick
    zoo is deliberately small: every (model, observation-pattern) pair is
    a distinct executable to compile, and the CI smoke budget is minutes."""
    names = ["survey", "cancer", "asia"]
    if not quick:
        names += ["sachs", "insurance", "alarm"]
    models = {n: bn_repository_replica(n) for n in names}
    size = 8 if quick else 16
    models["grid"] = GridMRF(size, size, 3, theta=1.1, h=1.8, name="grid")
    return models


def zipf_trace(
    n_queries: int = 150,
    *,
    quick: bool = False,
    seed: int = 0,
    s: float = 1.1,
    mean_interarrival_s: float = 1e-4,
    n_patterns: int = 2,
    n_chains: int = 8,
    n_iters: int = 40,
    burn_in: int = 10,
) -> tuple[dict, list[Query]]:
    """Build (models, queries) for a Zipf-distributed posterior workload.

    BN queries observe one of `n_patterns` fixed node subsets per model
    (values re-drawn per query); MRF queries carry a fresh noisy image and,
    half the time, a few pinned pixels.  Returns models keyed by name and
    queries sorted by arrival time."""
    if quick:
        n_queries = min(n_queries, 60)
        n_iters = min(n_iters, 16)
        n_chains = min(n_chains, 4)
        burn_in = min(burn_in, 4)
        n_patterns = 1  # one executable per model in the CI smoke budget
    rng = np.random.default_rng(seed)
    models = zipf_models(quick)
    names = list(models)
    weights = 1.0 / np.arange(1, len(names) + 1) ** s
    weights /= weights.sum()

    # per-BN-model pool of observed-node patterns (the serving reality that
    # makes static clamp sets cacheable)
    patterns: dict[str, list[np.ndarray]] = {}
    for name, m in models.items():
        if isinstance(m, GridMRF):
            continue
        k = max(1, m.n_nodes // 4)
        patterns[name] = [
            rng.choice(m.n_nodes, size=min(k, m.n_nodes - 1), replace=False)
            for _ in range(n_patterns)
        ]

    queries: list[Query] = []
    clock = 0.0
    for qid in range(n_queries):
        clock += float(rng.exponential(mean_interarrival_s))
        name = names[int(rng.choice(len(names), p=weights))]
        m = models[name]
        if isinstance(m, GridMRF):
            _, noisy = make_denoising_problem(
                m.height, m.width, m.n_labels, noise=0.25,
                seed=int(rng.integers(1 << 16)),
            )
            # pinned and unpinned MRF buckets are distinct executables;
            # the quick trace pins everything to compile just one
            pins = None
            if quick or rng.random() < 0.5:
                sites = rng.choice(
                    m.height * m.width, size=3, replace=False
                )
                pins = {
                    int(p): int(rng.integers(m.n_labels)) for p in sites
                }
            queries.append(Query(
                qid=qid, model=name, evidence=pins, image=noisy,
                n_chains=n_chains, n_iters=n_iters, burn_in=0,
                seed=int(rng.integers(1 << 30)), arrival_s=clock,
            ))
        else:
            nodes = patterns[name][int(rng.integers(len(patterns[name])))]
            ev = {
                int(v): int(rng.integers(m.cards[v])) for v in nodes
            }
            # per-query thinning splits buckets (it is a static loop
            # parameter), so the quick/CI trace keeps thin=1 to bound the
            # number of distinct executables it compiles
            thin = 1 if quick else int(rng.choice([1, 2]))
            queries.append(Query(
                qid=qid, model=name, evidence=ev,
                n_chains=n_chains, n_iters=n_iters, burn_in=burn_in,
                thin=thin,
                seed=int(rng.integers(1 << 30)), arrival_s=clock,
            ))
    return models, queries
