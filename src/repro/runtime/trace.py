"""Synthetic serving traces: Zipf-over-models and bursty on/off streams.

Real sampling-as-a-service traffic is heavy-tailed over a model zoo — a few
hot models take most queries, a long tail stays warm in the cache.  The
Zipf trace models exactly that: model i is drawn with probability
proportional to 1/(i+1)^s, arrivals are a Poisson process (exponential
interarrivals), and per-query observations are sampled from a small pool of
observation *patterns* per model (real deployments re-use feature masks far
more than feature values, which is what makes clamp-set bucketing pay off).

Steady-state Poisson arrivals never actually stress admission control, so
the **bursty** trace layers an on/off (Markov-modulated) envelope on top:
ON periods fire arrivals at a rate far above the executor's service rate,
OFF periods go silent.  That is the arrival pattern that fills bounded
queues, drains token buckets, and forces shed/defer decisions — the
backpressure machinery gets exercised instead of merely existing.

Everything is seeded `numpy.random.default_rng` — the same (seed, quick)
pair replays the identical trace, which the engine's deterministic clock
turns into identical metrics.
"""

from __future__ import annotations

import numpy as np

from repro.core.graphs import GridMRF, bn_repository_replica
from repro.core.mrf import make_denoising_problem
from repro.runtime.batcher import Query


def zipf_models(quick: bool = False) -> dict:
    """The model zoo, hottest first (rank order = Zipf rank).  The quick
    zoo is deliberately small: every (model, observation-pattern) pair is
    a distinct executable to compile, and the CI smoke budget is minutes."""
    names = ["survey", "cancer", "asia"]
    if not quick:
        names += ["sachs", "insurance", "alarm"]
    models = {n: bn_repository_replica(n) for n in names}
    size = 8 if quick else 16
    models["grid"] = GridMRF(size, size, 3, theta=1.1, h=1.8, name="grid")
    return models


def zipf_trace(
    n_queries: int = 150,
    *,
    quick: bool = False,
    seed: int = 0,
    s: float = 1.1,
    mean_interarrival_s: float = 1e-4,
    n_patterns: int = 2,
    n_chains: int = 8,
    n_iters: int = 40,
    burn_in: int = 10,
) -> tuple[dict, list[Query]]:
    """Build (models, queries) for a Zipf-distributed posterior workload.

    BN queries observe one of `n_patterns` fixed node subsets per model
    (values re-drawn per query); MRF queries carry a fresh noisy image and,
    half the time, a few pinned pixels.  Returns models keyed by name and
    queries sorted by arrival time."""
    if quick:
        n_queries = min(n_queries, 60)
        n_iters = min(n_iters, 16)
        n_chains = min(n_chains, 4)
        burn_in = min(burn_in, 4)
        n_patterns = 1  # one executable per model in the CI smoke budget
    rng = np.random.default_rng(seed)
    models = zipf_models(quick)
    patterns = _observation_patterns(models, rng, n_patterns)
    weights = _zipf_weights(models, s)
    # NB: the interarrival draw is interleaved with the query draws (not
    # pre-drawn) so the (seed, quick) -> trace mapping stays byte-identical
    # across PRs — benchmark baselines compare the same workload
    queries: list[Query] = []
    clock = 0.0
    for qid in range(n_queries):
        clock += float(rng.exponential(mean_interarrival_s))
        queries.append(_draw_query(
            qid, clock, models, patterns, weights, rng, quick=quick,
            n_chains=n_chains, n_iters=n_iters, burn_in=burn_in,
        ))
    return models, queries


def bursty_trace(
    n_queries: int = 150,
    *,
    quick: bool = False,
    seed: int = 0,
    s: float = 1.1,
    on_s: float = 1.5e-3,
    off_s: float = 6e-3,
    burst_interarrival_s: float = 2e-5,
    n_patterns: int = 2,
    n_chains: int = 8,
    n_iters: int = 40,
    burn_in: int = 10,
) -> tuple[dict, list[Query]]:
    """Build (models, queries) for a saturating on/off arrival pattern.

    The same Zipf zoo and observation patterns as `zipf_trace`, but
    arrivals come in bursts: ON periods (exponential, mean `on_s`) fire
    queries every ~`burst_interarrival_s` — far faster than the executor
    can serve — then OFF periods (mean `off_s`) go silent so queues drain.
    This is the trace that actually exercises token-bucket admission and
    bounded-queue shedding; Zipf steady-state never does."""
    if quick:
        n_queries = min(n_queries, 60)
        n_iters = min(n_iters, 16)
        n_chains = min(n_chains, 4)
        burn_in = min(burn_in, 4)
        n_patterns = 1
    rng = np.random.default_rng(seed)
    models = zipf_models(quick)
    patterns = _observation_patterns(models, rng, n_patterns)
    weights = _zipf_weights(models, s)
    arrivals = _onoff_arrivals(
        n_queries, rng, on_s, off_s, burst_interarrival_s
    )
    queries = [
        _draw_query(qid, clock, models, patterns, weights, rng, quick=quick,
                    n_chains=n_chains, n_iters=n_iters, burn_in=burn_in)
        for qid, clock in enumerate(arrivals)
    ]
    return models, queries


TRACES = {"zipf": zipf_trace, "bursty": bursty_trace}


# ---------------------------------------------------------------------------
# shared trace machinery
# ---------------------------------------------------------------------------


def _observation_patterns(
    models: dict, rng, n_patterns: int
) -> dict[str, list[np.ndarray]]:
    """Per-BN-model pool of observed-node patterns (the serving reality
    that makes static clamp sets cacheable)."""
    patterns: dict[str, list[np.ndarray]] = {}
    for name, m in models.items():
        if isinstance(m, GridMRF):
            continue
        k = max(1, m.n_nodes // 4)
        patterns[name] = [
            rng.choice(m.n_nodes, size=min(k, m.n_nodes - 1), replace=False)
            for _ in range(n_patterns)
        ]
    return patterns


def _onoff_arrivals(
    n: int, rng, on_s: float, off_s: float, burst_interarrival_s: float
) -> list[float]:
    """Markov-modulated arrivals: dense bursts during ON, silence OFF."""
    clock, out = 0.0, []
    phase_end = clock + float(rng.exponential(on_s))
    while len(out) < n:
        dt = float(rng.exponential(burst_interarrival_s))
        if clock + dt > phase_end:
            # end of the ON period: skip the OFF gap, start the next burst
            clock = phase_end + float(rng.exponential(off_s))
            phase_end = clock + float(rng.exponential(on_s))
            continue
        clock += dt
        out.append(clock)
    return out


def _zipf_weights(models: dict, s: float) -> np.ndarray:
    """Model-selection weights, hottest first (rank order = Zipf rank) —
    computed once per trace, they consume no RNG."""
    weights = 1.0 / np.arange(1, len(models) + 1) ** s
    return weights / weights.sum()


def _draw_query(
    qid: int, clock: float, models: dict, patterns: dict,
    weights: np.ndarray, rng, *,
    quick: bool, n_chains: int, n_iters: int, burn_in: int,
) -> Query:
    """One Zipf-distributed query at a given arrival instant (shared by
    every trace family — the families differ only in their arrival
    process)."""
    names = list(models)
    name = names[int(rng.choice(len(names), p=weights))]
    m = models[name]
    if isinstance(m, GridMRF):
        _, noisy = make_denoising_problem(
            m.height, m.width, m.n_labels, noise=0.25,
            seed=int(rng.integers(1 << 16)),
        )
        # pinned and unpinned MRF buckets are distinct executables;
        # the quick trace pins everything to compile just one
        pins = None
        if quick or rng.random() < 0.5:
            sites = rng.choice(m.height * m.width, size=3, replace=False)
            pins = {int(p): int(rng.integers(m.n_labels)) for p in sites}
        return Query(
            qid=qid, model=name, evidence=pins, image=noisy,
            n_chains=n_chains, n_iters=n_iters, burn_in=0,
            seed=int(rng.integers(1 << 30)), arrival_s=clock,
        )
    nodes = patterns[name][int(rng.integers(len(patterns[name])))]
    ev = {int(v): int(rng.integers(m.cards[v])) for v in nodes}
    # per-query thinning splits buckets (it is a static loop parameter), so
    # the quick/CI trace keeps thin=1 to bound the number of distinct
    # executables it compiles
    thin = 1 if quick else int(rng.choice([1, 2]))
    return Query(
        qid=qid, model=name, evidence=ev,
        n_chains=n_chains, n_iters=n_iters, burn_in=burn_in, thin=thin,
        seed=int(rng.integers(1 << 30)), arrival_s=clock,
    )
