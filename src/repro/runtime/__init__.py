"""`repro.runtime` — batched posterior-query serving over compiled programs.

The serving layer the ROADMAP's north star asks for: many users, many
models, one box.  A query names a registered model plus its runtime
observations (BN evidence clamps / MRF images and pinned pixels); the
engine canonicalizes models *structure-only* so every query on a model
shares one compiled program, buckets compatible queries, and answers each
microbatch with a single vmapped dispatch of the schedule-direct backend.

    from repro.runtime import Engine, zipf_trace

    models, queries = zipf_trace(60, quick=True)
    eng = Engine(models)            # backend="schedule" is the default here
    eng.submit(queries)
    results = eng.run()             # {qid: QueryResult}
    print(eng.metrics.table())

`python -m repro.runtime --trace zipf --quick` replays the synthetic Zipf
trace from the CLI.
"""

from repro.runtime.batcher import (
    BucketKey,
    Query,
    QueryResult,
    bucket_key,
    execute_bucket,
    pad_size,
)
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.metrics import BatchRecord, RuntimeMetrics
from repro.runtime.trace import zipf_models, zipf_trace

__all__ = [
    "BucketKey",
    "Query",
    "QueryResult",
    "bucket_key",
    "execute_bucket",
    "pad_size",
    "Engine",
    "EngineConfig",
    "BatchRecord",
    "RuntimeMetrics",
    "zipf_models",
    "zipf_trace",
]
