"""`repro.runtime` — batched posterior-query serving over compiled programs.

The serving layer the ROADMAP's north star asks for: many users, many
models, one box.  A query names a registered model plus its runtime
observations (BN evidence clamps / MRF images and pinned pixels); the
engine canonicalizes models *structure-only* so every query on a model
shares one compiled program, buckets compatible queries, and answers each
microbatch with a single vmapped dispatch of the schedule-direct backend.

Dispatches land on a pool of simulated workers (`executor.WorkerPool` —
the host-RISC-V work-distribution posture; large MRF buckets shard over a
mesh slice via `run_sharded`), long queries execute in bit-exact slices so
short queries interleave (`slice_iters` — continuous batching via chain-
state carry-over), service times come from measured-time calibration
(`calibrate.Calibrator`, line model cold), and saturating traffic meets
token-bucket admission + bounded queues (`admission.AdmissionConfig`).

    from repro.runtime import Engine, zipf_trace

    models, queries = zipf_trace(60, quick=True)
    eng = Engine(models, n_workers=4, slice_iters=16)
    eng.submit(queries)
    eng.calibrate()                 # optional measured-time warmup
    results = eng.run()             # {qid: QueryResult}
    print(eng.metrics.table())

`python -m repro.runtime --trace zipf --quick` replays the synthetic Zipf
trace from the CLI; `--trace bursty --workers 4 --rate-qps ...
--queue-limit ...` saturates the executor and exercises backpressure.
"""

from repro.runtime.admission import (
    AdmissionConfig,
    AdmissionController,
)
from repro.runtime.batcher import (
    BucketKey,
    Query,
    QueryResult,
    bucket_key,
    execute_bucket,
    pad_size,
)
from repro.runtime.calibrate import Calibrator, ServiceSig, sig_of
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.executor import Executor, ExecutorConfig, WorkerPool
from repro.runtime.metrics import BatchRecord, RuntimeMetrics
from repro.runtime.trace import TRACES, bursty_trace, zipf_models, zipf_trace

__all__ = [
    "AdmissionConfig",
    "AdmissionController",
    "BucketKey",
    "Query",
    "QueryResult",
    "bucket_key",
    "execute_bucket",
    "pad_size",
    "Calibrator",
    "ServiceSig",
    "sig_of",
    "Engine",
    "EngineConfig",
    "Executor",
    "ExecutorConfig",
    "WorkerPool",
    "BatchRecord",
    "RuntimeMetrics",
    "TRACES",
    "bursty_trace",
    "zipf_models",
    "zipf_trace",
]
