"""Measured-time service calibration for the serving executor.

The engine's original service model was a *line model*: schedule cycles at a
modeled clock plus a launch overhead.  It is deterministic and shape-aware,
but it is a guess — its constants were picked, not measured, so latency
dashboards and admission thresholds drift from what dispatches actually
cost.  This module replaces the guess with measurements while keeping the
event loop deterministic:

  * `Calibrator` holds a **frozen table** of measured service times, one
    entry per dispatch *signature* (program, backend, sampler, chain/iter
    budget, resumed-or-fresh, vmap-or-sharded route) at a probe pad size.
  * `warmup()` (driven by `Engine.calibrate()`) executes each signature a
    few times for real, wall-timed, drops the first repeat (jit compile)
    and freezes the median.
  * `predict()` answers from the table when the signature was warmed —
    scaled across pad sizes by the chain-wave ratio, which is the only
    shape effect the line model believes in — and **falls back to the line
    model cold**, so an uncalibrated engine behaves exactly like the old
    one.

Determinism: the table never updates during `Engine.run()` — measured
dispatch times observed by the run are recorded in the metrics for
prediction-error reporting, but the simulated clock only ever reads the
frozen table.  Two runs with the same seed and the same calibrator produce
identical metrics; re-calibrating produces a new table (wall time is noisy)
but each table is internally consistent.
"""

from __future__ import annotations

import dataclasses
import time

from repro.obs import tracer


@dataclasses.dataclass(frozen=True)
class ServiceSig:
    """Everything a dispatch's cost depends on, minus the pad size (pads
    scale by the wave ratio — see `Calibrator.predict`).  The BN clamp set
    and MRF pin flag are part of the signature: different clamp sets lower
    different gather-group structures with different per-sweep cost, so
    they must not share a measurement."""

    program_key: str
    kind: str
    backend: str
    sampler: str
    clamp_nodes: tuple
    has_pins: bool
    n_chains: int
    n_iters: int
    burn_in: int
    thin: int
    resumed: bool
    route: str  # "vmap" | "sharded"
    # fused Pallas rounds are bit-exact with unfused but cost differently;
    # they must not share a measurement
    fused: bool = False


def sig_of(key, route: str = "vmap") -> ServiceSig:
    """The service signature of a `batcher.BucketKey` on a given route."""
    return ServiceSig(
        program_key=key.program_key,
        kind=key.kind,
        backend=key.backend,
        sampler=key.sampler,
        clamp_nodes=key.clamp_nodes,
        has_pins=key.has_pins,
        n_chains=key.n_chains,
        n_iters=key.n_iters,
        burn_in=key.burn_in,
        thin=key.thin,
        resumed=key.resumed,
        route=route,
        fused=key.fused,
    )


def _median(xs: list[float]) -> float:
    ys = sorted(xs)
    m = len(ys) // 2
    return ys[m] if len(ys) % 2 else 0.5 * (ys[m - 1] + ys[m])


@dataclasses.dataclass
class Calibrator:
    """Per-signature measured service times with a line-model cold start.

    The line-model constants mirror the engine's historical defaults: one
    launch overhead per microbatch, the schedule's cycle estimate per sweep,
    one wave per `chain_slots` chains of the padded batch."""

    clock_hz: float = 500e6
    launch_overhead_cycles: int = 50_000
    chain_slots: int = 256
    # frozen measurements: sig -> (probe pad size, median seconds)
    measured: dict = dataclasses.field(default_factory=dict)

    # -- the cold fallback --------------------------------------------------

    def _waves(self, n_padded: int, n_chains: int) -> int:
        return -(-n_padded * n_chains // self.chain_slots)

    def line_s(
        self, program, sig: ServiceSig, n_padded: int, shard_width: int = 1
    ) -> float:
        """The line service model (the pre-calibration engine behavior).

        A sharded dispatch splits the *compute* cycles over the mesh slice
        but still pays every comm cycle — the paper's multi-chip posture,
        where inter-chip exchange is the part that does not scale."""
        cost = program.schedule.cost()
        if shard_width > 1:
            sweep = cost["compute_cycles"] / shard_width + cost["comm_cycles"]
        else:
            sweep = cost["total_cycles"]
        waves = self._waves(n_padded, sig.n_chains)
        cycles = self.launch_overhead_cycles + sweep * sig.n_iters * waves
        return cycles / self.clock_hz

    # -- measurements -------------------------------------------------------

    def record(self, sig: ServiceSig, n_padded: int, seconds: float) -> None:
        """Freeze a measurement for `sig` at probe pad `n_padded` (later
        records for the same signature overwrite — warmup records once)."""
        self.measured[sig] = (int(n_padded), float(seconds))

    def warmed(self, sig: ServiceSig) -> bool:
        return sig in self.measured

    def predict(
        self, program, sig: ServiceSig, n_padded: int, shard_width: int = 1
    ) -> tuple[float, str]:
        """(service seconds, "measured" | "line").

        Measured predictions scale across pad sizes by the chain-wave ratio
        (on the ladder sizes the engine uses, n_padded x n_chains rarely
        exceeds one wave, so this is usually the identity)."""
        entry = self.measured.get(sig)
        if entry is None:
            return self.line_s(program, sig, n_padded, shard_width), "line"
        probe_pad, probe_s = entry
        scale = self._waves(n_padded, sig.n_chains) / self._waves(
            probe_pad, sig.n_chains
        )
        return probe_s * scale, "measured"

    # -- warmup -------------------------------------------------------------

    def warmup(self, dispatch, buckets, repeats: int = 2) -> dict:
        """Time each distinct bucket signature through `dispatch` and freeze
        the medians.

        `buckets` is an iterable of (program, bucket_key, queries, route) —
        one representative microbatch per signature, on the route the
        serving loop will pick for it (the engine builds these from the
        submitted trace).  `dispatch(program, key, queries, route)` must
        execute the batch exactly as the serving loop will (same
        executable, same pad, same vmap/sharded path) and return the padded
        size.  The first timing of every signature pays the jit compile and
        is dropped; the median of the `repeats` that follow is frozen.
        Returns {sig: seconds}."""
        out = {}
        for program, key, qs, route in buckets:
            sig = sig_of(key, route)
            if self.warmed(sig):
                continue
            with tracer.span(
                "warmup_compile", cat="calibrate",
                program=sig.program_key, kind=sig.kind,
                sampler=sig.sampler, route=route, fused=sig.fused,
            ):
                # untimed rep: pays the jit compile
                n_padded = dispatch(program, key, qs, route)
            times = []
            for rep in range(max(1, repeats)):
                with tracer.span(
                    "warmup_rep", cat="calibrate",
                    program=sig.program_key, kind=sig.kind,
                    sampler=sig.sampler, route=route, rep=rep,
                ):
                    t0 = time.perf_counter()
                    dispatch(program, key, qs, route)
                    times.append(time.perf_counter() - t0)
            self.record(sig, n_padded, _median(times))
            out[sig] = self.measured[sig][1]
            tracer.instant(
                "calibrated", cat="calibrate",
                program=sig.program_key, kind=sig.kind,
                sampler=sig.sampler, route=route,
                n_padded=n_padded, n_reps=max(1, repeats),
                wargs={"median_s": self.measured[sig][1]},
            )
        return out
