"""`SamplingGraph` — the compile chain's input IR (paper Sec. II + Fig. 8).

Bayes nets and grid MRFs enter the compiler through one canonical form: an
undirected *conflict graph* (edge = the two RVs may not update in the same
round) plus per-RV cardinalities and baked-in evidence.  The original model
is kept as the `source` payload — later passes need the CPTs / potentials to
generate code — but every structural decision (coloring, placement,
scheduling) reads only the canonical fields, which is what lets one pipeline
serve both model families.

The IR hashes stably: `ir_key` is a sha256 over the canonical structure AND
the numeric parameters (CPT bytes, MRF potentials), so it can key the
program cache — two models that would compile to the same program share a
key, and any parameter change invalidates it.  Runtime inputs (the MRF
evidence image, PRNG keys, chain counts) are deliberately *not* part of the
IR: a serving workload re-runs one cached program with fresh data.

Evidence comes in two modes, recorded as `evidence_mode`:

  * ``"baked"``   — the (node, value) pairs are part of the program: they
    feed `ir_key`, the schedule drops them from every round, and the CPT
    gathers read their fixed values.  Two queries that differ only in an
    observed value hash to *different* programs.
  * ``"runtime"`` — structure-only canonicalization for the serving path
    (`repro.runtime`): `ir_key` hashes cards/edges/parameters but no
    evidence, and per-query observations enter `CompiledProgram.run()` as
    clamp masks (BN) / pinned pixels (MRF) instead.  Every query on the
    same model hits the same cached program.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib

import numpy as np

from repro.core.graphs import DiscreteBayesNet, GridMRF


def _hash_field(h, tag: str, data: bytes) -> None:
    """Domain-separated hashing: tag + 8-byte length prefix + payload, so no
    two field byte-streams can be re-split into a colliding message."""
    h.update(tag.encode())
    h.update(len(data).to_bytes(8, "little"))
    h.update(data)


@dataclasses.dataclass(frozen=True)
class SamplingGraph:
    """Canonical conflict-graph IR for a discrete sampling workload."""

    kind: str  # "bn" | "mrf"
    n_nodes: int
    cards: tuple[int, ...]  # per-RV cardinality
    edges: tuple[tuple[int, int], ...]  # sorted conflict edges, i < j
    evidence: tuple[tuple[int, int], ...]  # sorted (node, value) pairs
    source: DiscreteBayesNet | GridMRF
    name: str = "graph"
    evidence_mode: str = "baked"  # "baked" | "runtime"

    def adjacency(self) -> list[set[int]]:
        adj: list[set[int]] = [set() for _ in range(self.n_nodes)]
        for i, j in self.edges:
            adj[i].add(j)
            adj[j].add(i)
        return adj

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    @functools.cached_property
    def ir_key(self) -> str:
        """Stable content hash: structure + numeric parameters + evidence.

        Every field is hashed as tag + length + bytes (`_hash_field`): a bare
        concatenation of the byte streams would let distinct `(cards, edges,
        evidence)` splits collide — e.g. one edge vs the same two ints read
        as an evidence pair.

        `evidence_mode` is hashed too: a runtime-evidence program accepts
        per-query clamps that a baked one rejects, so the two must never
        share a cache slot even when the structural fields agree."""
        h = hashlib.sha256()
        _hash_field(h, "kind", self.kind.encode())
        _hash_field(h, "evmode", self.evidence_mode.encode())
        _hash_field(h, "cards", np.asarray(self.cards, np.int64).tobytes())
        _hash_field(h, "edges", np.asarray(self.edges, np.int64).tobytes())
        _hash_field(
            h, "evidence", np.asarray(self.evidence, np.int64).tobytes()
        )
        if isinstance(self.source, DiscreteBayesNet):
            for ps, cpt in zip(self.source.parents, self.source.cpts):
                _hash_field(h, "parents", np.asarray(ps, np.int64).tobytes())
                _hash_field(
                    h, "cpt",
                    np.ascontiguousarray(cpt, np.float64).tobytes(),
                )
        else:
            m = self.source
            _hash_field(
                h, "mrf",
                f"{m.height},{m.width},{m.n_labels},{m.theta!r},"
                f"{m.h!r},{m.data_cost}".encode(),
            )
        return h.hexdigest()


def from_bayesnet(
    bn: DiscreteBayesNet,
    evidence: dict[int, int] | None = None,
    evidence_mode: str = "baked",
) -> SamplingGraph:
    """Canonicalize a BN: the conflict graph is the moral graph (i ~ j iff
    j in MB(i)).  With `evidence_mode="baked"` (default) evidence is part of
    the program (baked into the CPT gathers), hence part of the IR; with
    `"runtime"` the IR is structure-only and observations arrive per query
    at `CompiledProgram.run(evidence=...)`."""
    bn.validate()
    if evidence_mode not in ("baked", "runtime"):
        raise ValueError(f"unknown evidence_mode {evidence_mode!r}")
    if evidence_mode == "runtime" and evidence:
        raise ValueError(
            "structure-only canonicalization takes no evidence; pass the "
            "observations to CompiledProgram.run(evidence=...) instead"
        )
    adj = bn.moral_adjacency()
    edges = tuple(
        (i, j) for i in range(bn.n_nodes) for j in sorted(adj[i]) if i < j
    )
    ev = tuple(sorted((int(k), int(v)) for k, v in (evidence or {}).items()))
    for node, val in ev:
        if not (0 <= node < bn.n_nodes and 0 <= val < bn.cards[node]):
            raise ValueError(f"evidence {node}={val} out of range")
    return SamplingGraph(
        kind="bn",
        n_nodes=bn.n_nodes,
        cards=tuple(int(c) for c in bn.cards),
        edges=edges,
        evidence=ev,
        source=bn,
        name=bn.name,
        evidence_mode=evidence_mode,
    )


def from_mrf(
    mrf: GridMRF, pinned: dict[int, int] | None = None
) -> SamplingGraph:
    """Canonicalize a grid MRF: the conflict graph is the 4-connected grid
    adjacency.  The evidence *image* is always a runtime input (same
    program, new data every request).  `pinned` optionally bakes pixels at
    known labels into the program ({site: label}); without it the IR is
    runtime-mode and per-query pins go to `CompiledProgram.run(pins=...)`."""
    adj = mrf.adjacency()
    n = mrf.height * mrf.width
    edges = tuple((i, j) for i in range(n) for j in sorted(adj[i]) if i < j)
    ev = tuple(sorted((int(k), int(v)) for k, v in (pinned or {}).items()))
    for site, lab in ev:
        if not (0 <= site < n and 0 <= lab < mrf.n_labels):
            raise ValueError(f"pinned pixel {site}={lab} out of range")
    # the checkerboard backend executes whole parity classes; a class that
    # is pinned away entirely would change the per-iteration key-split
    # structure and silently diverge from the eager engine
    for parity in (0, 1):
        cls = {
            r * mrf.width + c
            for r in range(mrf.height)
            for c in range(mrf.width)
            if (r + c) % 2 == parity
        }
        if cls and cls <= {site for site, _ in ev}:
            raise ValueError(
                f"pinned pixels cover the entire parity-{parity} class; "
                "at least one free site per checkerboard color is required"
            )
    return SamplingGraph(
        kind="mrf",
        n_nodes=n,
        cards=(mrf.n_labels,) * n,
        edges=edges,
        evidence=ev,
        source=mrf,
        name=mrf.name,
        evidence_mode="baked" if ev else "runtime",
    )


def canonicalize(
    model: DiscreteBayesNet | GridMRF,
    evidence: dict[int, int] | None = None,
    evidence_mode: str = "baked",
) -> SamplingGraph:
    """Front-end dispatch: any supported model -> SamplingGraph.

    `evidence_mode="runtime"` is the serving path's structure-only form:
    the returned IR hashes cards/edges/parameters but no observations, so
    every query on the same model shares one `ir_key`.  An MRF's mode is
    determined by its pins, not this argument (no pins here ⇒ runtime-mode
    IR; baked pins go through `ir.from_mrf(mrf, pinned=...)`), but the
    argument is still validated so a typo cannot pass silently."""
    if evidence_mode not in ("baked", "runtime"):
        raise ValueError(f"unknown evidence_mode {evidence_mode!r}")
    if isinstance(model, DiscreteBayesNet):
        return from_bayesnet(model, evidence, evidence_mode)
    if isinstance(model, GridMRF):
        if evidence:
            raise ValueError(
                "MRF evidence is a runtime input of CompiledProgram.run(), "
                "not part of the IR (baked pins go through ir.from_mrf)"
            )
        return from_mrf(model)
    raise TypeError(f"cannot canonicalize {type(model).__name__}")
