"""`CompiledProgram` — the executable artifact the compile chain emits.

One object carries everything a serving layer needs: the canonical IR (and
its content hash), the placement and round schedule the passes chose, the
backend tensors (dense per-color CPT gathers for BNs), and diagnostics.
`run()` executes on one device under `jax.jit`; `run_sharded()` executes the
same program across a device mesh via the `shard_map` engines in
`core/distributed.py`, with the Sec. IV-B placement deciding node ownership.

Execution is bit-exact with the eager paths (`bayesnet.run_gibbs`,
`mrf.run_mrf_gibbs`): the schedule's rounds are, by construction, the same
color groups in the same order, and the program cross-checks that at
compile time — so a cached program is a pure win, never a behavior change.

`compile_graph()` is the entry point and fronts an LRU program cache keyed
by `(ir_key, mesh_shape, pipeline)`: a serving workload that re-submits the
same model (fresh evidence image, fresh PRNG key) pays the pass pipeline
once.  The capacity is runtime-configurable (`set_cache_capacity`) and the
stats (`cache_stats`) report hits/misses/evictions/size for the serving
dashboards.

Programs compiled from a *runtime-evidence* IR (`evidence_mode="runtime"`,
see `ir.py`) additionally accept per-query observations at `run()`:
`evidence={node: value}` clamps BN nodes (the lowering is specialized per
observed-node *set* and cached on the program; values stay runtime), and
`pins={site: label}` pins MRF pixels (a plain runtime array — no
specialization).  Both are bit-exact with baking the same observations at
compile time, and the first use of every clamped specialization
cross-checks the schedule backend against the eager engine just like the
unclamped first lowering does.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import Finding
from repro.analysis import verify as verify_mod
from repro.compile import backend as backend_mod
from repro.compile import ir as ir_mod
from repro.compile import passes as passes_mod
from repro.compile.schedule import Schedule
from repro.core import bayesnet as bnet
from repro.core import distributed as dist_mod
from repro.core import mrf as mrf_mod
from repro.core.graphs import DiscreteBayesNet, GridMRF
from repro.core.mapping import MeshPlacement
from repro.obs import profile as profile_mod
from repro.obs import tracer


@dataclasses.dataclass
class CompiledProgram:
    ir: ir_mod.SamplingGraph
    placement: MeshPlacement
    schedule: Schedule
    diagnostics: dict
    cbn: bnet.CompiledBayesNet | None = None  # BN backend artifact
    compile_s: float = 0.0
    # lazily lowered + cross-checked schedule-direct executable
    _schedule_exec: object = dataclasses.field(default=None, repr=False)
    # runtime-evidence specializations, keyed by (clamp node set, backend);
    # values are round-ordered ColorGroup lists (BN only)
    _clamp_execs: dict = dataclasses.field(default_factory=dict, repr=False)
    # how many clamped lowerings were built (serving metric: "recompiles")
    clamp_lowerings: int = 0
    # samplers whose fused BN kernel path passed the first-use cross-check
    _fused_checked: set = dataclasses.field(default_factory=set, repr=False)

    @property
    def program_key(self) -> str:
        return self.ir.ir_key

    @property
    def kind(self) -> str:
        return self.ir.kind

    @property
    def mrf(self) -> GridMRF:
        if self.kind != "mrf":
            raise TypeError(f"program compiled for kind={self.kind!r}")
        return self.ir.source

    def schedule_executable(self):
        """The schedule lowered for direct execution (cached per program).

        The first lowering runs the backend cross-check: a tiny run of both
        backends must agree bit for bit before the schedule backend is ever
        trusted with real work."""
        if self._schedule_exec is None:
            with tracer.span(
                "lower_schedule", cat="compile", program=self.program_key,
                kind=self.kind, n_rounds=len(self.schedule.rounds),
            ):
                ex = backend_mod.lower_schedule(self)
            # the first-lowering cross-check is real compile+execute cost;
            # traced separately so the timeline shows what trust costs
            with tracer.span(
                "cross_check", cat="compile", program=self.program_key,
                kind=self.kind,
            ):
                backend_mod.cross_check(self, ex)
            self._schedule_exec = ex
        return self._schedule_exec

    def ensure_fused_cross_check(
        self, sampler: str, *, sharded: bool = False, mesh=None
    ) -> None:
        """First-use gate for the fused kernel paths (mirrors the schedule
        backend's first-lowering check): a tiny fused run must match the
        eager engine bit for bit before `fused=True` ever serves this
        program with this sampler.  `sharded=True` extends the guarantee to
        the one-shard_map-body engines in `core/distributed.py` (bits must
        also match the single-device fused run) and is checked lazily at
        first *sharded* fused use, so single-device serving never pays the
        shard_map compile.  Cached per (sampler, route) — each check runs
        once, the guarantee holds for the program's lifetime (the
        single-device tag stays the bare sampler name: it predates the
        sharded leg and callers key on it)."""
        tag = (sampler, "sharded") if sharded else sampler
        if tag in self._fused_checked:
            return
        with tracer.span(
            "cross_check_fused", cat="compile", program=self.program_key,
            sampler=sampler, sharded=sharded,
        ):
            backend_mod.cross_check_fused(
                self, self.schedule_executable(), sampler,
                sharded=sharded, mesh=mesh,
            )
        self._fused_checked.add(tag)

    def clamped_executable(self, clamp_nodes: tuple[int, ...], backend: str):
        """Round-ordered gather groups specialized for a runtime-evidence
        node set (BN only; cached per (set, backend) on the program).

        The node *set* is static — it fixes the gather-tensor shapes — while
        the observed *values* stay runtime inputs, so every query sharing an
        observation pattern reuses one specialization.  `backend="schedule"`
        derives the groups from `Schedule.rounds` and cross-checks the first
        lowering against an independently derived eager grouping
        (`cross_check_clamped`), mirroring the unclamped guarantee."""
        key = (clamp_nodes, backend)
        groups = self._clamp_execs.get(key)
        if groups is None:
            with tracer.span(
                "clamp_lowering", cat="compile", program=self.program_key,
                n_clamped=len(set(clamp_nodes)), backend=backend,
            ):
                groups = self._build_clamped(clamp_nodes, backend)
            self._clamp_execs[key] = groups
            self.clamp_lowerings += 1
        return groups

    def _build_clamped(self, clamp_nodes: tuple[int, ...], backend: str):
        if len(set(clamp_nodes)) >= self.ir.n_nodes:
            # same ValueError on both backends (the schedule lowering
            # would raise its own ScheduleLoweringError otherwise)
            raise ValueError(
                "runtime evidence clamps every free RV; nothing to sample"
            )
        if backend == "schedule":
            ex = backend_mod.lower_schedule(self, clamp_nodes)
            backend_mod.cross_check_clamped(self, ex)
            return ex.round_groups
        groups = bnet.build_clamped_groups(
            self.ir.source,
            [np.asarray(g.nodes) for g in self.cbn.groups],
            clamp_nodes,
        )
        if not groups:
            raise ValueError(
                "runtime evidence clamps every free RV; nothing to sample"
            )
        return groups

    def _bn_clamp_arrays(self, evidence: dict):
        """Validate a runtime-evidence dict -> (nodes, vals (n,), mask (n,))."""
        if self.ir.evidence_mode != "runtime":
            raise ValueError(
                "BN evidence is baked into this program at compile time; "
                "per-query evidence needs a structure-only IR "
                "(ir.canonicalize(bn, evidence_mode='runtime'))"
            )
        if not isinstance(evidence, dict):
            raise TypeError("BN runtime evidence is a {node: value} dict")
        n = self.ir.n_nodes
        vals = np.zeros(n, np.int64)
        mask = np.zeros(n, bool)
        for node, val in evidence.items():
            node, val = int(node), int(val)
            if not (0 <= node < n and 0 <= val < self.ir.cards[node]):
                raise ValueError(f"evidence {node}={val} out of range")
            vals[node] = val
            mask[node] = True
        nodes = tuple(sorted(int(k) for k in evidence))
        return nodes, jnp.asarray(vals, jnp.int32), jnp.asarray(mask)

    def _summarize_quality(self, state, free_mask=None, total_kept=None):
        """Host-side reduction of a run's quality accumulator ->
        `diag.accum.QualitySnapshot` (clamped nodes masked out of the
        R-hat/ESS rollups via `free_mask`)."""
        from repro.diag import accum as diag_accum

        if state.quality is None:
            raise ValueError(
                "chain state carries no quality accumulator; resume a run "
                "that was started with diagnostics=True"
            )
        cards = np.asarray(self.cbn.cards) if self.kind == "bn" else None
        return diag_accum.summarize(
            state.quality, cards=cards, free_mask=free_mask,
            total_kept=total_kept,
        )

    def run(
        self,
        key: jax.Array | None,
        *,
        n_chains: int = 32,
        n_iters: int = 200,
        burn_in: int | None = None,
        thin: int = 1,
        sampler: str = "lut_ky",
        evidence=None,
        pins=None,
        backend: str = "schedule",
        fused: bool = False,
        carry_state=None,
        return_state: bool = False,
        diagnostics: bool = False,
    ):
        """Single-device jitted execution.

        BN: returns (marginals (n, V), final vals); `burn_in` defaults to
        50 and `thin` keeps every thin-th post-burn-in sweep in the
        marginals.  On a baked-evidence program observations were fixed at
        compile time; on a runtime-evidence program (`evidence_mode=
        "runtime"`), `evidence={node: value}` clamps per query — bit-exact
        with baking the same dict.  MRF: `evidence` is the runtime
        observation image; returns final labels (B, H, W) and has no
        burn-in/thinning concept (passing one raises rather than being
        dropped).  `pins={site: label}` (or a ((H, W) bool, (H, W) int32)
        pair) clamps pixels per query on a runtime-mode MRF program.

        `backend` picks the execution path: "schedule" (the default)
        executes the compiled `Schedule`'s rounds directly — bit-exact with
        "eager", the eager Gibbs engines, cross-checked at first lowering;
        "eager" is the escape hatch.  `fused` additionally routes the
        schedule rounds through the fused Pallas kernels — MRF half-steps
        (lut_ky) and BN color rounds (lut_ky/exact_ky; first fused use per
        sampler runs its own eager cross-check) — still bit-exact.

        `return_state=True` additionally returns the chain state
        (`bayesnet.BNChainState` / `mrf.MRFChainState`) as the last element;
        passing it back via `carry_state=` resumes the run for `n_iters`
        *more* sweeps (then `key` is ignored and may be None).  A run sliced
        at any boundaries is bit-exact with the uninterrupted run, provided
        each slice repeats the same static arguments (burn_in, thin,
        sampler, backend, evidence/pins).

        `diagnostics=True` threads the streaming quality accumulator
        (`repro.diag.accum`) through the run and appends a
        `diag.accum.QualitySnapshot` (split-chain R-hat, batch-means ESS,
        pooled per-node marginals) to the return value: BN runs return
        (marginals, vals, snapshot[, state]), MRF runs (labels, snapshot
        [, state]).  The accumulator is pure jax riding on the chain-state
        carry — the draw stream (and therefore marginals/vals/labels) is
        bit-identical with diagnostics off.  Resuming with `carry_state=`
        requires the original run to have been started with
        diagnostics=True (the accumulator lives in the state)."""
        if backend not in ("eager", "schedule"):
            raise ValueError(f"unknown backend {backend!r}")
        if fused and backend != "schedule":
            raise ValueError("fused execution requires backend='schedule'")
        if thin < 1:
            raise ValueError(f"thin must be >= 1, got {thin}")
        if carry_state is None and key is None:
            raise ValueError("a fresh run (carry_state=None) needs a PRNG key")
        diag_total = None
        if diagnostics:
            if carry_state is None:
                # the accumulator's split point is fixed from this call's
                # full budget; resumed slices ignore diag_total entirely
                diag_total = jnp.asarray(n_iters, jnp.int32)
            elif getattr(carry_state, "quality", None) is None:
                raise ValueError(
                    "diagnostics=True on a resumed run needs a carry from a "
                    "run that was itself started with diagnostics=True (the "
                    "accumulator lives in the chain state)"
                )
        inner_state = return_state or diagnostics
        if self.kind == "bn":
            if carry_state is not None and not isinstance(
                carry_state, bnet.BNChainState
            ):
                raise TypeError(
                    "BN programs resume from a bayesnet.BNChainState, got "
                    f"{type(carry_state).__name__}"
                )
            if pins is not None:
                raise ValueError(
                    "pins are an MRF concept; BN observations go through "
                    "evidence={node: value}"
                )
            if fused:
                backend_mod.check_fused_sampler(sampler)
                self.ensure_fused_cross_check(sampler)
            burn_in = 50 if burn_in is None else burn_in
            free_mask = None
            if evidence is not None:
                nodes, ev_vals, ev_mask = self._bn_clamp_arrays(evidence)
                free_mask = ~np.asarray(ev_mask)
                groups = self.clamped_executable(nodes, backend)
                out = backend_mod.bn_run_clamped(
                    self.cbn, groups, ev_vals, ev_mask, key,
                    n_chains=n_chains, n_iters=n_iters, burn_in=burn_in,
                    sampler=sampler, thin=thin,
                    carry=carry_state, return_state=inner_state,
                    fused=fused, diag_total=diag_total,
                )
            elif backend == "schedule":
                if (profile_mod.enabled() and carry_state is None
                        and not diagnostics):
                    profile_mod.capture_program(
                        self, n_chains=n_chains, n_iters=n_iters,
                        burn_in=burn_in, thin=thin, sampler=sampler,
                        fused=fused,
                    )
                out = backend_mod.run_bn_schedule(
                    self.schedule_executable(), key, n_chains=n_chains,
                    n_iters=n_iters, burn_in=burn_in, sampler=sampler,
                    thin=thin, carry=carry_state, return_state=inner_state,
                    fused=fused, diag_total=diag_total,
                )
            else:
                out = bnet.run_gibbs(
                    self.cbn, key, n_chains=n_chains, n_iters=n_iters,
                    burn_in=burn_in, sampler=sampler, thin=thin,
                    carry=carry_state, return_state=inner_state,
                    diag_total=diag_total,
                )
            if not diagnostics:
                return out
            marginals, vals, state = out
            total_kept = None
            if carry_state is None:
                total_kept = max((n_iters - burn_in + thin - 1) // thin, 0)
            snap = self._summarize_quality(
                state, free_mask=free_mask, total_kept=total_kept
            )
            if return_state:
                return marginals, vals, snap, state
            return marginals, vals, snap
        if carry_state is not None and not isinstance(
            carry_state, mrf_mod.MRFChainState
        ):
            raise TypeError(
                "MRF programs resume from an mrf.MRFChainState, got "
                f"{type(carry_state).__name__}"
            )
        if evidence is None:
            raise ValueError("MRF programs take the evidence image at run()")
        if burn_in is not None:
            raise ValueError(
                "MRF programs return final states only; burn_in does not apply"
            )
        if thin != 1:
            raise ValueError(
                "MRF programs return final states only; thin does not apply"
            )
        pin_mask = pin_vals = None
        if pins is not None:
            if self.ir.evidence_mode != "runtime":
                raise ValueError(
                    "this program bakes its pinned pixels at compile time "
                    "(ir.from_mrf(mrf, pinned=...)); per-query pins need a "
                    "runtime-mode IR"
                )
            if isinstance(pins, dict):
                pin_mask, pin_vals = backend_mod.pin_arrays(self.mrf, pins)
            else:
                pin_mask, pin_vals = pins
        elif self.ir.evidence:
            pin_mask, pin_vals = backend_mod.pin_arrays(
                self.mrf, self.ir.evidence
            )
        if backend == "schedule":
            if fused:
                self.ensure_fused_cross_check(sampler)
            if (profile_mod.enabled() and carry_state is None
                    and not diagnostics and pin_mask is None):
                profile_mod.capture_program(
                    self, n_chains=n_chains, n_iters=n_iters,
                    sampler=sampler, fused=fused,
                )
            out = backend_mod.run_mrf_schedule(
                self.schedule_executable(), evidence, key, n_chains=n_chains,
                n_iters=n_iters, sampler=sampler, fused=fused,
                pin_mask=pin_mask, pin_vals=pin_vals,
                carry=carry_state, return_state=inner_state,
                diag_total=diag_total,
            )
        else:
            out = mrf_mod.run_mrf_gibbs(
                self.mrf, evidence, key, n_chains=n_chains, n_iters=n_iters,
                sampler=sampler, pin_mask=pin_mask, pin_vals=pin_vals,
                carry=carry_state, return_state=inner_state,
                diag_total=diag_total,
            )
        if not diagnostics:
            return out
        labels, state = out
        free_mask = None
        if pin_mask is not None:
            # pinned pixels are constant by construction; keep them out of
            # the R-hat/ESS rollups like clamped BN nodes
            free_mask = ~np.asarray(pin_mask).reshape(-1)
        snap = self._summarize_quality(
            state, free_mask=free_mask,
            total_kept=n_iters if carry_state is None else None,
        )
        if return_state:
            return labels, snap, state
        return labels, snap

    def run_sharded(
        self,
        key: jax.Array,
        mesh: jax.sharding.Mesh,
        *,
        n_chains: int = 32,
        n_iters: int = 200,
        burn_in: int | None = None,
        sampler: str = "lut_ky",
        evidence: jax.Array | None = None,
        backend: str = "schedule",
        fused: bool = False,
        thin: int = 1,
        carry_state=None,
        return_state: bool = False,
        diagnostics: bool = False,
        profile_sig: str | None = None,
        **axes,
    ):
        """shard_map execution across a device mesh; node ownership follows
        this program's placement (see distributed.run_program_sharded).
        With backend="schedule" (the default, like `run()`), rounds come
        from this program's schedule and each round's comm op is routed onto
        its named collective; backend="eager" is the escape hatch.

        `fused=True` runs the one-shard_map-body engines: the same Pallas
        color-round kernels as single-device `run(fused=True)`, with
        `lax.ppermute` halos / `lax.psum` merges between kernel calls, all
        inside the scanned loop.  The draw stream is bit-identical to the
        single-device fused run (asserted at first sharded-fused use), so
        `thin` / `carry_state` / `return_state` / `diagnostics` carry the
        exact `run()` contracts — a query may be sliced across a route
        boundary and resume on either side."""
        if self.kind == "bn" and evidence is not None:
            raise ValueError(
                "runtime evidence clamps are a single-device serving path; "
                "bake the evidence for sharded execution"
            )
        if not fused:
            if carry_state is not None or return_state or diagnostics:
                raise ValueError(
                    "carry_state/return_state/diagnostics ride the fused "
                    "sharded datapath; pass fused=True"
                )
            if thin != 1:
                raise ValueError(
                    "thin rides the fused sharded datapath; pass fused=True"
                )
            return dist_mod.run_program_sharded(
                self, key, mesh, n_chains=n_chains, n_iters=n_iters,
                burn_in=burn_in, sampler=sampler, evidence=evidence,
                backend=backend, **axes,
            )
        if backend != "schedule":
            raise ValueError("fused execution requires backend='schedule'")
        if thin < 1:
            raise ValueError(f"thin must be >= 1, got {thin}")
        if carry_state is None and key is None:
            raise ValueError("a fresh run (carry_state=None) needs a PRNG key")
        self.ensure_fused_cross_check(sampler, sharded=True)
        diag_total = None
        if diagnostics:
            if carry_state is None:
                diag_total = jnp.asarray(n_iters, jnp.int32)
            elif getattr(carry_state, "quality", None) is None:
                raise ValueError(
                    "diagnostics=True on a resumed run needs a carry from a "
                    "run that was itself started with diagnostics=True (the "
                    "accumulator lives in the chain state)"
                )
        inner_state = return_state or diagnostics
        if self.kind == "bn":
            if carry_state is not None and not isinstance(
                carry_state, bnet.BNChainState
            ):
                raise TypeError(
                    "BN programs resume from a bayesnet.BNChainState, got "
                    f"{type(carry_state).__name__}"
                )
            burn_in = 50 if burn_in is None else burn_in
        else:
            if carry_state is not None and not isinstance(
                carry_state, mrf_mod.MRFChainState
            ):
                raise TypeError(
                    "MRF programs resume from an mrf.MRFChainState, got "
                    f"{type(carry_state).__name__}"
                )
            if evidence is None:
                raise ValueError(
                    "MRF programs take the evidence image at run_sharded()"
                )
            if burn_in is not None:
                raise ValueError(
                    "MRF programs return final states only; burn_in does "
                    "not apply"
                )
            if thin != 1:
                raise ValueError(
                    "MRF programs return final states only; thin does not "
                    "apply"
                )
        out = dist_mod.run_program_sharded(
            self, key, mesh, n_chains=n_chains, n_iters=n_iters,
            burn_in=burn_in, sampler=sampler, evidence=evidence,
            backend=backend, fused=True, thin=thin, carry=carry_state,
            return_state=inner_state, diag_total=diag_total,
            profile_sig=profile_sig, **axes,
        )
        if not diagnostics:
            return out
        if self.kind == "bn":
            marginals, vals, state = out
            total_kept = None
            if carry_state is None:
                total_kept = max((n_iters - burn_in + thin - 1) // thin, 0)
            snap = self._summarize_quality(
                state, free_mask=None, total_kept=total_kept
            )
            if return_state:
                return marginals, vals, snap, state
            return marginals, vals, snap
        labels, state = out
        snap = self._summarize_quality(
            state, free_mask=None,
            total_kept=n_iters if carry_state is None else None,
        )
        if return_state:
            return labels, snap, state
        return labels, snap


def _compile_uncached(
    graph: ir_mod.SamplingGraph,
    mesh_shape: tuple[int, int],
    passes=None,
    pipeline: str = "default",
) -> CompiledProgram:
    t0 = time.perf_counter()
    if passes is None:
        passes = passes_mod.named_pipeline(pipeline)
    with tracer.span(
        "compile_graph", cat="compile", ir=graph.ir_key, kind=graph.kind,
        n_nodes=graph.n_nodes, pipeline=pipeline,
        mesh_shape=list(mesh_shape),
    ):
        ctx = passes_mod.run_pipeline(graph, mesh_shape, passes)
    cbn = None
    if graph.kind == "bn":
        cbn = bnet.compile_bayesnet(
            graph.source, evidence=dict(graph.evidence), colors=ctx.colors
        )
        # cross-check the two lowerings: schedule rounds must be exactly
        # the backend's color groups, else "bit-exact" would be a lie
        # (raised, not asserted: this must hold under `python -O` too)
        if len(cbn.groups) != len(ctx.schedule.rounds):
            raise verify_mod.ScheduleVerificationError([Finding(
                rule="coverage", loc=f"{graph.name}:lowering",
                message=(
                    f"backend built {len(cbn.groups)} color groups but the "
                    f"schedule has {len(ctx.schedule.rounds)} rounds"
                ),
            )])
        for g, r in zip(cbn.groups, ctx.schedule.rounds):
            if tuple(int(v) for v in np.asarray(g.nodes)) != r.nodes:
                raise verify_mod.ScheduleVerificationError([Finding(
                    rule="coverage", loc=f"{graph.name}:round {r.color}",
                    message=(
                        "backend color group and schedule round disagree on "
                        "node membership; the two lowerings would not be "
                        "bit-exact"
                    ),
                )])
    diagnostics = dict(ctx.diagnostics)
    diagnostics["pass_times_s"] = dict(ctx.pass_times_s)
    diagnostics["pipeline"] = pipeline
    prog = CompiledProgram(
        ir=graph,
        placement=ctx.placement,
        schedule=ctx.schedule,
        diagnostics=diagnostics,
        cbn=cbn,
        compile_s=time.perf_counter() - t0,
    )
    return prog


# ---------------------------------------------------------------------------
# LRU program cache (serving-style repeated workloads pay compile once)
# ---------------------------------------------------------------------------

_CACHE: collections.OrderedDict[tuple, CompiledProgram] = (
    collections.OrderedDict()
)
_CACHE_CAPACITY = 128
_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def set_cache_capacity(capacity: int) -> int:
    """Set the program-cache capacity (serving knob: how many distinct
    model structures stay warm).  Shrinking evicts LRU-first immediately.
    Returns the previous capacity."""
    global _CACHE_CAPACITY
    if capacity < 1:
        raise ValueError(f"cache capacity must be >= 1, got {capacity}")
    prev, _CACHE_CAPACITY = _CACHE_CAPACITY, capacity
    while len(_CACHE) > _CACHE_CAPACITY:
        _CACHE.popitem(last=False)
        _STATS["evictions"] += 1
    return prev


def compile_graph(
    model: DiscreteBayesNet | GridMRF | ir_mod.SamplingGraph,
    evidence: dict[int, int] | None = None,
    *,
    mesh_shape: tuple[int, int] = (4, 4),
    passes=None,
    pipeline: str = "default",
    cache: bool = True,
    cross_check: bool = False,
) -> CompiledProgram:
    """Front door of the compile chain: model -> IR -> passes -> program.

    With `cache=True` (default) programs are memoized by the IR content
    hash, mesh shape, and pipeline name; ad-hoc `passes` bypass the cache
    (they may not be a registered lowering), while `pipeline=` picks a
    *named* pass list from `passes.named_pipeline` ("default", "runtime")
    that caches like any other.  `cross_check=True` lowers the
    schedule-direct backend at compile time and bit-checks it against the
    eager engines (otherwise the check runs at the backend's first use)."""
    if isinstance(model, ir_mod.SamplingGraph):
        if evidence:
            # silently dropping it would compile a different program than
            # the caller asked for — evidence belongs to the IR (BN) or to
            # run() (MRF), never to an already-canonicalized graph
            raise ValueError(
                "evidence must be baked into the SamplingGraph at "
                "canonicalization (ir.from_bayesnet/canonicalize); it cannot "
                "be re-applied to an existing IR"
            )
        graph = model
    else:
        graph = ir_mod.canonicalize(model, evidence)
    if passes is not None or not cache:
        prog = _compile_uncached(graph, mesh_shape, passes, pipeline)
        if cross_check:
            prog.schedule_executable()
        return prog
    key = (graph.ir_key, mesh_shape, pipeline)
    prog = _CACHE.get(key)
    if prog is not None:
        _STATS["hits"] += 1
        _CACHE.move_to_end(key)
        return prog
    _STATS["misses"] += 1
    prog = _compile_uncached(graph, mesh_shape, pipeline=pipeline)
    if cross_check:
        prog.schedule_executable()
    _CACHE[key] = prog
    if len(_CACHE) > _CACHE_CAPACITY:
        _CACHE.popitem(last=False)
        _STATS["evictions"] += 1
    return prog


def cache_stats() -> dict:
    total = _STATS["hits"] + _STATS["misses"]
    return {
        **_STATS,
        "size": len(_CACHE),
        "capacity": _CACHE_CAPACITY,
        "hit_rate": _STATS["hits"] / total if total else 0.0,
    }


def clear_program_cache() -> None:
    _CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0
