"""Pass pipeline: `moralize -> dsatur -> greedy_map -> schedule -> verify`.

Each pass is a named, timed transformation over a `PassContext`; the context
accumulates the artifacts (conflict graph, colors, placement, schedule) and
a diagnostics dict that benchmarks and `launch/report.py` render directly.
The passes wrap the existing `core/coloring.py` and `core/mapping.py`
heuristics — the pipeline is the compiler spine those modules were missing,
not a reimplementation of them.

Custom pipelines are first-class: `run_pipeline(ir, passes=[...])` lets a
benchmark swap `GreedyMapPass` for `RandomMapPass` (the Fig. 9 baseline) or
a future pass without touching the driver.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.analysis import verify as verify_mod
from repro.compile import schedule as schedule_mod
from repro.compile.ir import SamplingGraph
from repro.core import coloring as coloring_mod
from repro.core import mapping as mapping_mod
from repro.obs import tracer


@dataclasses.dataclass
class PassContext:
    """Mutable state threaded through the pipeline."""

    ir: SamplingGraph
    mesh_shape: tuple[int, int] = (4, 4)
    adj: list[set[int]] | None = None
    colors: np.ndarray | None = None
    placement: mapping_mod.MeshPlacement | None = None
    schedule: schedule_mod.Schedule | None = None
    diagnostics: dict = dataclasses.field(default_factory=dict)
    pass_times_s: dict = dataclasses.field(default_factory=dict)

    def require(self, *fields: str) -> None:
        for f in fields:
            if getattr(self, f) is None:
                raise RuntimeError(
                    f"pass ordering error: '{f}' not produced yet"
                )


class Pass:
    """A named pipeline stage; subclasses mutate the context in `run`."""

    name = "pass"

    def run(self, ctx: PassContext) -> None:
        raise NotImplementedError

    def __call__(self, ctx: PassContext) -> None:
        with tracer.span(
            f"pass:{self.name}", cat="compile",
            ir=ctx.ir.ir_key, n_nodes=ctx.ir.n_nodes,
            mesh_shape=list(ctx.mesh_shape),
        ):
            t0 = time.perf_counter()
            self.run(ctx)
            ctx.pass_times_s[self.name] = time.perf_counter() - t0


class MoralizePass(Pass):
    """Materialize the conflict graph.  The IR already canonicalized the
    moral / grid adjacency into edges; this pass expands it to the adjacency
    sets every later pass consumes, and records graph-shape diagnostics."""

    name = "moralize"

    def run(self, ctx: PassContext) -> None:
        ctx.adj = ctx.ir.adjacency()
        degrees = np.array([len(a) for a in ctx.adj] or [0])
        ctx.diagnostics.update(
            n_nodes=ctx.ir.n_nodes,
            n_edges=ctx.ir.n_edges,
            max_degree=int(degrees.max()),
        )


class DsaturPass(Pass):
    """RV-parallelism detection (paper C3): DSATUR coloring + verification."""

    name = "dsatur"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj")
        ctx.colors = coloring_mod.dsatur(ctx.adj)
        verify_mod.require_proper_coloring(
            ctx.adj, ctx.colors, loc=f"{ctx.ir.name}:dsatur"
        )
        stats = coloring_mod.color_stats(ctx.colors)
        ctx.diagnostics.update(
            n_colors=stats["n_colors"],
            color_balance=stats["balance"],
        )


class MergeSmallColorsPass(Pass):
    """Fuse tiny independent color classes into one round (serving-path
    optimization: every round is a kernel launch plus a barrier, so a tail
    of near-singleton colors makes the microbatched runtime pay launch
    overhead per round per query batch).

    A class with at most `max_size` nodes is folded into the first other
    class it shares no conflict edge with (smallest candidate first, color
    id as the tie-break, so the result is deterministic).  Merging two
    independent classes preserves proper coloring by definition; the pass
    re-verifies anyway, and `backend.lower_schedule` re-checks legality a
    second time before the merged rounds ever execute.

    On raw DSATUR output this is provably the identity: greedy coloring
    gives every node of class d a neighbor in every class below d (else it
    would have taken the smaller color), so no two classes are ever
    independent.  Its value is as the *normalizer* in the serving pipeline —
    any pass or imported coloring that splinters rounds (round splitters,
    per-component colorings, hand-written schedules) gets its fragments
    re-fused before the runtime pays per-round launch overhead for them."""

    name = "merge_small_colors"

    def __init__(self, max_size: int = 4):
        self.max_size = max_size

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors")
        colors = np.asarray(ctx.colors).copy()
        n_before = int(colors.max()) + 1 if len(colors) else 0
        members = {
            c: set(np.where(colors == c)[0].tolist())
            for c in range(n_before)
        }
        # neighbor color sets make the independence test O(classes)
        adj_colors = {
            c: {int(colors[u]) for v in nodes for u in ctx.adj[v]}
            for c, nodes in members.items()
        }
        by_size = sorted(members, key=lambda c: (len(members[c]), c))
        for c in by_size:
            if len(members[c]) == 0 or len(members[c]) > self.max_size:
                continue
            for d in sorted(members, key=lambda d: (len(members[d]), d)):
                if d == c or not members[d] or c in adj_colors[d]:
                    continue
                members[d] |= members[c]
                adj_colors[d] |= adj_colors[c]
                for e in members:  # c's conflicts are now d's
                    if c in adj_colors[e]:
                        adj_colors[e].add(d)
                members[c] = set()
                break
        relabel = {}
        for c in range(n_before):
            for v in sorted(members.get(c, ())):
                colors[v] = relabel.setdefault(c, len(relabel))
        verify_mod.require_proper_coloring(
            ctx.adj, colors, loc=f"{ctx.ir.name}:merge_small_colors"
        )
        ctx.colors = colors
        stats = coloring_mod.color_stats(colors)
        ctx.diagnostics.update(
            n_colors=stats["n_colors"],
            color_balance=stats["balance"],
            rounds_merged=n_before - stats["n_colors"],
        )


class GreedyMapPass(Pass):
    """Spatial placement (Sec. IV-B): communication-distance-minimizing
    greedy mapping onto the core mesh."""

    name = "greedy_map"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors")
        ctx.placement = mapping_mod.greedy_map(
            ctx.adj, ctx.colors, ctx.mesh_shape
        )
        ctx.diagnostics["comm_hops"] = mapping_mod.comm_cost(
            ctx.adj, ctx.placement
        )


class RandomMapPass(Pass):
    """Baseline placement (the Fig. 9 'random' column) — drop-in for
    GreedyMapPass so benchmarks compare schedules, not code paths."""

    name = "random_map"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors")
        ctx.placement = mapping_mod.random_map(
            ctx.ir.n_nodes, ctx.mesh_shape, self.seed
        )
        ctx.diagnostics["comm_hops"] = mapping_mod.comm_cost(
            ctx.adj, ctx.placement
        )


class SchedulePass(Pass):
    """Lower (colors, placement) to the explicit per-color round schedule
    and record its cycle/byte cost model."""

    name = "schedule"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors", "placement")
        ctx.schedule = schedule_mod.build_schedule(
            ctx.ir, ctx.colors, ctx.placement, adj=ctx.adj
        )
        ctx.diagnostics["schedule_cost"] = ctx.schedule.cost()
        # placement quality at a glance: the worst per-core node count of
        # any round (what compute_cycles charges) vs the balanced ideal
        ctx.diagnostics["critical_core_load"] = max(
            (max(r.core_load) for r in ctx.schedule.rounds), default=0
        )
        ctx.diagnostics["balanced_core_load"] = max(
            (
                -(-len(r.nodes) // ctx.schedule.n_cores)
                for r in ctx.schedule.rounds
            ),
            default=0,
        )


class VerifyPass(Pass):
    """Static verification of the lowered artifact (`repro.analysis`): the
    parallel-Gibbs race check, comm completeness against an independently
    recomputed traffic matrix, placement/core_load legality, clamp/pin
    consistency, and cost-model reconciliation.  Runs by default as the
    last stage of every named pipeline; raises a structured
    `ScheduleVerificationError` on any error-severity finding (an
    explicit raise — it survives `python -O`, unlike the asserts it
    replaced).  Warning-severity findings (load imbalance, spurious comm)
    land in `diagnostics["verify"]` instead of failing the compile."""

    name = "verify"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors", "placement", "schedule")
        findings = verify_mod.verify_schedule_static(
            ctx.ir, ctx.schedule,
            placement=ctx.placement, diagnostics=ctx.diagnostics,
            adj=ctx.adj, model=ctx.ir.name,
        )
        verify_mod.raise_on_errors(findings)
        ctx.diagnostics["verify"] = {
            "n_rules": len(verify_mod.VERIFY_RULES),
            "n_findings": len(findings),
            "warnings": [f.render() for f in findings],
        }


def default_pipeline() -> list[Pass]:
    return [
        MoralizePass(), DsaturPass(), GreedyMapPass(), SchedulePass(),
        VerifyPass(),
    ]


def runtime_pipeline() -> list[Pass]:
    """The serving-path lowering (`repro.runtime`): the default pipeline
    plus small-color merging, so no coloring source can splinter rounds
    and charge the microbatched runtime per-round launch overhead (on
    DSATUR's own output the merge is an identity — see the pass docstring).
    Kept out of the default pipeline so standalone `compile_bayesnet`
    stays bit-comparable with default-compiled programs."""
    return [
        MoralizePass(), DsaturPass(), MergeSmallColorsPass(),
        GreedyMapPass(), SchedulePass(), VerifyPass(),
    ]


def random_baseline_pipeline(seed: int = 0) -> list[Pass]:
    """The Fig. 9 baseline: the default lowering with the greedy placement
    swapped for a seeded random one.  Kept here so benchmarks/tests compare
    against the real pipeline even as passes are added."""
    return [
        MoralizePass(), DsaturPass(), RandomMapPass(seed), SchedulePass(),
        VerifyPass(),
    ]


# Named pipelines are the cacheable ones: `compile_graph(pipeline=...)` keys
# the program cache by this name, so every registered lowering of a model
# gets its own slot (ad-hoc `passes=[...]` lists still bypass the cache).
_PIPELINES: dict[str, Callable[[], list[Pass]]] = {
    "default": default_pipeline,
    "runtime": runtime_pipeline,
}


def named_pipeline(name: str) -> list[Pass]:
    if name not in _PIPELINES:
        raise ValueError(
            f"unknown pipeline {name!r}; registered: {sorted(_PIPELINES)}"
        )
    return _PIPELINES[name]()


def run_pipeline(
    ir: SamplingGraph,
    mesh_shape: tuple[int, int] = (4, 4),
    passes: Sequence[Pass] | None = None,
) -> PassContext:
    """Run the (default or custom) pass list over a fresh context."""
    ctx = PassContext(ir=ir, mesh_shape=mesh_shape)
    for p in passes if passes is not None else default_pipeline():
        p(ctx)
    return ctx
