"""Pass pipeline: `moralize -> dsatur -> greedy_map -> schedule` (Fig. 8).

Each pass is a named, timed transformation over a `PassContext`; the context
accumulates the artifacts (conflict graph, colors, placement, schedule) and
a diagnostics dict that benchmarks and `launch/report.py` render directly.
The passes wrap the existing `core/coloring.py` and `core/mapping.py`
heuristics — the pipeline is the compiler spine those modules were missing,
not a reimplementation of them.

Custom pipelines are first-class: `run_pipeline(ir, passes=[...])` lets a
benchmark swap `GreedyMapPass` for `RandomMapPass` (the Fig. 9 baseline) or
a future pass without touching the driver.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.compile import schedule as schedule_mod
from repro.compile.ir import SamplingGraph
from repro.core import coloring as coloring_mod
from repro.core import mapping as mapping_mod


@dataclasses.dataclass
class PassContext:
    """Mutable state threaded through the pipeline."""

    ir: SamplingGraph
    mesh_shape: tuple[int, int] = (4, 4)
    adj: list[set[int]] | None = None
    colors: np.ndarray | None = None
    placement: mapping_mod.MeshPlacement | None = None
    schedule: schedule_mod.Schedule | None = None
    diagnostics: dict = dataclasses.field(default_factory=dict)
    pass_times_s: dict = dataclasses.field(default_factory=dict)

    def require(self, *fields: str) -> None:
        for f in fields:
            if getattr(self, f) is None:
                raise RuntimeError(
                    f"pass ordering error: '{f}' not produced yet"
                )


class Pass:
    """A named pipeline stage; subclasses mutate the context in `run`."""

    name = "pass"

    def run(self, ctx: PassContext) -> None:
        raise NotImplementedError

    def __call__(self, ctx: PassContext) -> None:
        t0 = time.perf_counter()
        self.run(ctx)
        ctx.pass_times_s[self.name] = time.perf_counter() - t0


class MoralizePass(Pass):
    """Materialize the conflict graph.  The IR already canonicalized the
    moral / grid adjacency into edges; this pass expands it to the adjacency
    sets every later pass consumes, and records graph-shape diagnostics."""

    name = "moralize"

    def run(self, ctx: PassContext) -> None:
        ctx.adj = ctx.ir.adjacency()
        degrees = np.array([len(a) for a in ctx.adj] or [0])
        ctx.diagnostics.update(
            n_nodes=ctx.ir.n_nodes,
            n_edges=ctx.ir.n_edges,
            max_degree=int(degrees.max()),
        )


class DsaturPass(Pass):
    """RV-parallelism detection (paper C3): DSATUR coloring + verification."""

    name = "dsatur"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj")
        ctx.colors = coloring_mod.dsatur(ctx.adj)
        assert coloring_mod.verify_coloring(ctx.adj, ctx.colors)
        stats = coloring_mod.color_stats(ctx.colors)
        ctx.diagnostics.update(
            n_colors=stats["n_colors"],
            color_balance=stats["balance"],
        )


class GreedyMapPass(Pass):
    """Spatial placement (Sec. IV-B): communication-distance-minimizing
    greedy mapping onto the core mesh."""

    name = "greedy_map"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors")
        ctx.placement = mapping_mod.greedy_map(
            ctx.adj, ctx.colors, ctx.mesh_shape
        )
        ctx.diagnostics["comm_hops"] = mapping_mod.comm_cost(
            ctx.adj, ctx.placement
        )


class RandomMapPass(Pass):
    """Baseline placement (the Fig. 9 'random' column) — drop-in for
    GreedyMapPass so benchmarks compare schedules, not code paths."""

    name = "random_map"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors")
        ctx.placement = mapping_mod.random_map(
            ctx.ir.n_nodes, ctx.mesh_shape, self.seed
        )
        ctx.diagnostics["comm_hops"] = mapping_mod.comm_cost(
            ctx.adj, ctx.placement
        )


class SchedulePass(Pass):
    """Lower (colors, placement) to the explicit per-color round schedule
    and record its cycle/byte cost model."""

    name = "schedule"

    def run(self, ctx: PassContext) -> None:
        ctx.require("adj", "colors", "placement")
        ctx.schedule = schedule_mod.build_schedule(
            ctx.ir, ctx.colors, ctx.placement, adj=ctx.adj
        )
        schedule_mod.verify_schedule(ctx.ir, ctx.schedule, adj=ctx.adj)
        ctx.diagnostics["schedule_cost"] = ctx.schedule.cost()
        # placement quality at a glance: the worst per-core node count of
        # any round (what compute_cycles charges) vs the balanced ideal
        ctx.diagnostics["critical_core_load"] = max(
            (max(r.core_load) for r in ctx.schedule.rounds), default=0
        )
        ctx.diagnostics["balanced_core_load"] = max(
            (
                -(-len(r.nodes) // ctx.schedule.n_cores)
                for r in ctx.schedule.rounds
            ),
            default=0,
        )


def default_pipeline() -> list[Pass]:
    return [MoralizePass(), DsaturPass(), GreedyMapPass(), SchedulePass()]


def random_baseline_pipeline(seed: int = 0) -> list[Pass]:
    """The Fig. 9 baseline: the default lowering with the greedy placement
    swapped for a seeded random one.  Kept here so benchmarks/tests compare
    against the real pipeline even as passes are added."""
    return [MoralizePass(), DsaturPass(), RandomMapPass(seed), SchedulePass()]


def run_pipeline(
    ir: SamplingGraph,
    mesh_shape: tuple[int, int] = (4, 4),
    passes: Sequence[Pass] | None = None,
) -> PassContext:
    """Run the (default or custom) pass list over a fresh context."""
    ctx = PassContext(ir=ir, mesh_shape=mesh_shape)
    for p in passes if passes is not None else default_pipeline():
        p(ctx)
    return ctx
