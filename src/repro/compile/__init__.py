"""`repro.compile` — the AIA compile chain (paper Sec. IV, Fig. 8).

Lowers a discrete probabilistic model into an executable sampling program
through an explicit multi-pass pipeline:

    SamplingGraph IR          (ir.py       — BN/MRF -> one conflict-graph form)
      -> moralize             (passes.py   — conflict-graph construction)
      -> dsatur               (            — RV-parallelism detection, C3)
      -> greedy_map           (            — spatial placement, Sec. IV-B)
      -> schedule             (schedule.py — per-color rounds + comm ops)
      -> CompiledProgram      (program.py  — jit / shard_map executable,
                                             LRU-cached by IR hash)

`compile_graph()` is the single entry point; everything else is exposed for
benchmarks, tests, and future passes/backends.
"""

from repro.compile.backend import (
    BackendMismatch,
    BNScheduleExec,
    FUSED_BN_SAMPLERS,
    MRFScheduleExec,
    ScheduleLoweringError,
    cross_check,
    cross_check_clamped,
    cross_check_fused,
    lower_schedule,
    pin_arrays,
    run_bn_schedule,
    run_mrf_schedule,
)
from repro.compile.ir import SamplingGraph, canonicalize
from repro.analysis.verify import ScheduleVerificationError
from repro.compile.passes import (
    MergeSmallColorsPass,
    PassContext,
    VerifyPass,
    default_pipeline,
    named_pipeline,
    run_pipeline,
    runtime_pipeline,
)
from repro.compile.program import (
    CompiledProgram,
    cache_stats,
    clear_program_cache,
    compile_graph,
    set_cache_capacity,
)
from repro.compile.schedule import (
    CommOp,
    Round,
    Schedule,
    build_schedule,
    verify_schedule,
)

__all__ = [
    "BackendMismatch",
    "BNScheduleExec",
    "MRFScheduleExec",
    "ScheduleLoweringError",
    "cross_check",
    "cross_check_clamped",
    "cross_check_fused",
    "FUSED_BN_SAMPLERS",
    "lower_schedule",
    "pin_arrays",
    "run_bn_schedule",
    "run_mrf_schedule",
    "SamplingGraph",
    "canonicalize",
    "MergeSmallColorsPass",
    "PassContext",
    "ScheduleVerificationError",
    "VerifyPass",
    "default_pipeline",
    "named_pipeline",
    "run_pipeline",
    "runtime_pipeline",
    "CompiledProgram",
    "compile_graph",
    "cache_stats",
    "clear_program_cache",
    "set_cache_capacity",
    "CommOp",
    "Round",
    "Schedule",
    "build_schedule",
    "verify_schedule",
]
