"""Round schedules — the compile chain's explicit execution plan (Alg. 2).

A `Schedule` lowers (colors, placement) into what the hardware actually
runs: one `Round` per color, each updating a conditionally-independent node
set in parallel across the core mesh, followed by the communication that
makes the new values visible before the next round.  The comm ops name the
paper's two data-movement mechanisms and their TPU analogues:

  * ``ppermute_halo``  — neighbor-RF read (C4): an MRF site reads labels
    from mesh-adjacent cores; on TPU a `lax.ppermute` boundary exchange.
  * ``psum_broadcast`` — shared-RF value broadcast: a BN node's new value
    is pushed to every core holding a Markov-blanket neighbor; on TPU the
    per-color `lax.psum` of the (disjoint) state-vector delta.

The cycle/byte cost model is deliberately simple — a line-graph model in the
spirit of Fig. 9, not a simulator: per round, compute is the update count of
the round's most-loaded core under the actual placement (the round barriers
on the slowest core), and communication pays a per-hop latency plus a
serialization term.  Its purpose is *relative* comparison (greedy vs random
placement, schedule A vs B), which is exactly what bench_compile reports.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.compile.ir import SamplingGraph
from repro.core.mapping import MeshPlacement, _manhattan

# Line-graph cost-model constants (relative units, one "cycle" = one core
# update slot).  HOP_CYCLES is the per-link latency of the mesh NoC; a
# 4-byte value serializes in one cycle on AIA's 32-bit links.
UPDATE_CYCLES = 1
HOP_CYCLES = 2
BYTES_PER_LINK_CYCLE = 4
VALUE_BYTES = 4  # one int32 RV value


@dataclasses.dataclass(frozen=True)
class CommOp:
    """Aggregated traffic from one core to another after a round."""

    mechanism: str  # "ppermute_halo" | "psum_broadcast"
    src_core: int
    dst_core: int
    n_bytes: int
    hops: int  # Manhattan distance on the core mesh

    @property
    def cycles(self) -> int:
        return HOP_CYCLES * self.hops + -(-self.n_bytes // BYTES_PER_LINK_CYCLE)


@dataclasses.dataclass(frozen=True)
class Round:
    """One color's parallel update step + the exchanges it triggers."""

    color: int
    nodes: tuple[int, ...]
    comm: tuple[CommOp, ...]
    # nodes-per-core under the *actual* placement (index = core id).  The
    # round barriers on its most-loaded core, so this — not the balanced
    # share ceil(n/n_cores) — is what compute costs.  Empty tuple = no
    # placement known (legacy), fall back to the balanced share.
    core_load: tuple[int, ...] = ()

    def compute_cycles(self, n_cores: int) -> int:
        if self.core_load:
            return UPDATE_CYCLES * max(self.core_load)
        return UPDATE_CYCLES * -(-len(self.nodes) // n_cores)

    def comm_cycles(self) -> int:
        # mesh links are independent: rounds pay the slowest single op,
        # not the sum (the event unit barriers on the last arrival)
        return max((op.cycles for op in self.comm), default=0)


@dataclasses.dataclass(frozen=True)
class Schedule:
    rounds: tuple[Round, ...]
    mesh_shape: tuple[int, int]

    @property
    def n_cores(self) -> int:
        return self.mesh_shape[0] * self.mesh_shape[1]

    def cost(self) -> dict:
        """Cycle/byte model of one full sweep (all rounds)."""
        compute = sum(r.compute_cycles(self.n_cores) for r in self.rounds)
        comm = sum(r.comm_cycles() for r in self.rounds)
        return {
            "n_rounds": len(self.rounds),
            "compute_cycles": compute,
            "comm_cycles": comm,
            "total_cycles": compute + comm,
            "total_bytes": sum(
                op.n_bytes for r in self.rounds for op in r.comm
            ),
            "total_hop_bytes": sum(
                op.n_bytes * op.hops for r in self.rounds for op in r.comm
            ),
            "n_comm_ops": sum(len(r.comm) for r in self.rounds),
        }


def build_schedule(
    ir: SamplingGraph,
    colors: np.ndarray,
    placement: MeshPlacement,
    adj: list[set[int]] | None = None,
) -> Schedule:
    """Lower (colors, placement) to per-color rounds with explicit comm.

    After round r updates node u, every conflict neighbor v of a *different*
    color reads u's new value in a later round; if v lives on another core
    that read is a message.  Messages are aggregated per (src, dst) core
    pair — that is what a halo exchange / delta broadcast physically ships.
    `adj` lets the caller reuse an already-materialized adjacency.
    """
    mechanism = "ppermute_halo" if ir.kind == "mrf" else "psum_broadcast"
    cols = placement.mesh_shape[1]
    n_cores = placement.mesh_shape[0] * placement.mesh_shape[1]
    if adj is None:
        adj = ir.adjacency()
    evid = {node for node, _ in ir.evidence}
    rounds = []
    for c in range(int(colors.max()) + 1 if len(colors) else 0):
        nodes = tuple(
            int(v) for v in np.where(colors == c)[0] if int(v) not in evid
        )
        if not nodes:
            continue  # all-evidence color: nothing to update or ship
        traffic: dict[tuple[int, int], int] = {}
        for u in nodes:
            cu = int(placement.placement[u])
            dst_cores = {
                int(placement.placement[v])
                for v in adj[u]
                if colors[v] != c and v not in evid
            }
            for cv in dst_cores - {cu}:
                traffic[(cu, cv)] = traffic.get((cu, cv), 0) + VALUE_BYTES
        comm = tuple(
            CommOp(
                mechanism=mechanism,
                src_core=src,
                dst_core=dst,
                n_bytes=nb,
                hops=_manhattan(src, dst, cols),
            )
            for (src, dst), nb in sorted(traffic.items())
        )
        core_load = np.bincount(
            placement.placement[list(nodes)], minlength=n_cores
        )
        rounds.append(Round(
            color=c, nodes=nodes, comm=comm,
            core_load=tuple(int(x) for x in core_load),
        ))
    return Schedule(rounds=tuple(rounds), mesh_shape=placement.mesh_shape)


def verify_schedule(
    ir: SamplingGraph,
    schedule: Schedule,
    adj: list[set[int]] | None = None,
) -> None:
    """Legality: rounds partition the free RVs, and no round contains two
    adjacent RVs (the conditional-independence precondition of Alg. 2).

    Delegates to the static verifier's legality rules and raises a
    structured `repro.analysis.ScheduleVerificationError` (an
    `AssertionError` subclass, but *raised*, so it survives `python -O`).
    The full rule set — comm completeness, placement legality, cost-model
    sanity — runs in the pipeline's `VerifyPass` and in
    `analysis.verify_program`, which also see the placement and
    diagnostics this signature does not carry."""
    from repro.analysis import verify as verify_mod  # analysis imports us

    verify_mod.raise_on_errors(
        verify_mod.verify_schedule_static(ir, schedule, adj=adj)
    )
