"""Schedule-direct execution backend: the `Schedule` IS the execution plan.

Until this module existed, `CompiledProgram.run()` delegated to the eager
Gibbs engines and the round schedule the pass pipeline built was used only
for cost reporting.  Here the schedule is *lowered* to an executable:

  * BN: one CPT-gather tensor set (`ColorGroup`) per `Round`, built from the
    round's node list — not from `cbn.groups` — and swept in schedule order
    inside one jitted loop.  A future pass that merges tiny colors or splits
    a round changes execution through this lowering alone; `core/bayesnet.py`
    never hears about it.
  * MRF: each round is recognized as one checkerboard parity and executed in
    schedule order.  The default path is the vectorized engine math (bit-
    exact with eager for every sampler); `fused=True` routes `lut_ky` rounds
    through the Pallas kernel in `kernels/mrf_gibbs.py` (same random-word
    derivation as `draw_from_logits`, so still bit-identical).

Bit-exactness with the eager backend is not an aspiration but a checked
invariant: `cross_check()` runs both backends on a tiny budget and compares
bits; `CompiledProgram` invokes it the first time a program is lowered (and
eagerly at compile time under `compile_graph(..., cross_check=True)`).

The sharded counterpart lives in `core/distributed.py`
(`run_program_sharded(..., backend="schedule")`), which routes each round's
named comm mechanism onto its collective: `psum_broadcast` -> a per-round
`lax.psum` of the disjoint state delta, `ppermute_halo` -> the `lax.ppermute`
boundary exchange.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.compile.schedule import Schedule, verify_schedule
from repro.core import bayesnet as bnet
from repro.core import mrf as mrf_mod
from repro.core.graphs import GridMRF
from repro.core.interp import build_exp_weight_lut
from repro.diag import accum as diag_accum
from repro.kernels import mrf_gibbs as mrf_kernels
from repro.kernels.bn_gibbs import FUSED_BN_SAMPLERS, check_fused_sampler
from repro.obs import tracer


class ScheduleLoweringError(RuntimeError):
    """The schedule cannot be lowered to this backend's execution form."""


class BackendMismatch(AssertionError):
    """The schedule backend produced different bits than the eager engine."""


# The schedule's named comm mechanisms and the collective each lowers to in
# the sharded execution path (core/distributed.py).
MECHANISM_COLLECTIVES = {
    "psum_broadcast": "lax.psum",
    "ppermute_halo": "lax.ppermute",
}


@dataclasses.dataclass
class BNScheduleExec:
    """A BN schedule lowered to per-round gather tensors."""

    cbn: bnet.CompiledBayesNet
    round_groups: list[bnet.ColorGroup]  # one per Round, schedule-ordered
    # runtime-evidence node set the groups were specialized for (static:
    # it determines the gather-tensor shapes); () = unclamped lowering
    clamp_nodes: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class MRFScheduleExec:
    """A grid-MRF schedule lowered to a checkerboard parity sequence."""

    mrf: GridMRF
    parities: tuple[int, ...]  # per-round parity, schedule-ordered
    pinned: tuple[tuple[int, int], ...] = ()  # baked (site, label) pins


def lower_schedule(
    program, clamp_nodes: tuple[int, ...] = ()
) -> BNScheduleExec | MRFScheduleExec:
    """Lower a `CompiledProgram`'s schedule into an executable form.

    Legality is re-verified first: round-ordered execution is only correct if
    the rounds still partition the free RVs with no intra-round conflicts
    (a buggy future pass must fail here, not corrupt samples).

    `clamp_nodes` specializes a BN lowering for a runtime-evidence node set
    (`evidence_mode="runtime"` IRs): clamped nodes drop out of every round's
    gather tensors exactly as baked evidence drops out at compile time,
    which is what keeps the two paths bit-identical.  MRF pins need no
    specialization (the pin mask is a plain runtime array), so `clamp_nodes`
    must be empty for MRF programs; *baked* MRF pins ride in from the IR."""
    ir = program.ir
    schedule: Schedule = program.schedule
    verify_schedule(ir, schedule)
    if ir.kind == "bn":
        bn = ir.source
        clamp = set(clamp_nodes)
        bases = bnet.cpt_bases(bn)
        groups = bnet.build_clamped_groups(
            bn, [r.nodes for r in schedule.rounds], clamp, bases
        )
        if not groups:
            raise ScheduleLoweringError(
                "runtime evidence clamps every free RV; nothing to sample"
            )
        return BNScheduleExec(
            cbn=program.cbn, round_groups=groups, clamp_nodes=tuple(
                sorted(clamp))
        )
    if clamp_nodes:
        raise ScheduleLoweringError(
            "MRF pins are runtime arrays (run(pins=...)), not a lowering "
            "specialization"
        )
    mrf = ir.source
    pinned_sites = {node for node, _ in ir.evidence}
    class_size = {
        p: sum(
            (r + c) % 2 == p and (r * mrf.width + c) not in pinned_sites
            for r in range(mrf.height) for c in range(mrf.width)
        )
        for p in (0, 1)
    }
    parities = []
    for r in schedule.rounds:
        pars = {(v // mrf.width + v % mrf.width) % 2 for v in r.nodes}
        if len(pars) != 1:
            raise ScheduleLoweringError(
                f"MRF round {r.color} mixes checkerboard parities {pars}; "
                "the fused grid path needs single-parity rounds"
            )
        parity = pars.pop()
        if len(r.nodes) != class_size[parity]:
            # the grid path executes whole parity classes (minus baked
            # pins); a round holding only part of one (e.g. from a round-
            # splitting pass) has no lowering here and must fail loudly,
            # not run the wrong plan
            raise ScheduleLoweringError(
                f"MRF round {r.color} covers {len(r.nodes)} of the "
                f"{class_size[parity]} free parity-{parity} sites; partial-"
                "parity rounds are not loweable by the grid backend"
            )
        parities.append(parity)
    return MRFScheduleExec(
        mrf=mrf, parities=tuple(parities), pinned=ir.evidence
    )


def pin_arrays(
    mrf: GridMRF, pinned
) -> tuple[jax.Array, jax.Array]:
    """(site, label) pin pairs -> ((H, W) bool mask, (H, W) int32 values).
    Accepts a dict or an iterable of pairs; values are validated against
    the label alphabet."""
    import numpy as np

    mask = np.zeros((mrf.height, mrf.width), bool)
    vals = np.zeros((mrf.height, mrf.width), np.int64)
    items = pinned.items() if isinstance(pinned, dict) else pinned
    for site, lab in items:
        site, lab = int(site), int(lab)
        if not (0 <= site < mrf.height * mrf.width and
                0 <= lab < mrf.n_labels):
            raise ValueError(f"pinned pixel {site}={lab} out of range")
        mask[site // mrf.width, site % mrf.width] = True
        vals[site // mrf.width, site % mrf.width] = lab
    return jnp.asarray(mask), jnp.asarray(vals, jnp.int32)


# ---------------------------------------------------------------------------
# BN: round-ordered jitted sweep
# ---------------------------------------------------------------------------


def bn_rounds_core(
    cbn, round_groups, key, *, n_chains, n_iters, burn_in, sampler, thin=1,
    clamp_vals=None, clamp_mask=None, carry=None, return_state=False,
    fused=False, interpret=False,
    diag_total=None, diag_batch=diag_accum.DEFAULT_BATCH_LEN,
):
    """Un-jitted BN round sweep: init (with optional runtime clamps) + the
    shared `gibbs_run_loop`.  `run_bn_schedule` jits it; the serving batcher
    vmaps it over per-query (key, clamp_vals) with shared static groups.

    A `carry` (`bayesnet.BNChainState`) skips the init and resumes the
    chain exactly — the clamped values already live in the carried state and
    clamped nodes are absent from the (same) groups, so slicing a clamped
    run needs nothing beyond the state itself.

    `fused=True` routes every sweep through the Pallas kernel in
    `kernels/bn_gibbs.py` (lut_ky/exact_ky only — anything else raises);
    clamps need no extra handling because clamped nodes are absent from
    `round_groups` on both paths."""
    if carry is None:
        vals, key = bnet.init_chain_values(
            cbn, key, n_chains, clamp_vals=clamp_vals, clamp_mask=clamp_mask
        )
    else:
        vals = None
    return bnet.gibbs_run_loop(
        cbn, round_groups, vals, key, n_iters, burn_in, sampler, thin,
        carry=carry, return_state=return_state,
        fused=fused, interpret=interpret,
        diag_total=diag_total, diag_batch=diag_batch,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_chains", "n_iters", "burn_in", "sampler", "thin", "return_state",
        "fused", "interpret",
    ),
    # sliced serving: resume the carried chain state in place (the caller
    # must treat a passed carry as consumed — see bayesnet.run_gibbs)
    donate_argnames=("carry",),
)
def _run_bn_rounds(
    cbn, round_groups, key, clamp_vals, clamp_mask, carry, *,
    n_chains, n_iters, burn_in, sampler, thin, return_state,
    fused=False, interpret=False,
    diag_total=None, diag_batch=diag_accum.DEFAULT_BATCH_LEN,
):
    return bn_rounds_core(
        cbn, round_groups, key, n_chains=n_chains, n_iters=n_iters,
        burn_in=burn_in, sampler=sampler, thin=thin,
        clamp_vals=clamp_vals, clamp_mask=clamp_mask,
        carry=carry, return_state=return_state,
        fused=fused, interpret=interpret,
        diag_total=diag_total, diag_batch=diag_batch,
    )


def run_bn_schedule(
    ex: BNScheduleExec,
    key: jax.Array | None,
    *,
    clamp_vals: jax.Array | None = None,
    clamp_mask: jax.Array | None = None,
    **kwargs,
):
    """Execute a lowered BN schedule; same contract as `bayesnet.run_gibbs`
    (returns (marginals (n, V), final vals)).  For a clamped lowering
    (`ex.clamp_nodes` non-empty) `clamp_vals`/`clamp_mask` carry the
    per-query evidence values; the mask must cover exactly the nodes the
    lowering was specialized for.  Convenience unpacking of
    `bn_run_clamped` — one body, two spellings."""
    return bn_run_clamped(
        ex.cbn, ex.round_groups, clamp_vals, clamp_mask, key, **kwargs
    )


def bn_run_clamped(
    cbn,
    round_groups,
    clamp_vals: jax.Array | None,
    clamp_mask: jax.Array | None,
    key: jax.Array | None,
    *,
    n_chains: int = 32,
    n_iters: int = 200,
    burn_in: int = 50,
    sampler: str = "lut_ky",
    thin: int = 1,
    carry=None,
    return_state: bool = False,
    fused: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
):
    """Execute an already-specialized clamped grouping (from
    `CompiledProgram.clamped_executable`, either backend's) with per-query
    evidence values; same contract as `bayesnet.run_gibbs`.

    `fused=True` drives the sweeps through the Pallas BN kernel
    (lut_ky/exact_ky only — the kernel hard-codes the C1+C2 datapath);
    random words are derived exactly as `draw_from_logits` derives them, so
    the fused path stays bit-identical to the eager engine."""
    if fused:
        check_fused_sampler(sampler)
    interpret = jax.default_backend() != "tpu"
    # host-level kernel entry span only: the rounds themselves run inside
    # jit/fori_loop where the tracer must never be called
    with tracer.span(
        "bn_rounds", cat="kernel", sampler=sampler, fused=fused,
        n_chains=n_chains, n_iters=n_iters, n_rounds=len(round_groups),
        resumed=carry is not None,
    ):
        return _run_bn_rounds(
            cbn, round_groups, key, clamp_vals, clamp_mask, carry,
            n_chains=n_chains, n_iters=n_iters, burn_in=burn_in,
            sampler=sampler, thin=thin, return_state=return_state,
            fused=fused, interpret=interpret,
            diag_total=diag_total, diag_batch=diag_batch,
        )


# ---------------------------------------------------------------------------
# MRF: schedule-ordered rounds, optionally fused through the Pallas kernel
# ---------------------------------------------------------------------------


def mrf_rounds_core(
    mrf, parities, evidence, key, *, n_chains, n_iters, sampler, fused,
    interpret, pin_mask=None, pin_vals=None, carry=None, return_state=False,
    diag_total=None, diag_batch=diag_accum.DEFAULT_BATCH_LEN,
):
    """Un-jitted schedule-ordered MRF sweep (the batcher vmaps this over
    per-query evidence images and pin masks — pins are runtime arrays, so
    one trace serves every pin pattern).  The fused Pallas kernel computes
    the whole parity update and pinned sites are restored afterwards, which
    matches the unfused path's masked `where` bit for bit because pinned
    sites always hold their pinned value going in.

    A `carry` (`mrf.MRFChainState`) skips the init and resumes the chain
    exactly — sliced runs are bit-exact with uninterrupted ones on the
    fused path too, because the per-iteration key-split structure is the
    carry itself."""
    exp_table, exp_spec = build_exp_weight_lut()
    if carry is None:
        labels, key = mrf_mod.init_labels(
            mrf, key, n_chains, pin_mask, pin_vals
        )
        quality = None
        if diag_total is not None:
            quality = diag_accum.make_accum(
                n_chains, mrf.height * mrf.width, mrf.n_labels,
                jnp.asarray(diag_total, jnp.int32), diag_batch,
            )
    else:
        labels, key, quality = carry.labels, carry.key, carry.quality

    def body(t, carry):
        labels, key, quality = carry
        ks = jax.random.split(key, 1 + len(parities))
        for i, parity in enumerate(parities):
            if fused:
                labels = mrf_kernels.mrf_round_step(
                    mrf, labels, evidence, ks[1 + i], parity,
                    exp_table, exp_spec, interpret=interpret,
                )
                if pin_mask is not None:
                    labels = jnp.where(pin_mask[None], pin_vals[None], labels)
            else:
                labels = mrf_mod.half_step(
                    mrf, labels, evidence, ks[1 + i], parity, sampler,
                    exp_table, exp_spec, pin_mask,
                )
        if quality is not None:
            onehot = (
                labels.reshape(labels.shape[0], -1)[..., None]
                == jnp.arange(mrf.n_labels, dtype=labels.dtype)
            ).astype(jnp.int32)
            quality = diag_accum.update(quality, onehot, jnp.asarray(True))
        return labels, ks[0], quality

    labels, key, quality = jax.lax.fori_loop(
        0, n_iters, body, (labels, key, quality)
    )
    if return_state:
        return labels, mrf_mod.MRFChainState(
            labels=labels, key=key, quality=quality
        )
    return labels


@functools.partial(
    jax.jit,
    static_argnames=(
        "mrf", "parities", "n_chains", "n_iters", "sampler", "fused",
        "interpret", "return_state",
    ),
    # sliced serving: resume the carried labels in place (a passed carry is
    # consumed — see bayesnet.run_gibbs)
    donate_argnames=("carry",),
)
def _run_mrf_rounds(
    mrf, parities, evidence, key, pin_mask, pin_vals, carry, *,
    n_chains, n_iters, sampler, fused, interpret, return_state,
    diag_total=None, diag_batch=diag_accum.DEFAULT_BATCH_LEN,
):
    return mrf_rounds_core(
        mrf, parities, evidence, key, n_chains=n_chains, n_iters=n_iters,
        sampler=sampler, fused=fused, interpret=interpret,
        pin_mask=pin_mask, pin_vals=pin_vals,
        carry=carry, return_state=return_state,
        diag_total=diag_total, diag_batch=diag_batch,
    )


def run_mrf_schedule(
    ex: MRFScheduleExec,
    evidence: jax.Array,
    key: jax.Array | None,
    *,
    n_chains: int = 32,
    n_iters: int = 200,
    sampler: str = "lut_ky",
    fused: bool = False,
    pin_mask: jax.Array | None = None,
    pin_vals: jax.Array | None = None,
    carry=None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
):
    """Execute a lowered MRF schedule; same contract as `mrf.run_mrf_gibbs`
    (returns final labels (B, H, W)).

    `fused=True` drives the rounds through the Pallas half-step kernel
    (lut_ky only — the kernel hard-codes the C1+C2 datapath); random words
    are derived exactly as `draw_from_logits` derives them, so the fused
    path stays bit-identical to the eager engine.

    Pins come from either the lowering (baked into the IR) or the caller
    (runtime queries) — `program.run()` guarantees they never both apply.
    `carry`/`return_state` slice the run: see `mrf_rounds_core`."""
    if fused and sampler != "lut_ky":
        raise ValueError(
            f"fused schedule rounds implement the lut_ky datapath only, "
            f"got sampler={sampler!r}"
        )
    if pin_mask is None and ex.pinned:
        pin_mask, pin_vals = pin_arrays(ex.mrf, ex.pinned)
    interpret = jax.default_backend() != "tpu"
    # host-level kernel entry span only (see bn_run_clamped)
    with tracer.span(
        "mrf_rounds", cat="kernel", sampler=sampler, fused=fused,
        n_chains=n_chains, n_iters=n_iters, n_rounds=len(ex.parities),
        resumed=carry is not None, pinned=pin_mask is not None,
    ):
        return _run_mrf_rounds(
            ex.mrf, ex.parities, evidence, key, pin_mask, pin_vals, carry,
            n_chains=n_chains, n_iters=n_iters, sampler=sampler, fused=fused,
            interpret=interpret, return_state=return_state,
            diag_total=diag_total, diag_batch=diag_batch,
        )


# ---------------------------------------------------------------------------
# Bit-exactness cross-check between the two backends
# ---------------------------------------------------------------------------

_CHECK_KEY = 0xA1A  # fixed: the check must be deterministic per program
_CHECK_CHAINS = 2
_CHECK_ITERS = 3


def cross_check(program, ex=None) -> None:
    """Run both backends on a tiny budget and require identical bits.

    Raises `BackendMismatch` on any divergence — a cached program whose
    schedule execution drifted from the eager engines must never serve."""
    import numpy as np

    ex = lower_schedule(program) if ex is None else ex
    key = jax.random.key(_CHECK_KEY)
    if program.kind == "bn":
        marg_e, vals_e = bnet.run_gibbs(
            program.cbn, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
            burn_in=0,
        )
        marg_s, vals_s = run_bn_schedule(
            ex, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS, burn_in=0,
        )
        same = (np.asarray(vals_e) == np.asarray(vals_s)).all() and (
            np.asarray(marg_e) == np.asarray(marg_s)
        ).all()
    else:
        mrf = program.mrf
        ev = jnp.zeros((mrf.height, mrf.width), jnp.int32)
        pin_mask = pin_vals = None
        if program.ir.evidence:  # baked pins bind the eager side too
            pin_mask, pin_vals = pin_arrays(mrf, program.ir.evidence)
        lab_e = mrf_mod.run_mrf_gibbs(
            mrf, ev, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
            pin_mask=pin_mask, pin_vals=pin_vals,
        )
        lab_s = run_mrf_schedule(
            ex, ev, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
        )
        same = (np.asarray(lab_e) == np.asarray(lab_s)).all()
    if not same:
        raise BackendMismatch(
            f"schedule backend diverged from eager on program "
            f"{program.program_key[:12]} ({program.kind})"
        )


def _check_mesh(program, mesh=None):
    """A tiny mesh for the sharded cross-check leg: (1, w) over the host's
    devices, with w a legal shard width for the program (divides the MRF
    grid height; any width partitions BN nodes).  A single available device
    still exercises the full shard_map body (self-permute halos)."""
    if mesh is not None:
        return mesh
    n_dev = len(jax.devices())
    if program.kind == "mrf":
        h = program.mrf.height
        w = next(d for d in range(min(n_dev, h), 0, -1) if h % d == 0)
    else:
        w = min(2, n_dev)
    from repro.core import compat

    return compat.make_mesh((1, w), ("data", "model"))


def cross_check_fused(
    program, ex, sampler: str = "lut_ky", *, sharded: bool = False,
    mesh=None,
) -> None:
    """First-use guarantee for the fused kernel paths: before a Pallas
    round kernel ever serves a program, a tiny fused run must match the
    eager engine bit for bit (the eager side never touches the kernels, so
    a word-derivation or layout drift in `kernels/bn_gibbs.py` /
    `kernels/mrf_gibbs.py` is caught here, not in production posteriors).

    `sharded=True` additionally runs the one-shard_map-body engine
    (`core/distributed.py`) on a tiny mesh and requires its bits to match
    the single-device fused run AND (transitively) eager — the acceptance
    invariant of the sharded-fused datapath.  Checked lazily at first
    sharded-fused use (`CompiledProgram.ensure_fused_cross_check`), so
    single-device fused serving never pays the shard_map compile."""
    import numpy as np

    key = jax.random.key(_CHECK_KEY)
    kwargs = dict(n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
                  sampler=sampler)
    if program.kind == "bn":
        marg_e, vals_e = bnet.run_gibbs(program.cbn, key, burn_in=0,
                                        **kwargs)
        marg_f, vals_f = run_bn_schedule(ex, key, fused=True, burn_in=0,
                                         **kwargs)
        if not ((np.asarray(vals_e) == np.asarray(vals_f)).all()
                and (np.asarray(marg_e) == np.asarray(marg_f)).all()):
            raise BackendMismatch(
                f"fused BN rounds diverged from eager on program "
                f"{program.program_key[:12]} (sampler={sampler})"
            )
        if sharded:
            from repro.core import distributed as dist_mod

            marg_s, vals_s = dist_mod.run_program_sharded(
                program, key, _check_mesh(program, mesh), burn_in=0,
                backend="schedule", fused=True, **kwargs,
            )
            if not ((np.asarray(vals_s) == np.asarray(vals_f)).all()
                    and (np.asarray(marg_s) == np.asarray(marg_f)).all()):
                raise BackendMismatch(
                    f"sharded fused BN rounds diverged from single-device "
                    f"fused on program {program.program_key[:12]} "
                    f"(sampler={sampler})"
                )
        return
    mrf = program.mrf
    ev = jnp.zeros((mrf.height, mrf.width), jnp.int32)
    pin_mask = pin_vals = None
    if program.ir.evidence:  # baked pins bind the eager side too
        pin_mask, pin_vals = pin_arrays(mrf, program.ir.evidence)
    lab_e = mrf_mod.run_mrf_gibbs(
        mrf, ev, key, pin_mask=pin_mask, pin_vals=pin_vals, **kwargs
    )
    lab_f = run_mrf_schedule(ex, ev, key, fused=True, **kwargs)
    if not (np.asarray(lab_e) == np.asarray(lab_f)).all():
        raise BackendMismatch(
            f"fused MRF rounds diverged from eager on program "
            f"{program.program_key[:12]} (sampler={sampler})"
        )
    if sharded:
        from repro.core import distributed as dist_mod

        lab_s = dist_mod.run_program_sharded(
            program, key, _check_mesh(program, mesh), evidence=ev,
            backend="schedule", fused=True, **kwargs,
        )
        if not (np.asarray(lab_s) == np.asarray(lab_f)).all():
            raise BackendMismatch(
                f"sharded fused MRF rounds diverged from single-device "
                f"fused on program {program.program_key[:12]} "
                f"(sampler={sampler})"
            )


def cross_check_clamped(program, ex: BNScheduleExec) -> None:
    """The clamped-lowering counterpart of `cross_check`: before a runtime-
    evidence specialization ever serves, both backends run a tiny clamped
    budget (every clamped node observed at value 0, which every alphabet
    admits) and must agree bit for bit.  The eager side rebuilds its groups
    from `cbn.groups` independently of the schedule lowering, so a pass
    that breaks the rounds/groups correspondence is caught here too."""
    import numpy as np

    bn = program.ir.source
    clamp = ex.clamp_nodes
    clamp_vals = jnp.zeros(bn.n_nodes, jnp.int32)
    clamp_mask = jnp.zeros(bn.n_nodes, bool).at[jnp.asarray(
        clamp, jnp.int32)].set(True)
    key = jax.random.key(_CHECK_KEY)
    eager_groups = bnet.build_clamped_groups(
        bn, [np.asarray(g.nodes) for g in program.cbn.groups], clamp
    )
    kwargs = dict(
        n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS, burn_in=0,
        sampler="lut_ky", thin=1,
    )
    marg_e, vals_e = _run_bn_rounds(
        program.cbn, eager_groups, key, clamp_vals, clamp_mask, None,
        return_state=False, **kwargs,
    )
    marg_s, vals_s = run_bn_schedule(
        ex, key, clamp_vals=clamp_vals, clamp_mask=clamp_mask, **kwargs
    )
    if not ((np.asarray(vals_e) == np.asarray(vals_s)).all()
            and (np.asarray(marg_e) == np.asarray(marg_s)).all()):
        raise BackendMismatch(
            f"clamped schedule backend diverged from eager on program "
            f"{program.program_key[:12]} (clamp={clamp})"
        )
