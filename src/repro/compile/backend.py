"""Schedule-direct execution backend: the `Schedule` IS the execution plan.

Until this module existed, `CompiledProgram.run()` delegated to the eager
Gibbs engines and the round schedule the pass pipeline built was used only
for cost reporting.  Here the schedule is *lowered* to an executable:

  * BN: one CPT-gather tensor set (`ColorGroup`) per `Round`, built from the
    round's node list — not from `cbn.groups` — and swept in schedule order
    inside one jitted loop.  A future pass that merges tiny colors or splits
    a round changes execution through this lowering alone; `core/bayesnet.py`
    never hears about it.
  * MRF: each round is recognized as one checkerboard parity and executed in
    schedule order.  The default path is the vectorized engine math (bit-
    exact with eager for every sampler); `fused=True` routes `lut_ky` rounds
    through the Pallas kernel in `kernels/mrf_gibbs.py` (same random-word
    derivation as `draw_from_logits`, so still bit-identical).

Bit-exactness with the eager backend is not an aspiration but a checked
invariant: `cross_check()` runs both backends on a tiny budget and compares
bits; `CompiledProgram` invokes it the first time a program is lowered (and
eagerly at compile time under `compile_graph(..., cross_check=True)`).

The sharded counterpart lives in `core/distributed.py`
(`run_program_sharded(..., backend="schedule")`), which routes each round's
named comm mechanism onto its collective: `psum_broadcast` -> a per-round
`lax.psum` of the disjoint state delta, `ppermute_halo` -> the `lax.ppermute`
boundary exchange.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.compile.schedule import Schedule, verify_schedule
from repro.core import bayesnet as bnet
from repro.core import mrf as mrf_mod
from repro.core.graphs import GridMRF
from repro.core.interp import build_exp_weight_lut
from repro.kernels import mrf_gibbs as mrf_kernels


class ScheduleLoweringError(RuntimeError):
    """The schedule cannot be lowered to this backend's execution form."""


class BackendMismatch(AssertionError):
    """The schedule backend produced different bits than the eager engine."""


# The schedule's named comm mechanisms and the collective each lowers to in
# the sharded execution path (core/distributed.py).
MECHANISM_COLLECTIVES = {
    "psum_broadcast": "lax.psum",
    "ppermute_halo": "lax.ppermute",
}


@dataclasses.dataclass
class BNScheduleExec:
    """A BN schedule lowered to per-round gather tensors."""

    cbn: bnet.CompiledBayesNet
    round_groups: list[bnet.ColorGroup]  # one per Round, schedule-ordered


@dataclasses.dataclass(frozen=True)
class MRFScheduleExec:
    """A grid-MRF schedule lowered to a checkerboard parity sequence."""

    mrf: GridMRF
    parities: tuple[int, ...]  # per-round parity, schedule-ordered


def lower_schedule(program) -> BNScheduleExec | MRFScheduleExec:
    """Lower a `CompiledProgram`'s schedule into an executable form.

    Legality is re-verified first: round-ordered execution is only correct if
    the rounds still partition the free RVs with no intra-round conflicts
    (a buggy future pass must fail here, not corrupt samples)."""
    ir = program.ir
    schedule: Schedule = program.schedule
    verify_schedule(ir, schedule)
    if ir.kind == "bn":
        bn = ir.source
        bases = bnet.cpt_bases(bn)
        groups = [
            bnet.build_color_group(bn, list(r.nodes), bases)
            for r in schedule.rounds
        ]
        return BNScheduleExec(cbn=program.cbn, round_groups=groups)
    mrf = ir.source
    class_size = {
        p: sum(
            (r + c) % 2 == p
            for r in range(mrf.height) for c in range(mrf.width)
        )
        for p in (0, 1)
    }
    parities = []
    for r in schedule.rounds:
        pars = {(v // mrf.width + v % mrf.width) % 2 for v in r.nodes}
        if len(pars) != 1:
            raise ScheduleLoweringError(
                f"MRF round {r.color} mixes checkerboard parities {pars}; "
                "the fused grid path needs single-parity rounds"
            )
        parity = pars.pop()
        if len(r.nodes) != class_size[parity]:
            # the grid path executes whole parity classes; a round holding
            # only part of one (e.g. from a round-splitting pass) has no
            # lowering here and must fail loudly, not run the wrong plan
            raise ScheduleLoweringError(
                f"MRF round {r.color} covers {len(r.nodes)} of the "
                f"{class_size[parity]} parity-{parity} sites; partial-parity "
                "rounds are not loweable by the grid backend"
            )
        parities.append(parity)
    return MRFScheduleExec(mrf=mrf, parities=tuple(parities))


# ---------------------------------------------------------------------------
# BN: round-ordered jitted sweep
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("n_chains", "n_iters", "burn_in", "sampler")
)
def _run_bn_rounds(
    cbn, round_groups, key, *, n_chains, n_iters, burn_in, sampler
):
    vals, key = bnet.init_chain_values(cbn, key, n_chains)
    return bnet.gibbs_run_loop(
        cbn, round_groups, vals, key, n_iters, burn_in, sampler
    )


def run_bn_schedule(
    ex: BNScheduleExec,
    key: jax.Array,
    *,
    n_chains: int = 32,
    n_iters: int = 200,
    burn_in: int = 50,
    sampler: str = "lut_ky",
):
    """Execute a lowered BN schedule; same contract as `bayesnet.run_gibbs`
    (returns (marginals (n, V), final vals))."""
    return _run_bn_rounds(
        ex.cbn, ex.round_groups, key,
        n_chains=n_chains, n_iters=n_iters, burn_in=burn_in, sampler=sampler,
    )


# ---------------------------------------------------------------------------
# MRF: schedule-ordered rounds, optionally fused through the Pallas kernel
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "mrf", "parities", "n_chains", "n_iters", "sampler", "fused",
        "interpret",
    ),
)
def _run_mrf_rounds(
    mrf, parities, evidence, key, *, n_chains, n_iters, sampler, fused,
    interpret,
):
    exp_table, exp_spec = build_exp_weight_lut()
    k0, key = jax.random.split(key)
    labels = jax.random.randint(
        k0, (n_chains, mrf.height, mrf.width), 0, mrf.n_labels, jnp.int32
    )

    def body(t, carry):
        labels, key = carry
        ks = jax.random.split(key, 1 + len(parities))
        for i, parity in enumerate(parities):
            if fused:
                labels = mrf_kernels.mrf_round_step(
                    mrf, labels, evidence, ks[1 + i], parity,
                    exp_table, exp_spec, interpret=interpret,
                )
            else:
                labels = mrf_mod.half_step(
                    mrf, labels, evidence, ks[1 + i], parity, sampler,
                    exp_table, exp_spec,
                )
        return labels, ks[0]

    labels, _ = jax.lax.fori_loop(0, n_iters, body, (labels, key))
    return labels


def run_mrf_schedule(
    ex: MRFScheduleExec,
    evidence: jax.Array,
    key: jax.Array,
    *,
    n_chains: int = 32,
    n_iters: int = 200,
    sampler: str = "lut_ky",
    fused: bool = False,
):
    """Execute a lowered MRF schedule; same contract as `mrf.run_mrf_gibbs`
    (returns final labels (B, H, W)).

    `fused=True` drives the rounds through the Pallas half-step kernel
    (lut_ky only — the kernel hard-codes the C1+C2 datapath); random words
    are derived exactly as `draw_from_logits` derives them, so the fused
    path stays bit-identical to the eager engine."""
    if fused and sampler != "lut_ky":
        raise ValueError(
            f"fused schedule rounds implement the lut_ky datapath only, "
            f"got sampler={sampler!r}"
        )
    interpret = jax.default_backend() != "tpu"
    return _run_mrf_rounds(
        ex.mrf, ex.parities, evidence, key,
        n_chains=n_chains, n_iters=n_iters, sampler=sampler, fused=fused,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Bit-exactness cross-check between the two backends
# ---------------------------------------------------------------------------

_CHECK_KEY = 0xA1A  # fixed: the check must be deterministic per program
_CHECK_CHAINS = 2
_CHECK_ITERS = 3


def cross_check(program, ex=None) -> None:
    """Run both backends on a tiny budget and require identical bits.

    Raises `BackendMismatch` on any divergence — a cached program whose
    schedule execution drifted from the eager engines must never serve."""
    import numpy as np

    ex = lower_schedule(program) if ex is None else ex
    key = jax.random.key(_CHECK_KEY)
    if program.kind == "bn":
        marg_e, vals_e = bnet.run_gibbs(
            program.cbn, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
            burn_in=0,
        )
        marg_s, vals_s = run_bn_schedule(
            ex, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS, burn_in=0,
        )
        same = (np.asarray(vals_e) == np.asarray(vals_s)).all() and (
            np.asarray(marg_e) == np.asarray(marg_s)
        ).all()
    else:
        mrf = program.mrf
        ev = jnp.zeros((mrf.height, mrf.width), jnp.int32)
        lab_e = mrf_mod.run_mrf_gibbs(
            mrf, ev, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
        )
        lab_s = run_mrf_schedule(
            ex, ev, key, n_chains=_CHECK_CHAINS, n_iters=_CHECK_ITERS,
        )
        same = (np.asarray(lab_e) == np.asarray(lab_s)).all()
    if not same:
        raise BackendMismatch(
            f"schedule backend diverged from eager on program "
            f"{program.program_key[:12]} ({program.kind})"
        )
