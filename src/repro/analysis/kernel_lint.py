"""Static VMEM footprint estimator for the fused Pallas kernels.

A fused bucket that exceeds per-core VMEM fails at dispatch time, on
device, after the batcher has already committed the microbatch.  This
linter estimates the footprint *statically* — from the model structure,
chain width, and sampler alone, no JAX import, no trace — so
`runtime.batcher.fused_eligible` can demote an oversized bucket to the
unfused route up front (`fused_fits`), and the CLI can flag wide replicas
(hepar2/pigs-class models) before anyone benchmarks them.

The estimate mirrors the kernels' actual buffer structure:

  * **BN** (`kernels.bn_gibbs.fused_gibbs_sweep`): the VMEM-resident
    inputs (value block ×2, per-round gather tensors at the padded
    (c_max, f_max, s_max) envelope, the round's random words, the whole
    log-CPT arena, the exp LUT) plus the kernel's live intermediates,
    dominated by the `val_or_v` candidate tensor — (B, C, F, S, V) × 4
    bytes — and the (B, C, F, V) address/gather pair.  The envelope is
    re-derived here numpy-only: DSATUR over the IR's moral adjacency
    gives c_max (bit-identical to `DsaturPass`; `MergeSmallColorsPass`
    is the identity on DSATUR output, so the runtime pipeline matches
    too), and f_max/s_max are coloring-independent structural maxima.
  * **MRF** (`kernels.mrf_gibbs.mrf_half_step_kernel`): one row-block
    tile — 3 label blocks + evidence + words + the per-candidate energy
    stack and (site, LANES) draw-stage buffers — times the chain count
    (the chain vmap batches the grid, so each grid step still holds one
    chain's tile; chains share nothing, and we budget for the batcher's
    whole chain width resident at once to stay conservative).

Estimates are deliberately *upper-ish* bounds, not bit-accurate sums:
Mosaic's scratch allocation and double-buffering are not modeled, so the
headroom factor below absorbs them.  The point is to demote buckets that
are clearly over budget, not to pack VMEM to the last byte.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis import Finding
from repro.core import coloring as coloring_mod

# Per-core VMEM on current TPUs (see /opt/skills/guides: ~16 MiB/core).
DEFAULT_VMEM_BYTES = 16 * 2**20
# Fraction of the budget at which a warning (not an error) fires.
PRESSURE_FRACTION = 0.75
# Mosaic scratch / double-buffering headroom multiplier on intermediates.
HEADROOM = 1.25

# Mirrors kernels.ky_sampler.LANES (the KY walk's fixed lane width).  Kept
# as a literal so this module stays jax-free; tests/test_analysis.py pins
# the two constants together.
KY_LANES = 128
# Mirrors bayesnet.build_exp_weight_lut defaults (paper Sec. III-D).
EXP_LUT_SIZE = 16
ITEM_BYTES = 4  # int32 / float32 throughout both kernels

_VMEM_BUDGET = DEFAULT_VMEM_BYTES


def vmem_budget() -> int:
    return _VMEM_BUDGET


def set_vmem_budget(n_bytes: int) -> int:
    """Set the global VMEM budget the linter (and through `fused_fits`,
    the batcher's fused-demotion check) enforces.  Returns the previous
    budget so tests can restore it."""
    global _VMEM_BUDGET
    if n_bytes < 1:
        raise ValueError(f"VMEM budget must be positive, got {n_bytes}")
    prev, _VMEM_BUDGET = _VMEM_BUDGET, int(n_bytes)
    _FIT_CACHE.clear()
    return prev


@dataclasses.dataclass(frozen=True)
class KernelFootprint:
    """A kernel's estimated per-core VMEM residency, with the breakdown
    that tells a human *which* buffer blew the budget."""

    kernel: str  # "bn_fused" | "mrf_fused"
    model: str
    n_chains: int
    sampler: str
    input_bytes: int
    intermediate_bytes: int
    breakdown: dict
    # mesh-slice width the estimate assumes: > 1 budgets the per-shard
    # envelope (local row slab + halo rows MRF; owned node slice BN)
    shard_width: int = 1

    @property
    def total_bytes(self) -> int:
        return self.input_bytes + int(self.intermediate_bytes * HEADROOM)

    def findings(
        self, budget: int | None = None, demotable: bool = True
    ) -> list[Finding]:
        """`demotable=True` (the default) means the batcher's `fused_fits`
        guard will route this bucket unfused before it ever dispatches, so
        an over-budget estimate is a capacity advisory (warning) rather
        than an OOM-in-waiting (error).  Pass False when lint is asked
        about a forced-fused path with no demotion guard."""
        budget = _VMEM_BUDGET if budget is None else budget
        total = self.total_bytes
        loc = f"{self.model}:{self.kernel}"
        if self.shard_width > 1:
            loc += f"@sh{self.shard_width}"
        top = max(self.breakdown, key=self.breakdown.get)
        detail = (
            f"estimated {total / 2**20:.2f} MiB resident "
            f"(B={self.n_chains}, sampler={self.sampler}; dominant buffer "
            f"{top!r} at {self.breakdown[top] / 2**20:.2f} MiB) vs "
            f"{budget / 2**20:.2f} MiB budget"
        )
        if total > budget:
            if demotable:
                detail += "; batcher demotes this bucket to the unfused route"
            return [Finding(
                rule="vmem-budget", loc=loc, message=detail,
                severity="warning" if demotable else "error",
                fixit="shrink n_chains / block size, or keep the bucket on "
                      "the unfused route",
            )]
        if total > PRESSURE_FRACTION * budget:
            return [Finding(rule="vmem-pressure", loc=loc, message=detail)]
        return []


def bn_group_envelope(graph) -> tuple[int, int, int]:
    """(c_max, f_max, s_max) of `build_fused_rounds`' padded envelope,
    re-derived without compiling: DSATUR over the IR's (moral) adjacency
    for the group sizes, structural maxima for the factor/scope dims."""
    adj = graph.adjacency()
    colors = coloring_mod.dsatur(adj)
    evid = {node for node, _ in graph.evidence}
    c_max = 0
    if len(colors):
        for c in range(int(colors.max()) + 1):
            group = [v for v in np.where(colors == c)[0] if v not in evid]
            c_max = max(c_max, len(group))
    bn = graph.source
    n_children = np.zeros(graph.n_nodes, np.int64)
    for j, ps in enumerate(bn.parents):
        for p in ps:
            n_children[p] += 1
    f_max = int(n_children.max() + 1) if graph.n_nodes else 0
    s_max = max((len(ps) + 1 for ps in bn.parents), default=0)
    return c_max, f_max, s_max


def _bn_arena_size(bn) -> int:
    # flat log-CPT arena: dummy entry 0 + every CPT flattened
    return 1 + sum(int(np.prod(np.shape(cpt))) for cpt in bn.cpts)


def _ky_words(v: int, sampler: str, precision: int = 16,
              max_retries: int = 8) -> int:
    # mirrors fused_gibbs_sweep's precision widening + word-count math
    weight_bits = 8 if sampler == "lut_ky" else 15
    precision = max(precision, weight_bits + max(v - 1, 1).bit_length() + 1)
    return -(-(precision * max_retries) // 32)


def bn_fused_footprint(
    graph, n_chains: int, sampler: str = "lut_ky", shard_width: int = 1
) -> KernelFootprint:
    """Estimate `fused_gibbs_sweep`'s per-core VMEM residency for one
    model at one chain width (the batcher vmaps buckets over query lanes,
    which batches the *grid*, so per-step residency stays one lane's).

    `shard_width > 1` models the sharded fused engine
    (`distributed.bn_fused_sharded`): each device's round kernel sees only
    its *owned node slice* — round-robin (or placement-mod) ownership
    caps the per-device group width at ceil(c_max / shard_width) — while
    the value block, CPT arena, and LUT stay fully resident (the psum
    merge needs whole-state values on every device)."""
    b = int(n_chains)
    n = graph.n_nodes
    c, f, s = bn_group_envelope(graph)
    c = -(-c // max(1, int(shard_width)))  # per-device owned slice
    v = max(graph.cards) if graph.cards else 0
    w = _ky_words(v, sampler)
    arena = _bn_arena_size(graph.source)
    inputs = {
        "value_block": 2 * b * n,  # vals_ref + resident out_ref
        "round_tensors": 2 * c + c * f + 3 * c * f * s,
        "random_words": b * c * w,
        "cpt_arena": arena,
        "exp_lut": EXP_LUT_SIZE,
    }
    inter = {
        "scope_vals": b * c * f * s,
        "val_or_v": b * c * f * s * v,  # the dominant candidate tensor
        "gather_addr": b * c * f * v,
        "gather_read": b * c * f * v,
        "logp": 3 * b * c * v,  # logp + flat + z
        "ky_weights": 3 * b * c * KY_LANES,  # w + m_ext + walk state
        "scatter": c * n + b * n,
    }
    breakdown = {k: x * ITEM_BYTES for k, x in {**inputs, **inter}.items()}
    return KernelFootprint(
        kernel="bn_fused", model=graph.name, n_chains=b, sampler=sampler,
        input_bytes=sum(inputs.values()) * ITEM_BYTES,
        intermediate_bytes=sum(inter.values()) * ITEM_BYTES,
        breakdown=breakdown, shard_width=int(shard_width),
    )


def mrf_fused_footprint(
    graph, n_chains: int, sampler: str = "lut_ky", block_h: int = 32,
    shard_width: int = 1,
) -> KernelFootprint:
    """Estimate `mrf_half_step_kernel`'s residency for one model.  Chains
    (and bucket lanes) are vmapped over the kernel, which batches the
    *grid* — grid steps execute sequentially, so per-step residency is one
    chain's (block_h, W) tile regardless of `n_chains` (kept in the record
    for the fit-cache key and the report).

    `shard_width > 1` models the sharded fused engine
    (`distributed.mrf_fused_sharded` via `mrf_halo_half_step_kernel`):
    each device tiles its *local row slab* of height // shard_width rows,
    with the two ppermute'd halo rows and the traced row offset resident
    beside the tile."""
    b = int(n_chains)
    mrf = graph.source
    height, width = int(mrf.height), int(mrf.width)
    h_loc = -(-height // max(1, int(shard_width)))
    bh = min(block_h, h_loc)
    v = int(mrf.n_labels)
    sites = bh * width
    w = _ky_words(v, sampler)
    inputs = {
        "label_blocks": 4 * sites,  # prev/cur/next halo blocks + out
        "evidence_block": sites,
        "random_words": sites * w,
        "exp_lut": EXP_LUT_SIZE,
    }
    if shard_width > 1:
        # the slab-edge halo rows + the (1, 1) row-offset ref
        inputs["halo_rows"] = 2 * width + 1
    inter = {
        "neighbor_shifts": 4 * sites,
        "energies": (2 * v + 1) * sites,  # energies + z columns + e_max
        "ky_weights": 3 * sites * KY_LANES,  # w + m_ext + walk state
    }
    breakdown = {k: x * ITEM_BYTES for k, x in {**inputs, **inter}.items()}
    return KernelFootprint(
        kernel="mrf_fused", model=graph.name, n_chains=b, sampler=sampler,
        input_bytes=sum(inputs.values()) * ITEM_BYTES,
        intermediate_bytes=sum(inter.values()) * ITEM_BYTES,
        breakdown=breakdown, shard_width=int(shard_width),
    )


def estimate_footprint(
    graph, n_chains: int, sampler: str = "lut_ky", shard_width: int = 1
) -> KernelFootprint:
    if graph.kind == "bn":
        return bn_fused_footprint(graph, n_chains, sampler, shard_width)
    return mrf_fused_footprint(graph, n_chains, sampler,
                               shard_width=shard_width)


# fit verdicts memoized by content hash — bucket_key calls this per query,
# so the steady-state cost must be a dict hit, not a DSATUR run
_FIT_CACHE: dict[tuple, bool] = {}


def fused_fits(graph, n_chains: int, sampler: str = "lut_ky",
               shard_width: int = 1) -> bool:
    """Demotion oracle for `runtime.batcher.fused_eligible`: does this
    (model, chain width, sampler, mesh-slice width) bucket fit the fused
    kernel's VMEM budget?  False means "route unfused" — bit-exact, just
    slower — instead of OOMing on device.  Sharded buckets
    (`shard_width > 1`) are judged on the per-shard envelope, since that
    is what each device of the shard_map body actually allocates."""
    key = (graph.ir_key, int(n_chains), sampler, int(shard_width),
           _VMEM_BUDGET)
    hit = _FIT_CACHE.get(key)
    if hit is None:
        fp = estimate_footprint(graph, n_chains, sampler, shard_width)
        hit = fp.total_bytes <= _VMEM_BUDGET
        _FIT_CACHE[key] = hit
    return hit


def lint_kernels(
    graphs, n_chains: int = 32, sampler: str = "lut_ky",
    budget: int | None = None, demotable: bool = True,
) -> list[Finding]:
    """Footprint findings for a set of IRs — the CLI/CI entry point."""
    out: list[Finding] = []
    for g in graphs:
        out.extend(
            estimate_footprint(g, n_chains, sampler).findings(
                budget, demotable=demotable
            )
        )
    return out
