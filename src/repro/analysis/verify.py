"""Static schedule verifier — the parallel-Gibbs race detector.

Given a `SamplingGraph` and a lowered `Schedule`, prove (or refute) the
invariants the whole execution stack assumes but never re-checks after
lowering:

  * **round independence** — no conflict edge inside a color round.  Two
    neighbors updating in the same round is the chromatic-Gibbs race
    condition: each reads the other's stale-or-fresh value depending on
    core timing, and the chain no longer targets the model's posterior.
  * **coverage** — the rounds partition exactly the free (non-evidence)
    RVs: no orphans, no duplicates, no unknown nodes.
  * **clamp/pin consistency** — evidence-clamped nodes never appear in a
    sampling round, and MRF pins never swallow a whole checkerboard
    parity class (which would silently change the per-iteration
    key-split structure).
  * **comm completeness** — every cross-core conflict edge whose value
    crosses a round boundary is covered by a comm op of the right
    mechanism, byte count, and hop distance; no op ships traffic nothing
    generates.
  * **placement legality** — nodes sit on real cores and each round's
    recorded `core_load` matches the placement (that tuple is what the
    cost model charges compute against).
  * **cost-model sanity** — the diagnostics the passes recorded
    (`schedule_cost`, critical/balanced core load) reconcile with the
    cost recomputed from the schedule itself.

Everything here is a pure function of the artifacts — no JAX, no
execution — so it can gate every compile (`VerifyPass`), every cached
program (`verify_program`), and every CI run without touching a device.

The expected-traffic recomputation deliberately re-derives what
`schedule.build_schedule` computes, from the *rounds themselves* rather
than the colors array: the verifier checks the artifact that will
execute, independent of how it was produced.
"""

from __future__ import annotations

import time

import numpy as np

from repro.analysis import Finding, Report
from repro.core import coloring as coloring_mod

# `repro.compile` imports this module (VerifyPass, the re-exported error
# type), so compile-side names are only touched lazily: type annotations
# stay strings (future-annotations) and VALUE_BYTES/_manhattan are fetched
# inside the functions that need them.

# the rule ids this analyzer can emit (the CLI/report "rules run" set)
VERIFY_RULES = (
    "race-in-round", "node-dup", "coverage", "clamp-resampled",
    "pin-full-parity", "comm-missing", "comm-mechanism", "comm-bytes",
    "comm-hops", "comm-spurious", "placement-range", "placement-load",
    "load-imbalance", "cost-model",
)


class ScheduleVerificationError(AssertionError):
    """A lowered schedule violates a statically provable invariant.

    Subclasses AssertionError so callers guarding with
    `pytest.raises(AssertionError)` (and the backend's legality re-check)
    keep working — but it is *raised*, never `assert`ed, so the check
    survives `python -O`.  Carries the structured findings that produced
    it."""

    def __init__(self, findings):
        self.findings = tuple(findings)
        lines = [f.render() for f in self.findings]
        super().__init__(
            "schedule verification failed "
            f"({len(self.findings)} error finding(s)):\n  "
            + "\n  ".join(lines)
        )


def raise_on_errors(findings, keep_warnings: bool = True) -> list[Finding]:
    """Raise `ScheduleVerificationError` if any error-severity finding is
    present; otherwise return the findings unchanged (warnings pass)."""
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        raise ScheduleVerificationError(errors)
    return list(findings) if keep_warnings else []


def require_proper_coloring(
    adj: list[set[int]], colors: np.ndarray, loc: str
) -> None:
    """The raised (non-strippable) replacement for the pipeline's old
    `assert verify_coloring(...)`: locate an offending edge and raise a
    structured race finding."""
    if coloring_mod.verify_coloring(adj, colors):
        return
    findings = []
    for u, nbrs in enumerate(adj):
        bad = [v for v in nbrs if colors[v] == colors[u] and v > u]
        if bad:
            findings.append(Finding(
                rule="race-in-round",
                loc=loc,
                message=(
                    f"nodes {u} and {bad[0]} are conflict-graph neighbors "
                    f"but share color {int(colors[u])}"
                ),
                fixit="re-run DSATUR or repair the imported coloring",
            ))
            break
    if not findings:  # length/range mismatch rather than a same-color edge
        findings.append(Finding(
            rule="race-in-round", loc=loc,
            message="coloring failed verify_coloring (malformed colors array)",
        ))
    raise ScheduleVerificationError(findings)


def _expected_traffic(
    schedule: Schedule,
    adj: list[set[int]],
    evid: set[int],
    placement: MeshPlacement,
) -> list[dict[tuple[int, int], int]]:
    """Per-round expected (src_core, dst_core) -> bytes, re-derived from
    round membership: after a round updates u, every free conflict neighbor
    outside the round reads u's new value; a cross-core read ships
    VALUE_BYTES, aggregated per core pair (one halo exchange / delta
    broadcast per pair)."""
    from repro.compile.schedule import VALUE_BYTES

    pl = placement.placement
    per_round = []
    n = len(pl)
    for r in schedule.rounds:
        in_round = set(r.nodes)
        traffic: dict[tuple[int, int], int] = {}
        for u in r.nodes:
            if not 0 <= u < n:  # unknown id; already a coverage finding
                continue
            cu = int(pl[u])
            dst_cores = {
                int(pl[v])
                for v in adj[u]
                if v not in in_round and v not in evid
            }
            for cv in dst_cores - {cu}:
                traffic[(cu, cv)] = traffic.get((cu, cv), 0) + VALUE_BYTES
        per_round.append(traffic)
    return per_round


def _legality_findings(
    ir: SamplingGraph, schedule: Schedule, adj: list[set[int]],
    evid: set[int], loc: str,
) -> list[Finding]:
    """Rules that need no placement: races, duplicates, coverage, clamps,
    full-parity pins."""
    out: list[Finding] = []
    seen: set[int] = set()
    for r in schedule.rounds:
        rloc = f"{loc}:round {r.color}"
        in_round = set(r.nodes)
        dup = in_round & seen
        if len(in_round) < len(r.nodes):
            out.append(Finding(
                rule="node-dup", loc=rloc,
                message=f"round lists {len(r.nodes) - len(in_round)} "
                        "node(s) more than once",
            ))
        if dup:
            out.append(Finding(
                rule="node-dup", loc=rloc,
                message=f"node(s) {sorted(dup)[:4]} already scheduled in an "
                        "earlier round",
            ))
        seen |= in_round
        clamped = in_round & evid
        if clamped:
            out.append(Finding(
                rule="clamp-resampled", loc=rloc,
                message=f"evidence-clamped node(s) {sorted(clamped)[:4]} "
                        "would be re-sampled",
                fixit="drop evidence nodes from the round in build_schedule",
            ))
        unknown = {u for u in in_round if not (0 <= u < ir.n_nodes)}
        if unknown:
            out.append(Finding(
                rule="coverage", loc=rloc,
                message=f"unknown node id(s) {sorted(unknown)[:4]} "
                        f"(IR has {ir.n_nodes} nodes)",
            ))
            in_round -= unknown
        for u in sorted(in_round):
            bad = adj[u] & in_round
            if bad:
                out.append(Finding(
                    rule="race-in-round", loc=rloc,
                    message=(
                        f"conflict-graph neighbors {u} and {min(bad)} update "
                        "in the same round (parallel-Gibbs race)"
                    ),
                    fixit="split the round so no conflict edge is internal",
                ))
                break  # one witness per round keeps reports readable
    free = set(range(ir.n_nodes)) - evid
    missing = free - seen
    if missing:
        out.append(Finding(
            rule="coverage", loc=loc,
            message=f"{len(missing)} free RV(s) appear in no round "
                    f"(first: {sorted(missing)[:4]}); their chains would "
                    "never mix",
        ))
    if ir.kind == "mrf":
        src = ir.source
        h, w = int(src.height), int(src.width)
        for parity in (0, 1):
            cls = {
                r * w + c
                for r in range(h) for c in range(w)
                if (r + c) % 2 == parity
            }
            if cls and cls <= evid:
                out.append(Finding(
                    rule="pin-full-parity", loc=f"{loc}:ir",
                    message=(
                        f"pins cover the entire parity-{parity} checkerboard "
                        "class; the per-iteration key-split structure would "
                        "silently change"
                    ),
                    fixit="leave at least one free site per parity class",
                ))
    return out


def _comm_findings(
    ir: SamplingGraph, schedule: Schedule, adj: list[set[int]],
    evid: set[int], placement: MeshPlacement, loc: str,
) -> list[Finding]:
    from repro.core.mapping import _manhattan

    out: list[Finding] = []
    expected_mech = "ppermute_halo" if ir.kind == "mrf" else "psum_broadcast"
    cols = schedule.mesh_shape[1]
    expected = _expected_traffic(schedule, adj, evid, placement)
    for r, want in zip(schedule.rounds, expected):
        rloc = f"{loc}:round {r.color}"
        got: dict[tuple[int, int], int] = {}
        for op in r.comm:
            if op.mechanism != expected_mech:
                out.append(Finding(
                    rule="comm-mechanism", loc=rloc,
                    message=(
                        f"comm op {op.src_core}->{op.dst_core} uses "
                        f"{op.mechanism!r}; {ir.kind} rounds move data via "
                        f"{expected_mech!r}"
                    ),
                    fixit=f"lower {ir.kind} comm onto {expected_mech}",
                ))
            want_hops = _manhattan(op.src_core, op.dst_core, cols)
            if op.hops != want_hops:
                out.append(Finding(
                    rule="comm-hops", loc=rloc,
                    message=(
                        f"comm op {op.src_core}->{op.dst_core} claims "
                        f"{op.hops} hop(s); Manhattan distance on the "
                        f"{schedule.mesh_shape} mesh is {want_hops}"
                    ),
                ))
            got[(op.src_core, op.dst_core)] = (
                got.get((op.src_core, op.dst_core), 0) + op.n_bytes
            )
        for pair in sorted(set(want) - set(got)):
            out.append(Finding(
                rule="comm-missing", loc=rloc,
                message=(
                    f"cross-core edge traffic core {pair[0]} -> core "
                    f"{pair[1]} ({want[pair]} B) has no covering comm op; "
                    "the next round would read a stale value"
                ),
                fixit="emit the aggregated comm op in build_schedule",
            ))
        for pair in sorted(set(got) - set(want)):
            out.append(Finding(
                rule="comm-spurious", loc=rloc,
                message=(
                    f"comm op core {pair[0]} -> core {pair[1]} "
                    f"({got[pair]} B) matches no cross-round conflict edge "
                    "(cost model overcharges)"
                ),
            ))
        for pair in sorted(set(got) & set(want)):
            if got[pair] != want[pair]:
                out.append(Finding(
                    rule="comm-bytes", loc=rloc,
                    message=(
                        f"comm op core {pair[0]} -> core {pair[1]} ships "
                        f"{got[pair]} B; the round's updates generate "
                        f"{want[pair]} B"
                    ),
                ))
    return out


def _placement_findings(
    ir: SamplingGraph, schedule: Schedule, evid: set[int],
    placement: MeshPlacement, loc: str,
) -> list[Finding]:
    out: list[Finding] = []
    n_cores = schedule.n_cores
    pl = np.asarray(placement.placement)
    off_mesh = np.where((pl < 0) | (pl >= n_cores))[0]
    if len(off_mesh):
        out.append(Finding(
            rule="placement-range", loc=loc,
            message=(
                f"node(s) {off_mesh[:4].tolist()} placed on core(s) "
                f"{pl[off_mesh[:4]].tolist()}; mesh has {n_cores} cores"
            ),
        ))
        return out  # load accounting is meaningless off-mesh
    for r in schedule.rounds:
        if not r.core_load:
            continue  # legacy schedule: compute falls back to balanced share
        rloc = f"{loc}:round {r.color}"
        known = [u for u in r.nodes if 0 <= u < len(pl)]
        want = np.bincount(pl[known], minlength=n_cores)
        got = np.asarray(r.core_load)
        if len(got) != n_cores or not np.array_equal(got, want):
            out.append(Finding(
                rule="placement-load", loc=rloc,
                message=(
                    "recorded core_load disagrees with the placement "
                    f"(critical core charge {int(got.max()) if len(got) else 0}"
                    f" recorded vs {int(want.max())} actual)"
                ),
                fixit="rebuild core_load from the placement in build_schedule",
            ))
            continue
        balanced = -(-len(r.nodes) // n_cores)
        if int(got.max()) > 2 * balanced:
            out.append(Finding(
                rule="load-imbalance", loc=rloc,
                message=(
                    f"critical core holds {int(got.max())} nodes vs balanced "
                    f"share {balanced} (placement quality, not correctness)"
                ),
                fixit="try a different mapper (ROADMAP item 5)",
            ))
    return out


def _cost_findings(
    schedule: Schedule, diagnostics: dict, loc: str
) -> list[Finding]:
    out: list[Finding] = []
    recorded = diagnostics.get("schedule_cost")
    if recorded is not None:
        actual = schedule.cost()
        diff = {
            k: (recorded.get(k), actual[k])
            for k in actual
            if recorded.get(k) != actual[k]
        }
        if diff:
            k, (rec, act) = next(iter(diff.items()))
            out.append(Finding(
                rule="cost-model", loc=loc,
                message=(
                    f"recorded schedule_cost[{k!r}]={rec} but the schedule "
                    f"recomputes {act} ({len(diff)} field(s) disagree)"
                ),
                fixit="re-record diagnostics after any schedule mutation",
            ))
    crit = diagnostics.get("critical_core_load")
    if crit is not None:
        actual_crit = max(
            (max(r.core_load) for r in schedule.rounds if r.core_load),
            default=0,
        )
        if crit != actual_crit:
            out.append(Finding(
                rule="cost-model", loc=loc,
                message=(
                    f"recorded critical_core_load={crit} but the rounds' "
                    f"core_load gives {actual_crit}"
                ),
            ))
    bal = diagnostics.get("balanced_core_load")
    if bal is not None:
        actual_bal = max(
            (-(-len(r.nodes) // schedule.n_cores) for r in schedule.rounds),
            default=0,
        )
        if bal != actual_bal:
            out.append(Finding(
                rule="cost-model", loc=loc,
                message=(
                    f"recorded balanced_core_load={bal} but the rounds give "
                    f"{actual_bal}"
                ),
            ))
    return out


def verify_schedule_static(
    ir: SamplingGraph,
    schedule: Schedule,
    *,
    placement: MeshPlacement | None = None,
    diagnostics: dict | None = None,
    adj: list[set[int]] | None = None,
    model: str | None = None,
) -> list[Finding]:
    """Run every applicable verify rule; return findings (never raises).

    Legality rules (races, coverage, clamps, pins) always run.  Comm and
    placement rules need the `placement`; cost-model rules need the pass
    `diagnostics` — both are optional so the verifier degrades gracefully
    on partial artifacts (e.g. a bare Schedule in a test)."""
    if adj is None:
        adj = ir.adjacency()
    evid = {node for node, _ in ir.evidence}
    loc = model or ir.name
    findings = _legality_findings(ir, schedule, adj, evid, loc)
    if placement is not None:
        findings += _comm_findings(ir, schedule, adj, evid, placement, loc)
        findings += _placement_findings(ir, schedule, evid, placement, loc)
    if diagnostics is not None:
        findings += _cost_findings(schedule, diagnostics, loc)
    return findings


def verify_program(program) -> Report:
    """Verify a `CompiledProgram`'s full artifact (schedule + placement +
    diagnostics) and wrap the result in a timed `Report` — the unit the
    CLI sweep and `launch/report.py`'s verification table consume."""
    t0 = time.perf_counter()
    findings = verify_schedule_static(
        program.ir,
        program.schedule,
        placement=program.placement,
        diagnostics=program.diagnostics,
        model=program.ir.name,
    )
    return Report(
        findings=findings,
        meta={
            "model": program.ir.name,
            "kind": program.ir.kind,
            "ir_key": program.ir.ir_key[:12],
            "pipeline": program.diagnostics.get("pipeline", "?"),
            "n_rounds": len(program.schedule.rounds),
            "n_rules": len(VERIFY_RULES),
            "verify_s": time.perf_counter() - t0,
        },
    )
