"""Static-analysis CLI: repo lint + schedule verification + kernel lint.

    python -m repro.analysis                       # everything, text report
    python -m repro.analysis --format json         # CI artifact (stdout)
    python -m repro.analysis --format json --out findings.json
    python -m repro.analysis --models survey alarm # restrict the sweep
    python -m repro.analysis --skip-lint           # artifact checks only
    python -m repro.analysis --root some/dir       # lint a different tree

Runs three analyzers and merges their findings into one report:

  1. repo-convention AST lint over the source tree (`source_lint`);
  2. schedule verification: every bench model compiled through *both*
     named pipelines (default/runtime) and statically verified — races,
     comm completeness, placement, clamps, cost model (`verify`);
  3. kernel VMEM lint over the same model set (`kernel_lint`).

Exit status is the report's: nonzero iff any error-severity finding —
the CI contract (the `repro.analysis` job fails the build on errors and
uploads the JSON report as an artifact).  Pure numpy end to end: the
sweep runs the pass pipeline, never the execution backends, so this CLI
needs no accelerator stack.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

from repro.analysis import Report
from repro.analysis import kernel_lint, source_lint
from repro.analysis import verify as verify_mod
from repro.compile import ir as ir_mod
from repro.compile.passes import named_pipeline, run_pipeline
from repro.core.graphs import GridMRF, bn_repository_replica

# the bench model set (mirrors benchmarks/bench_compile.py BN_WORKLOADS)
# plus two MRF grids — small enough to sweep in seconds, wide enough
# (pigs: 441 nodes) to exercise the envelope/VMEM paths
BENCH_BNS = ("survey", "alarm", "insurance", "water", "hepar2", "pigs")
BENCH_MRFS = ((16, 16, 4), (32, 32, 2))
PIPELINES = ("default", "runtime")


def iter_models(names=None):
    """(name, structure-only IR) for the sweep set."""
    for name in names if names is not None else BENCH_BNS:
        yield name, ir_mod.from_bayesnet(
            bn_repository_replica(name), evidence_mode="runtime"
        )
    if names is None:
        for h, w, v in BENCH_MRFS:
            mrf = GridMRF(h, w, v, name=f"mrf{h}x{w}v{v}")
            yield mrf.name, ir_mod.from_mrf(mrf)


def verify_sweep(models=None, mesh_shape=(4, 4)) -> Report:
    """Compile every model through both named pipelines and statically
    verify the lowered artifact.  A pipeline whose VerifyPass raises is
    recorded as its findings, not a crash — the sweep always completes."""
    report = Report(meta={"rows": [], "pipelines": list(PIPELINES)})
    for name, graph in iter_models(models):
        for pipe in PIPELINES:
            t0 = time.perf_counter()
            try:
                ctx = run_pipeline(graph, mesh_shape, named_pipeline(pipe))
                found = []
                verify_s = ctx.pass_times_s.get("verify", 0.0)
                n_rounds = len(ctx.schedule.rounds)
            except verify_mod.ScheduleVerificationError as e:
                found = list(e.findings)
                verify_s = time.perf_counter() - t0
                n_rounds = 0
            report.extend(found)
            report.meta["rows"].append({
                "model": name,
                "kind": graph.kind,
                "pipeline": pipe,
                "n_nodes": graph.n_nodes,
                "n_rounds": n_rounds,
                "n_rules": len(verify_mod.VERIFY_RULES),
                "n_findings": len(found),
                "verify_s": round(verify_s, 6),
            })
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: source lint + schedule verify + "
                    "kernel VMEM lint",
    )
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", help="also write the JSON report to this path")
    ap.add_argument(
        "--root", default=None,
        help="source tree to lint (default: the installed repro package)",
    )
    ap.add_argument(
        "--models", nargs="*", default=None,
        help=f"bench BNs to sweep (default: {' '.join(BENCH_BNS)} + MRFs)",
    )
    ap.add_argument("--n-chains", type=int, default=32,
                    help="chain width for the kernel VMEM lint")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-verify", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args(argv)

    report = Report(meta={"analyzers": []})
    if not args.skip_lint:
        root = pathlib.Path(
            args.root if args.root else pathlib.Path(__file__).parents[1]
        )
        report.extend(source_lint.lint_repo(root))
        report.meta["analyzers"].append("source_lint")
        report.meta["lint_root"] = str(root)
    if not args.skip_verify:
        sweep = verify_sweep(args.models)
        report.extend(sweep.findings)
        report.meta["analyzers"].append("verify")
        report.meta["verify_rows"] = sweep.meta["rows"]
    if not args.skip_kernels:
        graphs = [g for _, g in iter_models(args.models)]
        report.extend(
            kernel_lint.lint_kernels(graphs, n_chains=args.n_chains)
        )
        report.meta["analyzers"].append("kernel_lint")
        report.meta["vmem_budget_bytes"] = kernel_lint.vmem_budget()

    if args.out:
        pathlib.Path(args.out).write_text(report.to_json())
    if args.format == "json":
        print(report.to_json())
    else:
        if report.meta.get("verify_rows"):
            from repro.launch.report import verification_table

            print(verification_table(report.meta["verify_rows"]))
            print()
        print(report.render_text())
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
