"""`repro.analysis` — static analysis over schedules, kernels, and source.

Correctness of the whole stack rests on invariants that nothing used to
check *statically*: chromatic Gibbs is only valid if no two conflict-graph
neighbors are sampled in the same color round (the race the AIA companion
paper's inter-core register sharing is engineered to avoid), every
cross-core edge must be covered by a comm op before the round that reads
it, and a fused Pallas bucket must actually fit VMEM before it is
dispatched.  Runtime bit-exactness cross-checks execute the program; these
analyzers prove properties of the *artifact* without running it, so they
can gate every cached program, every lint run, and every CI build.

Three analyzers share one finding model (this module) and one CLI
(`python -m repro.analysis`):

  * `analysis.verify`      — schedule verifier / parallel-Gibbs race
    detector (`verify_schedule_static`, `verify_program`, the
    `VerifyPass` wired into `repro.compile.passes`);
  * `analysis.kernel_lint` — static VMEM footprint estimator for the
    fused Pallas kernels (`bn_fused_footprint`, `mrf_fused_footprint`,
    `fused_fits` — the demotion oracle `runtime.batcher.fused_eligible`
    consults before routing a bucket fused);
  * `analysis.source_lint` — AST lint enforcing the repo's standing
    maintenance conventions (compat routing, no wall clock in the
    deterministic sim paths, no Python-level RNG in jit bodies, no bare
    `assert` for compile-pipeline invariants).

This package deliberately imports no JAX: every analyzer runs on plain
numpy/ast so the lint CLI is fast and usable where no accelerator stack
is installed.  (`analysis.verify` pulls in `repro.compile.passes` for the
`Pass` protocol types only.)
"""

from __future__ import annotations

import dataclasses
import json


# ---------------------------------------------------------------------------
# Rule catalog: every finding names one of these ids.  The severity here is
# the rule's *default*; individual findings may downgrade (never upgrade).
# ---------------------------------------------------------------------------

RULES: dict[str, tuple[str, str]] = {
    # -- schedule verifier (analysis/verify.py) -----------------------------
    "race-in-round": (
        "error",
        "two conflict-graph neighbors are scheduled in the same color round "
        "(the parallel-Gibbs race condition)",
    ),
    "node-dup": ("error", "a node is scheduled in more than one round"),
    "coverage": (
        "error",
        "the rounds do not partition the free RVs (orphan or unknown node)",
    ),
    "clamp-resampled": (
        "error",
        "an evidence-clamped node appears in a sampling round",
    ),
    "pin-full-parity": (
        "error",
        "MRF pins cover an entire checkerboard parity class (the "
        "per-iteration key-split structure would silently change)",
    ),
    "comm-missing": (
        "error",
        "a cross-core conflict edge that crosses a round boundary has no "
        "covering comm op in the round that produces the value",
    ),
    "comm-mechanism": (
        "error",
        "a comm op names the wrong data-movement mechanism for this model "
        "family (ppermute_halo for MRF, psum_broadcast for BN)",
    ),
    "comm-bytes": (
        "error",
        "a comm op's byte count disagrees with the traffic its round "
        "actually generates",
    ),
    "comm-hops": (
        "error",
        "a comm op's hop count is not the Manhattan distance between its "
        "cores on the mesh",
    ),
    "comm-spurious": (
        "warning",
        "a comm op ships traffic no conflict edge generates (the cost "
        "model overcharges)",
    ),
    "placement-range": ("error", "a node is placed on a core off the mesh"),
    "placement-load": (
        "error",
        "a round's recorded core_load disagrees with the placement "
        "(compute_cycles would charge the wrong critical core)",
    ),
    "load-imbalance": (
        "warning",
        "a round's critical core load exceeds twice its balanced share "
        "(placement quality, not correctness)",
    ),
    "cost-model": (
        "error",
        "recorded cost diagnostics disagree with the cost recomputed from "
        "the schedule",
    ),
    # -- kernel resource linter (analysis/kernel_lint.py) -------------------
    "vmem-budget": (
        "error",
        "the fused kernel's estimated VMEM footprint exceeds the budget "
        "(the bucket would OOM on device; demote to unfused)",
    ),
    "vmem-pressure": (
        "warning",
        "the fused kernel's estimated VMEM footprint exceeds 75% of the "
        "budget",
    ),
    # -- sampling-quality diagnostics (repro.diag, python -m repro.diag) ----
    "diag-threshold-breach": (
        "error",
        "a run's sampling-quality diagnostic (split R-hat, TV-vs-exact "
        "marginal error, ESS floor) breached its threshold — the posterior "
        "is not converged/faithful at this budget",
    ),
    "diag-oracle-unavailable": (
        "warning",
        "the exact-inference oracle is intractable for this model "
        "(min-fill VE cost above the limit); marginal error went unaudited, "
        "not silently passed",
    ),
    "diag-accum-overflow": (
        "error",
        "the quality accumulator's kept-draw count approached the int32/"
        "float32 exactness headroom (statistics no longer trustworthy)",
    ),
    "diag-perf-regression": (
        "error",
        "a benchmark's wall time regressed beyond tolerance against "
        "BENCH_BASELINE.json",
    ),
    "diag-quality-regression": (
        "error",
        "a benchmark's sampling-quality metric (R-hat / TV / ESS) "
        "regressed beyond tolerance against BENCH_BASELINE.json",
    ),
    # -- observability (repro.obs profiler + trace integrity) ---------------
    "obs-trace-dropped": (
        "warning",
        "the tracer ring buffer overflowed during the run (dropped events "
        "silently skew attribution/profile coverage; re-run with "
        "obs.enable(capacity=...) raised)",
    ),
    "obs-cost-drift": (
        "error",
        "a bucket executable's static HLO cost (flops / hbm_bytes / "
        "collective_bytes) drifted beyond tolerance against the baseline "
        "profile rows — a silent recompute or fusion regression",
    ),
    # -- repo-convention AST lint (analysis/source_lint.py) -----------------
    "compat-import": (
        "error",
        "direct jax.experimental / jax.shard_map API use outside "
        "core/compat.py (route through the compat shims)",
    ),
    "wallclock-in-sim": (
        "error",
        "wall-clock call (time.time/perf_counter/monotonic, datetime.now) "
        "inside a deterministic-simulation module",
    ),
    "pyrandom-in-jit": (
        "error",
        "Python-level RNG (random.*, np.random.*) inside a jit/vmap-"
        "decorated function (retraces or freezes the draw)",
    ),
    "bare-assert": (
        "error",
        "bare `assert` guarding a compile-pipeline invariant (stripped "
        "under python -O; raise ScheduleVerificationError instead)",
    ),
}

SEVERITIES = ("error", "warning", "info")


def rule_severity(rule: str) -> str:
    return RULES[rule][0] if rule in RULES else "error"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer result: rule id, severity, location, message, fix hint.

    `loc` is a clickable `path:line` for source findings and a
    `model:round N` / `model:ir` style anchor for artifact findings —
    always something a human can jump to."""

    rule: str
    loc: str
    message: str
    severity: str = ""
    fixit: str = ""

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule id {self.rule!r}")
        sev = self.severity or rule_severity(self.rule)
        if sev not in SEVERITIES:
            raise ValueError(f"unknown severity {sev!r}")
        object.__setattr__(self, "severity", sev)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tail = f"  [fix: {self.fixit}]" if self.fixit else ""
        return f"{self.loc}: {self.severity}[{self.rule}] {self.message}{tail}"


@dataclasses.dataclass
class Report:
    """The shared reporting spine: findings + run metadata, renderable as
    text (one line per finding) or JSON (the CI artifact schema)."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def exit_code(self) -> int:
        """Nonzero exactly when an error-severity finding exists — the CLI
        and CI contract."""
        return 1 if self.errors else 0

    def to_dict(self) -> dict:
        return {
            "schema": 1,
            "n_findings": len(self.findings),
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings": [f.to_dict() for f in self.findings],
            "meta": self.meta,
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def render_text(self) -> str:
        lines = [f.render() for f in self.findings]
        lines.append(
            f"{len(self.findings)} finding(s): {len(self.errors)} error(s), "
            f"{len(self.warnings)} warning(s)"
        )
        return "\n".join(lines)


def __getattr__(name):
    # lazy re-exports so `from repro.analysis import verify_program` works
    # without eagerly importing every analyzer (PEP 562)
    from importlib import import_module

    for mod, names in (
        ("verify", ("ScheduleVerificationError", "verify_program",
                    "verify_schedule_static", "require_proper_coloring")),
        ("kernel_lint", ("bn_fused_footprint", "mrf_fused_footprint",
                         "fused_fits", "lint_kernels", "set_vmem_budget")),
        ("source_lint", ("lint_file", "lint_repo")),
    ):
        if name in names:
            return getattr(import_module(f"repro.analysis.{mod}"), name)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
