"""Repo-convention AST lint — the standing-maintenance rules, enforced.

Four rules, each encoding a convention this repo already follows by hand
(ROADMAP "standing maintenance") and has been burned by before:

  * ``compat-import`` — `jax.experimental.*` / `jax.shard_map` APIs are
    version-unstable; every use must route through `core/compat.py`'s
    shims (the only exempt file) so the repo runs on both the pinned
    0.4.x container toolchain and current JAX.
  * ``wallclock-in-sim`` — the serving runtime is a *deterministic
    simulation*; a wall-clock read inside the engine event loop, the
    batcher, or the tracer's virtual-clock half silently breaks replay
    determinism.  The legitimate wall-metric sites carry a
    `# lint: allow[wallclock-in-sim]` pragma.
  * ``pyrandom-in-jit`` — Python-level RNG (`random.*`,
    `np.random.*`) inside a jit/vmap-decorated body executes at trace
    time: the "random" draw is frozen into the compiled program.
  * ``bare-assert`` — `assert` guarding a compile-pipeline invariant is
    stripped under `python -O`; those checks must be raised
    (`ScheduleVerificationError` or ValueError), not asserted.

Suppression: a ``# lint: allow[rule-id]`` comment on the offending line
or the line directly above silences that rule at that site — an explicit,
grep-able exemption rather than a config file.
"""

from __future__ import annotations

import ast
import pathlib
import re

from repro.analysis import Finding

# Deterministic-simulation modules: wall-clock reads here break replay.
# (calibrate.py measures real time by design; compile/ and launch/ record
# offline timing diagnostics — neither is in the sim loop.)
SIM_FILES = (
    "runtime/engine.py",
    "runtime/executor.py",
    "runtime/batcher.py",
    "obs/tracer.py",
)

# Compile-pipeline + kernel files where a stripped assert means a silent
# correctness hole (races, bad lowerings, exhausted random bits).
PIPELINE_FILES = ("compile/", "kernels/", "core/bayesnet.py", "core/ky.py")

# The one file allowed to touch version-unstable JAX APIs directly.
COMPAT_FILE = "core/compat.py"

WALLCLOCK_CALLS = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("time", "perf_counter_ns"), ("time", "monotonic_ns"),
    ("datetime", "now"), ("datetime", "utcnow"),
}

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\[([a-z0-9-]+)\]")


def _allowed(lines: list[str], lineno: int, rule: str) -> bool:
    """Pragma check: `# lint: allow[rule]` on the line or the line above."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _ALLOW_RE.search(lines[ln - 1])
            if m and m.group(1) == rule:
                return True
    return False


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an attribute/name chain ('' if dynamic)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_decorator(dec: ast.AST) -> bool:
    """jax.jit / jax.vmap / pmap, bare or wrapped in functools.partial."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    name = _dotted(target)
    if name.endswith(("jax.jit", "jax.vmap", "jax.pmap")) or name in (
        "jit", "vmap", "pmap"
    ):
        return True
    if isinstance(dec, ast.Call) and name.endswith("partial"):
        return any(_is_jit_decorator(a) for a in dec.args)
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, lines: list[str]):
        self.rel = rel
        self.lines = lines
        self.findings: list[Finding] = []
        self.in_sim = any(rel.endswith(s) for s in SIM_FILES)
        self.in_pipeline = any(
            (rel.endswith(s) if s.endswith(".py") else f"/{s}" in f"/{rel}")
            for s in PIPELINE_FILES
        )
        self.is_compat = rel.endswith(COMPAT_FILE)
        self._jit_depth = 0

    def _emit(self, rule: str, node: ast.AST, message: str, fixit: str = ""):
        if _allowed(self.lines, node.lineno, rule):
            return
        self.findings.append(Finding(
            rule=rule, loc=f"{self.rel}:{node.lineno}",
            message=message, fixit=fixit,
        ))

    # -- compat-import ------------------------------------------------------

    def _check_unstable_import(self, module: str, node: ast.AST):
        if self.is_compat:
            return
        if module.startswith("jax.experimental") or module == "jax.shard_map":
            self._emit(
                "compat-import", node,
                f"direct import of {module!r} (version-unstable API)",
                fixit="route through a core/compat.py shim "
                      "(compat.pallas(), compat.shard_map(), ...)",
            )

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            self._check_unstable_import(alias.name, node)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        self._check_unstable_import(mod, node)
        if mod == "jax" and not self.is_compat:
            for alias in node.names:
                if alias.name == "shard_map":
                    self._emit(
                        "compat-import", node,
                        "direct import of jax.shard_map "
                        "(renamed across JAX versions)",
                        fixit="use core/compat.py's shard_map()",
                    )
        self.generic_visit(node)

    # -- function bodies: jit context tracking ------------------------------

    def _visit_func(self, node):
        jitted = any(_is_jit_decorator(d) for d in node.decorator_list)
        self._jit_depth += jitted
        self.generic_visit(node)
        self._jit_depth -= jitted

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    # -- calls: wall clock + python RNG -------------------------------------

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        parts = tuple(name.rsplit(".", 2)[-2:]) if "." in name else ()
        if self.in_sim and parts in WALLCLOCK_CALLS:
            self._emit(
                "wallclock-in-sim", node,
                f"{name}() inside a deterministic-simulation module",
                fixit="use the simulated clock, or annotate a genuine "
                      "wall-metric site with `# lint: allow[wallclock-in-sim]`",
            )
        if self._jit_depth and (
            name.startswith(("random.", "np.random.", "numpy.random."))
        ):
            self._emit(
                "pyrandom-in-jit", node,
                f"{name}() inside a jit/vmap-decorated body runs at trace "
                "time (the draw is frozen into the compiled program)",
                fixit="thread a jax.random key instead",
            )
        self.generic_visit(node)

    # -- bare asserts in pipeline files -------------------------------------

    def visit_Assert(self, node: ast.Assert):
        if self.in_pipeline:
            self._emit(
                "bare-assert", node,
                "bare `assert` guarding a pipeline/kernel invariant is "
                "stripped under `python -O`",
                fixit="raise ScheduleVerificationError / ValueError instead",
            )
        self.generic_visit(node)


def lint_file(path, root=None) -> list[Finding]:
    """Lint one Python source file; `root` anchors the reported path."""
    path = pathlib.Path(path)
    rel = str(path.relative_to(root) if root else path)
    text = path.read_text()
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError as e:
        return [Finding(
            rule="bare-assert", loc=f"{rel}:{e.lineno or 0}",
            message=f"file does not parse: {e.msg}", severity="error",
        )]
    linter = _Linter(rel, text.splitlines())
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: f.loc)


def lint_repo(root) -> list[Finding]:
    """Lint every `.py` under `root` (typically `src/repro`)."""
    root = pathlib.Path(root)
    out: list[Finding] = []
    for path in sorted(root.rglob("*.py")):
        out.extend(lint_file(path, root=root.parent))
    return out
