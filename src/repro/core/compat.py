"""JAX version-compat shims.

The codebase targets the current JAX API (`jax.shard_map`,
`pltpu.CompilerParams`); older releases (e.g. 0.4.x, the pinned container
toolchain) spell these `jax.experimental.shard_map.shard_map(check_rep=...)`
and `pltpu.TPUCompilerParams`.  Everything routes through here so call
sites stay written against the modern names.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` with graceful fallback to the experimental API
    (where `check_vma` was called `check_rep`)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name):
    """`jax.lax.axis_size` fallback: a psum of 1 over the axis is a
    compile-time constant equal to the axis size on older releases."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """`jax.make_mesh` with explicit Auto axis types where the installed
    JAX supports them (older releases have neither the kwarg nor the enum,
    and are Auto-only anyway)."""
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def pallas():
    """The `jax.experimental.pallas` module — the single import point for
    the Pallas API, so version churn (experimental namespace moves, as
    already happened to shard_map) lands here and not in four kernels.
    Kernels bind it at module import: `pl = compat.pallas()`."""
    from jax.experimental import pallas as pl

    return pl


def tpu_compiler_params(**kwargs):
    """`pltpu.CompilerParams` (new name) / `pltpu.TPUCompilerParams` (old)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def pallas_vmem():
    """The VMEM memory space across Pallas API generations: `pltpu.VMEM`
    where exported, `pltpu.TPUMemorySpace.VMEM` on releases that only ship
    the enum.  Every kernel's BlockSpecs route through here so the repo
    runs on both the pinned 0.4.x toolchain and current JAX."""
    from jax.experimental.pallas import tpu as pltpu

    ms = getattr(pltpu, "VMEM", None)
    if ms is None:
        ms = pltpu.TPUMemorySpace.VMEM
    return ms
