"""Spatial mapping of colored RVs onto a 2-D core/device mesh (paper Sec. IV-B).

After coloring, AIA's compiler places mutually-independent nodes on the 4x4
mesh "maximizing parallelism and minimizing the communication distance
between nodes that have to exchange information".  We reproduce that greedy
heuristic for an arbitrary (rows x cols) mesh:

  * nodes are placed in decreasing conflict-degree order;
  * each node goes to the core minimizing the summed Manhattan distance to
    its already-placed Markov-blanket neighbors;
  * per-(core, color) load is capped at ceil(|color|/n_cores) to keep every
    color's update step balanced (the parallelism half of the objective).

On AIA the payoff is 1-cycle neighbor-RF reads; on TPU the payoff is that
`ppermute` halo partners are mesh-adjacent (single ICI hop).  The distributed
BN engine uses the placement to partition color groups; `comm_cost` is the
metric reported in bench_coloring (vs. a random placement baseline).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MeshPlacement:
    placement: np.ndarray  # (n_nodes,) core id
    mesh_shape: tuple[int, int]

    def coords(self, core: int) -> tuple[int, int]:
        return divmod(core, self.mesh_shape[1])


def _manhattan(a: int, b: int, cols: int) -> int:
    ra, ca = divmod(a, cols)
    rb, cb = divmod(b, cols)
    return abs(ra - rb) + abs(ca - cb)


def greedy_map(
    adj: list[set[int]],
    colors: np.ndarray,
    mesh_shape: tuple[int, int] = (4, 4),
) -> MeshPlacement:
    rows, cols = mesh_shape
    n_cores = rows * cols
    n = len(adj)
    placement = np.full(n, -1, np.int64)
    # per-color per-core capacity keeps each color's parallel step balanced
    cap = {
        c: -(-int((colors == c).sum()) // n_cores)
        for c in range(int(colors.max()) + 1)
    }
    load = np.zeros((int(colors.max()) + 1, n_cores), np.int64)
    order = sorted(range(n), key=lambda v: -len(adj[v]))
    for v in order:
        c = int(colors[v])
        placed_nbrs = [u for u in adj[v] if placement[u] >= 0]
        best, best_cost = None, None
        for core in range(n_cores):
            if load[c, core] >= cap[c]:
                continue
            cost = sum(
                _manhattan(core, int(placement[u]), cols) for u in placed_nbrs
            )
            # prefer lightly-loaded cores on ties (spread for parallelism)
            key = (cost, int(load[:, core].sum()))
            if best_cost is None or key < best_cost:
                best, best_cost = core, key
        placement[v] = best
        load[c, best] += 1
    return MeshPlacement(placement, mesh_shape)


def random_map(
    n_nodes: int, mesh_shape: tuple[int, int] = (4, 4), seed: int = 0
) -> MeshPlacement:
    rng = np.random.default_rng(seed)
    n_cores = mesh_shape[0] * mesh_shape[1]
    return MeshPlacement(
        rng.integers(0, n_cores, size=n_nodes), mesh_shape
    )


def comm_cost(adj: list[set[int]], pl: MeshPlacement) -> float:
    """Total Manhattan hops over all conflict edges — the paper's
    communication-distance objective (lower = cheaper exchanges)."""
    cols = pl.mesh_shape[1]
    total = 0
    for v in range(len(adj)):
        for u in adj[v]:
            if u > v:
                total += _manhattan(int(pl.placement[v]), int(pl.placement[u]), cols)
    return float(total)
