"""Checkerboard (2-color) chromatic Gibbs for grid MRFs (paper Eqn. 7, Fig. 1f).

The regular-PM counterpart of `bayesnet.py`: a 4-connected Potts/Ising grid
needs exactly two colors, so one Gibbs iteration is two dense half-steps, each
updating every other site simultaneously — AIA's best-case workload (Penguin/
Art image tasks).  The per-site pipeline is the same C2->C1 chain:

    neighbor labels (C4 exchange) -> energy -> LUT-exp weights -> KY draw

`labels` carries a leading chains axis (B, H, W): chains are the DP axis.
`distributed.py` shards (H) across devices and swaps `jnp.roll` for
`lax.ppermute` halo exchange — the neighbor-RF access made ICI-native.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

# see bayesnet.py: chain-state donation is deliberately partial; the
# unusable-leaf warning is expected noise
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)

from repro.core.draws import draw_from_logits
from repro.core.graphs import GridMRF
from repro.core.interp import build_exp_weight_lut
from repro.diag import accum as diag_accum


def neighbor_value_counts(labels: jax.Array, n_labels: int) -> jax.Array:
    """(..., H, W) labels -> (..., H, W, V) count of 4-neighbors per value.

    Border sites see fewer neighbors (zero-padding), matching the free
    boundary of the benchmark MRFs."""
    onehot = (
        labels[..., None] == jnp.arange(n_labels, dtype=labels.dtype)
    ).astype(jnp.float32)

    def shift(x, d, axis):
        pad = [(0, 0)] * x.ndim
        pad[axis] = (1, 0) if d > 0 else (0, 1)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, -1) if d > 0 else slice(1, None)
        return jnp.pad(x[tuple(sl)], pad)

    h_ax, w_ax = labels.ndim - 2, labels.ndim - 1
    return (
        shift(onehot, 1, h_ax)
        + shift(onehot, -1, h_ax)
        + shift(onehot, 1, w_ax)
        + shift(onehot, -1, w_ax)
    )


def site_log_potentials(
    mrf: GridMRF, labels: jax.Array, evidence: jax.Array
) -> jax.Array:
    """Unnormalized log P(site = v | neighbors, evidence) for every site/value.
    labels (..., H, W), evidence (H, W) -> (..., H, W, V)."""
    v_range = jnp.arange(mrf.n_labels, dtype=labels.dtype)
    smooth = mrf.theta * neighbor_value_counts(labels, mrf.n_labels)
    if mrf.data_cost == "potts":
        data = mrf.h * (evidence[..., None] == v_range).astype(jnp.float32)
    elif mrf.data_cost == "quadratic":
        diff = (evidence[..., None] - v_range).astype(jnp.float32)
        data = -mrf.h * diff * diff
    else:
        raise ValueError(mrf.data_cost)
    return smooth + data


def checkerboard_mask(h: int, w: int, parity: int) -> jax.Array:
    ii = jnp.arange(h)[:, None] + jnp.arange(w)[None, :]
    return (ii % 2) == parity


@functools.partial(
    jax.jit, static_argnames=("mrf", "parity", "sampler", "exp_spec")
)
def half_step(
    mrf: GridMRF,
    labels: jax.Array,
    evidence: jax.Array,
    key: jax.Array,
    parity: int,
    sampler: str = "lut_ky",
    exp_table=None,
    exp_spec=None,
    pin_mask: jax.Array | None = None,
) -> jax.Array:
    """Update all sites of one checkerboard color simultaneously (Alg. 2).

    `pin_mask` ((H, W) bool) excludes pinned pixels from the update: draws
    are still computed for the whole grid (the random words per site do not
    depend on the mask, keeping pinned and unpinned runs comparable bit for
    bit on the free sites of the first half-step), but pinned sites keep
    their current labels."""
    if exp_table is None:
        exp_table, exp_spec = build_exp_weight_lut()
    logp = site_log_potentials(mrf, labels, evidence)
    new = draw_from_logits(logp, key, sampler, exp_table, exp_spec)
    mask = checkerboard_mask(mrf.height, mrf.width, parity)
    if pin_mask is not None:
        mask = mask & ~pin_mask
    return jnp.where(mask, new, labels)


@dataclasses.dataclass
class MRFChainState:
    """Resume point for a grid-MRF Gibbs run: carrying (labels, key) across
    `mrf_gibbs_loop` calls makes a sliced run bit-identical to an
    uninterrupted one (the key is split once per iteration in sequence and
    there is no burn-in/thinning state to realign).

    `quality` optionally carries a `diag.accum.QualityAccum` over the
    flattened site axis; None stays an empty pytree subtree so existing
    jit caches and carried states are untouched when diagnostics are off."""

    labels: jax.Array  # (B, H, W) int32 current chain states
    key: jax.Array  # PRNG key as of the next iteration
    quality: object = None  # diag.accum.QualityAccum | None


jax.tree_util.register_dataclass(
    MRFChainState, ["labels", "key", "quality"], []
)


def init_labels(
    mrf: GridMRF,
    key: jax.Array,
    n_chains: int,
    pin_mask: jax.Array | None = None,
    pin_vals: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Random (B, H, W) label init with pinned pixels clamped to their
    values; the random tensor covers every site regardless of the mask (same
    reasoning as `bayesnet.init_chain_values`).  Returns (labels, key)."""
    k0, key = jax.random.split(key)
    labels = jax.random.randint(
        k0, (n_chains, mrf.height, mrf.width), 0, mrf.n_labels, jnp.int32
    )
    if pin_mask is not None:
        labels = jnp.where(pin_mask[None], pin_vals[None], labels)
    return labels, key


def mrf_gibbs_loop(
    mrf: GridMRF,
    evidence: jax.Array,
    key: jax.Array | None,
    n_chains: int,
    n_iters: int,
    sampler: str,
    pin_mask: jax.Array | None = None,
    pin_vals: jax.Array | None = None,
    carry: MRFChainState | None = None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
):
    """The eager iteration body shared by `run_mrf_gibbs` and the batched
    serving path (which vmaps it over queries): n_iters x (even half-step,
    odd half-step), pins held fixed throughout.

    `carry` resumes a previous call's `MRFChainState` (then `key` is ignored
    and may be None) and `n_iters` counts *additional* iterations — sliced
    runs are bit-exact with uninterrupted ones.  `return_state=True` returns
    (labels, state) instead of labels alone.

    `diag_total` (the query's total iteration budget) switches the
    streaming quality accumulator on for a fresh run: every iteration's
    post-sweep labels feed a per-site one-hot into `diag.accum.update`
    (MRF runs have no burn-in/thinning, so every iteration is kept).  The
    update consumes no randomness — the label stream is bit-identical with
    diagnostics on.  On a resumed carry the accumulator rides in with the
    state and `diag_total` is ignored."""
    exp_table, exp_spec = build_exp_weight_lut()
    if carry is None:
        labels, key = init_labels(mrf, key, n_chains, pin_mask, pin_vals)
        quality = None
        if diag_total is not None:
            quality = diag_accum.make_accum(
                n_chains, mrf.height * mrf.width, mrf.n_labels,
                jnp.asarray(diag_total, jnp.int32), diag_batch,
            )
    else:
        labels, key, quality = carry.labels, carry.key, carry.quality

    def body(t, carry):
        labels, key, quality = carry
        key, ka, kb = jax.random.split(key, 3)
        labels = half_step(
            mrf, labels, evidence, ka, 0, sampler, exp_table, exp_spec,
            pin_mask,
        )
        labels = half_step(
            mrf, labels, evidence, kb, 1, sampler, exp_table, exp_spec,
            pin_mask,
        )
        if quality is not None:
            onehot = (
                labels.reshape(labels.shape[0], -1)[..., None]
                == jnp.arange(mrf.n_labels, dtype=labels.dtype)
            ).astype(jnp.int32)
            quality = diag_accum.update(
                quality, onehot, jnp.asarray(True)
            )
        return labels, key, quality

    labels, key, quality = jax.lax.fori_loop(
        0, n_iters, body, (labels, key, quality)
    )
    if return_state:
        return labels, MRFChainState(labels=labels, key=key, quality=quality)
    return labels


@functools.partial(
    jax.jit,
    static_argnames=("mrf", "n_chains", "n_iters", "sampler", "return_state"),
    # sliced serving: resume in place instead of copying the carried labels
    # every slice (a passed carry is consumed — see bayesnet.run_gibbs)
    donate_argnames=("carry",),
)
def run_mrf_gibbs(
    mrf: GridMRF,
    evidence: jax.Array,
    key: jax.Array | None,
    n_chains: int = 1,
    n_iters: int = 30,
    sampler: str = "lut_ky",
    pin_mask: jax.Array | None = None,
    pin_vals: jax.Array | None = None,
    carry: MRFChainState | None = None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
):
    """Full chromatic Gibbs: n_iters x (even half-step, odd half-step).

    Returns final labels (B, H, W) — the approximate MPE state for the
    denoising benchmarks (paper Eqn. 4).  `pin_mask`/`pin_vals` ((H, W)
    bool / int32) clamp pixels at known labels for the whole run.
    `carry`/`return_state` slice the run: see `mrf_gibbs_loop`
    (`diag_total`/`diag_batch` switch its quality accumulator on)."""
    return mrf_gibbs_loop(
        mrf, evidence, key, n_chains, n_iters, sampler, pin_mask, pin_vals,
        carry=carry, return_state=return_state,
        diag_total=diag_total, diag_batch=diag_batch,
    )


def total_energy(mrf: GridMRF, labels: jax.Array, evidence: jax.Array):
    """E(l) (paper Eqn. 3/7 numerator, log domain) — test/convergence metric."""
    onehot_v = jnp.arange(mrf.n_labels, dtype=labels.dtype)
    right = (labels[..., :, 1:] == labels[..., :, :-1]).astype(jnp.float32)
    down = (labels[..., 1:, :] == labels[..., :-1, :]).astype(jnp.float32)
    smooth = mrf.theta * (right.sum((-1, -2)) + down.sum((-1, -2)))
    if mrf.data_cost == "potts":
        data = mrf.h * (labels == evidence).astype(jnp.float32).sum((-1, -2))
    else:
        diff = (labels - evidence).astype(jnp.float32)
        data = -mrf.h * (diff * diff).sum((-1, -2))
    return smooth + data


def make_denoising_problem(
    h: int, w: int, n_labels: int, noise: float, seed: int = 0
):
    """Synthetic Penguin/Art-style task: piecewise-constant image + label noise.
    Returns (clean (H,W), noisy evidence (H,W))."""
    rng = np.random.default_rng(seed)
    clean = np.zeros((h, w), np.int32)
    for _ in range(max(3, n_labels)):
        r0, c0 = rng.integers(0, h), rng.integers(0, w)
        rh, cw = rng.integers(h // 4, h), rng.integers(w // 4, w)
        clean[r0 : r0 + rh, c0 : c0 + cw] = rng.integers(0, n_labels)
    flip = rng.random((h, w)) < noise
    noisy = np.where(flip, rng.integers(0, n_labels, (h, w)), clean)
    return clean, noisy.astype(np.int32)
