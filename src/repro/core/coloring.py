"""DSATUR graph coloring (paper Sec. IV-A) — the RV-parallelism detector.

Colors the Gibbs conflict graph (moral graph for BNs, grid adjacency for
MRFs) so that same-color RVs are conditionally independent and can be updated
simultaneously (Alg. 2).  DSATUR: repeatedly color the vertex with the
highest saturation degree (number of distinct neighbor colors), breaking ties
by degree.  The paper reports <= 6 colors on all BN-repo workloads.
"""

from __future__ import annotations

import heapq

import numpy as np


def dsatur(adj: list[set[int]]) -> np.ndarray:
    n = len(adj)
    colors = np.full(n, -1, np.int64)
    if n == 0:
        return colors
    sat: list[set[int]] = [set() for _ in range(n)]
    degree = np.array([len(a) for a in adj])
    # max-heap keyed by (saturation, degree); lazily invalidated entries
    heap = [(-0, -int(degree[i]), i) for i in range(n)]
    heapq.heapify(heap)
    colored = 0
    while colored < n:
        while True:
            s, d, v = heapq.heappop(heap)
            if colors[v] == -1 and -s == len(sat[v]):
                break
        used = {colors[u] for u in adj[v] if colors[u] != -1}
        c = 0
        while c in used:
            c += 1
        colors[v] = c
        colored += 1
        for u in adj[v]:
            if colors[u] == -1 and c not in sat[u]:
                sat[u].add(c)
                heapq.heappush(heap, (-len(sat[u]), -int(degree[u]), u))
    return colors


def verify_coloring(adj: list[set[int]], colors: np.ndarray) -> bool:
    """No two adjacent vertices share a color == the conditional-independence
    precondition of parallel Gibbs (checked after coloring, as in the paper)."""
    return all(
        colors[v] != colors[u] for v in range(len(adj)) for u in adj[v]
    ) and (colors >= 0).all()


def color_groups(colors: np.ndarray) -> list[np.ndarray]:
    return [np.where(colors == c)[0] for c in range(int(colors.max()) + 1)]


def color_stats(colors: np.ndarray) -> dict:
    groups = color_groups(colors)
    sizes = np.array([len(g) for g in groups])
    return {
        "n_colors": len(groups),
        "sizes": sizes,
        "balance": float(sizes.min() / sizes.max()) if len(sizes) else 1.0,
    }


def parallel_speedup(colors: np.ndarray, n_cores: int) -> float:
    """Fig. 9 line-graph model: sequential cost = n RVs; chromatic-parallel
    cost = sum_c ceil(|color c| / n_cores)."""
    groups = color_groups(colors)
    par = sum(-(-len(g) // n_cores) for g in groups)
    return len(colors) / max(par, 1)
