"""Rejection-based Knuth-Yao (KY) discrete sampling — algorithmic core.

This module implements the paper's C1 contribution (Sec. III-C) as pure
functions on integer weight vectors:

  * a discrete distribution is represented by non-negative integer weights
    ``m_i`` with ``P_i = m_i / sum(m)`` — NO normalization is ever performed;
  * preprocessing appends a *rejection bin* ``rej = 2^W - S`` so the extended
    weights sum to an exact power of two (Eqns. 8-9 of the paper), enabling a
    discrete-distribution-generating (DDG) tree walk;
  * the DDG walk consumes one uniform random bit per tree level and terminates
    in O(H) expected bits (H = entropy), the paper's headline complexity claim;
  * hitting the rejection bin restarts the walk with fresh bits (expected
    number of restarts < 2, typically ~1 thanks to scale-to-fill).

TPU adaptation (DESIGN.md Sec. 2): the paper walks the tree level-by-level and
resolves the terminating bin with a parallel-prefix adder over <=32 bins; we
keep the identical loop structure but resolve bins with a vectorized cumsum
across VPU lanes, batched over many simultaneous samples (the same-color RVs
of the chromatic Gibbs schedule, or the requests of a serving batch).

Everything here is shape-polymorphic pure jnp so it can run inside Pallas
kernel bodies, shard_map regions, and the ref.py oracle alike.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Default tree precision W: extended weights sum to exactly 2^W.
# W=16 reproduces the paper's 16b operating mode (Table II); 8 and 24/31 are
# the packed / high-precision modes. Must satisfy W <= 30 (int32 headroom).
DEFAULT_PRECISION = 16


class KYState(NamedTuple):
    """Per-sample DDG-walk state (all (B,) int32 unless noted)."""

    d: jax.Array  # distance within current tree level
    level: jax.Array  # current tree level, 0-indexed from the MSB
    label: jax.Array  # sampled bin, -1 while walking
    done: jax.Array  # bool
    bits_used: jax.Array  # random bits consumed so far (entropy accounting)
    rejections: jax.Array  # number of rejection-restarts


def scale_to_fill(m: jax.Array, precision: int = DEFAULT_PRECISION) -> jax.Array:
    """Multiply integer weights by floor(2^W / S).

    Scaling all weights by the same positive integer leaves the distribution
    unchanged but pushes the sum toward 2^W, shrinking the rejection bin
    (rej = 2^W - k*S < S).  This is the software analogue of the paper's
    observation that low-rejection configurations sample fastest.

    m: (..., N) int32, sum(m) in [1, 2^W].  Returns scaled weights.
    """
    s = jnp.sum(m, axis=-1, keepdims=True)
    s = jnp.maximum(s, 1)
    k = (1 << precision) // s
    k = jnp.maximum(k, 1)
    return m * k


def extend_with_rejection(
    m: jax.Array, precision: int = DEFAULT_PRECISION
) -> jax.Array:
    """Append the rejection bin: m' = [m_0..m_{N-1}, 2^W - S]  (Eqn. 9).

    Requires sum(m) <= 2^W; the result sums to exactly 2^W so the DDG tree is
    complete and every walk terminates within W levels.
    """
    s = jnp.sum(m, axis=-1, keepdims=True)
    rej = (1 << precision) - s
    return jnp.concatenate([m, rej], axis=-1)


def ddg_matrix(m_ext: jax.Array, precision: int = DEFAULT_PRECISION) -> jax.Array:
    """Binary DDG matrix M[i, j] = bit (W-1-j) of m'_i  (Eqn. 10 analogue).

    Column j lists which bins terminate at tree level j.  Only used by tests
    and documentation; the walk extracts columns on the fly with shifts.
    """
    shifts = precision - 1 - jnp.arange(precision)
    return (m_ext[..., :, None] >> shifts) & 1


def ddg_column(m_ext: jax.Array, level: jax.Array, precision: int) -> jax.Array:
    """Column `level` of the DDG matrix, per-sample level. m_ext (B, N+1)."""
    shift = precision - 1 - level
    return (m_ext >> shift[..., None]) & 1


def walk_step(
    m_ext: jax.Array, bit: jax.Array, state: KYState, n_bins: int, precision: int
) -> KYState:
    """One DDG level for a batch of samples (the paper's per-cycle datapath).

    m_ext: (B, N+1) int32 extended weights; bit: (B,) int32 in {0,1}.
    Mirrors Fig. 5: d <- 2d + bit, subtract terminal-leaf counts (cumsum),
    first negative crossing is the sampled label; the rejection bin restarts.
    """
    active = ~state.done
    d = jnp.where(active, 2 * state.d + bit, state.d)
    col = ddg_column(m_ext, state.level, precision)  # (B, N+1)
    c = jnp.cumsum(col, axis=-1)
    total = c[..., -1]
    hit = c > d[..., None]
    terminated = active & (total > d)
    idx = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    is_rej = idx >= n_bins
    accept = terminated & ~is_rej
    reject = terminated & is_rej
    cont = active & ~terminated

    return KYState(
        d=jnp.where(reject, 0, jnp.where(cont, d - total, d)),
        level=jnp.where(reject, 0, jnp.where(cont, state.level + 1, state.level)),
        label=jnp.where(accept, idx, state.label),
        done=state.done | accept,
        bits_used=state.bits_used + active.astype(jnp.int32),
        rejections=state.rejections + reject.astype(jnp.int32),
    )


def bit_at(words: jax.Array, t) -> jax.Array:
    """Bit t of a packed uint32 bit-stream words (B, n_words) (LFSR analogue)."""
    word = jax.lax.dynamic_index_in_dim(words, t // 32, axis=-1, keepdims=False)
    shift = jnp.asarray(t % 32).astype(words.dtype)
    return (jnp.right_shift(word, shift) & jnp.asarray(1, words.dtype)).astype(
        jnp.int32
    )


def init_state(batch_shape) -> KYState:
    z = jnp.zeros(batch_shape, jnp.int32)
    return KYState(
        d=z, level=z, label=z - 1, done=jnp.zeros(batch_shape, bool), bits_used=z,
        rejections=z,
    )


def random_words(key: jax.Array, batch_shape, n_words: int) -> jax.Array:
    """Packed uniform random bits — jax.random stands in for the paper's LFSR."""
    return jax.random.bits(key, batch_shape + (n_words,), jnp.uint32)


def prepare(m: jax.Array, precision: int = DEFAULT_PRECISION) -> jax.Array:
    """Full preprocessing: clamp -> scale-to-fill -> rejection-extend."""
    m = jnp.maximum(m.astype(jnp.int32), 0)
    # Guard the all-zero row (caller bug): fall back to uniform.
    s = jnp.sum(m, axis=-1, keepdims=True)
    m = jnp.where(s > 0, m, jnp.ones_like(m))
    m = scale_to_fill(m, precision)
    return extend_with_rejection(m, precision)


def quantize_probs(p: jax.Array, bits: int = 8) -> jax.Array:
    """Float probabilities/potentials -> integer weights (paper Sec. IV: fixed
    point with negligible accuracy loss). max(p) maps to 2^bits - 1."""
    top = (1 << bits) - 1
    scale = top / jnp.maximum(jnp.max(p, axis=-1, keepdims=True), 1e-30)
    return jnp.clip(jnp.round(p * scale), 0, top).astype(jnp.int32)


def entropy(p: np.ndarray) -> float:
    """Shannon entropy in bits — KY consumes at most H+2 bits per accepted
    sample (Knuth-Yao optimality), the basis of the Fig. 11 scaling claim."""
    p = np.asarray(p, np.float64)
    p = p / p.sum()
    nz = p[p > 0]
    return float(-(nz * np.log2(nz)).sum())


@functools.partial(jax.jit, static_argnames=("precision", "max_retries", "n_bins"))
def ky_sample_ref(
    weights: jax.Array,
    words: jax.Array,
    *,
    n_bins: int,
    precision: int = DEFAULT_PRECISION,
    max_retries: int = 8,
):
    """Reference batched rejection-KY walk (fully-masked, fixed trip count).

    weights: (B, N) int32 raw weights (N == n_bins); words: (B, n_words)
    packed random bits with n_words*32 >= precision*max_retries.
    Returns (labels (B,) int32, stats dict).  Deterministic given `words`,
    which is what lets the Pallas kernel be tested for exact equality.
    """
    m_ext = prepare(weights, precision)
    total_steps = precision * max_retries
    if words.shape[-1] * 32 < total_steps:
        # raised, not asserted: a stripped check here would let the walk
        # read past the random stream and silently bias the draw
        raise ValueError(
            f"not enough random bits: {words.shape[-1]} words < "
            f"{total_steps} steps"
        )

    def body(t, st):
        return walk_step(m_ext, bit_at(words, t), st, n_bins, precision)

    st = jax.lax.fori_loop(0, total_steps, body, init_state(weights.shape[:-1]))
    # Fallback (probability < 2^-max_retries): most-probable bin.
    fallback = jnp.argmax(weights, axis=-1).astype(jnp.int32)
    labels = jnp.where(st.done, st.label, fallback)
    stats = {
        "bits_used": st.bits_used,
        "rejections": st.rejections,
        "fallback": ~st.done,
    }
    return labels, stats


@functools.partial(jax.jit, static_argnames=("precision", "max_retries", "n_bins"))
def ky_sample_fast(
    weights: jax.Array,
    words: jax.Array,
    *,
    n_bins: int,
    precision: int = DEFAULT_PRECISION,
    max_retries: int = 8,
):
    """Early-exit variant of ky_sample_ref: identical outputs (same masked
    per-step updates and bit consumption), but the loop stops once every
    sample in the batch has terminated — expected O(H) steps, the software
    analogue of the hardware FSM's data-dependent latency."""
    m_ext = prepare(weights, precision)
    total_steps = precision * max_retries
    if words.shape[-1] * 32 < total_steps:
        # raised, not asserted (see ky_sample_ref): shape check runs at
        # trace time, so a plain ValueError is jit-safe
        raise ValueError(
            f"not enough random bits: {words.shape[-1]} words < "
            f"{total_steps} steps"
        )

    def cond(carry):
        t, st = carry
        return (t < total_steps) & jnp.any(~st.done)

    def body(carry):
        t, st = carry
        return t + 1, walk_step(m_ext, bit_at(words, t), st, n_bins,
                                precision)

    _, st = jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), init_state(weights.shape[:-1]))
    )
    fallback = jnp.argmax(weights, axis=-1).astype(jnp.int32)
    labels = jnp.where(st.done, st.label, fallback)
    return labels, {
        "bits_used": st.bits_used,
        "rejections": st.rejections,
        "fallback": ~st.done,
    }
