"""Distributed chromatic Gibbs via shard_map (paper Sec. III mesh, at pod scale).

AIA's 4x4 core mesh becomes the JAX device mesh; the two data-movement
mechanisms map 1:1 onto collectives:

  * neighbor shared-RF access (C4)  ->  `lax.ppermute` halo exchange between
    mesh-adjacent devices (MRF grids are row-partitioned over the "model"
    axis; only boundary rows move, one ICI hop, contention-free);
  * global barrier / event unit (C5) -> the implicit synchronization at each
    collective boundary: one per color, exactly Alg. 2's schedule;
  * shared-RF value broadcast (BN)   -> a psum of the (tiny) int delta of the
    state vector after each color update — each node is owned by exactly one
    device (the Sec. IV-B mapping), so deltas are disjoint.

Chains are the pure-DP axis ("data"; "pod" stacks more of it multi-pod):
no cross-chain communication at all, mirroring Alg. 1's MaxChain loop.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import bayesnet as bnet
from repro.core import compat
from repro.core import ky as ky_core
from repro.core import mrf as mrf_mod
from repro.core.draws import draw_from_logits
from repro.core.graphs import GridMRF
from repro.core.interp import build_exp_weight_lut
from repro.core.mapping import MeshPlacement
from repro.diag import accum as diag_accum
from repro.kernels import bn_gibbs
from repro.kernels import mrf_gibbs as mrf_kernels

# ---------------------------------------------------------------------------
# MRF: row-partitioned grid with ppermute halo exchange
# ---------------------------------------------------------------------------


def _halo_exchange(lab: jax.Array, axis: str) -> tuple[jax.Array, jax.Array]:
    """Send boundary rows to mesh neighbors; returns (up_halo, down_halo) of
    shape (..., 1, W).  Global grid boundary gets -1 (no neighbor)."""
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    down_perm = [(i, (i + 1) % n) for i in range(n)]
    up_perm = [(i, (i - 1) % n) for i in range(n)]
    up_halo = jax.lax.ppermute(lab[..., -1:, :], axis, down_perm)
    down_halo = jax.lax.ppermute(lab[..., :1, :], axis, up_perm)
    up_halo = jnp.where(idx == 0, -1, up_halo)
    down_halo = jnp.where(idx == n - 1, -1, down_halo)
    return up_halo, down_halo


def _local_half_step(
    mrf: GridMRF,
    lab: jax.Array,  # (B, h_loc, W)
    ev: jax.Array,  # (h_loc, W)
    key: jax.Array,
    parity: int,
    sampler: str,
    exp_table,
    exp_spec,
    axis: str,
) -> jax.Array:
    up_halo, down_halo = _halo_exchange(lab, axis)
    padded = jnp.concatenate([up_halo, lab, down_halo], axis=-2)
    up, down = padded[..., :-2, :], padded[..., 2:, :]
    neg_col = jnp.full(lab.shape[:-1] + (1,), -1, lab.dtype)
    left = jnp.concatenate([neg_col, lab[..., :, :-1]], axis=-1)
    right = jnp.concatenate([lab[..., :, 1:], neg_col], axis=-1)

    v_range = jnp.arange(mrf.n_labels, dtype=lab.dtype)
    cnt = sum(
        (nb[..., None] == v_range).astype(jnp.float32)
        for nb in (up, down, left, right)
    )
    if mrf.data_cost == "potts":
        data = mrf.h * (ev[..., None] == v_range).astype(jnp.float32)
    else:
        diff = (ev[..., None] - v_range).astype(jnp.float32)
        data = -mrf.h * diff * diff
    logp = mrf.theta * cnt + data
    new = draw_from_logits(logp, key, sampler, exp_table, exp_spec)

    h_loc, w = lab.shape[-2], lab.shape[-1]
    row0 = jax.lax.axis_index(axis) * h_loc
    gr = row0 + jnp.arange(h_loc)[:, None]
    gc = jnp.arange(w)[None, :]
    mask = ((gr + gc) % 2) == parity
    return jnp.where(mask, new, lab)


def mrf_gibbs_sharded(
    mrf: GridMRF,
    evidence: jax.Array,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int,
    n_iters: int,
    sampler: str = "lut_ky",
    chain_axes: tuple[str, ...] = ("data",),
    grid_axis: str = "model",
    parities: tuple[int, ...] = (0, 1),
):
    """Chromatic Gibbs with the grid row-sharded over `grid_axis` and chains
    sharded over `chain_axes`.  Returns final labels (B, H, W).  `parities`
    is the per-round checkerboard order — (0, 1) eagerly, or the compiled
    `Schedule`'s round order under the schedule backend; each round's halo
    read is the `ppermute_halo` comm op lowered to `lax.ppermute`."""
    exp_table, exp_spec = build_exp_weight_lut()
    n_grid = int(np.prod([mesh.shape[a] for a in (grid_axis,)]))
    assert mrf.height % n_grid == 0, "grid rows must divide over devices"
    n_chain_dev = int(np.prod([mesh.shape[a] for a in chain_axes]))
    assert n_chains % n_chain_dev == 0

    chain_spec = P(chain_axes if len(chain_axes) > 1 else chain_axes[0])

    def body(ev_loc, key):
        ci = jax.lax.axis_index(chain_axes[0])
        for a in chain_axes[1:]:
            ci = ci * compat.axis_size(a) + jax.lax.axis_index(a)
        gi = jax.lax.axis_index(grid_axis)
        key = jax.random.fold_in(jax.random.fold_in(key, ci), gi)
        k0, key = jax.random.split(key)
        lab = jax.random.randint(
            k0,
            (n_chains // n_chain_dev, mrf.height // n_grid, mrf.width),
            0,
            mrf.n_labels,
            jnp.int32,
        )

        def it(t, carry):
            lab, key = carry
            ks = jax.random.split(key, 1 + len(parities))
            for i, parity in enumerate(parities):
                lab = _local_half_step(
                    mrf, lab, ev_loc, ks[1 + i], parity, sampler, exp_table,
                    exp_spec, grid_axis,
                )
            return lab, ks[0]

        lab, _ = jax.lax.fori_loop(0, n_iters, it, (lab, key))
        return lab

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(grid_axis, None), P()),
        out_specs=P(chain_spec[0] if len(chain_axes) == 1 else chain_axes,
                    grid_axis, None),
        check_vma=False,
    )
    return jax.jit(f)(evidence, key)


# ---------------------------------------------------------------------------
# Bayes nets: color groups partitioned over devices per the Sec. IV-B mapping
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedGroup:
    """One color group partitioned over n_dev devices, padded to equal width.
    All arrays carry a leading (n_dev,) axis; node id == n_nodes marks a pad
    slot (dropped by out-of-bounds scatter)."""

    nodes: jax.Array  # (n_dev, nc_max)
    cards: jax.Array
    base: jax.Array  # (n_dev, nc_max, F)
    stride: jax.Array  # (n_dev, nc_max, F, S)
    scope_var: jax.Array
    is_self: jax.Array


jax.tree_util.register_dataclass(
    ShardedGroup, ["nodes", "cards", "base", "stride", "scope_var", "is_self"], []
)


def shard_bn_groups(
    cbn: bnet.CompiledBayesNet,
    n_dev: int,
    placement: MeshPlacement | None = None,
    groups: list[bnet.ColorGroup] | None = None,
) -> list[ShardedGroup]:
    """Partition each color group across devices.  With a mapping (Sec. IV-B)
    nodes go to their placed core modulo n_dev; otherwise round-robin.
    `groups` overrides `cbn.groups` — the schedule-direct backend passes its
    round-ordered groups here."""
    out = []
    for g in groups if groups is not None else cbn.groups:
        nodes = np.asarray(g.nodes)
        if placement is not None:
            owner = placement.placement[nodes] % n_dev
        else:
            owner = np.arange(len(nodes)) % n_dev
        parts = [np.where(owner == d)[0] for d in range(n_dev)]
        nc_max = max(1, max(len(p) for p in parts))

        def pack(arr, pad_value=0):
            arr = np.asarray(arr)
            res = np.full((n_dev, nc_max) + arr.shape[1:], pad_value,
                          arr.dtype)
            for d, p in enumerate(parts):
                res[d, : len(p)] = arr[p]
            return jnp.asarray(res)

        out.append(
            ShardedGroup(
                nodes=pack(np.asarray(g.nodes), pad_value=cbn.n_nodes),
                cards=pack(np.asarray(g.cards), pad_value=1),
                base=pack(np.asarray(g.base)),  # pad base 0 -> dummy entry
                stride=pack(np.asarray(g.stride)),
                scope_var=pack(np.asarray(g.scope_var)),
                is_self=pack(np.asarray(g.is_self)),
            )
        )
    return out


def bn_gibbs_sharded(
    cbn: bnet.CompiledBayesNet,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int,
    n_iters: int,
    burn_in: int,
    sampler: str = "lut_ky",
    placement: MeshPlacement | None = None,
    chain_axis: str = "data",
    node_axis: str = "model",
    groups: list[bnet.ColorGroup] | None = None,
):
    """Distributed Alg. 2: nodes of a color split over `node_axis` devices,
    chains over `chain_axis`.  After each color/round, the disjoint updates
    are merged with one small integer psum — the `psum_broadcast` comm op of
    the schedule, i.e. the shared-RF exchange.  `groups` overrides the
    eager color groups with schedule-round groups.
    Returns (marginals (n, V), final local vals)."""
    n_dev = mesh.shape[node_axis]
    n_chain_dev = mesh.shape[chain_axis]
    assert n_chains % n_chain_dev == 0
    sgroups = shard_bn_groups(cbn, n_dev, placement, groups=groups)
    b_loc = n_chains // n_chain_dev

    def body(key):
        ci = jax.lax.axis_index(chain_axis)
        di = jax.lax.axis_index(node_axis)
        kc = jax.random.fold_in(key, ci)
        vals, kc = bnet.init_chain_values(cbn, kc, b_loc)

        def sweep(vals, kk):
            keys = jax.random.split(kk, len(sgroups))
            for sg, k in zip(sgroups, keys):
                g = bnet.ColorGroup(
                    nodes=sg.nodes[di],
                    cards=sg.cards[di],
                    base=sg.base[di],
                    stride=sg.stride[di],
                    scope_var=sg.scope_var[di],
                    is_self=sg.is_self[di],
                )
                logp = bnet.group_log_conditionals(cbn, g, vals)
                lab = draw_from_logits(
                    logp, jax.random.fold_in(k, di), sampler,
                    cbn.exp_table, cbn.exp_spec,
                )
                upd = vals.at[:, g.nodes].set(lab, mode="drop")
                # disjoint ownership => one psum merges all devices' updates
                vals = vals + jax.lax.psum(upd - vals, node_axis)
            return vals

        hist0 = jnp.zeros((cbn.n_nodes, cbn.max_card), jnp.int32)

        def it(t, carry):
            vals, kk, hist = carry
            kk, sub = jax.random.split(kk)
            vals = sweep(vals, sub)
            onehot = (
                vals[..., None] == jnp.arange(cbn.max_card, dtype=jnp.int32)
            ).astype(jnp.int32)
            hist = hist + jnp.where(t >= burn_in, onehot.sum(0), 0)
            return vals, kk, hist

        vals, _, hist = jax.lax.fori_loop(0, n_iters, it, (vals, kc, hist0))
        hist = jax.lax.psum(hist, chain_axis)
        return hist, vals

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(),),
        out_specs=(P(), P(chain_axis, None)),
        check_vma=False,
    )
    hist, vals = jax.jit(f)(key)
    card_mask = (
        jnp.arange(cbn.max_card, dtype=jnp.int32)[None] < cbn.cards[:, None]
    )
    denom = jnp.maximum(hist.sum(-1, keepdims=True), 1)
    return jnp.where(card_mask, hist / denom, 0.0), vals


# ---------------------------------------------------------------------------
# Fused sharded engines: ONE shard_map body wraps the Pallas round kernel and
# its collectives, so the sharded route executes the same VMEM-resident
# datapath as single-device fused (the mesh-scale inter-core register-sharing
# analogue).  Bit-exact with the single-device fused schedule backend: the
# random stream is generated over the full grid/round on every device and
# sliced/gathered to the local shard, so each site consumes exactly the words
# the unsharded kernel would hand it.
# ---------------------------------------------------------------------------


def _quality_spec(chain_axis: str | None, site_axis: str | None):
    """PartitionSpecs for a `QualityAccum` carry: the (…, B, S, V) moment
    leaves shard over the chain and/or site axes; the scalar counters are
    replicated (their update depends only on the keep gate, which every
    device computes identically)."""
    return diag_accum.QualityAccum(
        counts=P(),
        mean=P(None, chain_axis, site_axis, None),
        m2=P(None, chain_axis, site_axis, None),
        split_at=P(),
        batch_len=P(),
        bm_count=P(),
        bm_mean=P(chain_axis, site_axis, None),
        bm_m2=P(chain_axis, site_axis, None),
        cur_sum=P(chain_axis, site_axis, None),
        cur_n=P(),
    )


def mrf_fused_sharded(
    mrf: GridMRF,
    evidence: jax.Array,  # (H, W) int32
    key: jax.Array | None,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int,
    n_iters: int,
    parities: tuple[int, ...],
    carry: mrf_mod.MRFChainState | None = None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
    chain_axis: str = "data",
    grid_axis: str = "model",
    interpret: bool | None = None,
    profile_sig: str | None = None,
):
    """The fused MRF schedule rounds inside one `shard_map` body: per shard,
    one `pallas_call` half-step over the local row slab per round, with the
    halo rows exchanged via `lax.ppermute` (the `ppermute_halo` mechanism)
    between rounds — comm and compute in a single scanned body instead of
    separate engine ops.

    Bit-exact with `compile/backend.run_mrf_schedule(fused=True)`: the init,
    key-split structure, and per-site word streams are identical (full-grid
    streams sliced to the slab), so carries cross the vmap<->sharded route
    boundary freely and sliced serving rides the sharded route.  The
    `MRFChainState` carry shards its labels (and `QualityAccum` site-moment
    leaves) over `grid_axis`; pins never route here (`executor.route`
    excludes them)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    exp_table, exp_spec = build_exp_weight_lut()
    n_grid = mesh.shape[grid_axis]
    n_chain_dev = mesh.shape[chain_axis]
    if mrf.height % n_grid != 0:
        raise ValueError(
            f"grid height {mrf.height} must divide over {n_grid} devices"
        )
    if n_chains % n_chain_dev != 0:
        raise ValueError(
            f"n_chains {n_chains} must divide over {n_chain_dev} devices"
        )
    h_loc = mrf.height // n_grid
    b_loc = n_chains // n_chain_dev

    if carry is None:
        labels, key = mrf_mod.init_labels(mrf, key, n_chains)
        quality = None
        if diag_total is not None:
            quality = diag_accum.make_accum(
                n_chains, mrf.height * mrf.width, mrf.n_labels,
                jnp.asarray(diag_total, jnp.int32), diag_batch,
            )
    else:
        labels, key, quality = carry.labels, carry.key, carry.quality

    qspec = None
    if quality is not None:
        qspec = _quality_spec(chain_axis, grid_axis)
    lab_spec = P(chain_axis, grid_axis, None)

    def body(labels, key, quality, ev_loc):
        gi = jax.lax.axis_index(grid_axis)
        ci = jax.lax.axis_index(chain_axis)
        row0 = gi * h_loc
        chain0 = ci * b_loc

        def it(t, st):
            labels, key, quality = st
            ks = jax.random.split(key, 1 + len(parities))
            for i, parity in enumerate(parities):
                up_halo, down_halo = _halo_exchange(labels, grid_axis)
                labels = mrf_kernels.mrf_sharded_round_step(
                    mrf, labels, ev_loc, ks[1 + i], parity, exp_table,
                    exp_spec, row0=row0, chain0=chain0,
                    n_chains_total=n_chains, up_halo=up_halo,
                    down_halo=down_halo, interpret=interpret,
                )
            if quality is not None:
                onehot = (
                    labels.reshape(labels.shape[0], -1)[..., None]
                    == jnp.arange(mrf.n_labels, dtype=labels.dtype)
                ).astype(jnp.int32)
                quality = diag_accum.update(quality, onehot,
                                            jnp.asarray(True))
            return labels, ks[0], quality

        return jax.lax.fori_loop(0, n_iters, it, (labels, key, quality))

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(lab_spec, P(), qspec, P(grid_axis, None)),
        out_specs=(lab_spec, P(), qspec),
        check_vma=False,
    )
    jf = jax.jit(f)
    args = (labels, key, quality, evidence)
    _maybe_capture(profile_sig, jf, args, mesh, kind="mrf",
                   model=getattr(mrf, "name", None), sampler="lut_ky",
                   fused=True, n_chains=n_chains, n_iters=n_iters)
    labels, key, quality = jf(*args)
    if return_state:
        return labels, mrf_mod.MRFChainState(
            labels=labels, key=key, quality=quality
        )
    return labels


@dataclasses.dataclass
class ShardedFusedRounds:
    """The fused-BN round tables partitioned over n_dev devices.

    Like `BNFusedRounds` but with a leading (n_dev,) ownership axis (the
    Sec. IV-B node->core mapping) and a `word_pos` gather table: each local
    node's position in its round's *full* group ordering, so every device
    can slice its rows out of the full random-word stream — the key to
    sharded draws being bit-identical to the single-device kernel's.
    Pad slots carry node id n_nodes (dropped by the one-hot scatter),
    cards 0 (masked to NEG_INF) and word_pos 0 (a real row whose draw is
    discarded)."""

    nodes: jax.Array  # (n_dev, R, C) int32
    cards: jax.Array  # (n_dev, R, C) int32
    base: jax.Array  # (n_dev, R, C, F) int32
    stride: jax.Array  # (n_dev, R, C, F, S) int32
    scope_var: jax.Array
    is_self: jax.Array
    word_pos: jax.Array  # (n_dev, R, C) int32
    n_c: tuple[int, ...]  # static: full real node count per round
    c_max: int  # static: local per-device node envelope
    f_max: int
    s_max: int


jax.tree_util.register_dataclass(
    ShardedFusedRounds,
    ["nodes", "cards", "base", "stride", "scope_var", "is_self", "word_pos"],
    ["n_c", "c_max", "f_max", "s_max"],
)


def build_sharded_fused_rounds(
    cbn: bnet.CompiledBayesNet,
    groups: list[bnet.ColorGroup],
    n_dev: int,
    placement: MeshPlacement | None = None,
) -> ShardedFusedRounds:
    """Partition each round's gather tensors across devices (same ownership
    rule as `shard_bn_groups`: placed core modulo n_dev, else round-robin)
    and stack them on a rounds axis padded to the common local envelope."""
    parts_by_round = []
    for g in groups:
        nodes = np.asarray(g.nodes)
        if placement is not None:
            owner = placement.placement[nodes] % n_dev
        else:
            owner = np.arange(len(nodes)) % n_dev
        parts_by_round.append([np.where(owner == d)[0] for d in range(n_dev)])
    c_max = max(
        1, max(len(p) for parts in parts_by_round for p in parts)
    )
    f_max = max(g.base.shape[1] for g in groups)
    s_max = max(g.stride.shape[2] for g in groups)
    n_rounds = len(groups)

    def table(field, pad_value=0, extra=()):
        res = np.full((n_dev, n_rounds, c_max) + extra, pad_value, np.int32)
        return res

    nodes = table("nodes", cbn.n_nodes)
    cards = table("cards", 0)
    base = table("base", 0, (f_max,))
    stride = table("stride", 0, (f_max, s_max))
    scope_var = table("scope_var", 0, (f_max, s_max))
    is_self = table("is_self", 0, (f_max, s_max))
    word_pos = table("word_pos", 0)
    for r, (g, parts) in enumerate(zip(groups, parts_by_round)):
        g_nodes = np.asarray(g.nodes)
        g_cards = np.asarray(g.cards)
        g_base = np.asarray(g.base)
        g_stride = np.asarray(g.stride)
        g_scope = np.asarray(g.scope_var)
        g_self = np.asarray(g.is_self).astype(np.int32)
        f, s = g_base.shape[1], g_stride.shape[2]
        for d, p in enumerate(parts):
            k = len(p)
            nodes[d, r, :k] = g_nodes[p]
            cards[d, r, :k] = g_cards[p]
            base[d, r, :k, :f] = g_base[p]
            stride[d, r, :k, :f, :s] = g_stride[p]
            scope_var[d, r, :k, :f, :s] = g_scope[p]
            is_self[d, r, :k, :f, :s] = g_self[p]
            word_pos[d, r, :k] = p
    return ShardedFusedRounds(
        nodes=jnp.asarray(nodes), cards=jnp.asarray(cards),
        base=jnp.asarray(base), stride=jnp.asarray(stride),
        scope_var=jnp.asarray(scope_var), is_self=jnp.asarray(is_self),
        word_pos=jnp.asarray(word_pos),
        n_c=tuple(int(np.asarray(g.nodes).shape[0]) for g in groups),
        c_max=c_max, f_max=f_max, s_max=s_max,
    )


def bn_fused_sharded(
    cbn: bnet.CompiledBayesNet,
    key: jax.Array | None,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int,
    n_iters: int,
    burn_in: int,
    sampler: str = "lut_ky",
    thin: int = 1,
    placement: MeshPlacement | None = None,
    groups: list[bnet.ColorGroup] | None = None,
    carry: bnet.BNChainState | None = None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
    chain_axis: str = "data",
    node_axis: str = "model",
    interpret: bool | None = None,
    profile_sig: str | None = None,
):
    """The fused BN color rounds inside one `shard_map` body: per round, one
    grid=(1,) `pallas_call` (`kernels/bn_gibbs.fused_color_round`) over the
    device's owned node slice, then the disjoint state deltas merge with the
    `psum_broadcast` collective — all inside the scanned sweep loop.

    Bit-exact with `compile/backend.run_bn_schedule(fused=True)`: the same
    `bn_round_step` kernel runs per round, the init/key-split/keep-gate
    structure matches `bayesnet.gibbs_run_loop`, and each device gathers its
    word rows out of the round's full stream via `word_pos`.  The
    `BNChainState` carry shards its vals/quality chain leaves over
    `chain_axis`; the histogram is merged exactly (int32 psum)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bn_gibbs.check_fused_sampler(sampler)
    groups = cbn.groups if groups is None else groups
    n_dev = mesh.shape[node_axis]
    n_chain_dev = mesh.shape[chain_axis]
    if n_chains % n_chain_dev != 0:
        raise ValueError(
            f"n_chains {n_chains} must divide over {n_chain_dev} devices"
        )
    b_loc = n_chains // n_chain_dev
    v = cbn.max_card
    weight_bits = 8 if sampler == "lut_ky" else 15
    precision = max(16, weight_bits + (v - 1).bit_length() + 1)
    max_retries = 8
    total_steps = precision * max_retries
    n_words = -(-total_steps // 32)
    sfr = build_sharded_fused_rounds(cbn, groups, n_dev, placement)
    logf = jnp.reshape(cbn.log_flat, (1, -1))
    tab = jnp.reshape(cbn.exp_table, (1, -1)).astype(jnp.float32)
    n_rounds = len(sfr.n_c)

    if carry is None:
        vals, key = bnet.init_chain_values(cbn, key, n_chains)
        quality = None
        if diag_total is not None:
            quality = diag_accum.make_accum(
                n_chains, cbn.n_nodes, cbn.max_card,
                diag_accum.kept_count(diag_total, burn_in, thin), diag_batch,
            )
        carry = bnet.BNChainState(
            vals=vals, key=key,
            hist=jnp.zeros((cbn.n_nodes, cbn.max_card), jnp.int32),
            t=jnp.zeros((), jnp.int32), quality=quality,
        )
    quality = carry.quality

    qspec = None
    if quality is not None:
        qspec = _quality_spec(chain_axis, None)
    table_spec = jax.tree_util.tree_map(
        lambda _: P(node_axis), sfr,
        is_leaf=lambda x: isinstance(x, jax.Array),
    )

    def body(vals, key, hist0, t0, quality, sfr_loc):
        ci = jax.lax.axis_index(chain_axis)
        chain0 = ci * b_loc
        nodes = sfr_loc.nodes[0]  # (R, C)
        cards = sfr_loc.cards[0]
        base = sfr_loc.base[0]
        stride = sfr_loc.stride[0]
        scope_var = sfr_loc.scope_var[0]
        is_self = sfr_loc.is_self[0]
        word_pos = sfr_loc.word_pos[0]

        def sweep(vals, sub):
            keys = jax.random.split(sub, n_rounds)
            for r in range(n_rounds):
                nc_r = sfr.n_c[r]
                # the round's FULL word stream — byte-for-byte what the
                # single-device kernel draws — sliced to local chains and
                # gathered to the owned nodes' rows
                wr = ky_core.random_words(
                    keys[r], (n_chains * nc_r,), n_words
                ).reshape(n_chains, nc_r, n_words)
                wr = jax.lax.dynamic_slice_in_dim(wr, chain0, b_loc, axis=0)
                wr = jnp.take(wr, word_pos[r], axis=1)  # (b_loc, C, W)
                new_vals = bn_gibbs.fused_color_round(
                    vals, nodes[r], cards[r], base[r], stride[r],
                    scope_var[r], is_self[r], wr, logf, tab,
                    sampler=sampler, exp_spec=cbn.exp_spec, v_max=v,
                    n_words=n_words, weight_bits=weight_bits,
                    precision=precision, total_steps=total_steps,
                    interpret=interpret,
                )
                # disjoint ownership => one int psum merges all updates
                # (the psum_broadcast mechanism, exact in int32)
                vals = vals + jax.lax.psum(new_vals - vals, node_axis)
            return vals

        delta0 = jnp.zeros_like(hist0)

        def it(_, st):
            vals, key, delta, t, quality = st
            key, sub = jax.random.split(key)
            vals = sweep(vals, sub)
            onehot = (
                vals[..., None]
                == jnp.arange(cbn.max_card, dtype=jnp.int32)
            ).astype(jnp.int32)
            keep = (t >= burn_in) & ((t - burn_in) % thin == 0)
            delta = delta + jnp.where(keep, onehot.sum(0), 0)
            if quality is not None:
                quality = diag_accum.update(quality, onehot, keep)
            return vals, key, delta, t + 1, quality

        vals, key, delta, t, quality = jax.lax.fori_loop(
            0, n_iters, it, (vals, key, delta0, t0, quality)
        )
        hist = hist0 + jax.lax.psum(delta, chain_axis)
        return vals, key, hist, t, quality

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(chain_axis, None), P(), P(), P(), qspec, table_spec),
        out_specs=(P(chain_axis, None), P(), P(), P(), qspec),
        check_vma=False,
    )
    jf = jax.jit(f)
    args = (carry.vals, carry.key, carry.hist, carry.t, quality, sfr)
    _maybe_capture(profile_sig, jf, args, mesh, kind="bn",
                   model=getattr(cbn, "name", None), sampler=sampler,
                   fused=True, n_chains=n_chains, n_iters=n_iters)
    vals, key, hist, t, quality = jf(*args)
    out = bnet.BNChainState(vals=vals, key=key, hist=hist, t=t,
                            quality=quality)
    card_mask = (
        jnp.arange(cbn.max_card, dtype=jnp.int32)[None] < cbn.cards[:, None]
    )
    denom = jnp.maximum(hist.sum(-1, keepdims=True), 1)
    marginals = jnp.where(card_mask, hist / denom, 0.0)
    if return_state:
        return marginals, vals, out
    return marginals, vals


def _maybe_capture(profile_sig, jf, args, mesh, **meta) -> None:
    """Stamp the shard_map executable into the profile registry (when
    profiling is on): the sharded-fused HLO is where the collective-permute
    / all-reduce bytes live, and `obs.profile.join_dispatches` attributes
    sharded dispatches by this signature like any other bucket."""
    if profile_sig is None:
        return
    from repro.obs import profile as profile_mod

    reg = profile_mod.get()
    if reg is None:
        return
    reg.capture(
        profile_sig, lambda: jf.lower(*args), n_chips=mesh.size,
        route="sharded", **meta,
    )


def _check_comm_mechanisms(program, expected: str) -> None:
    """The schedule backend routes each round's comm op onto the collective
    its mechanism names (`psum_broadcast` -> lax.psum, `ppermute_halo` ->
    lax.ppermute); a round carrying any other mechanism has no lowering in
    this engine and must be rejected, not silently psum'd."""
    for r in program.schedule.rounds:
        for op in r.comm:
            if op.mechanism != expected:
                raise ValueError(
                    f"round {r.color} comm op uses mechanism "
                    f"{op.mechanism!r}; this engine lowers {expected!r} only"
                )


def run_program_sharded(
    program,
    key: jax.Array | None,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int = 32,
    n_iters: int = 200,
    burn_in: int | None = None,
    sampler: str = "lut_ky",
    evidence: jax.Array | None = None,
    backend: str = "eager",
    fused: bool = False,
    thin: int = 1,
    carry=None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
    profile_sig: str | None = None,
    **axes,
):
    """Execute a `repro.compile.CompiledProgram` across a device mesh.

    BNs run the psum-broadcast engine with node ownership taken from the
    program's Sec. IV-B placement; MRFs run the ppermute-halo engine (the
    row partition *is* the placement for a grid).  Same key, same program
    => same states as calling these engines directly.

    With `backend="schedule"` the rounds and their order come from the
    compiled `Schedule` (via the program's lowered executable), and each
    round's comm ops are routed onto the collectives their mechanisms name:
    `psum_broadcast` -> the per-round `lax.psum` of the disjoint state
    delta, `ppermute_halo` -> the `lax.ppermute` boundary-row exchange.

    `fused=True` (schedule backend only) executes the whole run through ONE
    shard_map body wrapping the Pallas round kernels and those collectives
    (`mrf_fused_sharded` / `bn_fused_sharded`) — bit-exact with the
    single-device fused backend, so `carry`/`return_state` slicing and the
    `diag_total` quality accumulator are supported there (and only there:
    the legacy per-device-folded engines have neither a shared key
    structure nor carry pytrees)."""
    if backend not in ("eager", "schedule"):
        raise ValueError(f"unknown backend {backend!r}")
    if fused and backend != "schedule":
        raise ValueError("fused sharded execution is schedule-backend only")
    if not fused and (carry is not None or return_state
                      or diag_total is not None):
        raise ValueError(
            "carry/return_state/diag_total ride the fused sharded route "
            "only (the legacy sharded engines fold keys per device and "
            "carry no state)"
        )
    if program.kind == "bn":
        if evidence is not None:
            raise ValueError(
                "BN evidence is baked into the program at compile time"
            )
        groups = None
        if backend == "schedule":
            _check_comm_mechanisms(program, "psum_broadcast")
            groups = program.schedule_executable().round_groups
        if fused:
            return bn_fused_sharded(
                program.cbn, key, mesh,
                n_chains=n_chains, n_iters=n_iters,
                burn_in=50 if burn_in is None else burn_in,
                sampler=sampler, thin=thin, placement=program.placement,
                groups=groups, carry=carry, return_state=return_state,
                diag_total=diag_total, diag_batch=diag_batch,
                profile_sig=profile_sig, **axes,
            )
        return bn_gibbs_sharded(
            program.cbn, key, mesh,
            n_chains=n_chains, n_iters=n_iters,
            burn_in=50 if burn_in is None else burn_in,
            sampler=sampler, placement=program.placement, groups=groups,
            **axes,
        )
    if evidence is None:
        raise ValueError("MRF programs take the evidence image at run time")
    if burn_in is not None:
        raise ValueError(
            "MRF programs return final states only; burn_in does not apply"
        )
    parities = (0, 1)
    if backend == "schedule":
        _check_comm_mechanisms(program, "ppermute_halo")
        parities = program.schedule_executable().parities
    if fused:
        if sampler != "lut_ky":
            raise ValueError(
                f"fused sharded MRF rounds implement the lut_ky datapath "
                f"only, got sampler={sampler!r}"
            )
        if program.ir.evidence:
            raise ValueError(
                "baked MRF pins have no sharded-fused lowering (the "
                "executor route excludes pinned buckets)"
            )
        return mrf_fused_sharded(
            program.mrf, evidence, key, mesh,
            n_chains=n_chains, n_iters=n_iters, parities=parities,
            carry=carry, return_state=return_state,
            diag_total=diag_total, diag_batch=diag_batch,
            profile_sig=profile_sig, **axes,
        )
    return mrf_gibbs_sharded(
        program.mrf, evidence, key, mesh,
        n_chains=n_chains, n_iters=n_iters, sampler=sampler,
        parities=parities, **axes,
    )
