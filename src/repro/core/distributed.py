"""Distributed chromatic Gibbs via shard_map (paper Sec. III mesh, at pod scale).

AIA's 4x4 core mesh becomes the JAX device mesh; the two data-movement
mechanisms map 1:1 onto collectives:

  * neighbor shared-RF access (C4)  ->  `lax.ppermute` halo exchange between
    mesh-adjacent devices (MRF grids are row-partitioned over the "model"
    axis; only boundary rows move, one ICI hop, contention-free);
  * global barrier / event unit (C5) -> the implicit synchronization at each
    collective boundary: one per color, exactly Alg. 2's schedule;
  * shared-RF value broadcast (BN)   -> a psum of the (tiny) int delta of the
    state vector after each color update — each node is owned by exactly one
    device (the Sec. IV-B mapping), so deltas are disjoint.

Chains are the pure-DP axis ("data"; "pod" stacks more of it multi-pod):
no cross-chain communication at all, mirroring Alg. 1's MaxChain loop.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import bayesnet as bnet
from repro.core import compat
from repro.core.draws import draw_from_logits
from repro.core.graphs import GridMRF
from repro.core.interp import build_exp_weight_lut
from repro.core.mapping import MeshPlacement

# ---------------------------------------------------------------------------
# MRF: row-partitioned grid with ppermute halo exchange
# ---------------------------------------------------------------------------


def _halo_exchange(lab: jax.Array, axis: str) -> tuple[jax.Array, jax.Array]:
    """Send boundary rows to mesh neighbors; returns (up_halo, down_halo) of
    shape (..., 1, W).  Global grid boundary gets -1 (no neighbor)."""
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    down_perm = [(i, (i + 1) % n) for i in range(n)]
    up_perm = [(i, (i - 1) % n) for i in range(n)]
    up_halo = jax.lax.ppermute(lab[..., -1:, :], axis, down_perm)
    down_halo = jax.lax.ppermute(lab[..., :1, :], axis, up_perm)
    up_halo = jnp.where(idx == 0, -1, up_halo)
    down_halo = jnp.where(idx == n - 1, -1, down_halo)
    return up_halo, down_halo


def _local_half_step(
    mrf: GridMRF,
    lab: jax.Array,  # (B, h_loc, W)
    ev: jax.Array,  # (h_loc, W)
    key: jax.Array,
    parity: int,
    sampler: str,
    exp_table,
    exp_spec,
    axis: str,
) -> jax.Array:
    up_halo, down_halo = _halo_exchange(lab, axis)
    padded = jnp.concatenate([up_halo, lab, down_halo], axis=-2)
    up, down = padded[..., :-2, :], padded[..., 2:, :]
    neg_col = jnp.full(lab.shape[:-1] + (1,), -1, lab.dtype)
    left = jnp.concatenate([neg_col, lab[..., :, :-1]], axis=-1)
    right = jnp.concatenate([lab[..., :, 1:], neg_col], axis=-1)

    v_range = jnp.arange(mrf.n_labels, dtype=lab.dtype)
    cnt = sum(
        (nb[..., None] == v_range).astype(jnp.float32)
        for nb in (up, down, left, right)
    )
    if mrf.data_cost == "potts":
        data = mrf.h * (ev[..., None] == v_range).astype(jnp.float32)
    else:
        diff = (ev[..., None] - v_range).astype(jnp.float32)
        data = -mrf.h * diff * diff
    logp = mrf.theta * cnt + data
    new = draw_from_logits(logp, key, sampler, exp_table, exp_spec)

    h_loc, w = lab.shape[-2], lab.shape[-1]
    row0 = jax.lax.axis_index(axis) * h_loc
    gr = row0 + jnp.arange(h_loc)[:, None]
    gc = jnp.arange(w)[None, :]
    mask = ((gr + gc) % 2) == parity
    return jnp.where(mask, new, lab)


def mrf_gibbs_sharded(
    mrf: GridMRF,
    evidence: jax.Array,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int,
    n_iters: int,
    sampler: str = "lut_ky",
    chain_axes: tuple[str, ...] = ("data",),
    grid_axis: str = "model",
    parities: tuple[int, ...] = (0, 1),
):
    """Chromatic Gibbs with the grid row-sharded over `grid_axis` and chains
    sharded over `chain_axes`.  Returns final labels (B, H, W).  `parities`
    is the per-round checkerboard order — (0, 1) eagerly, or the compiled
    `Schedule`'s round order under the schedule backend; each round's halo
    read is the `ppermute_halo` comm op lowered to `lax.ppermute`."""
    exp_table, exp_spec = build_exp_weight_lut()
    n_grid = int(np.prod([mesh.shape[a] for a in (grid_axis,)]))
    assert mrf.height % n_grid == 0, "grid rows must divide over devices"
    n_chain_dev = int(np.prod([mesh.shape[a] for a in chain_axes]))
    assert n_chains % n_chain_dev == 0

    chain_spec = P(chain_axes if len(chain_axes) > 1 else chain_axes[0])

    def body(ev_loc, key):
        ci = jax.lax.axis_index(chain_axes[0])
        for a in chain_axes[1:]:
            ci = ci * compat.axis_size(a) + jax.lax.axis_index(a)
        gi = jax.lax.axis_index(grid_axis)
        key = jax.random.fold_in(jax.random.fold_in(key, ci), gi)
        k0, key = jax.random.split(key)
        lab = jax.random.randint(
            k0,
            (n_chains // n_chain_dev, mrf.height // n_grid, mrf.width),
            0,
            mrf.n_labels,
            jnp.int32,
        )

        def it(t, carry):
            lab, key = carry
            ks = jax.random.split(key, 1 + len(parities))
            for i, parity in enumerate(parities):
                lab = _local_half_step(
                    mrf, lab, ev_loc, ks[1 + i], parity, sampler, exp_table,
                    exp_spec, grid_axis,
                )
            return lab, ks[0]

        lab, _ = jax.lax.fori_loop(0, n_iters, it, (lab, key))
        return lab

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(grid_axis, None), P()),
        out_specs=P(chain_spec[0] if len(chain_axes) == 1 else chain_axes,
                    grid_axis, None),
        check_vma=False,
    )
    return jax.jit(f)(evidence, key)


# ---------------------------------------------------------------------------
# Bayes nets: color groups partitioned over devices per the Sec. IV-B mapping
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedGroup:
    """One color group partitioned over n_dev devices, padded to equal width.
    All arrays carry a leading (n_dev,) axis; node id == n_nodes marks a pad
    slot (dropped by out-of-bounds scatter)."""

    nodes: jax.Array  # (n_dev, nc_max)
    cards: jax.Array
    base: jax.Array  # (n_dev, nc_max, F)
    stride: jax.Array  # (n_dev, nc_max, F, S)
    scope_var: jax.Array
    is_self: jax.Array


jax.tree_util.register_dataclass(
    ShardedGroup, ["nodes", "cards", "base", "stride", "scope_var", "is_self"], []
)


def shard_bn_groups(
    cbn: bnet.CompiledBayesNet,
    n_dev: int,
    placement: MeshPlacement | None = None,
    groups: list[bnet.ColorGroup] | None = None,
) -> list[ShardedGroup]:
    """Partition each color group across devices.  With a mapping (Sec. IV-B)
    nodes go to their placed core modulo n_dev; otherwise round-robin.
    `groups` overrides `cbn.groups` — the schedule-direct backend passes its
    round-ordered groups here."""
    out = []
    for g in groups if groups is not None else cbn.groups:
        nodes = np.asarray(g.nodes)
        if placement is not None:
            owner = placement.placement[nodes] % n_dev
        else:
            owner = np.arange(len(nodes)) % n_dev
        parts = [np.where(owner == d)[0] for d in range(n_dev)]
        nc_max = max(1, max(len(p) for p in parts))

        def pack(arr, pad_value=0):
            arr = np.asarray(arr)
            res = np.full((n_dev, nc_max) + arr.shape[1:], pad_value,
                          arr.dtype)
            for d, p in enumerate(parts):
                res[d, : len(p)] = arr[p]
            return jnp.asarray(res)

        out.append(
            ShardedGroup(
                nodes=pack(np.asarray(g.nodes), pad_value=cbn.n_nodes),
                cards=pack(np.asarray(g.cards), pad_value=1),
                base=pack(np.asarray(g.base)),  # pad base 0 -> dummy entry
                stride=pack(np.asarray(g.stride)),
                scope_var=pack(np.asarray(g.scope_var)),
                is_self=pack(np.asarray(g.is_self)),
            )
        )
    return out


def bn_gibbs_sharded(
    cbn: bnet.CompiledBayesNet,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int,
    n_iters: int,
    burn_in: int,
    sampler: str = "lut_ky",
    placement: MeshPlacement | None = None,
    chain_axis: str = "data",
    node_axis: str = "model",
    groups: list[bnet.ColorGroup] | None = None,
):
    """Distributed Alg. 2: nodes of a color split over `node_axis` devices,
    chains over `chain_axis`.  After each color/round, the disjoint updates
    are merged with one small integer psum — the `psum_broadcast` comm op of
    the schedule, i.e. the shared-RF exchange.  `groups` overrides the
    eager color groups with schedule-round groups.
    Returns (marginals (n, V), final local vals)."""
    n_dev = mesh.shape[node_axis]
    n_chain_dev = mesh.shape[chain_axis]
    assert n_chains % n_chain_dev == 0
    sgroups = shard_bn_groups(cbn, n_dev, placement, groups=groups)
    b_loc = n_chains // n_chain_dev

    def body(key):
        ci = jax.lax.axis_index(chain_axis)
        di = jax.lax.axis_index(node_axis)
        kc = jax.random.fold_in(key, ci)
        vals, kc = bnet.init_chain_values(cbn, kc, b_loc)

        def sweep(vals, kk):
            keys = jax.random.split(kk, len(sgroups))
            for sg, k in zip(sgroups, keys):
                g = bnet.ColorGroup(
                    nodes=sg.nodes[di],
                    cards=sg.cards[di],
                    base=sg.base[di],
                    stride=sg.stride[di],
                    scope_var=sg.scope_var[di],
                    is_self=sg.is_self[di],
                )
                logp = bnet.group_log_conditionals(cbn, g, vals)
                lab = draw_from_logits(
                    logp, jax.random.fold_in(k, di), sampler,
                    cbn.exp_table, cbn.exp_spec,
                )
                upd = vals.at[:, g.nodes].set(lab, mode="drop")
                # disjoint ownership => one psum merges all devices' updates
                vals = vals + jax.lax.psum(upd - vals, node_axis)
            return vals

        hist0 = jnp.zeros((cbn.n_nodes, cbn.max_card), jnp.int32)

        def it(t, carry):
            vals, kk, hist = carry
            kk, sub = jax.random.split(kk)
            vals = sweep(vals, sub)
            onehot = (
                vals[..., None] == jnp.arange(cbn.max_card, dtype=jnp.int32)
            ).astype(jnp.int32)
            hist = hist + jnp.where(t >= burn_in, onehot.sum(0), 0)
            return vals, kk, hist

        vals, _, hist = jax.lax.fori_loop(0, n_iters, it, (vals, kc, hist0))
        hist = jax.lax.psum(hist, chain_axis)
        return hist, vals

    f = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(),),
        out_specs=(P(), P(chain_axis, None)),
        check_vma=False,
    )
    hist, vals = jax.jit(f)(key)
    card_mask = (
        jnp.arange(cbn.max_card, dtype=jnp.int32)[None] < cbn.cards[:, None]
    )
    denom = jnp.maximum(hist.sum(-1, keepdims=True), 1)
    return jnp.where(card_mask, hist / denom, 0.0), vals


# ---------------------------------------------------------------------------
# Compiled-program entry point (repro.compile emits CompiledProgram artifacts;
# this is their shard_map backend — duck-typed to avoid a circular import)
# ---------------------------------------------------------------------------


def _check_comm_mechanisms(program, expected: str) -> None:
    """The schedule backend routes each round's comm op onto the collective
    its mechanism names (`psum_broadcast` -> lax.psum, `ppermute_halo` ->
    lax.ppermute); a round carrying any other mechanism has no lowering in
    this engine and must be rejected, not silently psum'd."""
    for r in program.schedule.rounds:
        for op in r.comm:
            if op.mechanism != expected:
                raise ValueError(
                    f"round {r.color} comm op uses mechanism "
                    f"{op.mechanism!r}; this engine lowers {expected!r} only"
                )


def run_program_sharded(
    program,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    n_chains: int = 32,
    n_iters: int = 200,
    burn_in: int | None = None,
    sampler: str = "lut_ky",
    evidence: jax.Array | None = None,
    backend: str = "eager",
    **axes,
):
    """Execute a `repro.compile.CompiledProgram` across a device mesh.

    BNs run the psum-broadcast engine with node ownership taken from the
    program's Sec. IV-B placement; MRFs run the ppermute-halo engine (the
    row partition *is* the placement for a grid).  Same key, same program
    => same states as calling these engines directly.

    With `backend="schedule"` the rounds and their order come from the
    compiled `Schedule` (via the program's lowered executable), and each
    round's comm ops are routed onto the collectives their mechanisms name:
    `psum_broadcast` -> the per-round `lax.psum` of the disjoint state
    delta, `ppermute_halo` -> the `lax.ppermute` boundary-row exchange."""
    if backend not in ("eager", "schedule"):
        raise ValueError(f"unknown backend {backend!r}")
    if program.kind == "bn":
        if evidence is not None:
            raise ValueError(
                "BN evidence is baked into the program at compile time"
            )
        groups = None
        if backend == "schedule":
            _check_comm_mechanisms(program, "psum_broadcast")
            groups = program.schedule_executable().round_groups
        return bn_gibbs_sharded(
            program.cbn, key, mesh,
            n_chains=n_chains, n_iters=n_iters,
            burn_in=50 if burn_in is None else burn_in,
            sampler=sampler, placement=program.placement, groups=groups,
            **axes,
        )
    if evidence is None:
        raise ValueError("MRF programs take the evidence image at run time")
    if burn_in is not None:
        raise ValueError(
            "MRF programs return final states only; burn_in does not apply"
        )
    parities = (0, 1)
    if backend == "schedule":
        _check_comm_mechanisms(program, "ppermute_halo")
        parities = program.schedule_executable().parities
    return mrf_gibbs_sharded(
        program.mrf, evidence, key, mesh,
        n_chains=n_chains, n_iters=n_iters, sampler=sampler,
        parities=parities, **axes,
    )
