"""Pluggable discrete-draw pipelines shared by the BN and MRF Gibbs engines.

  lut_ky   : LUT-exp int8 weights + rejection-KY      (AIA, paper C1+C2)
  exact_ky : exact exp, 15-bit weights + rejection-KY (ablates C2)
  cdf      : normalized softmax + inverse-CDF search  (PULP/CPU baseline)
  gumbel   : Gumbel-max argmax                        (beyond-paper TPU-native)

All take (..., V) unnormalized log-potentials and return (...) int32 labels.
The KY paths are normalization-free end to end — the paper's core claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ky as ky_core
from repro.core.interp import LUTSpec, interp_ref

SAMPLERS = ("lut_ky", "exact_ky", "cdf", "gumbel")


def draw_from_logits(
    logp: jax.Array,
    key: jax.Array,
    sampler: str,
    exp_table: jax.Array | None = None,
    exp_spec: LUTSpec | None = None,
    precision: int = 16,
    max_retries: int = 8,
) -> jax.Array:
    shape = logp.shape[:-1]
    v = logp.shape[-1]
    flat = logp.reshape(-1, v)
    if sampler == "gumbel":
        gum = jax.random.gumbel(key, flat.shape, flat.dtype)
        return jnp.argmax(flat + gum, axis=-1).astype(jnp.int32).reshape(shape)
    if sampler == "cdf":
        p = jax.nn.softmax(flat, axis=-1)
        c = jnp.cumsum(p, axis=-1)
        u = jax.random.uniform(key, (flat.shape[0], 1), flat.dtype)
        lab = jnp.minimum(jnp.sum(c < u, axis=-1), v - 1)
        return lab.astype(jnp.int32).reshape(shape)

    z = flat - jax.lax.stop_gradient(jnp.max(flat, axis=-1, keepdims=True))
    if sampler == "lut_ky":
        assert exp_table is not None and exp_spec is not None
        w = jnp.maximum(jnp.round(interp_ref(z, exp_table, exp_spec)), 0.0)
        w = w.astype(jnp.int32)
        weight_bits = 8
    elif sampler == "exact_ky":
        weight_bits = 15
        w = ky_core.quantize_probs(jnp.exp(z), bits=weight_bits)
    else:
        raise ValueError(f"unknown sampler {sampler!r}")
    # sum(m) <= V * 2^weight_bits must fit in 2^precision or the rejection
    # bin would go negative and corrupt the DDG tree
    precision = max(precision, weight_bits + (v - 1).bit_length() + 1)
    n_words = -(-precision * max_retries // 32)
    words = ky_core.random_words(key, (flat.shape[0],), n_words)
    # early-exit walk: identical outputs to ky_sample_ref for the same
    # words, but O(entropy) steps instead of precision*max_retries
    labels, _ = ky_core.ky_sample_fast(
        w, words, n_bins=v, precision=precision, max_retries=max_retries
    )
    return labels.reshape(shape)
