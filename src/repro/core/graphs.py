"""Discrete probabilistic-model graph IR (paper Sec. II).

The front-end of the "AIA compiler": Bayes nets (irregular DAGs with CPTs)
and grid MRFs are described here as plain numpy structures; `coloring.py`
and `bayesnet.py` lower them to dense per-color update tensors.

BN-repository benchmarks (survey, cancer, alarm, ...) are not downloadable in
this offline container, so `bn_repository_replica()` generates *structure-
matched synthetic replicas*: same node count, comparable in/out-degree and
arity ranges taken from the published descriptions.  Every benchmark table
that uses them says so.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class DiscreteBayesNet:
    """Nodes 0..n-1 in topological order; cpts[i] has shape
    (card[p0], ..., card[pk], card[i]) for parents p0..pk of node i."""

    cards: np.ndarray  # (n,) int
    parents: list[list[int]]
    cpts: list[np.ndarray]
    name: str = "bn"

    @property
    def n_nodes(self) -> int:
        return len(self.cards)

    def children(self, i: int) -> list[int]:
        return [c for c in range(self.n_nodes) if i in self.parents[c]]

    def markov_blanket(self, i: int) -> set[int]:
        mb: set[int] = set(self.parents[i])
        for c in self.children(i):
            mb.add(c)
            mb.update(self.parents[c])
        mb.discard(i)
        return mb

    def moral_adjacency(self) -> list[set[int]]:
        """Undirected conflict graph for chromatic Gibbs: i ~ j iff j is in
        MB(i).  (Symmetric by construction of the Markov blanket.)"""
        adj = [set() for _ in range(self.n_nodes)]
        for i in range(self.n_nodes):
            for j in self.markov_blanket(i):
                adj[i].add(j)
                adj[j].add(i)
        return adj

    def n_edges(self) -> int:
        return sum(len(p) for p in self.parents)

    def validate(self) -> None:
        for i, (ps, cpt) in enumerate(zip(self.parents, self.cpts)):
            assert all(p < i for p in ps), f"node {i}: parents must precede"
            want = tuple(self.cards[p] for p in ps) + (self.cards[i],)
            assert cpt.shape == want, f"node {i}: cpt shape {cpt.shape} != {want}"
            s = cpt.sum(axis=-1)
            assert np.allclose(s, 1.0, atol=1e-6), f"node {i}: cpt not normalized"

    def joint_logp(self, assignment: np.ndarray) -> float:
        lp = 0.0
        for i, (ps, cpt) in enumerate(zip(self.parents, self.cpts)):
            idx = tuple(int(assignment[p]) for p in ps) + (int(assignment[i]),)
            lp += float(np.log(cpt[idx]))
        return lp


def random_cpt(rng: np.random.Generator, shape: tuple[int, ...]) -> np.ndarray:
    """Dirichlet(0.8) rows — mildly peaked, no zero entries (ergodic Gibbs)."""
    flat = rng.dirichlet(np.full(shape[-1], 0.8), size=int(np.prod(shape[:-1])))
    return np.clip(flat, 1e-4, None).reshape(shape) / np.clip(
        flat, 1e-4, None
    ).reshape(shape).sum(-1, keepdims=True)


def random_bayesnet(
    n_nodes: int,
    max_parents: int = 3,
    cards: Sequence[int] | int = 2,
    seed: int = 0,
    name: str = "random",
    edge_density: float = 0.5,
) -> DiscreteBayesNet:
    rng = np.random.default_rng(seed)
    if isinstance(cards, int):
        card_arr = np.full(n_nodes, cards, np.int64)
    else:
        card_arr = rng.choice(list(cards), size=n_nodes)
    parents: list[list[int]] = []
    for i in range(n_nodes):
        k = min(i, max_parents)
        k = int(rng.binomial(k, edge_density)) if k else 0
        ps = sorted(rng.choice(i, size=k, replace=False).tolist()) if k else []
        parents.append(ps)
    cpts = [
        random_cpt(rng, tuple(card_arr[p] for p in ps) + (int(card_arr[i]),))
        for i, ps in enumerate(parents)
    ]
    bn = DiscreteBayesNet(card_arr, parents, cpts, name=name)
    bn.validate()
    return bn


# (n_nodes, max_parents, arity candidates, density) from published BN-repo
# descriptions — structure-matched replicas, NOT the original CPTs.
_BN_REPO_STATS: dict[str, tuple[int, int, tuple[int, ...], float]] = {
    "survey": (6, 2, (2, 3), 0.7),
    "cancer": (5, 2, (2,), 0.7),
    "asia": (8, 2, (2,), 0.7),
    "sachs": (11, 3, (3,), 0.6),
    "insurance": (27, 3, (2, 3, 4, 5), 0.6),
    "water": (32, 5, (3, 4), 0.5),
    "alarm": (37, 4, (2, 3, 4), 0.55),
    "hailfinder": (56, 4, (2, 3, 4, 5, 11), 0.5),
    "hepar2": (70, 6, (2, 3, 4), 0.45),
    "win95pts": (76, 7, (2,), 0.4),
    "pigs": (441, 2, (3,), 0.6),
}


def bn_repository_replica(name: str, seed: int = 0) -> DiscreteBayesNet:
    n, mp, cards, dens = _BN_REPO_STATS[name]
    return random_bayesnet(
        n, max_parents=mp, cards=cards, seed=seed, name=name, edge_density=dens
    )


def bn_repository_names() -> list[str]:
    return list(_BN_REPO_STATS)


@dataclasses.dataclass(frozen=True)
class GridMRF:
    """Potts/Ising MRF on an (H, W) 4-connected grid (paper Eqn. 7).

    E(l) = sum_(i~j) theta·[l_i == l_j] + sum_i datacost(l_i, e_i)
    datacost = h·[l_i == e_i]           ('potts', the paper's form)
             | -h·(l_i - e_i)^2          ('quadratic', gray-level denoising)
    """

    height: int
    width: int
    n_labels: int
    theta: float = 1.0
    h: float = 2.0
    data_cost: str = "potts"
    name: str = "mrf"

    def checkerboard_colors(self) -> np.ndarray:
        ii = np.add.outer(np.arange(self.height), np.arange(self.width))
        return (ii % 2).astype(np.int64)

    def adjacency(self) -> list[set[int]]:
        def nid(r, c):
            return r * self.width + c

        adj = [set() for _ in range(self.height * self.width)]
        for r in range(self.height):
            for c in range(self.width):
                for dr, dc in ((0, 1), (1, 0)):
                    r2, c2 = r + dr, c + dc
                    if r2 < self.height and c2 < self.width:
                        adj[nid(r, c)].add(nid(r2, c2))
                        adj[nid(r2, c2)].add(nid(r, c))
        return adj
