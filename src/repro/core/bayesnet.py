"""Chromatic parallel Gibbs sampling for Bayes nets (paper Alg. 2 + Sec. IV).

This is the software half of AIA: the "compiler" lowers an irregular DAG into
dense, padded per-color update tensors (the analogue of mapping RVs onto the
4x4 core mesh), and the jitted engine executes one color at a time:

  compile time (numpy)                      run time (jit, per color)
  ----------------------------------------  -------------------------------
  moral graph -> DSATUR colors (C3)         gather CPT entries for all
  per node: Markov-blanket factor list        (chain, node, factor, value)
  factor -> (base, stride, scope) tensors     in one vectorized address calc
  pad to (n_c, F, S) per color              logp -> LUT-exp weights (C2)
                                            -> rejection-KY draw (C1)
                                            -> scatter into the state vector

The state-vector scatter/gather between colors is the paper's shared-RF
exchange; on one chip it is a VMEM gather, across devices `distributed.py`
turns it into an all-gather of the (tiny) value vector.

All samplers are pluggable so the Fig. 12 ablations are first-class:
  lut_ky   : LUT-exp int8 weights + rejection-KY      (AIA, C1+C2)
  exact_ky : exact exp, 16-bit weights + rejection-KY (ablate C2)
  cdf      : normalized softmax + inverse-CDF search  (PULP/CPU baseline)
  gumbel   : Gumbel-max argmax                        (beyond-paper TPU-native)
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# The jitted wrappers donate the whole chain-state pytree; the PRNG-key and
# scalar-counter leaves have no aliasable output when return_state=False and
# jax warns once per compile.  The partial donation is deliberate (vals and
# the histogram are the big buffers) — silence exactly that warning.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)

from repro.core import coloring as coloring_mod
from repro.core import ky as ky_core
from repro.core.draws import SAMPLERS, draw_from_logits
from repro.core.graphs import DiscreteBayesNet
from repro.core.interp import LUTSpec, build_exp_weight_lut
from repro.diag import accum as diag_accum

NEG_INF = -1e30


@dataclasses.dataclass
class ColorGroup:
    nodes: jax.Array  # (n_c,) int32
    cards: jax.Array  # (n_c,) int32
    base: jax.Array  # (n_c, F) int32; 0 => padded factor slot (dummy entry)
    stride: jax.Array  # (n_c, F, S) int32
    scope_var: jax.Array  # (n_c, F, S) int32
    is_self: jax.Array  # (n_c, F, S) bool


@dataclasses.dataclass
class CompiledBayesNet:
    log_flat: jax.Array  # (T,) f32: [0.0] + concat(log cpts)
    groups: list[ColorGroup]
    cards: jax.Array  # (n,) int32
    init_vals: jax.Array  # (n,) int32 (evidence baked in)
    free_mask: jax.Array  # (n,) bool
    max_card: int
    n_nodes: int
    colors: tuple[int, ...]  # hashable: this dataclass crosses jit boundaries
    exp_table: jax.Array
    exp_spec: LUTSpec
    name: str = "bn"


def cpt_bases(bn: DiscreteBayesNet) -> np.ndarray:
    """Offset of each node's CPT in the flat log-CPT arena (entry 0 is the
    dummy used by padded factor slots)."""
    bases = np.zeros(bn.n_nodes, np.int64)
    off = 1
    for i, cpt in enumerate(bn.cpts):
        bases[i] = off
        off += cpt.size
    return bases


def build_color_group(
    bn: DiscreteBayesNet, free: list[int], bases: np.ndarray | None = None
) -> ColorGroup:
    """Dense CPT-gather tensors for one conditionally-independent node set.

    `compile_bayesnet` calls this per color; `repro.compile.backend` calls it
    per *schedule round*, so passes that regroup rounds (e.g. color merging)
    change execution without touching this module."""
    if bases is None:
        bases = cpt_bases(bn)

    def factor_slots(fnode: int):
        """(base, stride-per-scope-var, scope vars) for CPT of `fnode`."""
        scope = list(bn.parents[fnode]) + [fnode]
        dims = [int(bn.cards[v]) for v in scope]
        strides = np.ones(len(dims), np.int64)
        for k in range(len(dims) - 2, -1, -1):
            strides[k] = strides[k + 1] * dims[k + 1]
        return bases[fnode], strides, scope

    factor_lists = [[i] + bn.children(i) for i in free]
    f_max = max(len(fl) for fl in factor_lists)
    s_max = max(len(bn.parents[f]) + 1 for fl in factor_lists for f in fl)
    nc = len(free)
    base = np.zeros((nc, f_max), np.int64)
    stride = np.zeros((nc, f_max, s_max), np.int64)
    scope_var = np.zeros((nc, f_max, s_max), np.int64)
    is_self = np.zeros((nc, f_max, s_max), bool)
    for a, (i, fl) in enumerate(zip(free, factor_lists)):
        for b, f in enumerate(fl):
            fb, fs, sc = factor_slots(f)
            base[a, b] = fb
            stride[a, b, : len(sc)] = fs
            scope_var[a, b, : len(sc)] = sc
            is_self[a, b, : len(sc)] = [v == i for v in sc]
    return ColorGroup(
        nodes=jnp.asarray(free, jnp.int32),
        cards=jnp.asarray([bn.cards[i] for i in free], jnp.int32),
        base=jnp.asarray(base, jnp.int32),
        stride=jnp.asarray(stride, jnp.int32),
        scope_var=jnp.asarray(scope_var, jnp.int32),
        is_self=jnp.asarray(is_self),
    )


def build_clamped_groups(
    bn: DiscreteBayesNet,
    node_lists,
    clamp_nodes,
    bases: np.ndarray | None = None,
) -> list[ColorGroup]:
    """Rebuild gather groups with a runtime-evidence set removed.

    `node_lists` is the unclamped grouping (eager: `[g.nodes for g in
    cbn.groups]`; schedule backend: `[r.nodes for r in rounds]`); clamped
    nodes are dropped from every group and all-clamped groups vanish —
    exactly what `compile_bayesnet` does when the same evidence is baked,
    which is what makes the runtime-clamp path bit-exact with it."""
    if bases is None:
        bases = cpt_bases(bn)
    clamp = set(int(v) for v in clamp_nodes)
    out: list[ColorGroup] = []
    for nodes in node_lists:
        free = [int(v) for v in nodes if int(v) not in clamp]
        if free:
            out.append(build_color_group(bn, free, bases))
    return out


def compile_bayesnet(
    bn: DiscreteBayesNet,
    evidence: dict[int, int] | None = None,
    lut_size: int = 16,
    lut_range: float = 8.0,
    lut_bits: int = 8,
    seed: int = 0,
    colors: np.ndarray | None = None,
) -> CompiledBayesNet:
    """Backend code generation (Fig. 8 right half): per-color CPT-gather
    tensors.  `repro.compile` drives this with the pass pipeline's coloring
    (`colors=`); called standalone it runs DSATUR itself."""
    bn.validate()
    evidence = dict(evidence or {})
    n = bn.n_nodes
    if colors is None:
        colors = coloring_mod.dsatur(bn.moral_adjacency())
    # raised, not asserted: a bad imported coloring is the parallel-Gibbs
    # race condition, and that check must survive `python -O`
    from repro.analysis import verify as verify_mod

    verify_mod.require_proper_coloring(
        bn.moral_adjacency(), colors, loc=f"{bn.name}:compile_bayesnet"
    )

    # flat log-CPT arena; entry 0 is the dummy used by padded factor slots
    bases = cpt_bases(bn)
    tables = [np.zeros(1)] + [np.log(cpt.reshape(-1)) for cpt in bn.cpts]
    log_flat = jnp.asarray(np.concatenate(tables), jnp.float32)

    groups: list[ColorGroup] = []
    for group_nodes in coloring_mod.color_groups(colors):
        free = [v for v in group_nodes if v not in evidence]
        if not free:
            continue
        groups.append(build_color_group(bn, free, bases))

    rng = np.random.default_rng(seed)
    init = rng.integers(0, np.asarray(bn.cards), size=n)
    free_mask = np.ones(n, bool)
    for v, x in evidence.items():
        init[v] = x
        free_mask[v] = False

    # integer-weight exp table (paper Sec. III-D: 16 entries, 8-bit values)
    exp_table, exp_spec = build_exp_weight_lut(
        bits=lut_bits, x_min=-lut_range, size=lut_size
    )
    return CompiledBayesNet(
        log_flat=log_flat,
        groups=groups,
        cards=jnp.asarray(np.asarray(bn.cards), jnp.int32),
        init_vals=jnp.asarray(init, jnp.int32),
        free_mask=jnp.asarray(free_mask),
        max_card=int(np.max(bn.cards)),
        n_nodes=n,
        colors=tuple(int(c) for c in colors),
        exp_table=exp_table,
        exp_spec=exp_spec,
        name=bn.name,
    )


@dataclasses.dataclass
class BNChainState:
    """Everything a BN Gibbs run needs to resume exactly where it stopped.

    Carrying (vals, key, hist, t) across `gibbs_run_loop` calls makes a
    sliced run bit-identical to an uninterrupted one: the key is split once
    per sweep in sequence, the marginal histogram keeps accumulating, and
    `t` (global sweeps completed) keeps the burn-in/thinning gate aligned
    with where the chain actually is, not where the current slice started.

    `quality` is the optional streaming quality accumulator
    (`repro.diag.accum.QualityAccum`) — None (the default, an empty
    pytree subtree) when diagnostics are off, so every existing jit cache
    and carry pattern is bit-compatible.  When present it rides the carry
    exactly like the histogram, which is what makes R-hat/ESS bit-exact
    across sliced runs."""

    vals: jax.Array  # (B, n) int32 current chain states
    key: jax.Array  # PRNG key as of the next sweep
    hist: jax.Array  # (n, V) int32 marginal histogram so far
    t: jax.Array  # () int32 sweeps completed
    quality: object = None  # diag.accum.QualityAccum | None


jax.tree_util.register_dataclass(
    ColorGroup, ["nodes", "cards", "base", "stride", "scope_var", "is_self"], []
)
jax.tree_util.register_dataclass(
    CompiledBayesNet,
    ["log_flat", "groups", "cards", "init_vals", "free_mask", "exp_table"],
    ["max_card", "n_nodes", "colors", "exp_spec", "name"],
)
jax.tree_util.register_dataclass(
    BNChainState, ["vals", "key", "hist", "t", "quality"], []
)


def group_log_conditionals(
    cbn: CompiledBayesNet, g: ColorGroup, vals: jax.Array
) -> jax.Array:
    """log P(X_i = v | MB(X_i)) up to a constant, for all chains and all
    nodes of one color at once.  vals: (B, n) -> (B, n_c, V)."""
    v_range = jnp.arange(cbn.max_card, dtype=jnp.int32)
    sv = vals[:, g.scope_var]  # (B, n_c, F, S)
    val_or_v = jnp.where(
        g.is_self[None, ..., None], v_range, sv[..., None]
    )  # (B, n_c, F, S, V)
    addr = g.base[None, :, :, None] + jnp.sum(
        g.stride[None, ..., None] * val_or_v, axis=-2
    )  # (B, n_c, F, V)
    logp = jnp.sum(cbn.log_flat[addr], axis=-2)  # (B, n_c, V)
    return jnp.where(v_range < g.cards[None, :, None], logp, NEG_INF)




def update_color_group(
    cbn: CompiledBayesNet,
    g: ColorGroup,
    vals: jax.Array,
    key: jax.Array,
    sampler: str = "lut_ky",
) -> jax.Array:
    logp = group_log_conditionals(cbn, g, vals)
    labels = draw_from_logits(logp, key, sampler, cbn.exp_table, cbn.exp_spec)
    return vals.at[:, g.nodes].set(labels)


def gibbs_sweep(
    cbn: CompiledBayesNet,
    vals: jax.Array,
    key: jax.Array,
    sampler: str,
    groups: list[ColorGroup] | None = None,
) -> jax.Array:
    """One iteration of Alg. 2: loop over rounds, parallel within a round.
    `groups` defaults to the eager color groups; the schedule backend passes
    its round-ordered groups (same key-split structure either way)."""
    groups = cbn.groups if groups is None else groups
    keys = jax.random.split(key, len(groups))
    for g, k in zip(groups, keys):
        vals = update_color_group(cbn, g, vals, k, sampler)
    return vals


def init_chain_values(
    cbn: CompiledBayesNet,
    key: jax.Array,
    n_chains: int,
    clamp_vals: jax.Array | None = None,
    clamp_mask: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-chain random initialization of the free RVs (evidence stays
    clamped).  Draws are uniform in [0, card_i) via `jax.random.randint`
    with the per-node maxval broadcast — NOT `randint(...) % card`, whose
    modulo fold is biased for cards that do not divide the draw range.

    `clamp_vals`/`clamp_mask` ((n,) int32 / (n,) bool) add *runtime*
    evidence on top of whatever the compile baked in: masked nodes start at
    their clamped value instead of a random draw.  The random tensor is
    drawn for every node either way, so a runtime-clamped init is bit-exact
    with a compile that baked the same evidence.  Returns (vals (B, n),
    advanced key)."""
    k0, key = jax.random.split(key)
    rnd = jax.random.randint(
        k0, (n_chains, cbn.n_nodes), 0,
        jnp.maximum(cbn.cards[None], 1), jnp.int32,
    )
    fixed = cbn.init_vals
    free = cbn.free_mask
    if clamp_mask is not None:
        fixed = jnp.where(clamp_mask, clamp_vals, fixed)
        free = free & ~clamp_mask
    vals = jnp.where(free[None], rnd, fixed[None])
    return vals, key


def gibbs_run_loop(
    cbn: CompiledBayesNet,
    groups: list[ColorGroup],
    vals: jax.Array | None,
    key: jax.Array | None,
    n_iters: int,
    burn_in: int,
    sampler: str,
    thin: int = 1,
    carry: BNChainState | None = None,
    return_state: bool = False,
    fused: bool = False,
    interpret: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
):
    """The iteration loop shared by the eager engine (`groups=cbn.groups`)
    and the schedule-direct backend (`groups` built from `Schedule.rounds`):
    identical tensors + identical key-split structure => identical bits.

    `fused=True` executes every sweep through the Pallas kernel in
    `kernels/bn_gibbs.py` — one `pallas_call` per sweep, chain values
    VMEM-resident across all rounds — bit-exact with the unfused sweep for
    the samplers the kernel implements (anything else raises here, at
    trace time, rather than silently falling back).  The key-split
    structure, histogram accumulation, and carry-state semantics are shared
    with the unfused path, so slicing and runtime clamps work unchanged:
    clamped nodes are simply absent from `groups` (the same rebuild baked
    evidence gets), mirroring how the fused MRF path restores pins.

    `thin` keeps every thin-th post-burn-in sweep in the marginal histogram
    (streaming accumulation — no sample matrix is ever materialized); the
    chain itself always advances every sweep, so thin=1 reproduces today's
    bits exactly and any thin leaves the final state unchanged.

    `carry` resumes a previous call's `BNChainState` (then `vals`/`key` are
    ignored and may be None) and `n_iters` counts *additional* sweeps; the
    burn-in/thinning gate tests the carried global sweep count, so a run
    sliced at any boundaries — with the same static burn_in/thin/groups per
    slice — is bit-exact with the uninterrupted run.  `return_state=True`
    appends the state needed to continue.

    `diag_total` (the query's *total* sweep budget — under slicing that is
    more than this call's `n_iters`) switches the streaming quality
    accumulator on for a fresh run: a `diag.accum.QualityAccum` joins the
    carry and ingests the same one-hot tensor the histogram does, masked
    by the same keep gate — pure jax, no extra randomness, so the draw
    stream is untouched.  On a resumed carry the accumulator (or its
    absence) rides in with the state and `diag_total` is ignored — the
    split point was fixed at creation, which is what makes sliced and
    unsliced accumulation bit-identical."""
    if fused:
        # lazy import: kernels/bn_gibbs imports this module for NEG_INF
        from repro.kernels import bn_gibbs

        bn_gibbs.check_fused_sampler(sampler)
        fr = bn_gibbs.build_fused_rounds(groups)
        sweep = lambda v, k: bn_gibbs.fused_gibbs_sweep(
            cbn, fr, v, k, sampler, interpret=interpret
        )
    else:
        sweep = lambda v, k: gibbs_sweep(cbn, v, k, sampler, groups)

    if carry is None:
        quality = None
        if diag_total is not None:
            quality = diag_accum.make_accum(
                vals.shape[0], cbn.n_nodes, cbn.max_card,
                diag_accum.kept_count(diag_total, burn_in, thin), diag_batch,
            )
        carry = BNChainState(
            vals=vals,
            key=key,
            hist=jnp.zeros((cbn.n_nodes, cbn.max_card), jnp.int32),
            t=jnp.zeros((), jnp.int32),
            quality=quality,
        )

    def body(_, st):
        key, sub = jax.random.split(st.key)
        vals = sweep(st.vals, sub)
        onehot = (
            vals[..., None] == jnp.arange(cbn.max_card, dtype=jnp.int32)
        ).astype(jnp.int32)
        keep = (st.t >= burn_in) & ((st.t - burn_in) % thin == 0)
        hist = st.hist + jnp.where(keep, onehot.sum(0), 0)
        quality = st.quality
        if quality is not None:
            quality = diag_accum.update(quality, onehot, keep)
        return BNChainState(
            vals=vals, key=key, hist=hist, t=st.t + 1, quality=quality
        )

    carry = jax.lax.fori_loop(0, n_iters, body, carry)
    card_mask = (
        jnp.arange(cbn.max_card, dtype=jnp.int32)[None] < cbn.cards[:, None]
    )
    denom = jnp.maximum(carry.hist.sum(-1, keepdims=True), 1)
    marginals = jnp.where(card_mask, carry.hist / denom, 0.0)
    if return_state:
        return marginals, carry.vals, carry
    return marginals, carry.vals


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_chains", "n_iters", "burn_in", "sampler", "thin", "return_state",
    ),
    # sliced serving resumes a chain it will never touch again: donating the
    # carried state lets XLA update it in place instead of copying (B, n)
    # vals + histogram every slice.  Callers must treat a passed carry as
    # consumed (tests/test_bn_fused.py has the donation smoke test).
    donate_argnames=("carry",),
)
def run_gibbs(
    cbn: CompiledBayesNet,
    key: jax.Array | None,
    n_chains: int = 32,
    n_iters: int = 200,
    burn_in: int = 50,
    sampler: str = "lut_ky",
    thin: int = 1,
    carry: BNChainState | None = None,
    return_state: bool = False,
    diag_total=None,
    diag_batch: int = diag_accum.DEFAULT_BATCH_LEN,
):
    """Multi-chain chromatic Gibbs; returns (marginals (n, V), final vals).

    Chains are the data-parallel axis (AIA's MaxChain loop, Alg. 1 line 1);
    the single-marginal histogram accumulates over all chains and kept
    iterations, giving every node's marginal at no extra cost (the paper's
    "compute all single marginals without overhead" observation).

    `carry`/`return_state` slice the run: see `gibbs_run_loop`
    (`diag_total`/`diag_batch` switch its quality accumulator on)."""
    vals = None
    if carry is None:
        vals, key = init_chain_values(cbn, key, n_chains)
    return gibbs_run_loop(
        cbn, cbn.groups, vals, key, n_iters, burn_in, sampler, thin,
        carry=carry, return_state=return_state,
        diag_total=diag_total, diag_batch=diag_batch,
    )
