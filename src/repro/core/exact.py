"""Exact inference by variable elimination — the test oracle and the
"exact inference" baseline column of Table IV (Dice's role in the paper).

Factors are dense numpy arrays over sorted variable scopes; elimination order
is min-fill.  Tractable for the small/medium replicas (treewidth-bounded);
the large ones (pigs, hepar2) are exactly the regime where the paper argues
sampling wins — our Table IV reproduction reports VE runtime or timeout there.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.graphs import DiscreteBayesNet


@dataclasses.dataclass
class Factor:
    scope: tuple[int, ...]  # sorted variable ids
    table: np.ndarray  # shape = cards[scope]

    def __post_init__(self):
        assert tuple(sorted(self.scope)) == tuple(self.scope)


def _product(a: Factor, b: Factor, cards: np.ndarray) -> Factor:
    scope = tuple(sorted(set(a.scope) | set(b.scope)))

    def expand(f: Factor) -> np.ndarray:
        shape = [cards[v] if v in f.scope else 1 for v in scope]
        perm = [f.scope.index(v) for v in scope if v in f.scope]
        return f.table.transpose(perm).reshape(shape)

    return Factor(scope, expand(a) * expand(b))


def _marginalize(f: Factor, var: int) -> Factor:
    ax = f.scope.index(var)
    return Factor(tuple(v for v in f.scope if v != var), f.table.sum(axis=ax))


def _reduce_evidence(f: Factor, evidence: dict[int, int]) -> Factor:
    idx: list = []
    scope: list[int] = []
    for v in f.scope:
        if v in evidence:
            idx.append(evidence[v])
        else:
            idx.append(slice(None))
            scope.append(v)
    return Factor(tuple(scope), f.table[tuple(idx)])


def _min_fill_order(scopes: list[set[int]], elim: set[int]) -> list[int]:
    all_vars = set().union(*scopes) if scopes else set()
    adj: dict[int, set[int]] = {v: set() for v in all_vars | elim}
    for s in scopes:
        for a, b in itertools.combinations(sorted(s), 2):
            adj[a].add(b)
            adj[b].add(a)
    order: list[int] = []
    remaining = set(elim)
    alive = set(adj)
    while remaining:
        best, best_fill = None, None
        for v in sorted(remaining):
            nbrs = adj[v] & alive - {v}
            fill = sum(
                1
                for a, b in itertools.combinations(sorted(nbrs), 2)
                if b not in adj[a]
            )
            if best_fill is None or fill < best_fill:
                best, best_fill = v, fill
        nbrs = adj[best] & alive - {best}
        for a, b in itertools.combinations(sorted(nbrs), 2):
            adj[a].add(b)
            adj[b].add(a)
        order.append(best)
        remaining.remove(best)
        alive.remove(best)
    return order


def ve_marginal(
    bn: DiscreteBayesNet, query: int, evidence: dict[int, int] | None = None
) -> np.ndarray:
    """P(X_query | evidence) by variable elimination."""
    evidence = dict(evidence or {})
    assert query not in evidence
    factors = []
    for i, (ps, cpt) in enumerate(zip(bn.parents, bn.cpts)):
        scope = tuple(ps) + (i,)
        order = tuple(np.argsort(scope))
        f = Factor(tuple(sorted(scope)), np.ascontiguousarray(cpt.transpose(order)))
        factors.append(_reduce_evidence(f, evidence))

    elim = set(range(bn.n_nodes)) - {query} - set(evidence)
    scopes = [set(f.scope) for f in factors]
    for v in _min_fill_order(scopes, elim):
        touching = [f for f in factors if v in f.scope]
        rest = [f for f in factors if v not in f.scope]
        prod = touching[0]
        for f in touching[1:]:
            prod = _product(prod, f, bn.cards)
        factors = rest + [_marginalize(prod, v)]

    result = factors[0]
    for f in factors[1:]:
        result = _product(result, f, bn.cards)
    assert result.scope == (query,), result.scope
    t = result.table.astype(np.float64)
    return t / t.sum()


def all_marginals(
    bn: DiscreteBayesNet, evidence: dict[int, int] | None = None
) -> list[np.ndarray]:
    return [
        ve_marginal(bn, q, evidence)
        if q not in (evidence or {})
        else np.eye(bn.cards[q])[(evidence or {})[q]]
        for q in range(bn.n_nodes)
    ]


def brute_force_marginal(
    bn: DiscreteBayesNet, query: int, evidence: dict[int, int] | None = None
) -> np.ndarray:
    """O(prod cards) enumeration — oracle for the oracle (tiny nets only)."""
    evidence = dict(evidence or {})
    out = np.zeros(bn.cards[query], np.float64)
    ranges = [range(c) for c in bn.cards]
    for assign in itertools.product(*ranges):
        if any(assign[v] != x for v, x in evidence.items()):
            continue
        p = np.exp(bn.joint_logp(np.asarray(assign)))
        out[assign[query]] += p
    return out / out.sum()
