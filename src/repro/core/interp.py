"""LUT-based linear interpolation of nonlinear functions (paper C2, Sec. III-D).

AIA evaluates exp/log/... in one cycle from a 16-entry, 8-bit lookup table
held in the private RF (the CoopMC-validated accuracy/efficiency point).  Here
the same unit becomes (i) a pure-jnp reference (`interp_ref`) and (ii) a
Pallas kernel (kernels/interp_lut.py) whose table lives in VMEM and whose
gather is unrolled into `size` lane-selects — the TPU-idiomatic fused lookup.

Tables are described by `LUTSpec`: uniform grid y = f(x0 + i*dx), inputs are
clamped to the table range (saturating ends, as in the hardware unit).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# Paper defaults (Sec. III-D "Accuracy Impact"): 16 entries, 8-bit values.
DEFAULT_SIZE = 16
DEFAULT_BITS = 8


@dataclasses.dataclass(frozen=True)
class LUTSpec:
    x0: float
    dx: float
    size: int

    @property
    def x1(self) -> float:
        return self.x0 + self.dx * (self.size - 1)


def build_lut(
    fn: Callable[[np.ndarray], np.ndarray],
    x0: float,
    x1: float,
    size: int = DEFAULT_SIZE,
    dtype=jnp.float32,
) -> tuple[jax.Array, LUTSpec]:
    spec = LUTSpec(x0=float(x0), dx=float(x1 - x0) / (size - 1), size=size)
    xs = np.asarray(x0 + spec.dx * np.arange(size), np.float64)
    return jnp.asarray(fn(xs), dtype), spec


def build_exp_weight_lut(
    bits: int = DEFAULT_BITS, x_min: float = -8.0, size: int = DEFAULT_SIZE
):
    """exp() table emitting integer sampling weights in [0, 2^bits - 1].

    Inputs are max-subtracted log-potentials (<= 0).  exp(x_min) ~ 3e-4 maps
    to weight 0 — bins that improbable are dropped, matching the paper's 8-bit
    quantization with "negligible accuracy loss"."""
    top = float((1 << bits) - 1)
    return build_lut(lambda x: np.rint(np.exp(x) * top), x_min, 0.0, size)


def build_log_lut(size: int = DEFAULT_SIZE, x0: float = 1.0, x1: float = 2.0):
    """log() over one octave; range-reduced callers handle the exponent."""
    return build_lut(np.log, x0, x1, size)


def interp_ref(x: jax.Array, table: jax.Array, spec: LUTSpec) -> jax.Array:
    """Pure-jnp oracle: y = Y[i] + frac * (Y[i+1] - Y[i])   (paper Sec. III-D)."""
    u = jnp.clip((x - spec.x0) / spec.dx, 0.0, spec.size - 1)
    idx = jnp.clip(jnp.floor(u), 0, spec.size - 2).astype(jnp.int32)
    frac = u - idx.astype(u.dtype)
    y0 = jnp.take(table, idx)
    y1 = jnp.take(table, idx + 1)
    return y0 + frac * (y1 - y0)
