"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Routing is the static-shape, SPMD-friendly formulation:

  1. router logits -> softmax -> top-k (probs renormalized over the chosen k);
  2. the (tokens x k) assignments are sorted by expert id and packed into an
     (E, C, d) buffer with capacity C = ceil(T*k/E * capacity_factor)
     (overflow tokens are dropped — Switch-style — and contribute their
     residual stream unchanged);
  3. per-expert SwiGLU as one (E, C, d) x (E, d, f) grouped einsum — the
     expert dimension shards over the "model" axis (expert parallelism) when
     E divides the axis, otherwise the f dimension shards (tensor
     parallelism); decided by the sharding rules, not here;
  4. results scatter back and combine with the routing weights;
  5. optional shared experts run densely over all tokens (qwen2-moe/llama4).

Also returns the switch load-balancing auxiliary loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import Params, init_dense

# Mesh context for in-layer sharding constraints (set by launch.steps when
# building distributed step functions; None on single-device paths).
# Without the constraints GSPMD contracts the FSDP-sharded weight dim and
# replicates the (b, e, cap, f) expert activations over "data" — a measured
# 30 GiB all-reduce per MoE layer at jamba scale (EXPERIMENTS.md §Perf it.3).
_MESH_CTX: dict = {"dp": None, "tp": None, "tp_size": 1}


def set_moe_mesh(dp_axes, tp_axis, tp_size: int) -> None:
    _MESH_CTX.update(dp=dp_axes, tp=tp_axis, tp_size=int(tp_size))


def clear_moe_mesh() -> None:
    _MESH_CTX.update(dp=None, tp=None, tp_size=1)


def _wsc(x, *axes):
    if _MESH_CTX["dp"] is None:
        return x
    spec = P(*axes, *([None] * (x.ndim - len(axes))))
    return jax.lax.with_sharding_constraint(x, spec)


def _dp():
    dp = _MESH_CTX["dp"]
    return dp if dp is None or len(dp) > 1 else dp[0]


def _tp_div(dim: int):
    tp = _MESH_CTX["tp"]
    return tp if tp and dim % _MESH_CTX["tp_size"] == 0 else None


def init_moe(key, cfg: ModelConfig, moe: MoEConfig) -> Params:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    e, f = moe.n_experts, moe.d_expert

    def expert_stack(k, in_dim, shape):
        kk = jax.random.split(k, e)
        return jnp.stack([init_dense(kk[i], in_dim, shape, dt) for i in range(e)])

    p = {
        "router": init_dense(ks[0], d, (e,), dt),
        "wg": expert_stack(ks[1], d, (f,)),
        "wu": expert_stack(ks[2], d, (f,)),
        "wd": expert_stack(ks[3], f, (d,)),
    }
    if moe.d_shared:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(ks[4], cfg, d_ff=moe.d_shared)
    return p


def moe_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, moe: MoEConfig
) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is PER BATCH ROW (Mesh-TF "group" = row): every sort/scatter/
    gather carries the leading B axis, so the data-parallel sharding of B
    survives routing and no global token all-gather is ever materialized.
    (The earlier global-token argsort forced GSPMD to replicate the full
    (B*S, d) activation on every device — 10-17 GiB/layer at llama4/jamba
    scale, measured in the dry-run; see EXPERIMENTS.md §Perf iteration 1.)
    """
    dt = cfg.act_dtype
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k

    if s == 1 and b > 1:
        # Decode: per-row dispatch would compute E*cap slots per single
        # token (measured 60x useless FLOPs in the dry-run); route the whole
        # batch as ONE group instead — the (b, d) activation is tiny, so the
        # global sort costs nothing.  In-layer dp constraints are disabled
        # (the group axis has size 1).
        saved = dict(_MESH_CTX)
        _MESH_CTX.update(dp=None)
        try:
            y, aux = moe_apply(p, x.transpose(1, 0, 2), cfg, moe)
        finally:
            _MESH_CTX.update(saved)
        return y.transpose(1, 0, 2), aux

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # (b, s, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # switch aux loss: fraction of tokens per expert x mean router prob
    density = jnp.mean(
        (top_i[..., None] == jnp.arange(e)).any(axis=2).astype(jnp.float32),
        axis=(0, 1),
    )
    aux = moe.router_aux_weight * e * jnp.sum(density * probs.mean((0, 1)))

    # ---- per-row sort-based dispatch --------------------------------------
    n_assign = s * k
    cap = int(-(-s * k // e) * moe.capacity_factor)
    cap = max(4, -(-cap // 4) * 4)
    flat_e = top_i.reshape(b, n_assign)
    flat_w = top_p.reshape(b, n_assign).astype(dt)
    flat_tok = jnp.tile(jnp.repeat(jnp.arange(s), k)[None], (b, 1))

    order = jnp.argsort(flat_e, axis=-1)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    stok = jnp.take_along_axis(flat_tok, order, axis=-1)
    # position within my expert's run: searchsorted per row (vmapped)
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left")
    )(se)
    pos = jnp.arange(n_assign)[None] - first
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)  # OOB -> dropped

    # Layout dance (see EXPERIMENTS.md §Perf): scatter/gather run with d
    # TP-sharded (fully local); d is all-gathered only at the expert matmul
    # (2.7 GiB bf16 transient at jamba scale); the expert hidden dim f is
    # the TP dim of the expert weights (dense-FFN-style), and out_e is
    # constrained back to d@tp so XLA emits a reduce-scatter, not a 30 GiB
    # replicated all-reduce.
    dp, tp_d = _dp(), _tp_div(d)
    f_dim = moe.d_expert
    xt = jnp.take_along_axis(
        x, stok[..., None], axis=1
    )  # (b, n_assign, d) routed-token activations
    xt = _wsc(xt, dp, None, tp_d)
    buf = jnp.zeros((b, e * cap, d), dt)
    buf = jax.vmap(
        lambda bb, sl, xx: bb.at[sl].set(xx, mode="drop")
    )(buf, slot, xt)
    buf = _wsc(buf, dp, None, tp_d)
    h = _wsc(buf.reshape(b, e, cap, d), dp, None, None, None)  # d gathered
    gate = jax.nn.silu(
        jnp.einsum("becd,edf->becf", h, p["wg"].astype(dt))
    )
    up = jnp.einsum("becd,edf->becf", h, p["wu"].astype(dt))
    gate = _wsc(gate, dp, None, None, _tp_div(f_dim))
    up = _wsc(up, dp, None, None, _tp_div(f_dim))
    out_e = jnp.einsum("becf,efd->becd", gate * up, p["wd"].astype(dt))
    out_e = _wsc(out_e, dp, None, None, tp_d)  # reduce-scatter on d

    flat_out = out_e.reshape(b, e * cap, d)
    gathered = jnp.take_along_axis(
        flat_out, jnp.minimum(slot, e * cap - 1)[..., None], axis=1
    )
    gathered = gathered * (keep & (sw > 0))[..., None].astype(dt) \
        * sw[..., None]
    y = jnp.zeros((b, s, d), dt)
    y = jax.vmap(lambda yy, tk, gg: yy.at[tk].add(gg))(y, stok, gathered)
    y = _wsc(y, dp, None, tp_d)

    if "shared" in p:
        from repro.models.layers import mlp_apply

        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux
