"""Token sampling — the paper's technique as a first-class serving feature.

`ky` mode is the AIA pipeline C2->C1 applied to LM logits:

    logits -> max-subtract -> LUT-exp (16-entry, 8-bit integer weights)
           -> hierarchical rejection-KY draw (128-ary tree over the vocab)

No softmax and no normalization anywhere: the draw is exact for the
quantized weights, costs O(H) random bits per token (entropy-adaptive, the
paper's Fig. 11 claim), and the integer group-sums are exact so the
hierarchical decomposition P(group)·P(token|group) introduces zero bias.
Large vocabularies (up to 202k here) exceed the paper's 32-bin sampler; the
128-ary hierarchy is the TPU-lane-width generalization of the paper's
"sample from 2/4/8/16 distributions in parallel" packing trick.

`gumbel` (one argmax over logits+noise) is the beyond-paper TPU-native
baseline benchmarked against it; `greedy` for determinism.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ky as ky_core
from repro.core.interp import LUTSpec, build_exp_weight_lut, interp_ref

BRANCH = 128  # tree arity == TPU lane width


def ky_token_sample(
    logits: jax.Array,
    key: jax.Array,
    *,
    exp_table: jax.Array | None = None,
    exp_spec: LUTSpec | None = None,
    max_retries: int = 8,
) -> jax.Array:
    """logits (B, V) -> sampled token ids (B,) int32."""
    if exp_table is None:
        exp_table, exp_spec = build_exp_weight_lut()
    b, v = logits.shape
    z = logits.astype(jnp.float32)
    z = z - jax.lax.stop_gradient(z.max(axis=-1, keepdims=True))
    w = jnp.maximum(jnp.round(interp_ref(z, exp_table, exp_spec)), 0.0)
    w = w.astype(jnp.int32)

    # build the integer-sum pyramid (leaf -> root), exact in int32
    pad = (-v) % BRANCH
    levels = [jnp.pad(w, ((0, 0), (0, pad)))]
    while levels[-1].shape[-1] > BRANCH:
        cur = levels[-1]
        grp = cur.reshape(b, -1, BRANCH).sum(-1)
        gpad = (-grp.shape[-1]) % BRANCH
        levels.append(jnp.pad(grp, ((0, 0), (0, gpad))))

    # draw root -> leaf; each level is one <=128-bin rejection-KY walk
    n_levels = len(levels)
    keys = jax.random.split(key, n_levels)
    # root: whole top level is one distribution
    top = levels[-1]
    precision = min(30, 8 + 7 * n_levels + 2)
    idx = _ky_draw(top, keys[-1], precision, max_retries)
    for li in range(n_levels - 2, -1, -1):
        rows = levels[li].reshape(b, -1, BRANCH)
        row = jnp.take_along_axis(rows, idx[:, None, None], axis=1)[:, 0]
        sub = _ky_draw(row, keys[li], min(30, 8 + 7 * (li + 1) + 2),
                       max_retries)
        idx = idx * BRANCH + sub
    return jnp.minimum(idx, v - 1)


def _ky_draw(weights: jax.Array, key, precision: int, max_retries: int):
    b, n = weights.shape
    n_words = -(-precision * max_retries // 32)
    words = ky_core.random_words(key, (b,), n_words)
    labels, _ = ky_core.ky_sample_ref(
        weights, words, n_bins=n, precision=precision,
        max_retries=max_retries,
    )
    return labels


def gumbel_token_sample(logits: jax.Array, key: jax.Array) -> jax.Array:
    g = jax.random.gumbel(key, logits.shape, jnp.float32)
    return jnp.argmax(logits.astype(jnp.float32) + g, axis=-1).astype(
        jnp.int32
    )


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_tokens(
    logits: jax.Array, key: jax.Array, method: str = "ky", **kw
) -> jax.Array:
    if method == "ky":
        return ky_token_sample(logits, key, **kw)
    if method == "gumbel":
        return gumbel_token_sample(logits, key)
    if method == "greedy":
        return greedy_token(logits)
    raise ValueError(method)
