"""Transformer substrate: RMSNorm, RoPE, flash attention (pure JAX, online
softmax over KV chunks), GQA attention blocks (train/prefill/decode), SwiGLU.

Memory posture: prefill at 32k would materialize (B, H, S, S) scores with a
naive einsum — 25+ GB/device for the large archs — so training/prefill always
runs the double-chunked flash path (scan over KV chunks, f32 running max/sum).
Decode (S_q = 1) uses the direct masked einsum over the cache.

Chunked-local attention (llama4 iRoPE style) is the same kernel with an
extra same-chunk mask term; global (non-chunked) layers can opt out of RoPE
(`rope_on_global=False`) per iRoPE.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict[str, Any]
NEG_INF = -1e30


def init_dense(key, in_dim: int, out_shape: tuple[int, ...], dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim,) + out_shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D), positions (..., S) -> rotated x (half-split layout)."""
    d_half = x.shape[-1] // 2
    freqs = theta ** (-jnp.arange(0, d_half, dtype=jnp.float32) / d_half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :d_half], x[..., d_half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# flash attention (pure JAX): scan over KV chunks with online softmax
# ---------------------------------------------------------------------------


def _pick_chunk(s: int, target: int) -> int:
    """Largest power-of-two divisor of s, capped at target."""
    c = 1
    while c < target and s % (2 * c) == 0:
        c *= 2
    return c if s % c == 0 else s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D), already scaled & roped
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,  # (B, Skv, KVH, D)
    q_offset: int = 0,  # absolute position of q[0]
    window: int = 0,  # >0: chunked-local attention (same-chunk mask)
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention with a hand-written VJP.

    The forward scans KV chunks carrying (m, l, acc); the custom backward
    recomputes probabilities per chunk from the saved log-sum-exp instead of
    letting autodiff checkpoint the per-chunk carries (which would cost
    O(S/kv_chunk) copies of the output — hundreds of GiB at 32k prefill;
    measured in the dry-run before this fix).  Residuals are O(B·S·H·D):
    q, k, v, out, lse — the flash-attention trade, TPU-adapted in pure JAX.
    """
    out, _ = _flash_fwd_impl(q, k, v, q_offset, window, q_chunk, kv_chunk)
    return out


def _flash_geom(q, k, q_chunk, kv_chunk):
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qc = _pick_chunk(sq, min(q_chunk, sq))
    kc = _pick_chunk(skv, min(kv_chunk, skv))
    return b, sq, h, d, skv, kvh, g, qc, kc


def _mask_for(q_pos, k_pos, window):
    """q_pos (nq, qc), k_pos (kc,) -> (nq, qc, kc) bool."""
    mask = q_pos[:, :, None] >= k_pos[None, None, :]
    if window:
        mask &= (q_pos[:, :, None] // window) == (k_pos[None, None, :]
                                                  // window)
    return mask


def _flash_fwd_impl(q, k, v, q_offset, window, q_chunk, kv_chunk):
    b, sq, h, d, skv, kvh, g, qc, kc = _flash_geom(q, k, q_chunk, kv_chunk)
    nq, nk = sq // qc, skv // kc
    qr = q.reshape(b, nq, qc, kvh, g, d)
    kr = k.reshape(b, nk, kc, kvh, d)
    vr = v.reshape(b, nk, kc, kvh, d)
    q_pos = q_offset + jnp.arange(sq).reshape(nq, qc)

    def step(carry, inputs):
        m, l, acc = carry
        k_c, v_c, kpos = inputs  # (b, kc, kvh, d), (kc,)
        s = jnp.einsum("bnqhgd,bkhd->bnqhgk", qr, k_c,
                       preferred_element_type=jnp.float32)
        mask = _mask_for(q_pos, kpos, window)
        s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bnqhgk,bkhd->bnqhgd", p.astype(v_c.dtype), v_c,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nq, qc, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, qc, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, nq, qc, kvh, g, d), jnp.float32)
    kv_pos = jnp.arange(skv).reshape(nk, kc)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kr.swapaxes(0, 1), vr.swapaxes(0, 1), kv_pos),
    )
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (b, nq, qc, kvh, g)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype), lse


def _flash_fwd(q, k, v, q_offset, window, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, q_offset, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(q_offset, window, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d, skv, kvh, g, qc, kc = _flash_geom(q, k, q_chunk, kv_chunk)
    nq, nk = sq // qc, skv // kc
    qr = q.reshape(b, nq, qc, kvh, g, d)
    kr = k.reshape(b, nk, kc, kvh, d).swapaxes(0, 1)
    vr = v.reshape(b, nk, kc, kvh, d).swapaxes(0, 1)
    dor = dout.reshape(b, nq, qc, kvh, g, d).astype(jnp.float32)
    our = out.reshape(b, nq, qc, kvh, g, d).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq).reshape(nq, qc)
    kv_pos = jnp.arange(skv).reshape(nk, kc)
    delta = jnp.sum(dor * our, axis=-1)  # (b, nq, qc, kvh, g)

    def step(dq, inputs):
        k_c, v_c, kpos = inputs
        s = jnp.einsum("bnqhgd,bkhd->bnqhgk", qr, k_c,
                       preferred_element_type=jnp.float32)
        mask = _mask_for(q_pos, kpos, window)
        s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # exact probabilities
        dp = jnp.einsum("bnqhgd,bkhd->bnqhgk", dor, v_c,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bnqhgk,bkhd->bnqhgd", ds, k_c,
                             preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bnqhgk,bnqhgd->bkhd", ds, qr,
                          preferred_element_type=jnp.float32)
        dv_c = jnp.einsum("bnqhgk,bnqhgd->bkhd", p, dor,
                          preferred_element_type=jnp.float32)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b, nq, qc, kvh, g, d), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(step, dq0, (kr, vr, kv_pos))
    dk = dk.swapaxes(0, 1).reshape(b, skv, kvh, d).astype(k.dtype)
    dv = dv.swapaxes(0, 1).reshape(b, skv, kvh, d).astype(v.dtype)
    dq = dq.reshape(b, sq, h, d).astype(q.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention_reference(q, k, v, *, q_offset=0, window=0):
    """Naive oracle for flash_attention (test use only)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(k.shape[1])
    mask = q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] // window) == (k_pos[None, :] // window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, sq, h, d)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def head_geometry(cfg: ModelConfig):
    """(hp, kvp, g_pad, q_head_mask) — padded-head layout for clean TP.

    With attn_pad_heads set, query heads are padded group-major (each KV
    group gains pad slots) so GQA group assignment is unchanged; pad heads
    are masked to zero after attention, keeping the math identical to the
    unpadded architecture while letting the head axis divide the mesh."""
    h, kvh, pad = cfg.n_heads, cfg.n_kv_heads, cfg.attn_pad_heads
    if not pad or pad == h:
        return h, kvh, h // kvh, None
    if kvh == h:  # MHA: pad q and kv together
        mask = (jnp.arange(pad) < h)
        return pad, pad, 1, mask
    assert pad % kvh == 0, "pad must preserve KV grouping"
    g, g_pad = h // kvh, pad // kvh
    mask = (jnp.arange(pad) % g_pad) < g
    return pad, kvh, g_pad, mask


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    hp, kvp, _, _ = head_geometry(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, (hp, hd), dt),
        "wk": init_dense(ks[1], d, (kvp, hd), dt),
        "wv": init_dense(ks[2], d, (kvp, hd), dt),
        "wo": init_dense(ks[3], hp * hd, (d,), dt).reshape(hp, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp, hd), dt)
        p["bk"] = jnp.zeros((kvp, hd), dt)
        p["bv"] = jnp.zeros((kvp, hd), dt)
    return p


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    dt = cfg.act_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def attention_apply(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    kind: str,
    positions: jax.Array,  # (S,)
    q_offset=0,
) -> tuple[jax.Array, Params]:
    """Training / prefill path.  Returns (out, cache) — cache holds the roped
    k and raw v for decode continuation."""
    dt = cfg.act_dtype
    q, k, v = _qkv(p, x, cfg)
    use_rope = kind == "attn_chunked" or cfg.rope_on_global
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = q * (1.0 / math.sqrt(cfg.hd))
    window = cfg.chunk_size if kind == "attn_chunked" else 0
    # GQA under TP: repeat K/V to full head count so the head axis shards
    # cleanly over "model" — the (kvh, g) factorized reshape defeats the
    # SPMD propagator and replicates the whole attention computation
    # (measured 16x FLOP inflation in the dry-run before this change).
    hp, kvp, g_pad, qmask = head_geometry(cfg)
    kr = jnp.repeat(k, g_pad, axis=2) if g_pad > 1 else k
    vr = jnp.repeat(v, g_pad, axis=2) if g_pad > 1 else v
    # positional: custom_vjp nondiff args cannot be keywords
    o = flash_attention(q, kr, vr, q_offset, window)
    if qmask is not None:
        o = o * qmask[None, None, :, None].astype(o.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, {"k": k, "v": v}


def attention_decode(
    p: Params,
    x: jax.Array,  # (B, 1, d)
    cache: Params,  # {"k","v": (B, S_max, KVH, D)}
    pos: jax.Array,  # scalar: absolute position of the new token
    cfg: ModelConfig,
    *,
    kind: str,
) -> tuple[jax.Array, Params]:
    dt = cfg.act_dtype
    q, k, v = _qkv(p, x, cfg)
    use_rope = kind == "attn_chunked" or cfg.rope_on_global
    pos_arr = jnp.full((1,), pos, jnp.int32)
    if use_rope:
        q = rope(q, pos_arr, cfg.rope_theta)
        k = rope(k, pos_arr, cfg.rope_theta)
    q = q * (1.0 / math.sqrt(cfg.hd))

    s_max = cache["k"].shape[1]
    slot = pos % s_max if kind == "attn_chunked" else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)

    hp, kvp, g_pad, qmask = head_geometry(cfg)
    b, _, h, d = q.shape
    kvh = ck.shape[2]
    g = h // kvh
    qr = q.reshape(b, kvh, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, ck,
                   preferred_element_type=jnp.float32)
    if kind == "attn_chunked":
        # ring cache of one window; valid entries share the query's chunk
        k_pos = pos - ((pos - jnp.arange(s_max)) % s_max)
        mask = (k_pos >= 0) & (k_pos // cfg.chunk_size == pos // cfg.chunk_size)
    else:
        mask = jnp.arange(s_max) <= pos
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1).astype(dt)
    o = jnp.einsum("bhgk,bkhd->bhgd", pattn, cv)
    if qmask is not None:
        o = o * qmask.reshape(kvh, g, 1).astype(o.dtype)[None]
    o = o.reshape(b, 1, h * d)
    out = jnp.einsum(
        "bsx,xd->bsd", o, p["wo"].astype(dt).reshape(h * d, cfg.d_model)
    )
    return out, {"k": ck, "v": cv}


def init_attn_cache(cfg: ModelConfig, batch: int, s_max: int, kind: str):
    if kind == "attn_chunked":
        s_max = min(s_max, cfg.chunk_size)
    kvp = head_geometry(cfg)[1]
    shape = (batch, s_max, kvp, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.act_dtype),
        "v": jnp.zeros(shape, cfg.act_dtype),
    }


def ring_from_prefill(kv: jax.Array, w: int, axis: int = 1) -> jax.Array:
    """Arrange the last min(S, w) prefilled K/V entries into the ring-cache
    slot order used by attention_decode (slot = pos % w)."""
    s = kv.shape[axis]
    if s <= w:
        pad = [(0, 0)] * kv.ndim
        pad[axis] = (0, w - s)
        return jnp.pad(kv, pad)
    tail = jax.lax.slice_in_dim(kv, s - w, s, axis=axis)
    return jnp.roll(tail, shift=(s - w) % w, axis=axis)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "wg": init_dense(ks[0], d, (ff,), dt),
        "wu": init_dense(ks[1], d, (ff,), dt),
        "wd": init_dense(ks[2], ff, (d,), dt),
    }


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = cfg.act_dtype
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt)))
    up = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", gate * up, p["wd"].astype(dt))
